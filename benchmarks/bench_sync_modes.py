"""The paper's distributed-training claim in collective-bytes form.

DA-MolDQN replaces DDP's per-step gradient all-reduce with a per-episode
parameter sync (§3.2).  This bench lowers both jit'd update paths of the
actual trainer and walks the partitioned HLO: collective bytes per EPISODE
under each mode (updates_per_episode x grad-allreduce vs 1 x param-sync).
Also times a real CPU episode under both modes.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, services
from repro.core import DQNConfig, EnvConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer
from repro.roofline.hlo_walk import aggregate


def _collective_bytes(jitted, *args) -> float:
    lowered = jitted.lower(*args)
    return aggregate(lowered.compile().as_text())["collective_bytes"]


def run(scale: str = "quick") -> None:
    service, train, _, rcfg, _ = services()
    updates = 4

    def build(sync):
        cfg = TrainerConfig(
            n_workers=2, mols_per_worker=2, episodes=2, sync_mode=sync,
            updates_per_episode=updates, train_batch_size=16,
            max_candidates=32, dqn=DQNConfig(epsilon_decay=0.9),
            env=EnvConfig(max_steps=4), seed=0)
        return DistributedTrainer(cfg, train[:4], service, rcfg,
                                  network=QNetwork(hidden=(512, 128, 32)))

    tr = build("step")
    for w, env in enumerate(tr.envs):
        env.run_episode(tr._views[w], service, rcfg, tr.buffers[w])
    batch = tr._stacked_sample()

    ddp_bytes = _collective_bytes(tr._ddp_update, tr.params, tr.target_params,
                                  tr.opt_state, batch)
    local_bytes = _collective_bytes(tr._local_update, tr.params, tr.target_params,
                                    tr.opt_state, batch)
    sync_bytes = _collective_bytes(tr._sync, tr.params)

    per_episode_ddp = updates * ddp_bytes
    per_episode_paper = updates * local_bytes + sync_bytes
    emit("sync.ddp_bytes_per_episode", int(per_episode_ddp), "B",
         f"{updates} grad all-reduces")
    emit("sync.episode_bytes_per_episode", int(per_episode_paper), "B",
         "local updates + ONE param pmean (the paper's §3.2 schedule)")
    if per_episode_paper > 0:
        emit("sync.traffic_ratio", round(per_episode_ddp / per_episode_paper, 2),
             "x", "collective-term reduction of episode-boundary sync")

    # wall-clock per episode, both modes (CPU, 1 device: measures overheads)
    for mode in ("step", "episode"):
        t = build(mode)
        t.train_episode()  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(2):
            t.train_episode()
        emit(f"sync.{mode}_episode_wall_s", round((time.perf_counter() - t0) / 2, 2), "s")
