"""Benchmark harness: one module per paper table/figure (see DESIGN.md §6).

Run everything:      PYTHONPATH=src python -m benchmarks.run
Run one:             PYTHONPATH=src python -m benchmarks.run --only env,fingerprint
Scale up:            PYTHONPATH=src python -m benchmarks.run --scale full

Each benchmark prints ``name,value,unit[,derived]`` CSV rows and the runner
writes the aggregate to experiments/bench/results.json.
"""
