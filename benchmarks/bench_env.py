"""§3.6 environment speedup, measured at two levels.

Micro (single molecule): the three enumeration tiers — naive Python port,
the materialise-then-filter reference, and the production delta enumerator
(edit descriptors, array filters, lazy Molecule materialisation) — plus the
batched-fingerprint cost per candidate.

Engine (the per-step hot path, W ∈ {64, 256, 512} workers): rolls seeded
episodes through ``RolloutEngine`` under both candidate-chemistry paths and
reports, per worker count

* chem ms/step (enumeration + fingerprints, the engine's own counters),
* candidate-fingerprint ms/step — the §3.6 metric: ``chem="incremental"``
  (shared-parent incremental pass + fleet-wide ChemCache) vs the
  ``chem="full"`` per-step recompute,
* ChemCache hit rate.

The policy is a fixed random linear Q head with per-worker ε-greedy streams
(ε = 0.1, the post-decay exploit regime where MolDQN actually spends its
250-episode runs); one warmup episode populates the cache, mirroring
bench_rollout's warmup-then-measure protocol.  Both chem paths see identical
seeded trajectories, so the comparison is work-per-step, not workload.

``python benchmarks/bench_env.py --smoke`` is the CI gate: steps the full
and incremental engines in LOCKSTEP and fails if any candidate fingerprint
row (dense or packed) differs, or if the warm cache stops hitting.
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/bench_env.py --smoke`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import emit
from repro.chem.actions import (
    enumerate_actions, enumerate_actions_naive, enumerate_actions_ref)
from repro.chem.fingerprint import batch_morgan_fingerprints
from repro.chem.smiles import from_smiles
from repro.core import EnvConfig, RewardConfig, RolloutEngine
from repro.core.rollout import CHEM_MODES, STATE_DIM

MOLS = ["CC1=CC(C)=CC(C)=C1O", "C1=CC=CC=C1O", "CC1=C(N)C(C)=C(N)C(C)=C1O",
        "OC1=CC=C(C=C1)C(C)(C)C"]

# (W, warmup episodes, measured episodes, max env steps)
ENGINE_PLANS = ((64, 1, 3, 6), (256, 1, 2, 4), (512, 1, 2, 3))
EPSILON = 0.1


# the shared deterministic property stand-in (no jax compute, no predictor
# training — keeps the engine bench focused on host chemistry)
from repro.predictors.service import OracleService as _OracleSvc


class _LinearQPolicy:
    """Fixed random linear Q head + per-worker ε-greedy RNG streams.

    Deterministic per state (like a trained, synced network), so repeated
    episodes revisit the same trajectories up to ε-deviations — the access
    pattern the ChemCache is built for.  Two engines driven by identically
    seeded instances take identical actions.
    """

    def __init__(self, n_workers: int, eps: float = EPSILON, seed: int = 0):
        self.eps = eps
        self.w = np.random.default_rng(seed).standard_normal(STATE_DIM) \
            .astype(np.float32)
        self.rngs = [np.random.default_rng(seed + 101 * w)
                     for w in range(n_workers)]

    def fleet_q_values(self, per_worker):
        return [x @ self.w for x in per_worker]

    def select_action(self, q: np.ndarray, worker: int) -> int:
        rng = self.rngs[worker]
        if rng.random() < self.eps:
            return int(rng.integers(0, q.shape[0]))
        return int(np.argmax(q))


def _engine(W: int, chem: str, max_steps: int) -> RolloutEngine:
    from repro.data.datasets import antioxidant_dataset
    mols = antioxidant_dataset(W)
    return RolloutEngine([[m] for m in mols], EnvConfig(max_steps=max_steps),
                         chem=chem)


def _roll(W: int, chem: str, warmup: int, episodes: int, max_steps: int) -> dict:
    engine = _engine(W, chem, max_steps)
    svc, rcfg = _OracleSvc(), RewardConfig()
    policy = _LinearQPolicy(W)
    for _ in range(warmup):
        engine.run_episode(policy, svc, rcfg)
    engine.reset_chem_stats()
    steps0 = engine.n_env_steps
    t0 = time.perf_counter()
    for _ in range(episodes):
        engine.run_episode(policy, svc, rcfg)
    wall = time.perf_counter() - t0
    st = engine.chem_stats()
    n_steps = engine.n_env_steps - steps0
    return {
        "chem_ms_per_step": (st["enum_s"] + st["fp_s"]) / n_steps * 1e3,
        "enum_ms_per_step": st["enum_s"] / n_steps * 1e3,
        "fp_ms_per_step": st["fp_s"] / n_steps * 1e3,
        "wall_ms_per_step": wall / n_steps * 1e3,
        "hit_rate": st.get("hit_rate", 0.0),
    }


def run(scale: str = "quick") -> None:
    reps = 30 if scale == "quick" else 100
    mols = [from_smiles(s) for s in MOLS]

    # ---- micro: the three enumeration tiers -------------------------- #
    t0 = time.perf_counter()
    for _ in range(reps):
        for m in mols:
            enumerate_actions(m)
    delta = (time.perf_counter() - t0) / (reps * len(mols))

    t0 = time.perf_counter()
    for _ in range(reps):
        for m in mols:
            enumerate_actions_ref(m)
    ref = (time.perf_counter() - t0) / (reps * len(mols))

    t0 = time.perf_counter()
    for _ in range(max(reps // 3, 5)):
        for m in mols:
            enumerate_actions_naive(m)
    slow = (time.perf_counter() - t0) / (max(reps // 3, 5) * len(mols))

    emit("env.enumerate_delta", round(delta * 1e6), "us_per_call",
         "edit descriptors + lazy materialisation (production)")
    emit("env.enumerate_vectorised", round(ref * 1e6), "us_per_call",
         "materialise-then-filter reference")
    emit("env.enumerate_naive", round(slow * 1e6), "us_per_call")
    emit("env.speedup", round(slow / delta, 2), "x",
         "paper §3.6 reports 2.6x for the C++ port")
    emit("env.delta_vs_ref_speedup", round(ref / delta, 2), "x")

    # batched candidate fingerprints (the per-step hot loop)
    cands = [a.result for m in mols for a in enumerate_actions(m)]
    t0 = time.perf_counter()
    for _ in range(reps):
        batch_morgan_fingerprints(cands)
    per = (time.perf_counter() - t0) / reps
    emit("env.batched_fp_per_candidate", round(per / len(cands) * 1e6, 1),
         "us", f"{len(cands)} candidates per batch")

    # ---- engine level: chem ms/step under both chem paths ------------- #
    for W, warmup, episodes, max_steps in ENGINE_PLANS:
        res = {chem: _roll(W, chem, warmup, episodes, max_steps)
               for chem in CHEM_MODES}
        for chem in CHEM_MODES:
            r = res[chem]
            emit(f"env.chem.w{W}.{chem}.chem_ms_per_step",
                 round(r["chem_ms_per_step"], 2), "ms",
                 "enumeration + candidate fingerprints, engine counters")
            emit(f"env.chem.w{W}.{chem}.fp_ms_per_step",
                 round(r["fp_ms_per_step"], 2), "ms")
            emit(f"env.chem.w{W}.{chem}.wall_ms_per_step",
                 round(r["wall_ms_per_step"], 1), "ms")
        emit(f"env.chem.w{W}.cache_hit_rate",
             round(res["incremental"]["hit_rate"], 3), "frac",
             f"warm cache, eps={EPSILON} exploit regime")
        emit(f"env.chem.w{W}.fp_reduction",
             round(res["full"]["fp_ms_per_step"]
                   / max(res["incremental"]["fp_ms_per_step"], 1e-9), 2), "x",
             "acceptance target at W=64: >= 5x")
        emit(f"env.chem.w{W}.chem_reduction",
             round(res["full"]["chem_ms_per_step"]
                   / max(res["incremental"]["chem_ms_per_step"], 1e-9), 2), "x")


# ------------------------------------------------------------------ #
# CI smoke gate: incremental chemistry bit-identical to full, cache hits
# ------------------------------------------------------------------ #
def smoke(W: int = 16) -> None:
    from repro.data.datasets import antioxidant_dataset

    max_steps, svc, rcfg = 4, _OracleSvc(), RewardConfig()
    mols = antioxidant_dataset(W)
    # the incremental engine additionally runs MESH-PADDED (two dead worker
    # slots, as a W-not-divisible-by-nd fleet on a device mesh would be):
    # padding must not perturb any live worker's candidate chemistry
    engines = {chem: RolloutEngine(
        [[m] for m in mols], EnvConfig(max_steps=max_steps), chem=chem,
        pad_workers_to=W + 2 if chem == "incremental" else None)
        for chem in CHEM_MODES}
    policies = {chem: _LinearQPolicy(W) for chem in CHEM_MODES}

    for episode in range(2):
        for chem in CHEM_MODES:
            engines[chem].reset()
        while not engines["full"].done:
            for chem in CHEM_MODES:
                engines[chem].step(policies[chem], svc, rcfg)
            for w in range(W):
                for sf, si in zip(engines["full"].workers[w],
                                  engines["incremental"].workers[w]):
                    if not np.array_equal(sf.cand_fps, si.cand_fps) or \
                       not np.array_equal(sf.cand_fps_packed, si.cand_fps_packed):
                        raise SystemExit(
                            f"FAIL: candidate fingerprints diverged "
                            f"(episode {episode}, worker {w}, slot {sf.index})")

    padded = engines["incremental"]
    if padded.n_workers != W + 2 or any(padded.workers[w] for w in (W, W + 1)):
        raise SystemExit("FAIL: mesh-padding workers own slots (must be dead)")

    st = engines["incremental"].chem_stats()
    emit(f"env.smoke.w{W}.cache_hit_rate", round(st["hit_rate"], 3), "frac",
         "gate: must be > 0.2 after a warm episode")
    emit(f"env.smoke.w{W}.relabel_misses", st["relabel_misses"], "lookups")
    if st["hit_rate"] <= 0.2:
        raise SystemExit(f"FAIL: warm ChemCache hit rate {st['hit_rate']:.3f} "
                         f"<= 0.2 — fleet-wide chem memoisation broken")
    print(f"SMOKE PASS: W={W}, all candidate fingerprints bit-identical "
          f"across chem modes over 2 episodes, warm hit rate "
          f"{st['hit_rate']:.2f}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: lockstep chem-mode bit-identity + cache hits")
    ap.add_argument("--w", type=int, default=16, help="smoke worker count")
    ap.add_argument("--scale", choices=("quick", "full"), default="quick")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.w)
    else:
        run(args.scale)
