"""§3.6 environment speedup: naive-Python port vs vectorised (the paper's
"C++ re-implementation" claim, 2.6x) + the batched-fingerprint win."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.chem.actions import enumerate_actions, enumerate_actions_naive
from repro.chem.fingerprint import batch_morgan_fingerprints
from repro.chem.smiles import from_smiles

MOLS = ["CC1=CC(C)=CC(C)=C1O", "C1=CC=CC=C1O", "CC1=C(N)C(C)=C(N)C(C)=C1O",
        "OC1=CC=C(C=C1)C(C)(C)C"]


def run(scale: str = "quick") -> None:
    reps = 30 if scale == "quick" else 100
    mols = [from_smiles(s) for s in MOLS]

    t0 = time.perf_counter()
    for _ in range(reps):
        for m in mols:
            enumerate_actions(m)
    fast = (time.perf_counter() - t0) / (reps * len(mols))

    t0 = time.perf_counter()
    for _ in range(max(reps // 3, 5)):
        for m in mols:
            enumerate_actions_naive(m)
    slow = (time.perf_counter() - t0) / (max(reps // 3, 5) * len(mols))

    emit("env.enumerate_vectorised", round(fast * 1e6), "us_per_call")
    emit("env.enumerate_naive", round(slow * 1e6), "us_per_call")
    emit("env.speedup", round(slow / fast, 2), "x",
         "paper §3.6 reports 2.6x for the C++ port")

    # batched candidate fingerprints (the per-step hot loop)
    cands = [a.result for m in mols for a in enumerate_actions(m)]
    t0 = time.perf_counter()
    for _ in range(reps):
        batch_morgan_fingerprints(cands)
    per = (time.perf_counter() - t0) / reps
    emit("env.batched_fp_per_candidate", round(per / len(cands) * 1e6, 1),
         "us", f"{len(cands)} candidates per batch")
