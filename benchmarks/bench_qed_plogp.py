"""Appendix D (Table 4 / Fig 10): QED and penalised-logP objectives.

Swaps the antioxidant reward for QED / PlogP surrogates (the pluggable-
objective path) on the ZINC-like set, comparing single-molecule MolDQN
against the DA-MolDQN general model.  The qualitative claims under test:
top-QED saturates near the 0.948 ceiling for both, and PlogP is maximised
by the degenerate carbon-chain strategy (which the surrogate reproduces).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.chem.properties import penalized_logp, qed_score
from repro.core import DQNConfig, EnvConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer, greedy_optimize
from repro.data.datasets import zinc_like_dataset
from repro.predictors.service import Properties

ENV = EnvConfig(max_steps=5, protect_oh=False)   # QED/PlogP need no O-H
NET = QNetwork(hidden=(256, 64))


class _NullService:
    """Objectives computed from structure alone — no predictors needed."""

    def predict(self, mols):
        return [Properties(bde=0.0, ip=0.0) for _ in mols]


def _reward_fn(objective):
    def fn(props, initial, current, steps_left):
        return float(objective(current))
    return fn


def run(scale: str = "quick") -> None:
    mols = zinc_like_dataset(16 if scale == "quick" else 64, seed=3)
    episodes = 20 if scale == "quick" else 40
    service = _NullService()

    for obj_name, obj in (("qed", qed_score), ("plogp", penalized_logp)):
        reward = _reward_fn(obj)
        cfg = TrainerConfig(
            n_workers=4, mols_per_worker=len(mols) // 4, episodes=episodes,
            sync_mode="episode", train_batch_size=24, max_candidates=48,
            updates_per_episode=5, dqn=DQNConfig(epsilon_decay=0.85),
            env=ENV, seed=42)
        tr = DistributedTrainer(cfg, mols, service, reward, network=NET)
        tr.train()
        recs = [r for r in greedy_optimize(tr.as_agent(0.0), mols, service,
                                           reward, ENV, seed=5) if r.done]
        vals = sorted((obj(r.molecule) for r in recs), reverse=True)
        init_vals = sorted((obj(m) for m in mols), reverse=True)
        emit(f"table4.{obj_name}.top3",
             "/".join(f"{v:.3f}" for v in vals[:3]), "score",
             "paper top-3 QED: 0.948/0.948/0.947" if obj_name == "qed"
             else "paper top-3 PlogP: 7.12/7.07/6.94")
        emit(f"table4.{obj_name}.init_top1", round(init_vals[0], 3), "score")
        emit(f"table4.{obj_name}.improved",
             sum(1 for v, r in zip(vals, recs) if v > init_vals[0] - 1e-9),
             "molecules")
