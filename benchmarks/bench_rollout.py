"""Fleet-level rollout acting paths, scaled to the paper's 512 molecules.

Per worker count the bench rolls identical seeded episodes under the acting
paths and reports

* Q-network jit dispatches per environment step (fleet target: exactly 1),
* predictor batches per environment step (``PropertyService`` §3.6 stats;
  cache disabled so every step predicts),
* XLA recompiles during the measured episodes (``RecompileCounter``; the
  shape-discipline claim is that this is ZERO after warmup — at any W),
* end-to-end steps per second and the speedup of the new pipelined+sharded
  path over the PR-1 fleet engine,
* acting seconds per step (time inside Q evaluation + property prediction),
* acting H2D bytes per step (``Trainer.acting_h2d_bytes``): the dense f32
  ``[W, C, 2049]`` batch vs the packed u8 ``[W, C, 256]`` bit planes, same
  engine mode, same episode stream — plus the dense/packed reduction.

Every cell is (rollout mode, acting representation).  W=64 still includes
the seed sequential per-worker path; at W in {256, 512} it would be
pathologically slow (W dispatches + W predictor batches per step), so only
the ``fleet`` engine and the ``fleet_pipelined`` (sharded dispatch +
overlapped chemistry) path are compared, under the packed / packed_async /
dense acting representations.

``python benchmarks/bench_rollout.py --smoke`` runs the CI gate: W=16,
pipelined path with packed acting, randomly-initialised predictors (no
training needed), and FAILS if any XLA compile happens after warmup, if
the dispatch count is not exactly one per step, or if packed acting ships
more than 0.05x the dense acting H2D bytes per step.  The gate also runs
a mixed-scenario cell (heterogeneous objectives cycled across the fleet
through the vectorized reward layer) which must hold the same
zero-recompile / one-dispatch bar and reports its steps/s overhead vs the
homogeneous fleet.  The gate is
mesh-size-agnostic: CI also runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (the
multidevice-smoke job), which shards the fleet over nd=2 host devices and
must hold the same zero-recompile bar.
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/bench_rollout.py --smoke`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit, services
from repro.core import DQNConfig, EnvConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer
from repro.core.jit_stats import RecompileCounter
from repro.predictors.service import PropertyService

MAX_STEPS = 3
# (rollout mode, acting representation) cells per worker count: the
# sequential path only where it is affordable; (fleet, dense) vs
# (fleet, packed) isolates the acting representation at every W
PLANS = (
    (64, (("per_worker", "dense"), ("fleet", "dense"), ("fleet", "packed"),
          ("fleet_pipelined", "packed"),
          ("fleet_pipelined", "packed_async"))),
    (256, (("fleet", "dense"), ("fleet", "packed"),
           ("fleet_pipelined", "packed"))),
    (512, (("fleet", "dense"), ("fleet", "packed"),
           ("fleet_pipelined", "packed"),
           ("fleet_pipelined", "packed_async"))),
)


def _uncached_service(base: PropertyService) -> PropertyService:
    """Share the trained predictor params; fresh stats, no LRU cache so the
    per-step batch counts are structural, not workload-dependent."""
    return PropertyService(base.bde_model, base.bde_params,
                           base.ip_model, base.ip_params, cache=None)


def _instrument_acting(tr: DistributedTrainer, svc: PropertyService) -> dict:
    """Accumulate wall time spent in Q evaluation + property prediction
    (both synchronous: results are converted to numpy before returning)."""
    acting = {"s": 0.0}

    def timed(fn):
        def wrapper(*a, **kw):
            t0 = time.perf_counter()
            out = fn(*a, **kw)
            acting["s"] += time.perf_counter() - t0
            return out
        return wrapper

    # dense entry point + the packed split pair; the sync packed path
    # (fleet_q_values_packed) routes through the instance-patched
    # dispatch/fetch attributes, so wrapping the pair covers it too
    for pol in (tr._fleet_policy, tr._fleet_policy_sharded):
        pol.fleet_q_values = timed(pol.fleet_q_values)
        pol.fleet_q_dispatch_packed = timed(pol.fleet_q_dispatch_packed)
        pol.fleet_q_fetch = timed(pol.fleet_q_fetch)
    for view in tr._views:
        view.q_values = timed(view.q_values)
    svc.predict = timed(svc.predict)
    return acting


def _measure(tr: DistributedTrainer, svc: PropertyService, counter,
             warmup: int, episodes: int) -> dict:
    """Warm up (jit shapes + capacity reserve), then measure."""
    acting = _instrument_acting(tr, svc)
    for _ in range(warmup):
        tr.rollout_episode()
    # reserve one ladder rung of headroom past the warmup high-water mark so
    # candidate-count drift in the measured episodes cannot grow the shape
    if tr.candidate_capacity:
        tr.reserve_candidates(int(tr.candidate_capacity * 1.3))

    tr.n_q_dispatches = 0
    tr.acting_h2d_bytes = 0
    b0, c0 = svc.n_predictor_batches, svc.n_predict_calls
    acting["s"] = 0.0
    mark = counter.count
    t0 = time.perf_counter()
    for _ in range(episodes):
        tr.rollout_episode()
    dt = time.perf_counter() - t0

    n_steps = episodes * MAX_STEPS
    return {
        "steps_per_s": n_steps / dt,
        "q_dispatches_per_step": tr.n_q_dispatches / n_steps,
        "predict_calls_per_step": (svc.n_predict_calls - c0) / n_steps,
        "predictor_batches_per_step": (svc.n_predictor_batches - b0) / n_steps,
        "acting_s_per_step": acting["s"] / n_steps,
        "acting_h2d_bytes_per_step": tr.acting_h2d_bytes / n_steps,
        "recompiles": counter.delta_since(mark),
    }


def _trainer(W: int, mode: str, mols, svc, rcfg, net,
             acting: str = "packed", scenarios=None) -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=W, mols_per_worker=1, episodes=1, sync_mode="episode",
        rollout=mode, acting=acting, train_batch_size=8, max_candidates=16,
        dqn=DQNConfig(), env=EnvConfig(max_steps=MAX_STEPS), seed=0,
        scenarios=scenarios)
    return DistributedTrainer(cfg, mols, svc, rcfg, network=net)


def run(scale: str = "quick") -> None:
    counter = RecompileCounter.install()
    base, train, _, rcfg, _ = services()
    warmup = 2  # covers the jit shapes the measured episodes revisit
    net = QNetwork(hidden=(128, 32))

    for W, cells in PLANS:
        # small-W episodes are cheap: buy variance reduction where it costs
        # little (a 6-step sample on a shared box is hopelessly noisy)
        episodes = (6 if W <= 64 else 2) if scale == "quick" else (10 if W <= 64 else 4)
        mols = (train * (W // len(train) + 1))[:W]
        speed: dict[tuple, float] = {}
        acting_per_step: dict[tuple, float] = {}
        h2d: dict[tuple, float] = {}
        for mode, acting in cells:
            svc = _uncached_service(base)
            tr = _trainer(W, mode, mols, svc, rcfg, net, acting=acting)
            m = _measure(tr, svc, counter, warmup, episodes)
            speed[mode, acting] = m["steps_per_s"]
            acting_per_step[mode, acting] = m["acting_s_per_step"]
            h2d[mode, acting] = m["acting_h2d_bytes_per_step"]
            key = f"rollout.w{W}.{mode}.{acting}"
            emit(f"{key}.q_dispatches_per_step",
                 round(m["q_dispatches_per_step"], 2), "calls",
                 f"seed path: {W}" if mode == "per_worker" else "fleet target: exactly 1")
            emit(f"{key}.predict_calls_per_step",
                 round(m["predict_calls_per_step"], 2), "calls")
            emit(f"{key}.predictor_batches_per_step",
                 round(m["predictor_batches_per_step"], 2), "calls")
            emit(f"{key}.recompiles_after_warmup",
                 m["recompiles"], "compiles", "shape discipline target: 0")
            emit(f"{key}.steps_per_s",
                 round(m["steps_per_s"], 3), "steps/s")
            emit(f"{key}.acting_ms_per_step",
                 round(m["acting_s_per_step"] * 1e3, 1), "ms",
                 "Q dispatch + property predict only")
            emit(f"{key}.acting_h2d_bytes_per_step",
                 int(m["acting_h2d_bytes_per_step"]), "B",
                 "fleet Q input batches shipped host -> device")
        if ("per_worker", "dense") in speed:
            emit(f"rollout.w{W}.fleet_speedup",
                 round(speed["fleet", "dense"] / speed["per_worker", "dense"], 2),
                 "x", "fleet engine vs sequential per-worker acting, end to end")
        emit(f"rollout.w{W}.acting_h2d_reduction",
             round(h2d["fleet", "dense"] / h2d["fleet", "packed"], 1), "x",
             "packed u8 candidate planes vs dense f32 batches; "
             "acceptance target at W=512: >= 10x")
        emit(f"rollout.w{W}.packed_acting_speedup",
             round(speed["fleet", "packed"] / speed["fleet", "dense"], 2), "x",
             "same fleet engine, packed vs dense acting representation")
        emit(f"rollout.w{W}.pipelined_speedup",
             round(speed["fleet_pipelined", "packed"] / speed["fleet", "packed"], 2),
             "x", "pipelined+sharded path vs the fleet engine, end to end")
        emit(f"rollout.w{W}.pipelined_acting_speedup",
             round(acting_per_step["fleet", "packed"]
                   / acting_per_step["fleet_pipelined", "packed"], 2),
             "x", "overlapped chemistry hides part of the property batch")
        if ("fleet_pipelined", "packed_async") in speed:
            emit(f"rollout.w{W}.async_acting_speedup",
                 round(speed["fleet_pipelined", "packed_async"]
                       / speed["fleet_pipelined", "packed"], 2), "x",
                 "eager Q dispatch overlapped with selection + early chem")


# ------------------------------------------------------------------ #
# CI smoke gate: zero recompiles after warmup on the pipelined path
# ------------------------------------------------------------------ #
def smoke(W: int = 16) -> None:
    """Fast, training-free shape-discipline gate (random predictor params:
    recompile behaviour only depends on shapes, not weights)."""
    import jax

    from repro.core import RewardConfig
    from repro.data.datasets import antioxidant_dataset, dataset_property_table
    from repro.predictors.gnn import AlfabetS
    from repro.predictors.ip_net import AIMNetS

    counter = RecompileCounter.install()
    bde_model, ip_model = AlfabetS(), AIMNetS()
    svc = PropertyService(bde_model, bde_model.init(jax.random.PRNGKey(0)),
                          ip_model, ip_model.init(jax.random.PRNGKey(1)),
                          cache=None)
    mols = antioxidant_dataset(W)
    props = dataset_property_table(mols)
    rcfg = RewardConfig.from_dataset(props["bde"], props["ip"])
    net = QNetwork(hidden=(64, 32))
    tr = _trainer(W, "fleet_pipelined", mols, svc, rcfg, net, acting="packed")

    mark0 = counter.count
    m = _measure(tr, svc, counter, warmup=2, episodes=2)
    warmup_compiles = counter.count - mark0 - m["recompiles"]

    # dense-acting reference on the same workload: the identical episode
    # stream (the acting representations are bit-equivalent), so the byte
    # ratio compares like shapes.  gate: packed ships <= 0.05x the bytes.
    svc_d = _uncached_service(svc)
    tr_d = _trainer(W, "fleet", mols, svc_d, rcfg, net, acting="dense")
    m_d = _measure(tr_d, svc_d, counter, warmup=1, episodes=2)
    h2d_ratio = (m["acting_h2d_bytes_per_step"]
                 / max(m_d["acting_h2d_bytes_per_step"], 1e-9))

    emit(f"rollout.smoke.w{W}.devices", jax.device_count(), "devices",
         "mesh size the fleet acted on (nd; force with XLA_FLAGS)")
    emit(f"rollout.smoke.w{W}.warmup_compiles", warmup_compiles, "compiles")
    emit(f"rollout.smoke.w{W}.recompiles_after_warmup", m["recompiles"],
         "compiles", "gate: must be 0")
    emit(f"rollout.smoke.w{W}.q_dispatches_per_step",
         round(m["q_dispatches_per_step"], 2), "calls", "gate: must be 1.0")
    emit(f"rollout.smoke.w{W}.steps_per_s", round(m["steps_per_s"], 3),
         "steps/s", "pipelined packed acting, random predictor params")
    emit(f"rollout.smoke.w{W}.packed_acting_h2d_bytes_per_step",
         int(m["acting_h2d_bytes_per_step"]), "B")
    emit(f"rollout.smoke.w{W}.dense_acting_h2d_bytes_per_step",
         int(m_d["acting_h2d_bytes_per_step"]), "B")
    emit(f"rollout.smoke.w{W}.acting_h2d_ratio", round(h2d_ratio, 4), "frac",
         "packed / dense acting bytes per step; gate: <= 0.05")

    # mixed-scenario cell (PR 10): the SAME pipelined fleet, heterogeneous
    # objectives cycled across workers through the fleet-vectorized reward
    # layer.  The reward layer is NumPy-side, so the shape-discipline gate
    # must hold unchanged (0 recompiles after warmup, 1 Q dispatch/step);
    # the steps/s ratio vs the homogeneous fleet is the layer's overhead.
    mix = ("antioxidant", "qed", "plogp", "antioxidant_novel")
    svc_m = _uncached_service(svc)
    tr_m = _trainer(W, "fleet_pipelined", mols, svc_m, rcfg, net,
                    acting="packed", scenarios=mix)
    m_m = _measure(tr_m, svc_m, counter, warmup=2, episodes=2)
    mixed_overhead = (m["steps_per_s"] / max(m_m["steps_per_s"], 1e-9)) - 1.0
    emit(f"rollout.smoke.w{W}.mixed.steps_per_s",
         round(m_m["steps_per_s"], 3), "steps/s",
         f"scenarios={','.join(mix)} cycled across {W} workers")
    emit(f"rollout.smoke.w{W}.mixed.recompiles_after_warmup",
         m_m["recompiles"], "compiles", "gate: must be 0")
    emit(f"rollout.smoke.w{W}.mixed.q_dispatches_per_step",
         round(m_m["q_dispatches_per_step"], 2), "calls", "gate: must be 1.0")
    emit(f"rollout.smoke.w{W}.mixed_overhead_frac",
         round(mixed_overhead, 4), "frac",
         "mixed-fleet slowdown vs homogeneous (steps/s ratio - 1)")

    if warmup_compiles <= 0:
        raise SystemExit("smoke self-check failed: warmup compiled nothing — "
                         "the recompile counter is not observing this process")
    if m["recompiles"] != 0:
        raise SystemExit(
            f"FAIL: {m['recompiles']} XLA compile(s) during measured episodes "
            f"(shape discipline broken on the pipelined path)")
    if m["q_dispatches_per_step"] != 1.0:
        raise SystemExit(
            f"FAIL: {m['q_dispatches_per_step']} Q dispatches/step (expected 1)")
    if h2d_ratio > 0.05:
        raise SystemExit(
            f"FAIL: packed acting ships {h2d_ratio:.4f}x the dense H2D "
            f"bytes/step (gate: <= 0.05)")
    if m_m["recompiles"] != 0:
        raise SystemExit(
            f"FAIL: {m_m['recompiles']} XLA compile(s) during the measured "
            f"mixed-scenario episodes (objectives leaked into jit shapes)")
    if m_m["q_dispatches_per_step"] != 1.0:
        raise SystemExit(
            f"FAIL: mixed fleet made {m_m['q_dispatches_per_step']} Q "
            f"dispatches/step (expected 1)")
    print(f"SMOKE PASS: W={W} on {jax.device_count()} device(s), "
          f"{warmup_compiles} warmup compiles, 0 recompiles after warmup "
          f"(homogeneous AND mixed-scenario), 1 Q dispatch/step, "
          f"packed/dense acting H2D ratio {h2d_ratio:.4f}, "
          f"mixed-fleet overhead {mixed_overhead:+.1%}")


def measure_acting_h2d(W: int = 512, episodes: int = 1) -> dict:
    """Measured acting H2D bytes/step at the paper's fleet size, dense vs
    packed on the SAME fleet engine.  Training-free (random predictor
    params): the byte counters are structural — they depend on the sticky
    buffer shapes the episode stream reaches, not on predictor weights."""
    import jax

    from repro.core import RewardConfig
    from repro.data.datasets import antioxidant_dataset, dataset_property_table
    from repro.predictors.gnn import AlfabetS
    from repro.predictors.ip_net import AIMNetS

    counter = RecompileCounter.install()
    bde_model, ip_model = AlfabetS(), AIMNetS()
    base = PropertyService(bde_model, bde_model.init(jax.random.PRNGKey(0)),
                           ip_model, ip_model.init(jax.random.PRNGKey(1)),
                           cache=None)
    mols = antioxidant_dataset(W)
    props = dataset_property_table(mols)
    rcfg = RewardConfig.from_dataset(props["bde"], props["ip"])
    net = QNetwork(hidden=(64,))

    bytes_per_step: dict[str, float] = {}
    for acting in ("dense", "packed"):
        svc = _uncached_service(base)
        tr = _trainer(W, "fleet", mols, svc, rcfg, net, acting=acting)
        m = _measure(tr, svc, counter, warmup=1, episodes=episodes)
        bytes_per_step[acting] = m["acting_h2d_bytes_per_step"]
        emit(f"rollout.h2d.w{W}.{acting}.acting_h2d_bytes_per_step",
             int(m["acting_h2d_bytes_per_step"]), "B",
             "fleet engine, measured byte counter")
    reduction = bytes_per_step["dense"] / max(bytes_per_step["packed"], 1e-9)
    emit(f"rollout.h2d.w{W}.acting_h2d_reduction", round(reduction, 1), "x",
         "measured; acceptance target at W=512: >= 10x")
    return {"dense_bytes_per_step": bytes_per_step["dense"],
            "packed_bytes_per_step": bytes_per_step["packed"],
            "reduction": reduction}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: W=16 pipelined path, fail on recompiles")
    ap.add_argument("--w", type=int, default=16, help="smoke worker count")
    ap.add_argument("--scale", choices=("quick", "full"), default="quick")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.w)
    else:
        run(args.scale)
