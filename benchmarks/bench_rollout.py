"""Fleet-level rollout engine vs the seed sequential per-worker acting.

The refactor's claim: acting costs O(1) jit dispatches and O(1) property
batches per environment step regardless of worker count, where the seed
path paid O(W) of each.  For W in {4, 16, 64} this bench rolls identical
episodes under both paths and reports

* Q-network jit dispatches per environment step (trainer dispatch counter),
* predictor batches per environment step (``PropertyService`` §3.6 stats;
  cache disabled so every step predicts),
* end-to-end steps per second and the fleet/sequential speedup,
* acting seconds per step (time inside Q evaluation + property prediction
  only) — candidate enumeration + fingerprinting is identical host work in
  both paths, so this isolates what the fleet batching actually changes.
"""

from __future__ import annotations

import time

from benchmarks.common import emit, services
from repro.core import DQNConfig, EnvConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer
from repro.predictors.service import PropertyService

MAX_STEPS = 3


def _uncached_service(base: PropertyService) -> PropertyService:
    """Share the trained predictor params; fresh stats, no LRU cache so the
    per-step batch counts are structural, not workload-dependent."""
    return PropertyService(base.bde_model, base.bde_params,
                           base.ip_model, base.ip_params, cache=None)


def _instrument_acting(tr: DistributedTrainer, svc: PropertyService) -> dict:
    """Accumulate wall time spent in Q evaluation + property prediction
    (both synchronous: results are converted to numpy before returning)."""
    acting = {"s": 0.0}

    def timed(fn):
        def wrapper(*a, **kw):
            t0 = time.perf_counter()
            out = fn(*a, **kw)
            acting["s"] += time.perf_counter() - t0
            return out
        return wrapper

    tr._fleet_policy.fleet_q_values = timed(tr._fleet_policy.fleet_q_values)
    for view in tr._views:
        view.q_values = timed(view.q_values)
    svc.predict = timed(svc.predict)
    return acting


def run(scale: str = "quick") -> None:
    base, train, _, rcfg, _ = services()
    episodes = 3 if scale == "quick" else 6
    warmup = 2  # covers the jit shapes the measured episodes revisit
    net = QNetwork(hidden=(128, 32))

    for W in (4, 16, 64):
        mols = (train * (W // len(train) + 1))[:W]
        speed: dict[str, float] = {}
        acting_per_step: dict[str, float] = {}
        for mode in ("per_worker", "fleet"):
            svc = _uncached_service(base)
            cfg = TrainerConfig(
                n_workers=W, mols_per_worker=1, episodes=1, sync_mode="episode",
                rollout=mode, train_batch_size=8, max_candidates=16,
                dqn=DQNConfig(), env=EnvConfig(max_steps=MAX_STEPS), seed=0)
            tr = DistributedTrainer(cfg, mols, svc, rcfg, network=net)
            acting = _instrument_acting(tr, svc)

            for _ in range(warmup):                   # compile both paths' shapes
                tr.rollout_episode()
            tr.n_q_dispatches = 0
            b0, c0 = svc.n_predictor_batches, svc.n_predict_calls
            acting["s"] = 0.0
            t0 = time.perf_counter()
            for _ in range(episodes):
                tr.rollout_episode()
            dt = time.perf_counter() - t0

            n_steps = episodes * MAX_STEPS
            speed[mode] = n_steps / dt
            emit(f"rollout.w{W}.{mode}.q_dispatches_per_step",
                 round(tr.n_q_dispatches / n_steps, 2), "calls",
                 "fleet target: exactly 1" if mode == "fleet" else f"seed path: {W}")
            emit(f"rollout.w{W}.{mode}.predict_calls_per_step",
                 round((svc.n_predict_calls - c0) / n_steps, 2), "calls")
            emit(f"rollout.w{W}.{mode}.predictor_batches_per_step",
                 round((svc.n_predictor_batches - b0) / n_steps, 2), "calls")
            emit(f"rollout.w{W}.{mode}.steps_per_s", round(speed[mode], 3), "steps/s")
            acting_per_step[mode] = acting["s"] / n_steps
            emit(f"rollout.w{W}.{mode}.acting_ms_per_step",
                 round(acting_per_step[mode] * 1e3, 1), "ms",
                 "Q dispatch + property predict only")
        emit(f"rollout.w{W}.fleet_speedup",
             round(speed["fleet"] / speed["per_worker"], 2), "x",
             "fleet engine vs sequential per-worker acting, end to end")
        emit(f"rollout.w{W}.fleet_acting_speedup",
             round(acting_per_step["per_worker"] / acting_per_step["fleet"], 2),
             "x", "batched acting path alone (host chemistry is identical)")
