"""§3.6 LRU property cache: hit rate + effective speedup during RL-style
re-visitation (episodes restart from the same initial molecules)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, services
from repro.predictors import PropertyService
from repro.predictors.cache import LRUCache


def run(scale: str = "quick") -> None:
    service, train, _, _, metrics = services()
    emit("predictor.bde_rel_err", round(metrics["bde"]["rel_err_mean"], 4), "frac",
         "paper §2.2: <5%")
    emit("predictor.ip_rel_err", round(metrics["ip"]["rel_err_mean"], 4), "frac")

    mols = train[:64]
    rng = np.random.default_rng(0)

    # simulate episode revisitation: 6 passes with small perturbation of order
    cold = PropertyService(service.bde_model, service.bde_params,
                           service.ip_model, service.ip_params, cache=None)
    t0 = time.perf_counter()
    for _ in range(3):
        order = rng.permutation(len(mols))
        cold.predict([mols[i] for i in order])
    t_cold = time.perf_counter() - t0

    warm = PropertyService(service.bde_model, service.bde_params,
                           service.ip_model, service.ip_params,
                           cache=LRUCache(100_000))
    t0 = time.perf_counter()
    for _ in range(3):
        order = rng.permutation(len(mols))
        warm.predict([mols[i] for i in order])
    t_warm = time.perf_counter() - t0

    emit("cache.no_cache_s", round(t_cold, 3), "s", "3 passes x 64 molecules")
    emit("cache.with_cache_s", round(t_warm, 3), "s")
    emit("cache.speedup", round(t_cold / t_warm, 2), "x")
    emit("cache.hit_rate", round(warm.cache.hit_rate, 3), "frac",
         "paper: cache turns 16 days into ~1 hour end-to-end")
