"""Shared benchmark plumbing."""

from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

RESULTS: dict[str, dict] = {}


def emit(name: str, value, unit: str, derived: str = "") -> None:
    RESULTS[name] = {"value": value, "unit": unit, "derived": derived}
    print(f"{name},{value},{unit}" + (f",{derived}" if derived else ""), flush=True)


@contextmanager
def timer():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def save_results(path: str = "experiments/bench/results.json") -> None:
    """Merge this run's metrics into the results file (a partial run — e.g.
    ``--only rollout`` — must not clobber the other benches' entries)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    merged: dict[str, dict] = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
    merged.update(RESULTS)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, default=str)


def services():
    """Shared predictor service + dataset + reward config."""
    from repro.core import RewardConfig
    from repro.data.datasets import antioxidant_dataset, dataset_property_table, \
        train_test_split
    from repro.predictors import PropertyService
    from repro.predictors.training import ensure_trained

    bm, bp, im, ip_, metrics = ensure_trained(verbose=False)
    service = PropertyService(bm, bp, im, ip_)
    ds = antioxidant_dataset(600)
    train, test = train_test_split(ds)
    props = dataset_property_table(train)
    rcfg = RewardConfig.from_dataset(props["bde"], props["ip"])
    return service, train, test, rcfg, metrics
