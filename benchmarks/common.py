"""Shared benchmark plumbing."""

from __future__ import annotations

import glob
import json
import os
import re
import time
from contextlib import contextmanager

RESULTS: dict[str, dict] = {}

# the committed perf-trajectory snapshot format (BENCH_PR<n>.json series,
# written by `benchmarks/run.py --bench-json`)
BENCH_SNAPSHOT_SCHEMA = "bench-snapshot-v1"
_BENCH_NAME = re.compile(r"BENCH_PR(\d+)\.json")
_BENCH_SECTIONS = ("host", "summary", "metrics")
# the serve section (PR 9) is OPTIONAL — earlier snapshots in the series
# predate serving — but when present it must carry the full metrics block
_SERVE_REQUIRED = ("requests_per_s", "p50_latency_ms", "p99_latency_ms",
                   "completed", "degraded", "shed", "deadline_exceeded",
                   "failed", "recompiles_after_warmup")


class BenchTrajectoryError(ValueError):
    """A committed BENCH_*.json snapshot is unreadable as part of the
    series — wrong name, malformed JSON, wrong schema, missing sections.
    Raised LOUDLY instead of silently yielding an empty trajectory."""


def load_bench_trajectory(root: str = ".") -> list[dict]:
    """Discover the committed ``BENCH_*.json`` snapshots under ``root``,
    validate each against ``bench-snapshot-v1``, and return them ordered
    chronologically (by PR number — numeric, so PR10 sorts after PR9).

    Every snapshot dict gains ``name`` (basename) and ``pr`` (int) keys
    next to its ``host``/``summary``/``metrics`` sections.  Any snapshot
    that does not parse or validate raises :class:`BenchTrajectoryError`
    naming the file and the defect — a truncated or hand-mangled snapshot
    must fail the trajectory, not vanish from it."""
    snaps = []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        base = os.path.basename(path)
        m = _BENCH_NAME.fullmatch(base)
        if not m:
            raise BenchTrajectoryError(
                f"{path}: unrecognised snapshot name (expected "
                f"BENCH_PR<n>.json — the series is ordered by PR number)")
        try:
            with open(path) as f:
                data = json.load(f)
        except json.JSONDecodeError as e:
            raise BenchTrajectoryError(f"{path}: malformed JSON: {e}") from e
        if not isinstance(data, dict):
            raise BenchTrajectoryError(f"{path}: snapshot is not an object")
        if data.get("schema") != BENCH_SNAPSHOT_SCHEMA:
            raise BenchTrajectoryError(
                f"{path}: schema {data.get('schema')!r}, expected "
                f"{BENCH_SNAPSHOT_SCHEMA!r}")
        for key in _BENCH_SECTIONS:
            if not isinstance(data.get(key), dict):
                raise BenchTrajectoryError(
                    f"{path}: missing or non-object {key!r} section")
        if "serve" in data:
            if not isinstance(data["serve"], dict):
                raise BenchTrajectoryError(
                    f"{path}: non-object 'serve' section")
            missing = [k for k in _SERVE_REQUIRED if k not in data["serve"]]
            if missing:
                raise BenchTrajectoryError(
                    f"{path}: serve section missing {missing} — a partial "
                    f"serve cell must fail the trajectory, not blend in")
        snaps.append({"name": base, "pr": int(m.group(1)), **data})
    snaps.sort(key=lambda s: s["pr"])
    return snaps


def diff_bench_trajectory(snaps: list[dict]) -> list[dict]:
    """Per-summary-metric deltas between consecutive snapshots of a
    :func:`load_bench_trajectory` series.  Each row: ``from``/``to``
    snapshot names, ``metric``, ``old``/``new`` values, and ``delta_pct``
    when both values are finite numbers (None for new/dropped metrics)."""
    rows = []
    for prev, cur in zip(snaps, snaps[1:]):
        for metric in sorted(set(prev["summary"]) | set(cur["summary"])):
            old = prev["summary"].get(metric)
            new = cur["summary"].get(metric)
            delta = None
            if isinstance(old, (int, float)) and isinstance(new, (int, float)) \
                    and not isinstance(old, bool) and old:
                delta = 100.0 * (new - old) / abs(old)
            rows.append({"from": prev["name"], "to": cur["name"],
                         "metric": metric, "old": old, "new": new,
                         "delta_pct": delta})
    return rows


def emit(name: str, value, unit: str, derived: str = "") -> None:
    RESULTS[name] = {"value": value, "unit": unit, "derived": derived}
    print(f"{name},{value},{unit}" + (f",{derived}" if derived else ""), flush=True)


@contextmanager
def timer():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def save_results(path: str = "experiments/bench/results.json") -> None:
    """Merge this run's metrics into the results file (a partial run — e.g.
    ``--only rollout`` — must not clobber the other benches' entries)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    merged: dict[str, dict] = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
    merged.update(RESULTS)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, default=str)


def services():
    """Shared predictor service + dataset + reward config."""
    from repro.core import RewardConfig
    from repro.data.datasets import antioxidant_dataset, dataset_property_table, \
        train_test_split
    from repro.predictors import PropertyService
    from repro.predictors.training import ensure_trained

    bm, bp, im, ip_, metrics = ensure_trained(verbose=False)
    service = PropertyService(bm, bp, im, ip_)
    ds = antioxidant_dataset(600)
    train, test = train_test_split(ds)
    props = dataset_property_table(train)
    rcfg = RewardConfig.from_dataset(props["bde"], props["ip"])
    return service, train, test, rcfg, metrics
