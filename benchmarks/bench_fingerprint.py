"""§3.6 incremental Morgan fingerprint: reference (per-atom cryptographic
hashing, the original implementation's cost profile) vs the paper's
incremental algorithm vs this framework's vectorised full recompute."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.chem.actions import enumerate_actions
from repro.chem.fingerprint import (IncrementalMorgan, morgan_fingerprint,
                                    morgan_fingerprint_reference)
from repro.chem.smiles import from_smiles


def run(scale: str = "quick") -> None:
    reps = 200 if scale == "quick" else 1000
    rng = np.random.default_rng(0)

    # grow a ~30-atom molecule (incremental shines on larger graphs)
    mol = from_smiles("CC1=CC(C)=CC(C)=C1O")
    for _ in range(20):
        adds = [a for a in enumerate_actions(mol, allow_removal=False)
                if a.kind == "add_atom"]
        mol = adds[int(rng.integers(0, len(adds)))].result
    inc = IncrementalMorgan(mol)
    act = next(a for a in enumerate_actions(mol) if a.kind == "add_atom")

    t0 = time.perf_counter()
    for _ in range(max(reps // 4, 20)):
        morgan_fingerprint_reference(act.result)
    ref = (time.perf_counter() - t0) / max(reps // 4, 20)

    t0 = time.perf_counter()
    for _ in range(reps):
        inc.after_action(act.result, act.kind, act.detail)
    inc_t = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    for _ in range(reps):
        morgan_fingerprint(act.result)
    full = (time.perf_counter() - t0) / reps

    emit("fp.reference_full", round(ref * 1e6), "us",
         "per-atom hashing — the pre-optimisation baseline (paper's profile)")
    emit("fp.incremental", round(inc_t * 1e6), "us", "the paper's §3.6 algorithm")
    emit("fp.vectorised_full", round(full * 1e6), "us", "beyond-paper: batched uint64 hashing")
    emit("fp.incremental_speedup_vs_reference", round(ref / inc_t, 2), "x")
    emit("fp.vectorised_speedup_vs_reference", round(ref / full, 2), "x",
         f"n_atoms={mol.num_atoms}")
