"""Learner-path benchmark: the replay->update pipeline at fleet scale.

PRs 1-2 made acting O(1)-dispatch; this bench quantifies the learner-side
twin (packed SoA replay, on-device unpack, double-buffered sampling).  Per
worker count it fills W per-worker replay buffers with an identical
synthetic transition stream and reports

* host-sample ms per update batch: seed list buffer (per-row Python loop +
  per-transition ``np.unpackbits``) vs SoA dense (vectorized gather + ONE
  batched unpack) vs SoA packed (gather only, no unpack at all),
* H2D bytes per update: dense float32 layout vs packed uint8 bit planes
  (structural ~32x, measured from what the trainer actually ships),
* device-update ms (the jit'd train step on an already-shipped batch),
* end-to-end updates/sec through ``DistributedTrainer.run_updates`` for
  each ``TrainerConfig.learner`` mode (the double-buffer win = packed ->
  packed_pipelined),
* XLA recompiles during the measured updates (``RecompileCounter``; the
  train-step shape-discipline gate — must be 0 after warmup at every W).

The dense learner is skipped at W=512: its stacked batch alone would be
~8.6 GB at the paper's B=32/C=64 (the wall this PR removes); its H2D bytes
are still reported analytically via ``dense_nbytes_equivalent``.

Honest perf notes (2-core CPU container):
* ``soa_dense`` host sampling can be SLOWER than the seed list loop — the
  vectorized densify unpacks all C candidate slots while the loop unpacks
  only each transition's actual count.  The packed sample is the point: it
  unpacks nothing.
* ``device_update_ms`` is higher for the packed paths here because the
  unpack runs inside the update and XLA-CPU "H2D" is a free memcpy;
  end-to-end the packed learner still wins (the host densify it deletes
  costs far more), and on a real accelerator the unpack rides the VPU
  while the 32x transfer reduction is genuine PCIe/ICI bytes.
* the double-buffer is ~parity on 2 cores (same as the acting overlap in
  bench_rollout): XLA-CPU already saturates both cores during the update,
  so the sampler thread has no idle core to hide in.

``python benchmarks/bench_train.py --smoke`` runs the CI gate: W=8, fails
on any XLA compile after warmup, an H2D reduction below 30x, or a
host-sample speedup below 3x.  Like the rollout gate it is mesh-size-
agnostic: the multidevice-smoke CI job re-runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so the packed
``shard_map`` train step holds the zero-recompile bar at nd=2 too.
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/bench_train.py --smoke`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import emit
from repro.chem.smiles import from_smiles
from repro.core import DQNConfig, EnvConfig, RewardConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer
from repro.core.jit_stats import RecompileCounter, jit_cache_size
from repro.core.packed_batch import dense_nbytes_equivalent
from repro.core.replay import FP_BYTES, ListReplayBuffer, ReplayBuffer, Transition

# (W, train_batch B, replay max_candidates C, learner modes to time)
PLANS = (
    (4, 16, 32, ("dense", "packed", "packed_pipelined")),
    (64, 32, 64, ("dense", "packed", "packed_pipelined")),
    (512, 4, 8, ("packed", "packed_pipelined")),
)
FILL = 192          # transitions per worker buffer


class _NullService:
    """The learner never predicts properties; satisfy the trainer ctor."""

    def predict(self, mols):  # pragma: no cover - never called here
        raise RuntimeError("bench_train never rolls out")


def _transition_stream(rng, n: int, C: int) -> list[Transition]:
    state_bits = rng.integers(0, 256, size=(n, FP_BYTES), dtype=np.uint8)
    counts = rng.integers(0, C + 1, size=n)
    dones = rng.random(n) < 0.15
    out = []
    for i in range(n):
        k = int(0 if dones[i] else counts[i])
        out.append(Transition(
            state_fp=state_bits[i],
            steps_left_frac=float(rng.random()),
            reward=float(rng.standard_normal()),
            done=bool(dones[i]),
            next_fps=rng.integers(0, 256, size=(k, FP_BYTES), dtype=np.uint8),
            next_steps_left_frac=float(rng.random()),
        ))
    return out


def _fill(buffers, W: int, C: int) -> None:
    for w in range(W):
        rng = np.random.default_rng(1000 + w)
        buffers[w].add_many(_transition_stream(rng, FILL, C))


def _time(fn, reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _trainer(W: int, B: int, C: int, learner: str,
             replay: str = "uniform") -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=W, mols_per_worker=1, episodes=1, sync_mode="episode",
        learner=learner, train_batch_size=B, max_candidates=C,
        replay_capacity=FILL, replay=replay,
        dqn=DQNConfig(), env=EnvConfig(max_steps=3), seed=0)
    net = QNetwork(hidden=(64,) if W >= 512 else (128, 32))
    tr = DistributedTrainer(cfg, [from_smiles("C1=CC=CC=C1O")] * W,
                            _NullService(), RewardConfig(), network=net)
    _fill(tr.buffers, W, C)
    return tr


def _measure_host_sampling(W: int, B: int, C: int, reps: int) -> dict[str, float]:
    """ms to gather one stacked [W, B, ...] update batch on the host."""
    list_bufs = [ListReplayBuffer(FILL, seed=w) for w in range(W)]
    soa_bufs = [ReplayBuffer(FILL, seed=w, max_candidates=C) for w in range(W)]
    _fill(list_bufs, W, C)
    _fill(soa_bufs, W, C)

    def stack(per):
        return {k: np.stack([p[k] for p in per]) for k in per[0]}

    return {
        "seed_list": _time(lambda: stack([b.sample(B, C) for b in list_bufs]), reps),
        "soa_dense": _time(lambda: stack([b.sample(B, C) for b in soa_bufs]), reps),
        "soa_packed": _time(
            lambda: stack([b.sample_packed(B, C) for b in soa_bufs]), reps),
    }


def _measure_updates(tr: DistributedTrainer, counter: RecompileCounter,
                     warmup: int, n: int) -> dict[str, float]:
    import jax

    tr.run_updates(warmup)
    packed = tr.cfg.learner != "dense"
    batch = (tr._stacked_sample_packed() if packed else tr._stacked_sample())
    tr._update_once(batch, packed=packed)          # device-only step, warm
    device_s = _time(
        lambda: jax.block_until_ready(tr._update_once(batch, packed=packed)),
        max(2, n // 2))

    tr.h2d_update_bytes = 0
    tr.n_updates = 0
    mark = counter.count
    wall = _time(lambda: tr.run_updates(n), 1)
    return {
        "updates_per_s": n / wall,
        "device_update_ms": device_s * 1e3,
        "h2d_bytes_per_update": tr.h2d_update_bytes / tr.n_updates,
        "recompiles": counter.delta_since(mark),
    }


def run(scale: str = "quick") -> None:
    counter = RecompileCounter.install()
    reps = 5 if scale == "quick" else 20
    for W, B, C, modes in PLANS:
        n = (8 if W <= 64 else 3) if scale == "quick" else (20 if W <= 64 else 6)
        host = _measure_host_sampling(W, B, C, reps if W <= 64 else max(2, reps // 2))
        for name, s in host.items():
            emit(f"train.w{W}.host_sample.{name}_ms", round(s * 1e3, 2), "ms",
                 f"stacked [W={W}, B={B}, C={C}] batch gather on host")
        emit(f"train.w{W}.host_sample.soa_packed_speedup",
             round(host["seed_list"] / host["soa_packed"], 1), "x",
             "packed SoA gather vs seed per-row list loop")

        h2d, ups = {}, {}
        for mode in modes:
            tr = _trainer(W, B, C, mode)
            m = _measure_updates(tr, counter, warmup=2, n=n)
            h2d[mode] = m["h2d_bytes_per_update"]
            ups[mode] = m["updates_per_s"]
            emit(f"train.w{W}.{mode}.updates_per_s", round(m["updates_per_s"], 2),
                 "upd/s")
            emit(f"train.w{W}.{mode}.device_update_ms",
                 round(m["device_update_ms"], 1), "ms")
            emit(f"train.w{W}.{mode}.h2d_bytes_per_update",
                 int(m["h2d_bytes_per_update"]), "B")
            emit(f"train.w{W}.{mode}.recompiles_after_warmup", m["recompiles"],
                 "compiles", "train-step shape discipline target: 0")
        if "dense" not in h2d:   # W=512: the dense batch would be ~W*B*C*8KB
            shapes = {"state_bits": np.zeros((W, B, 0), np.uint8),
                      "next_bits": np.zeros((W, B, C, 0), np.uint8)}
            h2d["dense"] = float(dense_nbytes_equivalent(shapes))
            emit(f"train.w{W}.dense.h2d_bytes_per_update", int(h2d["dense"]), "B",
                 "analytic (dense learner unaffordable at this W)")
        emit(f"train.w{W}.h2d_reduction",
             round(h2d["dense"] / h2d["packed"], 1), "x",
             "packed uint8 bit planes vs seed dense float32 batches")
        if "dense" in ups:
            emit(f"train.w{W}.packed_update_speedup",
                 round(ups["packed"] / ups["dense"], 2), "x",
                 "packed learner vs seed dense learner, end to end")
        emit(f"train.w{W}.pipelined_update_speedup",
             round(ups["packed_pipelined"] / ups["packed"], 2), "x",
             "double-buffered sampling vs synchronous packed learner")


# ------------------------------------------------------------------ #
# CI smoke gate: train-step shape discipline + structural reductions
# ------------------------------------------------------------------ #
def smoke(W: int = 8) -> None:
    import jax

    B, C, n = 8, 16, 6
    counter = RecompileCounter.install()
    emit(f"train.smoke.w{W}.devices", jax.device_count(), "devices",
         "mesh size the update step sharded over (nd; force with XLA_FLAGS)")

    host = _measure_host_sampling(W, B, C, reps=5)
    host_speedup = host["seed_list"] / host["soa_packed"]
    emit(f"train.smoke.w{W}.host_sample_speedup", round(host_speedup, 1), "x",
         "gate: >= 3")

    tr = _trainer(W, B, C, "packed_pipelined")
    m = _measure_updates(tr, counter, warmup=2, n=n)
    dense_bytes = dense_nbytes_equivalent(tr._stacked_sample_packed_np())
    ratio = dense_bytes / m["h2d_bytes_per_update"]
    emit(f"train.smoke.w{W}.h2d_reduction", round(ratio, 1), "x", "gate: >= 30")
    emit(f"train.smoke.w{W}.updates_per_s", round(m["updates_per_s"], 2), "upd/s")
    emit(f"train.smoke.w{W}.recompiles_after_warmup", m["recompiles"],
         "compiles", "gate: must be 0")
    emit(f"train.smoke.w{W}.update_shapes",
         jit_cache_size(tr._local_update_packed), "shapes", "gate: must be 1")

    # prioritized-replay cell: the same shape-discipline bar with PER on.
    # The measured window sweeps the beta anneal (beta is batch VALUES, not
    # a traced shape) and runs priority feedback after every update (so the
    # weighted-draw branch is exercised, not just the flat fast path) —
    # gate: 0 recompiles after the weighted update's own warmup, and still
    # exactly ONE compiled train-step shape.
    trp = _trainer(W, B, C, "packed_pipelined", replay="prioritized")
    trp.run_updates(2)                       # warmup: traces the weighted step
    mark = counter.count
    for ep in (0, 3, 9):                     # distinct betas along the anneal
        trp.episode = ep
        trp.run_updates(2)
    prio_recompiles = counter.delta_since(mark)
    prio_shapes = jit_cache_size(trp._local_update_packed)
    emit(f"train.smoke.w{W}.prioritized_recompiles_after_warmup",
         prio_recompiles, "compiles", "gate: must be 0 (beta sweep included)")
    emit(f"train.smoke.w{W}.prioritized_update_shapes", prio_shapes,
         "shapes", "gate: must be 1")

    if m["recompiles"] != 0:
        raise SystemExit(
            f"FAIL: {m['recompiles']} XLA compile(s) during measured updates "
            f"(train-step shape discipline broken)")
    if jit_cache_size(tr._local_update_packed) != 1:
        raise SystemExit("FAIL: packed train step traced more than one shape")
    if prio_recompiles != 0:
        raise SystemExit(
            f"FAIL: {prio_recompiles} XLA compile(s) during prioritized "
            f"updates (the beta anneal must not retrace)")
    if prio_shapes != 1:
        raise SystemExit("FAIL: prioritized train step traced more than one shape")
    if ratio < 30:
        raise SystemExit(f"FAIL: H2D reduction {ratio:.1f}x < 30x")
    if host_speedup < 3:
        raise SystemExit(
            f"FAIL: host-sample speedup {host_speedup:.1f}x < 3x vs seed list buffer")
    print(f"SMOKE PASS: W={W} on {jax.device_count()} device(s), "
          f"0 recompiles after warmup (uniform AND prioritized), "
          f"1 train-step shape, "
          f"{ratio:.1f}x H2D reduction, {host_speedup:.1f}x host-sample speedup")


# ------------------------------------------------------------------ #
# fault smoke: the robustness CI gate (PR 8)
# ------------------------------------------------------------------ #
def fault_smoke(W: int = 8) -> dict:
    """End-to-end training under a seeded FaultPlan (property-service
    timeouts + chem transients, all inside the retry budgets) gated on

    * retried-batch bit-equality: a predict() that only succeeded after
      injected transients returns the exact batch a fault-free service
      returns,
    * full-run bit-equality: the faulted trainer's loss/reward trajectory
      equals the fault-free twin's,
    * shape discipline: 0 XLA recompiles in the measured window WITH the
      retry/backoff machinery active (retries re-enter the same compiled
      shapes),
    * no degradation: zero quarantined slots when faults stay in budget.
    """
    import jax

    from repro.core.faults import FaultPlan, FaultRule
    from repro.predictors.service import (
        OracleService, ResilientService, RetryPolicy,
    )

    counter = RecompileCounter.install()
    mols = [from_smiles(s) for s in MULTISTART_SMILES[:W]]
    emit(f"train.fault_smoke.w{W}.devices", jax.device_count(), "devices")

    # micro-gate first: the retried batch itself, bit for bit
    plan_micro = FaultPlan([FaultRule(site="predict", kind="transient",
                                      every=1, fail_attempts=2)])
    rsvc = ResilientService(OracleService(), RetryPolicy(),
                            fault_plan=plan_micro, sleep=None)
    if rsvc.predict(mols) != OracleService().predict(mols):
        raise SystemExit("FAIL: retried predict batch != fault-free batch")
    if rsvc.n_retries != 2:
        raise SystemExit("FAIL: fault plan injected but no retries counted")

    def build(faulted: bool):
        plan = None
        svc = OracleService()
        if faulted:
            plan = FaultPlan([
                FaultRule(site="predict", kind="timeout", every=3,
                          fail_attempts=1),
                FaultRule(site="chem", kind="transient", rate=0.3,
                          fail_attempts=1),
            ], seed=8)
            svc = ResilientService(svc, RetryPolicy(seed=8),
                                   fault_plan=plan, sleep=None)
        cfg = TrainerConfig(
            n_workers=W, mols_per_worker=1, episodes=4, sync_mode="episode",
            rollout="fleet_sharded", learner="packed", acting="packed",
            chem="incremental", replay="prioritized", updates_per_episode=2,
            train_batch_size=4, max_candidates=16, replay_capacity=256,
            dqn=DQNConfig(epsilon_decay=0.97), env=EnvConfig(max_steps=3),
            seed=0)
        tr = DistributedTrainer(cfg, mols, svc, RewardConfig(),
                                network=QNetwork(hidden=(64,)),
                                fault_plan=plan)
        return tr, plan, svc

    ref, _, _ = build(False)
    for _ in range(4):
        ref.train_episode()

    tr, plan, svc = build(True)
    for _ in range(2):                       # warmup: acting + update compile
        tr.train_episode()
    if tr.candidate_capacity:
        tr.reserve_candidates(int(tr.candidate_capacity * 1.3))
    mark = counter.count
    for _ in range(2):
        tr.train_episode()
    recompiles = counter.delta_since(mark)

    def _traj_eq(a, b):  # episode 0's loss is nan (buffer below min fill)
        return np.array_equal(np.asarray(a, np.float64),
                              np.asarray(b, np.float64), equal_nan=True)

    leaves = jax.tree_util.tree_leaves
    est = tr.engine.fault_stats()
    out = {
        "n_faults_injected": plan.n_injected,
        "n_retries": svc.n_retries,
        "n_timeouts": svc.n_timeouts,
        "n_chem_retries": est["n_chem_retries"],
        "n_quarantined": est["n_quarantined"],
        "recompiles_after_warmup": recompiles,
        "bit_identical": (
            _traj_eq(tr.loss_log, ref.loss_log)
            and _traj_eq(tr.reward_log, ref.reward_log)
            and all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
                    for x, y in zip(leaves(tr.params), leaves(ref.params)))),
    }
    emit(f"train.fault_smoke.w{W}.n_faults_injected",
         out["n_faults_injected"], "faults", "seeded FaultPlan, in-budget")
    emit(f"train.fault_smoke.w{W}.n_retries", out["n_retries"], "retries",
         "property-service retry loop traffic")
    emit(f"train.fault_smoke.w{W}.n_chem_retries", out["n_chem_retries"],
         "retries", "chem enumeration retry traffic")
    emit(f"train.fault_smoke.w{W}.n_quarantined", out["n_quarantined"],
         "slots", "gate: must be 0 (faults stay inside budgets)")
    emit(f"train.fault_smoke.w{W}.recompiles_after_warmup", recompiles,
         "compiles", "gate: must be 0 with retries active")
    emit(f"train.fault_smoke.w{W}.bit_identical",
         int(out["bit_identical"]), "bool",
         "gate: faulted trajectory == fault-free trajectory")

    if out["n_faults_injected"] == 0:
        raise SystemExit("FAIL: the fault plan never fired — vacuous gate")
    if out["n_quarantined"] != 0:
        raise SystemExit(
            f"FAIL: {out['n_quarantined']} slot(s) quarantined under "
            f"in-budget faults")
    if recompiles != 0:
        raise SystemExit(
            f"FAIL: {recompiles} XLA compile(s) during faulted updates "
            f"(retries broke shape discipline)")
    if not out["bit_identical"]:
        raise SystemExit(
            "FAIL: training under absorbed faults diverged from fault-free")
    print(f"FAULT SMOKE PASS: W={W}, {out['n_faults_injected']} faults "
          f"injected ({out['n_retries']} service retries, "
          f"{out['n_chem_retries']} chem retries), 0 quarantines, "
          f"0 recompiles, bit-identical to fault-free")
    return out


# ------------------------------------------------------------------ #
# multi-start end-to-end cell (the paper-scale generalist loop)
# ------------------------------------------------------------------ #
MULTISTART_SMILES = (
    "C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O", "CC1=CC=CC=C1O", "OC1=CC=CC=C1O",
    "CC1=CC=C(O)C=C1", "COC1=CC=CC=C1O", "CC(C)C1=CC=CC=C1O", "NC1=CC=CC=C1O",
    "CC1=C(O)C(C)=CC=C1", "OC1=CC=C(O)C=C1", "CCC1=CC=CC=C1O", "CC1=CC(O)=CC=C1",
)


def multistart(W: int = 512, episodes: int = 2) -> dict:
    """End-to-end multi-start training cell at fleet scale: every episode
    draws fresh start molecules from a seeded DatasetStream cursor (here an
    inline phenol pool, so the bench measures the streaming machinery, not
    molecule generation), acting packed + pipelined, prioritized packed
    learner with per-update |TD| priority feedback.  Reports steps/s,
    updates, start-schedule coverage and the recompile count over the
    measured episodes."""
    import jax

    from repro.core.jit_stats import RecompileCounter
    from repro.predictors.service import OracleService

    counter = RecompileCounter.install()
    pool = [from_smiles(s) for s in MULTISTART_SMILES]
    cfg = TrainerConfig(
        n_workers=W, mols_per_worker=1, episodes=episodes + 2,
        sync_mode="episode", rollout="fleet_pipelined", learner="packed",
        acting="packed", chem="incremental", replay="prioritized",
        updates_per_episode=2, train_batch_size=4, max_candidates=8,
        replay_capacity=256, dataset="inline",
        dqn=DQNConfig(epsilon_decay=0.97), env=EnvConfig(max_steps=2), seed=0)
    tr = DistributedTrainer(cfg, None, OracleService(), RewardConfig(),
                            network=QNetwork(hidden=(64,)), dataset_pool=pool)

    # two warmup episodes: the first compiles acting, the second reaches
    # min-fill and compiles the (weighted) update; then candidate headroom
    for _ in range(2):
        tr.train_episode()
    if tr.candidate_capacity:
        tr.reserve_candidates(int(tr.candidate_capacity * 1.3))

    mark = counter.count
    steps0, updates0 = tr.engine.n_env_steps, tr.n_updates
    t0 = time.perf_counter()
    for _ in range(episodes):
        tr.train_episode()
    wall = time.perf_counter() - t0
    steps = tr.engine.n_env_steps - steps0
    updates = tr.n_updates - updates0
    unique = len({k for ep in tr.start_log for k in ep})
    out = {
        "steps_per_s": steps / wall,
        "updates": updates,
        "episode_wall_s": wall / episodes,
        "unique_starts": unique,
        "episodes_streamed": len(tr.start_log),
        "recompiles_after_warmup": counter.delta_since(mark),
    }
    emit(f"train.multistart.w{W}.steps_per_s", round(out["steps_per_s"], 2),
         "steps/s", f"end-to-end fleet env steps, {episodes} measured episodes")
    emit(f"train.multistart.w{W}.episode_wall_s",
         round(out["episode_wall_s"], 2), "s",
         "rollout + prioritized updates + episode param sync")
    emit(f"train.multistart.w{W}.unique_starts", unique, "molecules",
         f"start-schedule coverage of the {len(pool)}-molecule pool")
    emit(f"train.multistart.w{W}.recompiles_after_warmup",
         out["recompiles_after_warmup"], "compiles", "target: 0")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: W=8 packed_pipelined learner")
    ap.add_argument("--multistart", action="store_true",
                    help="W=512 multi-start end-to-end cell (dataset "
                         "streaming + prioritized replay)")
    ap.add_argument("--faults", action="store_true",
                    help="fault-injection CI gate: training under a seeded "
                         "FaultPlan stays bit-identical, 0 recompiles")
    ap.add_argument("--w", type=int, default=8, help="smoke worker count")
    ap.add_argument("--scale", choices=("quick", "full"), default="quick")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.w)
    elif args.faults:
        fault_smoke(args.w)
    elif args.multistart:
        multistart(args.w if args.w != 8 else 512)
    else:
        run(args.scale)
