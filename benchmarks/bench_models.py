"""Table 1 + Figs 2/3/4 (+ Fig 9-right): the four training regimes.

    Individual  1 molecule/model  (MolDQN)        -> N models
    Parallel    8 molecules/model (MT-MolDQN)     -> N/8 models
    General     all molecules, W workers, episode sync (DA-MolDQN)
    Fine-Tuned  general + per-molecule fine-tuning (§3.5)

All regimes share the environment, predictors and Q-net topology; episode
counts are CPU-scaled (paper: 8000/8000/250/200) with the paper's ratios
kept qualitative: the general model must (a) cost a fraction of
individual/parallel at equal molecule coverage [Fig 3], (b) reach lower
OFR / higher reward [Fig 2], and (c) transfer to unseen molecules where
individual models cannot [Fig 4].
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, services
from repro.core import DQNConfig, EnvConfig, RewardConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import (DistributedTrainer, greedy_optimize,
                                    optimization_failure_rate)
from repro.core.finetune import fine_tune

NET = QNetwork(hidden=(512, 128, 32))
ENV = EnvConfig(max_steps=5)


def _mean_reward(recs):
    return float(np.mean([r.reward for r in recs])) if recs else float("nan")


def _train_one(mols, *, workers, episodes, eps_decay, seed, service, rcfg,
               sync="episode"):
    cfg = TrainerConfig(
        n_workers=workers, mols_per_worker=len(mols) // workers,
        episodes=episodes, sync_mode=sync, train_batch_size=32,
        max_candidates=48, updates_per_episode=6,
        dqn=DQNConfig(epsilon_decay=eps_decay), env=ENV, seed=seed)
    tr = DistributedTrainer(cfg, mols, service, rcfg, network=NET)
    stats = tr.train()
    return tr, stats


def run(scale: str = "quick") -> None:
    service, train, test, rcfg, _ = services()
    N = 8 if scale == "quick" else 16
    ep_ind = 30 if scale == "quick" else 60
    ep_gen = 30 if scale == "quick" else 60
    ep_ft = 10 if scale == "quick" else 20
    mols = train[:N]
    test_mols = test[: max(N // 2, 4)]

    results = {}

    # ---- Individual: one model per molecule ------------------------- #
    t0 = time.perf_counter()
    ind_agents = []
    for i, m in enumerate(mols):
        tr, _ = _train_one([m], workers=1, episodes=ep_ind, eps_decay=0.9,
                           seed=100 + i, service=service, rcfg=rcfg)
        ind_agents.append(tr.as_agent(0.0))
    t_ind = time.perf_counter() - t0
    recs = [greedy_optimize(a, [m], service, rcfg, ENV, seed=7)[-1]
            for a, m in zip(ind_agents, mols)]
    results["individual"] = (t_ind, t_ind / N, _mean_reward(recs),
                             optimization_failure_rate(recs))

    # ---- Parallel: 8 molecules per model (one worker) ---------------- #
    t0 = time.perf_counter()
    par_agents = []
    groups = [mols[i : i + 8] for i in range(0, N, 8)]
    for gi, g in enumerate(groups):
        tr, _ = _train_one(g, workers=1, episodes=ep_ind, eps_decay=0.9,
                           seed=200 + gi, service=service, rcfg=rcfg)
        par_agents.append((tr.as_agent(0.0), g))
    t_par = time.perf_counter() - t0
    recs = [r for a, g in par_agents
            for r in _final(greedy_optimize(a, g, service, rcfg, ENV, seed=8))]
    results["parallel"] = (t_par, t_par / len(groups), _mean_reward(recs),
                           optimization_failure_rate(recs))

    # ---- General: all molecules, 4 workers, episode sync ------------- #
    t0 = time.perf_counter()
    gen_tr, gen_stats = _train_one(mols, workers=4, episodes=ep_gen,
                                   eps_decay=0.88, seed=300,
                                   service=service, rcfg=rcfg)
    t_gen = time.perf_counter() - t0
    gen_agent = gen_tr.as_agent(0.0)
    recs = _final(greedy_optimize(gen_agent, mols, service, rcfg, ENV, seed=9))
    results["general"] = (t_gen, t_gen, _mean_reward(recs),
                          optimization_failure_rate(recs))

    # Fig 9-right: invalid-conformer avoidance during general training
    inv = [s["invalid_conformer_rate"] for s in gen_stats]
    emit("fig9.invalid_rate_first3", round(float(np.mean(inv[:3])), 3), "frac")
    emit("fig9.invalid_rate_last3", round(float(np.mean(inv[-3:])), 3), "frac",
         "agent learns to avoid invalid 3D conformers (§3.3)")

    # ---- Fine-Tuned: general + per-molecule episodes ----------------- #
    t0 = time.perf_counter()
    ft_recs = []
    for i, m in enumerate(mols[: max(N // 2, 4)]):
        ag = fine_tune(gen_agent, m, service, rcfg, episodes=ep_ft,
                       env_cfg=ENV, train_batch_size=16, max_candidates=32,
                       updates_per_episode=2, seed=400 + i)
        ft_recs.extend(_final(greedy_optimize(ag, [m], service, rcfg, ENV, seed=10)))
    t_ft = time.perf_counter() - t0
    results["fine_tuned"] = (t_gen + t_ft, t_ft / max(N // 2, 4),
                             _mean_reward(ft_recs),
                             optimization_failure_rate(ft_recs))

    for name, (total, per_model, rew, ofr) in results.items():
        emit(f"table1.{name}.total_s", round(total, 1), "s")
        emit(f"table1.{name}.per_model_s", round(per_model, 1), "s")
        emit(f"fig2.{name}.mean_reward", round(rew, 3), "reward")
        emit(f"fig2.{name}.ofr", round(ofr, 3), "frac")

    emit("fig3.general_speedup_vs_individual",
         round(results["individual"][0] / results["general"][0], 2), "x",
         "paper: 28.1x at equal coverage (8000-ep individual vs 250-ep general)")
    emit("fig3.general_speedup_vs_parallel",
         round(results["parallel"][0] / results["general"][0], 2), "x",
         "paper: 106x")

    # ---- Fig 4: unseen molecules -------------------------------------- #
    recs_gen = _final(greedy_optimize(gen_agent, test_mols, service, rcfg, ENV, seed=11))
    recs_ind = _final(greedy_optimize(ind_agents[0], test_mols, service, rcfg, ENV, seed=12))
    ft_unseen = []
    for i, m in enumerate(test_mols):
        ag = fine_tune(gen_agent, m, service, rcfg, episodes=ep_ft, env_cfg=ENV,
                       train_batch_size=16, max_candidates=32,
                       updates_per_episode=2, seed=500 + i)
        ft_unseen.extend(_final(greedy_optimize(ag, [m], service, rcfg, ENV, seed=13)))
    emit("fig4.general.unseen_reward", round(_mean_reward(recs_gen), 3), "reward")
    emit("fig4.general.unseen_ofr", round(optimization_failure_rate(recs_gen), 3), "frac")
    emit("fig4.individual.unseen_reward", round(_mean_reward(recs_ind), 3), "reward",
         "an individual model applied to molecules it never saw")
    emit("fig4.fine_tuned.unseen_reward", round(_mean_reward(ft_unseen), 3), "reward")
    emit("fig4.fine_tuned.unseen_ofr", round(optimization_failure_rate(ft_unseen), 3), "frac")

    # stash artifacts for bench_properties / bench_dft
    run.artifacts = {"gen_agent": gen_agent, "mols": mols, "test": test_mols,
                     "service": service, "rcfg": rcfg, "env": ENV}


def _final(recs):
    done = [r for r in recs if r.done]
    return done if done else recs
