"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits, per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and fits-in-HBM.  Writes the markdown
table EXPERIMENTS.md §Roofline embeds."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

COLS = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
        "dominant", "useful_flops_ratio", "hbm_gb_per_chip", "fits_16gb")


def load(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        d = json.load(open(f))
        if d.get("status") == "ok":
            rows.append(d)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | dominant | useful FLOPs | HBM GiB | fits |"
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3g} | {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['hbm_gb_per_chip']:.2f} | {'Y' if r.get('fits_16gb') else 'N'} |")
    return "\n".join(lines)


def run(scale: str = "quick") -> None:
    rows = load()
    if not rows:
        emit("roofline.rows", 0, "configs", "run repro.launch.dryrun --all first")
        return
    emit("roofline.rows", len(rows), "configs")
    single = [r for r in rows if r["mesh"] == "16x16"]
    by_dom = {}
    for r in single:
        by_dom.setdefault(r["dominant"], []).append(f"{r['arch']}x{r['shape']}")
    for dom, names in sorted(by_dom.items()):
        emit(f"roofline.dominant.{dom}", len(names), "configs", ";".join(names[:4]) + "...")
    worst = max(single, key=lambda r: (max(r["compute_s"], r["memory_s"], r["collective_s"])
                                       / max(r["compute_s"], 1e-12)))
    emit("roofline.worst_fraction", f"{worst['arch']}x{worst['shape']}", "pair",
         f"dominant={worst['dominant']}")
    most_coll = max(single, key=lambda r: r["collective_s"])
    emit("roofline.most_collective_bound", f"{most_coll['arch']}x{most_coll['shape']}",
         "pair", f"{most_coll['collective_s']:.1f}s")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_table.md", "w") as f:
        f.write(markdown_table(rows) + "\n")
    emit("roofline.table", "experiments/roofline_table.md", "path")
