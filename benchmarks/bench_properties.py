"""Fig 5 + Table 5 + Fig 7: properties of proposed antioxidants.

Optimizes molecules with the general model (reusing bench_models' agent if
it ran first), applies the §3.5 filter, then 'DFT'-validates survivors
against the oracle: predicted-vs-oracle errors (Table 5) and the
stability/performance quadrant agreement (Fig 7)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, services
from repro.chem.oracle import oracle_bde, oracle_ip
from repro.chem.properties import sa_score, tanimoto
from repro.core import EnvConfig, FilterCriteria, filter_molecules
from repro.core.distributed import greedy_optimize


def run(scale: str = "quick") -> None:
    from benchmarks import bench_models
    if not hasattr(bench_models.run, "artifacts"):
        bench_models.run(scale)
    art = bench_models.run.artifacts
    service, rcfg, env = art["service"], art["rcfg"], art["env"]
    mols = art["mols"] + art["test"]

    recs = [r for r in greedy_optimize(art["gen_agent"], mols, service, rcfg,
                                       env, seed=21) if r.done]

    # Fig 5-left: BDE down, IP up vs initial
    init_bde = np.array([oracle_bde(m) for m in mols])
    init_ip = np.array([oracle_ip(m) for m in mols])
    out_bde = np.array([r.bde if r.bde is not None else np.nan for r in recs])
    out_ip = np.array([r.ip if r.ip is not None else np.nan for r in recs])
    emit("fig5.init_bde_mean", round(float(np.nanmean(init_bde)), 2), "kcal/mol")
    emit("fig5.opt_bde_mean", round(float(np.nanmean(out_bde)), 2), "kcal/mol",
         "lower is better (<76 target)")
    emit("fig5.init_ip_mean", round(float(np.nanmean(init_ip)), 2), "kcal/mol")
    emit("fig5.opt_ip_mean", round(float(np.nanmean(out_ip)), 2), "kcal/mol",
         "higher is better (>145 target)")

    # Fig 5-right: similarity + SA distributions
    sims = [tanimoto(r.molecule, m) for r, m in zip(recs, mols)]
    sas = [sa_score(r.molecule) for r in recs]
    emit("fig5.mean_similarity", round(float(np.mean(sims)), 3), "tanimoto",
         "paper Table 5 similarities are 0.12-0.19")
    emit("fig5.mean_sa", round(float(np.mean(sas)), 2), "score",
         "paper: 2.4-2.9")

    # filter script
    res = filter_molecules([(r.molecule, r.bde, r.ip) for r in recs],
                           known=mols, criteria=FilterCriteria())
    passed = [r for r in res if r.passed]
    emit("filter.pass_rate", round(len(passed) / max(len(res), 1), 3), "frac")

    # Table 5: ML vs 'DFT' (oracle) on survivors (or best-effort set)
    finite = [r for r in res if np.isfinite(r.bde) and np.isfinite(r.ip)]
    pool = passed if passed else finite[: min(7, len(finite))]
    bde_err, ip_err, quad_ok = [], [], 0
    for r in pool:
        dft_b, dft_i = oracle_bde(r.molecule), oracle_ip(r.molecule)
        if dft_b is None:
            continue
        bde_err.append(abs(r.bde - dft_b))
        ip_err.append(abs(r.ip - dft_i))
        # Fig 7: classification agreement (performance: bde<76; stability: ip>145)
        if ((r.bde < 76) == (dft_b < 76)) and ((r.ip > 145) == (dft_i > 145)):
            quad_ok += 1
    if bde_err:
        emit("table5.bde_mae_vs_dft", round(float(np.mean(bde_err)), 2), "kcal/mol",
             "paper Table 5 |ML-DFT| is 2-8 kcal/mol")
        emit("table5.ip_mae_vs_dft", round(float(np.mean(ip_err)), 2), "kcal/mol")
        emit("fig7.classification_agreement",
             f"{quad_ok}/{len(bde_err)}", "molecules", "paper: 5/7")
