"""Kernel-level bench: fused_qnet vs the unfused XLA path (the per-step Q
evaluation over all candidates — the paper's §3.6 hot loop), plus
interpret-mode correctness spot checks for all three kernels.

Wall-clock on CPU measures the XLA path only (the Pallas kernels run in
interpret mode here — Python emulation, not a performance path);
the kernel's VMEM-resident benefit is a roofline argument recorded in
EXPERIMENTS.md §Perf."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.agent import QNetwork


def run(scale: str = "quick") -> None:
    net = QNetwork()
    params = net.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n = 1024  # ~ 8 molecules x ~128 candidates
    x = jnp.asarray((rng.random((n, 2049)) > 0.8).astype(np.float32))

    apply_fn = jax.jit(net.apply)
    apply_fn(params, x).block_until_ready()
    reps = 20 if scale == "quick" else 100
    t0 = time.perf_counter()
    for _ in range(reps):
        apply_fn(params, x).block_until_ready()
    xla = (time.perf_counter() - t0) / reps
    emit("qnet.xla_path", round(xla * 1e6), "us_per_batch", f"{n} candidates")

    # roofline napkin math for the fused kernel on TPU v5e
    pbytes = sum(l["w"].size + l["b"].size for l in params["layers"]) * 4
    flops = 2 * n * sum(l["w"].size for l in params["layers"])
    t_unfused = 5 * pbytes / 819e9 + flops / 197e12   # 5 weight reads (per-layer)
    t_fused = pbytes / 819e9 + flops / 197e12          # 1 weight read
    emit("qnet.v5e_unfused_roofline", round(t_unfused * 1e6, 1), "us_per_batch")
    emit("qnet.v5e_fused_roofline", round(t_fused * 1e6, 1), "us_per_batch",
         f"kernel keeps {pbytes/2**20:.1f} MiB of weights VMEM-resident")

    # correctness spot checks (interpret mode)
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.fused_qnet.ops import fused_qnet
    from repro.kernels.fused_qnet.ref import qnet_ref
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_ref

    q = jnp.asarray(rng.standard_normal((1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    err_fa = float(jnp.abs(
        flash_attention(q, k, v, causal=True)
        - attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    ).max())
    emit("kernel.flash_attention_max_err", f"{err_fa:.2e}", "abs")

    xs = jnp.asarray(rng.standard_normal((1, 256, 2, 32)) * 0.5, jnp.float32)
    dts = jnp.asarray(np.abs(rng.standard_normal((1, 256, 2))) * 0.1 + 0.01, jnp.float32)
    As = jnp.asarray(np.abs(rng.standard_normal(2)) + 0.5, jnp.float32)
    Bs = jnp.asarray(rng.standard_normal((1, 256, 1, 16)) * 0.3, jnp.float32)
    Cs = jnp.asarray(rng.standard_normal((1, 256, 1, 16)) * 0.3, jnp.float32)
    yk, _ = ssd_scan(xs, dts, As, Bs, Cs, chunk=64)
    yr, _ = ssd_ref(xs, dts, As, Bs, Cs)
    emit("kernel.ssd_scan_max_err", f"{float(jnp.abs(yk - yr).max()):.2e}", "abs")

    qk = fused_qnet(params, x[:256])
    qr = qnet_ref(x[:256], [(l["w"], l["b"]) for l in params["layers"]])
    emit("kernel.fused_qnet_max_err", f"{float(jnp.abs(qk - qr).max()):.2e}", "abs")
