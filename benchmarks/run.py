"""Benchmark runner: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                 # everything, quick
    PYTHONPATH=src python -m benchmarks.run --only env,cache
    PYTHONPATH=src python -m benchmarks.run --scale full
    PYTHONPATH=src python -m benchmarks.run --bench-json BENCH_PR7.json
    PYTHONPATH=src python -m benchmarks.run --trajectory    # diff the series

Prints ``name,value,unit[,derived]`` CSV; writes experiments/bench/results.json.

``--bench-json PATH`` instead runs the training-free smoke benches plus the
W=512 measured acting-bytes cell and writes a standing perf-trajectory
snapshot (steps/s, updates/s, acting H2D bytes/step, chem cache hit rate,
recompiles-after-warmup) to PATH — the committed ``BENCH_*.json`` series
that lets successive PRs be compared on one box.
"""

from __future__ import annotations

import argparse
import os
import time
import traceback

from benchmarks.common import RESULTS, emit, save_results

BENCHES = ("env", "fingerprint", "cache", "rollout", "train", "models",
           "properties", "qed_plogp", "sync_modes", "kernels", "roofline")


def bench_json(path: str) -> None:
    """Write the perf-trajectory snapshot (see module docstring): smoke
    benches only — training-free, minutes not hours — plus the measured
    W=512 dense-vs-packed acting H2D cell, the W=8 fault-injection gate
    (training under a seeded FaultPlan bit-identical to fault-free, zero
    recompiles with retries active), the W=512 multi-start end-to-end
    training cell (dataset streaming + prioritized replay), and the W=8
    serving cell (request throughput/latency + the serve determinism
    gates, written as the snapshot's ``serve`` section).  Finishes by
    printing the per-metric delta table of the whole committed
    BENCH_*.json series, this snapshot included."""
    import json
    import platform

    import jax

    from benchmarks import bench_env, bench_rollout, bench_serve, bench_train

    bench_rollout.smoke(16)
    bench_train.smoke(8)
    bench_env.smoke(16)
    fs = bench_train.fault_smoke(8)
    h2d = bench_rollout.measure_acting_h2d(512)
    ms = bench_train.multistart(512)
    sv = bench_serve.serve_cell(8)

    def val(key):
        return RESULTS[key]["value"] if key in RESULTS else None

    snapshot = {
        "schema": "bench-snapshot-v1",
        "host": {"platform": platform.platform(),
                 "backend": jax.default_backend(),
                 "devices": jax.device_count()},
        "summary": {
            "rollout_steps_per_s_w16_pipelined_packed":
                val("rollout.smoke.w16.steps_per_s"),
            "rollout_steps_per_s_w16_mixed_scenarios":
                val("rollout.smoke.w16.mixed.steps_per_s"),
            "mixed_scenario_overhead_frac_w16":
                val("rollout.smoke.w16.mixed_overhead_frac"),
            "learner_updates_per_s_w8_packed_pipelined":
                val("train.smoke.w8.updates_per_s"),
            "acting_h2d_bytes_per_step_w512_dense":
                int(h2d["dense_bytes_per_step"]),
            "acting_h2d_bytes_per_step_w512_packed":
                int(h2d["packed_bytes_per_step"]),
            "acting_h2d_reduction_w512": round(h2d["reduction"], 1),
            "learner_h2d_reduction_w8": val("train.smoke.w8.h2d_reduction"),
            "chem_cache_hit_rate_w16": val("env.smoke.w16.cache_hit_rate"),
            "multistart_steps_per_s_w512": round(ms["steps_per_s"], 2),
            "multistart_episode_wall_s_w512": round(ms["episode_wall_s"], 2),
            "multistart_unique_starts_w512": int(ms["unique_starts"]),
            "prioritized_recompiles_after_warmup":
                val("train.smoke.w8.prioritized_recompiles_after_warmup"),
            "fault_smoke_n_faults_injected_w8": int(fs["n_faults_injected"]),
            "fault_smoke_n_retries_w8": int(fs["n_retries"]),
            "fault_smoke_bit_identical_w8": int(fs["bit_identical"]),
            "serve_requests_per_s_w8": sv["requests_per_s"],
            "serve_p99_latency_ms_w8": sv["p99_latency_ms"],
            "serve_deterministic_w8": int(sv["deterministic"]),
            "recompiles_after_warmup": max(
                int(v["value"]) for k, v in RESULTS.items()
                if k.endswith("recompiles_after_warmup")),
        },
        "metrics": dict(sorted(RESULTS.items())),
        "serve": sv,
    }
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2, default=str)
        f.write("\n")
    print(f"\n[bench-json] wrote {path}")
    print_trajectory(os.path.dirname(os.path.abspath(path)) or ".")


def print_trajectory(root: str = ".") -> None:
    """Load the committed BENCH_*.json series and print the per-metric
    delta table between consecutive snapshots (the diffable perf
    trajectory).  Fails loudly — malformed snapshots raise, an empty
    series exits nonzero."""
    from benchmarks.common import diff_bench_trajectory, load_bench_trajectory

    snaps = load_bench_trajectory(root)
    if not snaps:
        raise SystemExit(
            f"no BENCH_*.json snapshots under {root!r} — run "
            f"`benchmarks/run.py --bench-json BENCH_PR<n>.json` first")
    names = ", ".join(s["name"] for s in snaps)
    print(f"\n[trajectory] {len(snaps)} snapshot(s): {names}")
    rows = diff_bench_trajectory(snaps)
    if not rows:
        print("[trajectory] single snapshot — nothing to diff yet")
        return
    width = max(len(r["metric"]) for r in rows)
    last_pair = None
    for r in rows:
        pair = (r["from"], r["to"])
        if pair != last_pair:
            print(f"\n  {pair[0]} -> {pair[1]}")
            last_pair = pair
        if r["delta_pct"] is None:
            change = "new" if r["old"] is None else \
                ("dropped" if r["new"] is None else "--")
        else:
            change = f"{r['delta_pct']:+8.1f}%"
        print(f"    {r['metric']:<{width}}  {r['old']!s:>12} -> "
              f"{r['new']!s:>12}  {change}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of bench names")
    ap.add_argument("--scale", choices=("quick", "full"), default="quick")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="write the perf-trajectory snapshot to PATH and exit "
                         "(smoke benches + measured W=512 acting bytes + the "
                         "W=512 multi-start training cell)")
    ap.add_argument("--trajectory", action="store_true",
                    help="print the committed BENCH_*.json series as a "
                         "per-metric delta table and exit (no benches run)")
    args = ap.parse_args()

    if args.trajectory:
        print_trajectory(".")
        return
    if args.bench_json:
        bench_json(args.bench_json)
        return

    names = args.only.split(",") if args.only else list(BENCHES)
    t0 = time.time()
    failures = []
    for name in names:
        print(f"\n# --- bench: {name} ({args.scale}) ---", flush=True)
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            mod.run(args.scale)
        except Exception as e:  # noqa: BLE001 -- report, continue
            traceback.print_exc()
            failures.append(name)
            emit(f"{name}.FAILED", str(e)[:120], "error")
    emit("bench.total_wall", round(time.time() - t0, 1), "s")
    save_results()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
