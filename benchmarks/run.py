"""Benchmark runner: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                 # everything, quick
    PYTHONPATH=src python -m benchmarks.run --only env,cache
    PYTHONPATH=src python -m benchmarks.run --scale full

Prints ``name,value,unit[,derived]`` CSV; writes experiments/bench/results.json.
"""

from __future__ import annotations

import argparse
import time
import traceback

from benchmarks.common import RESULTS, emit, save_results

BENCHES = ("env", "fingerprint", "cache", "rollout", "train", "models",
           "properties", "qed_plogp", "sync_modes", "kernels", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of bench names")
    ap.add_argument("--scale", choices=("quick", "full"), default="quick")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(BENCHES)
    t0 = time.time()
    failures = []
    for name in names:
        print(f"\n# --- bench: {name} ({args.scale}) ---", flush=True)
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            mod.run(args.scale)
        except Exception as e:  # noqa: BLE001 -- report, continue
            traceback.print_exc()
            failures.append(name)
            emit(f"{name}.FAILED", str(e)[:120], "error")
    emit("bench.total_wall", round(time.time() - t0, 1), "s")
    save_results()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
