"""Serving benchmark: throughput + robustness gates for MoleculeOptService.

Measures an open-loop seeded request stream against the continuously-
batched router (requests/s, p50/p99 wall latency, terminal-status mix)
and pins the serve determinism contract:

* TERMINAL — 100% of submitted requests reach a terminal status under an
  active FaultPlan (predict crashes tripping the breaker, chem crashes
  quarantining slots, transient request-site bind faults): none lost,
  none hung.
* DETERMINISTIC — rerunning the identical seeded stream reproduces every
  request's (status, steps, degraded_steps, latency, best-reward BYTES).
* FAULT-FREE BIT-EQUALITY — every request the faults never touched
  (completed, zero degraded steps) returns a result bit-identical to the
  unfaulted run's: injected failures are invisible outside their blast
  radius.
* 0 RECOMPILES — after warmup (+ capacity-ladder headroom), a churning
  request mix of mixed budgets/deadlines/molecules holds ZERO XLA
  recompiles: continuous batching reuses one compiled dispatch shape.

    PYTHONPATH=src python benchmarks/bench_serve.py --smoke   # CI gates, W=8
    PYTHONPATH=src python benchmarks/bench_serve.py           # bigger cell
"""

from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/bench_serve.py --smoke`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import emit, save_results


# ------------------------------------------------------------------ #
def _build_service(n_slots: int, *, faulted: bool, seed: int = 0,
                   max_queue: int = 64, shed_policy: str = "reject_new",
                   fault_seed: int = 7, heavy: bool = True,
                   breaker_cooldown: int = 8):
    import jax

    from repro.core.agent import QNetwork
    from repro.core.faults import FaultPlan, FaultRule
    from repro.predictors.service import (OracleService, ResilientService,
                                          RetryPolicy)
    from repro.serving import MoleculeOptService, ServeConfig

    # heavy: long predict-crash bursts keep the breaker open for most of
    # the run (the equivalence cell's worst case); mild: short bursts so
    # the measured cell cycles trip -> degraded -> half-open -> recovery
    # and serves a realistic completed/degraded mix
    predict_rule = FaultRule(site="predict", kind="crash", every=6,
                             fail_attempts=30) if heavy else \
        FaultRule(site="predict", kind="crash", every=9, fail_attempts=4)
    plan = FaultPlan([
        predict_rule,
        FaultRule(site="chem", kind="crash", rate=0.02),
        FaultRule(site="request", kind="transient", rate=0.1,
                  fail_attempts=1),
    ], seed=fault_seed) if faulted else None
    net = QNetwork(hidden=(64,))
    params = net.init(jax.random.PRNGKey(0))
    prop = ResilientService(OracleService(), RetryPolicy(max_retries=1),
                            fault_plan=plan, sleep=None)
    svc = MoleculeOptService(
        net, params, prop, fault_plan=plan,
        cfg=ServeConfig(n_slots=n_slots, max_queue=max_queue,
                        shed_policy=shed_policy, epsilon=0.05, seed=seed,
                        breaker_cooldown=breaker_cooldown))
    return svc


def _signature(svc) -> list[tuple]:
    """Bit-level per-request outcome fingerprint (sorted by request id)."""
    return [(r.request_id, r.status, r.steps_used, r.degraded_steps,
             r.latency, r.best_smiles,
             None if r.best_reward is None
             else np.float64(r.best_reward).tobytes())
            for r in sorted(svc.results, key=lambda r: r.request_id)]


def _run_stream(n_slots: int, stream_cfg, *, faulted: bool, **svc_kw):
    from repro.serving import drive_open_loop, seeded_request_stream

    svc = _build_service(n_slots, faulted=faulted, **svc_kw)
    drive_open_loop(svc, seeded_request_stream(stream_cfg))
    return svc


# ------------------------------------------------------------------ #
def equivalence_cell(W: int, n_requests: int) -> dict:
    """Faulted run twice (determinism) + unfaulted twin (bit-equality of
    fault-free requests).  No deadlines, ample queue: every difference
    between the runs is then attributable to the injected faults alone."""
    from repro.serving import StreamConfig

    scfg = StreamConfig(n_requests=n_requests, rate=2.0, seed=3,
                        invalid_every=9)
    f1 = _run_stream(W, scfg, faulted=True)
    f2 = _run_stream(W, scfg, faulted=True)
    u = _run_stream(W, scfg, faulted=False)

    all_terminal = (len(f1.results) == n_requests
                    and len(u.results) == n_requests)
    deterministic = _signature(f1) == _signature(f2)
    fault_free = [r for r in f1.results
                  if r.status == "completed" and r.degraded_steps == 0]
    bit_identical = bool(fault_free)
    for r in fault_free:
        ur = u.result_by_id[r.request_id]
        if not (ur.status == "completed"
                and ur.steps_used == r.steps_used
                and ur.best_smiles == r.best_smiles
                and np.float64(ur.best_reward).tobytes()
                == np.float64(r.best_reward).tobytes()):
            bit_identical = False
    counts = f1.stats()["status_counts"]
    return {
        "all_terminal": all_terminal,
        "deterministic": deterministic,
        "fault_free_bit_identical": bit_identical,
        "n_fault_free": len(fault_free),
        "n_degraded": counts["degraded"],
        "n_failed": counts["failed"],
        "breaker_trips": f1.breaker.stats()["n_trips"],
        "breaker_recoveries": f1.breaker.stats()["n_recoveries"],
    }


def overload_cell(W: int, n_requests: int) -> dict:
    """Backpressure under a hot stream: tight queue + deadlines + poisoned
    requests, faults active.  Sheds and deadline misses MUST happen, and
    their counts must reproduce exactly on a rerun (virtual-clock
    admission is deterministic)."""
    from repro.serving import StreamConfig

    scfg = StreamConfig(n_requests=n_requests, rate=6.0, seed=11,
                        deadline_frac=0.4, invalid_every=7)
    o1 = _run_stream(W, scfg, faulted=True, max_queue=6)
    o2 = _run_stream(W, scfg, faulted=True, max_queue=6)
    c = o1.stats()["status_counts"]
    return {
        "all_terminal": len(o1.results) == n_requests,
        "deterministic": _signature(o1) == _signature(o2),
        "shed": c["shed"],
        "deadline_exceeded": c["deadline_exceeded"],
        "queue_high_water": o1.queue.depth_high_water,
    }


def throughput_cell(W: int, n_requests: int) -> dict:
    """Measured serving cell: warmup stream -> capacity-ladder headroom ->
    recompile mark -> the measured churning stream (mixed budgets,
    deadlines, invalids, faults active).  Reports requests/s, p50/p99
    wall latency, terminal-status mix, and recompiles after warmup."""
    from repro.core.jit_stats import RecompileCounter
    from repro.serving import (StreamConfig, drive_open_loop, latency_stats,
                               seeded_request_stream)

    counter = RecompileCounter.install()
    svc = _build_service(W, faulted=True, max_queue=32, heavy=False,
                         breaker_cooldown=3)
    warm = seeded_request_stream(StreamConfig(
        n_requests=2 * W, rate=4.0, seed=5, prefix="warm"))
    drive_open_loop(svc, warm)
    svc.reserve_candidates(int(svc._policy._cap * 1.3))
    mark = counter.count

    arrivals = seeded_request_stream(StreamConfig(
        n_requests=n_requests, rate=3.0, seed=17, deadline_frac=0.25,
        deadline_lo=2.0, deadline_hi=8.0, invalid_every=10))
    t0 = time.perf_counter()
    drive_open_loop(svc, arrivals)
    wall = time.perf_counter() - t0

    measured = [r for r in svc.results
                if r.request_id.startswith("req-")]
    lat = latency_stats(measured)
    c = {s: 0 for s in ("completed", "degraded", "deadline_exceeded",
                        "shed", "failed")}
    for r in measured:
        c[r.status] += 1
    return {
        "requests": n_requests,
        "all_terminal": len(measured) == n_requests,
        "wall_s": wall,
        "requests_per_s": n_requests / wall,
        "p50_latency_ms": lat["p50_wall_ms"],
        "p99_latency_ms": lat["p99_wall_ms"],
        "recompiles_after_warmup": counter.delta_since(mark),
        "service_steps": svc.n_service_steps,
        "q_dispatches": svc._policy.n_dispatches,
        **c,
    }


# ------------------------------------------------------------------ #
def serve_cell(W: int = 8, n_requests: int = 64) -> dict:
    """The BENCH_*.json serve block: every gate + the measured numbers."""
    eq = equivalence_cell(W, 4 * W)
    ov = overload_cell(W, 6 * W)
    th = throughput_cell(W, n_requests)
    cell = {
        "slots": W,
        "requests": th["requests"],
        "requests_per_s": round(th["requests_per_s"], 2),
        "p50_latency_ms": round(th["p50_latency_ms"], 2),
        "p99_latency_ms": round(th["p99_latency_ms"], 2),
        "completed": th["completed"],
        "degraded": th["degraded"],
        "shed": ov["shed"],
        "deadline_exceeded": th["deadline_exceeded"],
        "failed": th["failed"],
        "recompiles_after_warmup": int(th["recompiles_after_warmup"]),
        "all_terminal": int(th["all_terminal"] and eq["all_terminal"]
                            and ov["all_terminal"]),
        "deterministic": int(eq["deterministic"] and ov["deterministic"]),
        "fault_free_bit_identical": int(eq["fault_free_bit_identical"]),
        "breaker_trips": eq["breaker_trips"],
    }
    for k, v in sorted(cell.items()):
        emit(f"serve.smoke.w{W}.{k}", v, "" if isinstance(v, int) else "x")
    return cell


def smoke(W: int = 8) -> None:
    """The serve-smoke CI job: run every cell, fail loudly on any gate."""
    cell = serve_cell(W)
    failures = []
    if not cell["all_terminal"]:
        failures.append("a submitted request never reached a terminal status")
    if not cell["deterministic"]:
        failures.append("statuses/results not deterministic across reruns")
    if not cell["fault_free_bit_identical"]:
        failures.append("fault-free requests differ from the unfaulted run")
    if cell["recompiles_after_warmup"] != 0:
        failures.append(f"{cell['recompiles_after_warmup']} recompiles after "
                        f"warmup (want 0)")
    if cell["shed"] == 0:
        failures.append("overload cell shed nothing — backpressure untested")
    if cell["breaker_trips"] == 0:
        failures.append("breaker never tripped — degraded path untested")
    if failures:
        raise SystemExit("serve smoke FAILED:\n  " + "\n  ".join(failures))
    print(f"\n[serve-smoke] OK: {cell['requests']} requests at "
          f"{cell['requests_per_s']:.1f}/s, p50/p99 "
          f"{cell['p50_latency_ms']:.0f}/{cell['p99_latency_ms']:.0f} ms, "
          f"0 recompiles, deterministic, fault-free bit-identical")


def run(scale: str = "quick") -> None:
    W, n = (8, 64) if scale == "quick" else (16, 160)
    serve_cell(W, n)
    save_results()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gates at W=8 (exit nonzero on any failure)")
    ap.add_argument("--scale", choices=("quick", "full"), default="quick")
    args = ap.parse_args()
    if args.smoke:
        smoke(8)
    else:
        run(args.scale)


if __name__ == "__main__":
    main()
