"""End-to-end client of the molecule-optimization service.

Builds a ``MoleculeOptService`` (the continuously-batched request router
of docs/serving.md), submits a small mixed request batch — different
start molecules, objectives, budgets, one deadline-bound request, one
INVALID SMILES — and prints each request's terminal status and latency.
Every request gets exactly one structured answer; the poisoned one fails
at the door without disturbing its co-batched neighbours.

    PYTHONPATH=src python examples/serve_predictor.py            # oracle stub
    PYTHONPATH=src python examples/serve_predictor.py --trained  # real predictors
"""

import argparse
import time

import jax

from repro.core.agent import QNetwork
from repro.predictors.service import OracleService
from repro.serving import MoleculeOptService, OptimizeRequest, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--trained", action="store_true",
                    help="serve through the trained BDE+IP predictors "
                         "(trains them on first run) instead of the oracle stub")
    args = ap.parse_args()

    net = QNetwork()
    params = net.init(jax.random.PRNGKey(0))
    if args.trained:
        from repro.predictors import PropertyService
        from repro.predictors.training import ensure_trained
        bm, bp, im, ip_, metrics = ensure_trained()
        properties = PropertyService(bm, bp, im, ip_)
        print(f"predictor accuracy: BDE {metrics['bde']['rel_err_mean']:.2%}, "
              f"IP {metrics['ip']['rel_err_mean']:.2%} (paper: <5%)")
    else:
        properties = OracleService()
    svc = MoleculeOptService(
        net, params, properties,
        cfg=ServeConfig(n_slots=args.slots, max_queue=16, epsilon=0.05))

    requests = [
        OptimizeRequest("phenol", "C1=CC=CC=C1O", budget=8, seed=1),
        OptimizeRequest("catechol", "OC1=CC=CC=C1O", budget=8, seed=2),
        OptimizeRequest("cresol-bde", "CC1=CC=C(O)C=C1",
                        objective="antioxidant_bde", budget=6, seed=3),
        OptimizeRequest("anisole-ip", "COC1=CC=CC=C1O",
                        objective="antioxidant_ip", budget=6, seed=4),
        # a non-antioxidant scenario: any registry name is requestable
        # (configs/scenarios.py — the same table the trainer mixes)
        OptimizeRequest("druglike", "CC(=O)NC1=CC=C(O)C=C1",
                        objective="qed", budget=6, seed=6),
        OptimizeRequest("hurried", "CC(C)C1=CC=CC=C1O", budget=10,
                        deadline=9.0, seed=5),
        OptimizeRequest("poisoned", "this is not a molecule", budget=8),
    ]

    t0 = time.perf_counter()
    for req in requests:
        verdict = svc.submit(req)
        print(f"submit {req.request_id:12s} -> {verdict}")
    svc.run_until_idle()
    wall = time.perf_counter() - t0

    print(f"\n{'request':12s} {'status':18s} {'steps':>5s} {'lat':>5s} "
          f"{'wall_ms':>8s}  best")
    for r in svc.results:
        best = "-" if r.best_reward is None else \
            f"{r.best_reward:+.4f}  {r.best_smiles}"
        err = f"  [{r.error[:44]}]" if r.error else ""
        print(f"{r.request_id:12s} {r.status:18s} {r.steps_used:5d} "
              f"{r.latency:5.1f} {r.wall_latency_s * 1e3:8.1f}  {best}{err}")

    st = svc.stats()
    print(f"\n{len(requests)} requests in {wall:.2f}s | statuses "
          f"{st['status_counts']} | {st['n_service_steps']} service steps, "
          f"{st['n_q_dispatches']} Q dispatches, breaker "
          f"{st['breaker']['state']}")


if __name__ == "__main__":
    main()
