"""Serve the property predictors as a batched scoring service.

The inference-side counterpart of the paper's predictor integration: a
request loop that accepts SMILES batches, featurizes, runs the jit'd
Alfabet-S/AIMNet-S models (with the §3.6 LRU cache), and reports
throughput + cache statistics.

    PYTHONPATH=src python examples/serve_predictor.py --requests 20 --batch 16
"""

import argparse
import time

import numpy as np

from repro.chem.smiles import canonical_smiles, from_smiles
from repro.data.datasets import antioxidant_dataset, public_antioxidant_dataset
from repro.predictors import PropertyService
from repro.predictors.training import ensure_trained


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    bm, bp, im, ip_, metrics = ensure_trained()
    service = PropertyService(bm, bp, im, ip_)
    print(f"predictor accuracy: BDE {metrics['bde']['rel_err_mean']:.2%}, "
          f"IP {metrics['ip']['rel_err_mean']:.2%} (paper: <5%)")

    pool = antioxidant_dataset(256) + public_antioxidant_dataset(128)
    rng = np.random.default_rng(0)

    t0 = time.time()
    n = 0
    for req in range(args.requests):
        idx = rng.integers(0, len(pool), size=args.batch)
        mols = [pool[i] for i in idx]
        props = service.predict(mols)
        n += len(mols)
        if req < 3:
            for m, p in list(zip(mols, props))[:2]:
                print(f"  req{req}: {canonical_smiles(m):40s} "
                      f"BDE {p.bde:6.1f}  IP {p.ip and round(p.ip, 1)}")
    dt = time.time() - t0
    print(f"\n{n} molecules in {dt:.2f}s = {n/dt:.0f} mol/s "
          f"(cache hit rate {service.cache.hit_rate:.2f}, "
          f"{service.n_predictor_mols} cold predictions)")


if __name__ == "__main__":
    main()
