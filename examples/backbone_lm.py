"""Train a model-zoo backbone as a SMILES language model (reduced, CPU).

Demonstrates the swappable-learner substrate of DESIGN.md §3: the same
train_step the multi-pod dry-run lowers for the full architectures runs
here on a reduced config over the antioxidant SMILES corpus — loss should
drop from ~ln(vocab) toward the corpus entropy within ~100 steps.

    PYTHONPATH=src python examples/backbone_lm.py --arch mamba2-2.7b --steps 100
"""

import argparse
import time

import jax
import numpy as np

from repro.chem.smiles import canonical_smiles
from repro.configs import get_config
from repro.data.datasets import antioxidant_dataset
from repro.data.pipeline import lm_batches_from_smiles
from repro.data.tokenizer import SmilesTokenizer
from repro.launch.steps import make_train_step
from repro.models import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    tok = SmilesTokenizer()
    smiles = [canonical_smiles(m) for m in antioxidant_dataset(256)]
    batches = lm_batches_from_smiles(smiles, tok, args.batch, args.seq)

    params = init_params(cfg, jax.random.PRNGKey(0))
    step, opt = make_train_step(cfg)
    opt_state = opt.init(params)
    jstep = jax.jit(step)
    rng = np.random.default_rng(0)

    first = None
    t0 = time.time()
    for i, batch in zip(range(args.steps), batches):
        if cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (args.batch, cfg.encdec.n_frames, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            batch["patches"] = rng.standard_normal(
                (args.batch, cfg.vlm.n_patches, cfg.vlm.vision_dim)).astype(np.float32)
        params, opt_state, loss = jstep(params, opt_state, batch)
        first = first if first is not None else float(loss)
        if (i + 1) % 20 == 0:
            print(f"[{args.arch} step {i+1:4d}] loss {float(loss):.4f}")
    print(f"loss {first:.3f} -> {float(loss):.3f} in {args.steps} steps "
          f"({time.time()-t0:.0f}s)")
    assert float(loss) < first, "LM loss must decrease"


if __name__ == "__main__":
    main()
