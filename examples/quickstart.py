"""Quickstart: optimize ONE antioxidant with a freshly-trained tiny agent.

    PYTHONPATH=src python examples/quickstart.py

Walks the whole public API in ~2 minutes on CPU: dataset -> predictors ->
environment -> DQN training -> greedy optimization -> filter script.
"""

import numpy as np

from repro.chem.smiles import canonical_smiles
from repro.core import (
    DQNConfig, EnvConfig, FilterCriteria, RewardConfig, TrainerConfig,
    filter_molecules,
)
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer, greedy_optimize
from repro.data.datasets import antioxidant_dataset, dataset_property_table
from repro.predictors import PropertyService
from repro.predictors.training import ensure_trained


def main() -> None:
    # 1. predictors (Alfabet-S / AIMNet-S), trained against the oracle once
    bde_model, bde_params, ip_model, ip_params, metrics = ensure_trained()
    print(f"predictors ready: BDE rel err {metrics['bde']['rel_err_mean']:.2%}, "
          f"IP rel err {metrics['ip']['rel_err_mean']:.2%}")
    service = PropertyService(bde_model, bde_params, ip_model, ip_params)

    # 2. data + reward normalisation bounds (§3.4)
    mols = antioxidant_dataset(32, seed=9)
    props = dataset_property_table(mols)
    rcfg = RewardConfig.from_dataset(props["bde"], props["ip"])
    print(f"dataset: {len(mols)} antioxidants, "
          f"BDE [{rcfg.bde_min:.0f}, {rcfg.bde_max:.0f}] kcal/mol")

    # 3. train a small general model on 4 molecules (2 workers x 2)
    cfg = TrainerConfig(
        n_workers=2, mols_per_worker=2, episodes=15, sync_mode="episode",
        train_batch_size=16, max_candidates=32, updates_per_episode=3,
        dqn=DQNConfig(epsilon_decay=0.85), env=EnvConfig(max_steps=4))
    trainer = DistributedTrainer(cfg, mols[:4], service, rcfg,
                                 network=QNetwork(hidden=(256, 64)))
    for st in trainer.train(log_every=5):
        pass
    # acting is fleet-batched: ONE Q dispatch + ONE property batch per step
    # across all workers (rollout="per_worker" restores the sequential path)
    print(f"acting: {trainer.n_q_dispatches} Q dispatches for "
          f"{trainer.engine.n_env_steps} fleet steps, "
          f"{service.n_predict_calls} property batches")

    # 4. greedy optimization with the general model
    agent = trainer.as_agent(epsilon=0.0)
    recs = greedy_optimize(agent, mols[:4], service, rcfg, cfg.env)
    for r in recs:
        print(f"  {canonical_smiles(r.molecule):40s} reward {r.reward:7.3f} "
              f"BDE {r.bde and round(r.bde,1)} IP {r.ip and round(r.ip,1)}")

    # 5. filter script (§3.5)
    results = filter_molecules(
        [(r.molecule, r.bde, r.ip) for r in recs], known=mols,
        criteria=FilterCriteria())
    kept = [r for r in results if r.passed]
    print(f"filter: {len(kept)}/{len(results)} pass BDE<76 & IP>145 & SA<=3.5")


if __name__ == "__main__":
    main()
