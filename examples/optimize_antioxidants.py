"""End-to-end driver: the paper's §4 experiment at CPU scale.

Trains the DA-MolDQN GENERAL model on a set of antioxidants for a few
hundred episodes (default 60 — raise --episodes for a longer run), then:
  * optimizes the training molecules (Fig. 2),
  * optimizes UNSEEN test molecules (Fig. 4),
  * fine-tunes on the worst test molecule (§3.5) and reports the delta,
  * runs the filter script and prints surviving candidates with
    oracle ("DFT") validation of the predicted properties (Table 5).

    PYTHONPATH=src python examples/optimize_antioxidants.py \
        --episodes 60 --workers 4 --mols-per-worker 4
"""

import argparse
import time

import numpy as np

from repro.chem.smiles import canonical_smiles
from repro.core import (DQNConfig, EnvConfig, FilterCriteria, RewardConfig,
                        TrainerConfig, filter_molecules)
from repro.core.agent import QNetwork
from repro.core.distributed import (DistributedTrainer, greedy_optimize,
                                    optimization_failure_rate)
from repro.core.finetune import fine_tune
from repro.chem.oracle import oracle_bde, oracle_ip
from repro.data.datasets import (antioxidant_dataset, dataset_property_table,
                                 train_test_split)
from repro.predictors import PropertyService
from repro.predictors.training import ensure_trained


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=60)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--mols-per-worker", type=int, default=4)
    ap.add_argument("--max-steps", type=int, default=5)
    ap.add_argument("--n-test", type=int, default=8)
    args = ap.parse_args()

    bm, bp, im, ipar, _ = ensure_trained()
    service = PropertyService(bm, bp, im, ipar)
    ds = antioxidant_dataset(600)
    train, test = train_test_split(ds)
    props = dataset_property_table(train)
    rcfg = RewardConfig.from_dataset(props["bde"], props["ip"])

    n_mols = args.workers * args.mols_per_worker
    env_cfg = EnvConfig(max_steps=args.max_steps)
    cfg = TrainerConfig(
        n_workers=args.workers, mols_per_worker=args.mols_per_worker,
        episodes=args.episodes, sync_mode="episode", train_batch_size=32,
        max_candidates=48, updates_per_episode=4,
        dqn=DQNConfig(epsilon_decay=0.95), env=env_cfg)

    print(f"== training general model: {n_mols} molecules, {args.episodes} episodes ==")
    t0 = time.time()
    trainer = DistributedTrainer(cfg, train[:n_mols], service, rcfg,
                                 network=QNetwork(hidden=(512, 128, 32)))
    trainer.train(log_every=10)
    print(f"trained in {time.time()-t0:.0f}s; cache hit rate {service.cache.hit_rate:.2f}")

    agent = trainer.as_agent(epsilon=0.0)

    print("\n== Fig. 2: training molecules ==")
    recs = greedy_optimize(agent, train[:n_mols], service, rcfg, env_cfg, seed=1)
    print(f"mean reward {np.mean([r.reward for r in recs]):.3f}  "
          f"OFR {optimization_failure_rate(recs):.2f}")

    print(f"\n== Fig. 4: {args.n_test} unseen molecules ==")
    trecs = greedy_optimize(agent, test[:args.n_test], service, rcfg, env_cfg, seed=2)
    print(f"mean reward {np.mean([r.reward for r in trecs]):.3f}  "
          f"OFR {optimization_failure_rate(trecs):.2f}")

    print("\n== §3.5 fine-tuning the worst unseen molecule ==")
    worst = int(np.argmin([r.reward for r in trecs]))
    ft = fine_tune(agent, test[worst], service, rcfg, episodes=15,
                   env_cfg=env_cfg, train_batch_size=16, max_candidates=32)
    before = trecs[worst].reward
    after = greedy_optimize(ft, [test[worst]], service, rcfg, env_cfg, seed=3)[0].reward
    print(f"reward before {before:.3f} -> after fine-tune {after:.3f}")

    print("\n== filter script + oracle ('DFT') validation ==")
    results = filter_molecules([(r.molecule, r.bde, r.ip) for r in recs + trecs],
                               known=train[:n_mols] + test[:args.n_test],
                               criteria=FilterCriteria())
    for r in results:
        if r.passed:
            dft_bde = oracle_bde(r.molecule)
            dft_ip = oracle_ip(r.molecule)
            print(f"  {canonical_smiles(r.molecule):44s} "
                  f"ML bde/ip {r.bde:5.1f}/{r.ip:5.1f}  "
                  f"DFT {dft_bde:5.1f}/{dft_ip:5.1f}  SA {r.sa:.2f}")
    n_pass = sum(r.passed for r in results)
    print(f"{n_pass}/{len(results)} pass the filter")


if __name__ == "__main__":
    main()
