"""Objective layer: term composition, the scenario registry, and the
bit-identity contract between the scalar reference (``compute_reward``)
and the fleet-vectorized paths (``evaluate_rewards`` / compiled specs)."""

import math

import numpy as np
import pytest

from repro.chem.properties import penalized_logp, qed_score, sa_score, tanimoto
from repro.chem.smiles import from_smiles
from repro.configs.scenarios import (
    SCENARIOS, compile_worker_objectives, get_scenario, list_scenarios,
    register_scenario, worker_scenarios,
)
from repro.core.reward import (
    CompiledObjective, INVALID_CONFORMER_REWARD, ObjectiveSpec, RewardConfig,
    TermSpec, compute_reward, evaluate_rewards,
)
from repro.predictors.service import Properties

PHENOL = from_smiles("C1=CC=CC=C1O")
CATECHOL = from_smiles("OC1=CC=CC=C1O")
BHT = from_smiles("CC1=CC(C)=CC(C)=C1O")
CRESOL = from_smiles("CC1=CC=C(O)C=C1")

MOLS = [PHENOL, CATECHOL, BHT, CRESOL]


def _rows(n=16, seed=0, invalid_every=5):
    """Random (props, initials, currents, steps_left) rows incl. invalid
    conformers."""
    rng = np.random.default_rng(seed)
    props, initials, currents, sls = [], [], [], []
    for i in range(n):
        if invalid_every and i % invalid_every == invalid_every - 1:
            props.append(Properties(bde=float(rng.uniform(55, 95)), ip=None))
        else:
            props.append(Properties(bde=float(rng.uniform(55, 95)),
                                    ip=float(rng.uniform(95, 200))))
        initials.append(MOLS[int(rng.integers(len(MOLS)))])
        currents.append(MOLS[int(rng.integers(len(MOLS)))])
        sls.append(int(rng.integers(0, 6)))
    return props, initials, currents, sls


# ------------------------------------------------------------------ #
# evaluate_rewards == compute_reward, bit for bit
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("cfg", [
    RewardConfig(),
    RewardConfig(bde_min=60, bde_max=90, ip_min=100, ip_max=200),
    RewardConfig(bde_weight=1.0, ip_weight=0.0),
    RewardConfig(bde_weight=0.0, ip_weight=1.0, gamma_weight=0.0),
])
def test_evaluate_rewards_bit_identical_to_scalar_reference(cfg):
    props, initials, currents, sls = _rows()
    vec = evaluate_rewards(cfg, props, initials, currents, sls)
    ref = [compute_reward(cfg, bde=p.bde, ip=p.ip, initial=m0, current=m,
                          steps_left=s)
           for p, m0, m, s in zip(props, initials, currents, sls)]
    assert vec.tolist() == ref            # EXACT equality: the contract


@pytest.mark.parametrize("name", [
    "antioxidant", "antioxidant_bde", "antioxidant_ip"])
def test_compiled_eq1_scenarios_bit_identical_to_scalar_reference(name):
    # registry Eq. 1 specs defer bounds to the compile-time base config —
    # exactly how the trainer's dataset-derived RewardConfig flows in
    base = RewardConfig(bde_min=58.0, bde_max=93.0, ip_min=101.0, ip_max=188.0)
    w = {"antioxidant": (0.8, 0.2), "antioxidant_bde": (1.0, 0.0),
         "antioxidant_ip": (0.0, 1.0)}[name]
    ref_cfg = RewardConfig(bde_weight=w[0], ip_weight=w[1],
                           bde_min=58.0, bde_max=93.0,
                           ip_min=101.0, ip_max=188.0)
    obj = get_scenario(name).compile(base=base)
    props, initials, currents, sls = _rows(seed=1)
    vec = obj.evaluate(props, initials, currents, sls)
    ref = [compute_reward(ref_cfg, bde=p.bde, ip=p.ip, initial=m0, current=m,
                          steps_left=s)
           for p, m0, m, s in zip(props, initials, currents, sls)]
    assert vec.tolist() == ref
    # the one-row scalar convenience (__call__, the Slot.objective form)
    # agrees with its own vectorized path
    assert obj(props[0], initials[0], currents[0], sls[0]) == ref[0]


def test_from_reward_config_roundtrip():
    cfg = RewardConfig(bde_weight=0.7, ip_weight=0.3, gamma_weight=0.4,
                       bde_factor=0.85, ip_factor=0.75,
                       bde_min=60, bde_max=90, ip_min=100, ip_max=200)
    obj = ObjectiveSpec.from_reward_config("custom", cfg).compile()
    props, initials, currents, sls = _rows(seed=2)
    ref = [compute_reward(cfg, bde=p.bde, ip=p.ip, initial=m0, current=m,
                          steps_left=s)
           for p, m0, m, s in zip(props, initials, currents, sls)]
    assert obj.evaluate(props, initials, currents, sls).tolist() == ref


def test_invalid_conformer_guard_only_for_prop_specs():
    bad = Properties(bde=70.0, ip=None)
    eq1 = get_scenario("antioxidant").compile()
    assert eq1(bad, PHENOL, PHENOL, 0) == INVALID_CONFORMER_REWARD
    # structure-only specs never read props -> no guard, no crash
    qed = get_scenario("qed").compile()
    assert qed(bad, PHENOL, CATECHOL, 0) == qed_score(CATECHOL)


# ------------------------------------------------------------------ #
# term semantics
# ------------------------------------------------------------------ #
def test_structure_term_values():
    qed = get_scenario("qed").compile()
    plogp = get_scenario("plogp").compile()
    qed_sa = get_scenario("qed_sa").compile()
    p = Properties(bde=70.0, ip=150.0)
    for m in MOLS:
        assert qed(p, PHENOL, m, 3) == qed_score(m)
        assert plogp(p, PHENOL, m, 3) == penalized_logp(m)
        assert qed_sa(p, PHENOL, m, 0) == \
            1.0 * qed_score(m) + (-0.1) * sa_score(m)


def test_similarity_term_tethers_to_start_or_fixed_target():
    p = Properties(bde=70.0, ip=150.0)
    tether = ObjectiveSpec("t", (TermSpec("similarity", weight=1.0),)).compile()
    assert tether(p, BHT, CRESOL, 0) == tanimoto(CRESOL, BHT)
    assert tether(p, BHT, BHT, 0) == 1.0          # identical -> sim 1
    fixed = ObjectiveSpec("f", (
        TermSpec("similarity", weight=1.0, target="C1=CC=CC=C1O"),)).compile()
    assert fixed(p, BHT, CRESOL, 0) == pytest.approx(
        tanimoto(CRESOL, PHENOL))


def test_term_decay_factor():
    spec = ObjectiveSpec("d", (TermSpec("qed", weight=2.0, factor=0.5),))
    obj = spec.compile()
    p = Properties(bde=None, ip=None)   # structure-only: props unread
    assert obj(p, PHENOL, BHT, 3) == 2.0 * (qed_score(BHT) * 0.5 ** 3)


def test_novelty_counts_per_instance():
    p = Properties(bde=70.0, ip=150.0)
    spec = ObjectiveSpec("n", (TermSpec("novelty", weight=1.0),))
    a, b = spec.compile(), spec.compile()
    # 1/sqrt(visits) in visit order, scoped to the instance
    assert a(p, PHENOL, BHT, 0) == 1.0
    assert a(p, PHENOL, BHT, 0) == 1.0 / math.sqrt(2)
    assert a(p, PHENOL, CRESOL, 0) == 1.0        # new key
    assert b(p, PHENOL, BHT, 0) == 1.0           # fresh instance, fresh counts


def test_novelty_state_dict_roundtrip():
    p = Properties(bde=70.0, ip=150.0)
    spec = get_scenario("antioxidant_novel")
    a = spec.compile()
    for m in (BHT, BHT, CRESOL):
        a(p, PHENOL, m, 0)
    b = spec.compile()
    b.load_state_dict(a.state_dict())
    # restored counts continue the SAME visit sequence
    assert b(p, PHENOL, BHT, 1) == a(p, PHENOL, BHT, 1)
    # stateless specs expose (and accept) None
    s = get_scenario("qed").compile()
    assert s.state_dict() == {"novelty_counts": None}
    s.load_state_dict({"novelty_counts": None})
    with pytest.raises(ValueError, match="mismatch"):
        s.load_state_dict({"novelty_counts": {"k": 1}})


# ------------------------------------------------------------------ #
# spec validation + registry
# ------------------------------------------------------------------ #
def test_spec_validation():
    with pytest.raises(ValueError, match="unknown reward term"):
        TermSpec("bde_squared")
    with pytest.raises(ValueError, match="no terms"):
        ObjectiveSpec("empty", ())


def test_registry_resolution_and_rejects():
    assert "antioxidant" in list_scenarios()
    assert "qed" in list_scenarios() and "plogp" in list_scenarios()
    assert get_scenario("antioxidant") is SCENARIOS["antioxidant"]
    with pytest.raises(ValueError, match="registry scenarios"):
        get_scenario("make_it_sticky")
    with pytest.raises(ValueError, match="already registered"):
        register_scenario(ObjectiveSpec("qed", (TermSpec("qed"),)))


def test_worker_scenarios_cycle_and_validate():
    assert worker_scenarios(["antioxidant", "qed"], 5) == \
        ["antioxidant", "qed", "antioxidant", "qed", "antioxidant"]
    with pytest.raises(ValueError):
        worker_scenarios([], 4)
    with pytest.raises(ValueError, match="registry scenarios"):
        worker_scenarios(["antioxidant", "nope"], 4)


def test_compile_worker_objectives_fresh_instances():
    objs = compile_worker_objectives(["antioxidant_novel"], 3)
    assert len(objs) == 3
    assert len({id(o) for o in objs}) == 3       # never shared (novelty state)
    assert all(isinstance(o, CompiledObjective) for o in objs)
