"""SoA replay buffer vs the seed list-based reference: ring/eviction
semantics, seeded sample equivalence, packed-batch consistency (host densify
== jit densify), candidate truncation and storage growth, and prioritized
sampling (flat-priority bit-parity with the uniform sampler, weighted-draw
correctness, |TD| priority feedback)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # declared in pyproject [test]; degrade to a skip
    HAVE_HYPOTHESIS = False

from repro.core.packed_batch import (
    dense_nbytes_equivalent, densify_batch, packed_nbytes, unpack_bits,
)
from repro.core.replay import (
    FP_BYTES, SAMPLING_MODES, ListReplayBuffer, ReplayBuffer, Transition,
    densify_sample, pack_fp,
)

RNG = np.random.default_rng(7)


def _transition(rng, n_candidates: int, done: bool = False) -> Transition:
    fp = (rng.random(2048) > 0.7).astype(np.float32)
    nxt = (np.stack([pack_fp((rng.random(2048) > 0.5).astype(np.float32))
                     for _ in range(n_candidates)])
           if n_candidates else np.zeros((0, FP_BYTES), np.uint8))
    return Transition(pack_fp(fp), float(rng.random()),
                      float(rng.standard_normal()), done, nxt,
                      float(rng.random()))


def _fill_pair(n: int, capacity: int, seed: int = 11, max_cands: int | None = None):
    """The SoA buffer and the list reference fed the identical stream."""
    rng = np.random.default_rng(3)
    soa = ReplayBuffer(capacity, seed=seed, max_candidates=max_cands)
    ref = ListReplayBuffer(capacity, seed=seed)
    for i in range(n):
        t = _transition(rng, int(rng.integers(0, 7)), done=(i % 5 == 0))
        soa.add(t)
        ref.add(t)
    return soa, ref


# ------------------------------------------------------------------ #
# ring semantics
# ------------------------------------------------------------------ #
def test_wraparound_matches_list_eviction_order():
    """After 2.5x capacity of adds, slot i must hold exactly what the seed
    list buffer holds at _items[i] (cyclic overwrite, oldest-first)."""
    soa, ref = _fill_pair(20, capacity=8)
    assert len(soa) == len(ref) == 8
    for a, b in zip(soa._items, ref._items):
        assert a.state_fp.tobytes() == b.state_fp.tobytes()
        assert a.next_fps.tobytes() == b.next_fps.tobytes()
        assert a.done == b.done
        assert a.reward == np.float32(b.reward)          # stored as f32
        assert a.steps_left_frac == np.float32(b.steps_left_frac)


def test_partial_fill_preserves_insertion_order():
    soa, ref = _fill_pair(5, capacity=8)
    assert len(soa) == 5
    assert [a.state_fp.tobytes() for a in soa._items] == \
        [b.state_fp.tobytes() for b in ref._items]


def test_empty_buffer_raises():
    buf = ReplayBuffer(capacity=4, seed=0)
    with pytest.raises(ValueError):
        buf.sample(4)
    with pytest.raises(ValueError):
        buf.sample_packed(4)


# ------------------------------------------------------------------ #
# seeded sample equivalence to the seed list-based buffer
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("n,capacity", [(6, 16), (40, 16)])
def test_seeded_sample_equivalence(n, capacity):
    """Same seed, same adds -> byte-identical dense batches, repeatedly
    (the RNG streams must stay in lockstep draw after draw)."""
    soa, ref = _fill_pair(n, capacity)
    for _ in range(3):
        a = soa.sample(8, max_candidates=4)
        b = ref.sample(8, max_candidates=4)
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_sample_packed_draws_same_indices_as_sample():
    """sample_packed + host densify == sample, under one shared RNG
    stream (two same-seeded buffers, one call each)."""
    soa1, _ = _fill_pair(12, capacity=16, seed=23)
    soa2, _ = _fill_pair(12, capacity=16, seed=23)
    dense = soa1.sample(8, max_candidates=4)
    packed = soa2.sample_packed(8, max_candidates=4)
    round_trip = densify_sample(packed)
    for k in dense:
        np.testing.assert_array_equal(round_trip[k], dense[k], err_msg=k)


def test_jit_densify_matches_host_densify():
    """repro.core.packed_batch.densify_batch (the in-jit unpack) is the
    exact twin of the host-side densify — including a stacked [W, B, ...]
    leading axis like the trainer ships."""
    soa, _ = _fill_pair(15, capacity=16, seed=5)
    per = [soa.sample_packed(6, max_candidates=4) for _ in range(2)]
    stacked = {k: np.stack([p[k] for p in per]) for k in per[0]}
    jit_dense = {k: np.asarray(v) for k, v in densify_batch(stacked).items()}
    for w in range(2):
        host = densify_sample(per[w])
        for k in host:
            np.testing.assert_array_equal(jit_dense[k][w], host[k], err_msg=k)


def test_unpack_bits_matches_numpy():
    raw = RNG.integers(0, 256, size=(3, 5, 32), dtype=np.uint8)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(raw)),
        np.unpackbits(raw, axis=-1).astype(np.float32))


# ------------------------------------------------------------------ #
# candidate truncation + storage growth
# ------------------------------------------------------------------ #
def test_candidate_truncation_at_max_candidates():
    """A storage bound keeps only the first max_candidates successors —
    exactly the rows sample() would keep at the same cap."""
    rng = np.random.default_rng(0)
    t = _transition(rng, 10)
    bound = ReplayBuffer(4, seed=0, max_candidates=4)
    bound.add(t)
    stored = bound._items[0]
    assert stored.next_fps.shape[0] == 4
    np.testing.assert_array_equal(stored.next_fps, t.next_fps[:4])
    # and the sampled batch equals the unbounded buffer sampled at C=4
    free = ReplayBuffer(4, seed=0)
    free.add(t)
    a, b = bound.sample(4, max_candidates=4), free.sample(4, max_candidates=4)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_sample_truncates_below_stored_count():
    """max_candidates at sample time below the stored count: first-C rows,
    like the reference."""
    soa, ref = _fill_pair(10, capacity=16, seed=9)
    a = soa.sample(6, max_candidates=2)
    b = ref.sample(6, max_candidates=2)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_candidate_axis_growth_preserves_rows():
    """Adding a wide transition after narrow ones regrows the candidate
    axis without corrupting earlier rows."""
    rng = np.random.default_rng(1)
    buf = ReplayBuffer(8, seed=0)
    narrow = [_transition(rng, 2) for _ in range(3)]
    for t in narrow:
        buf.add(t)
    wide = _transition(rng, 40)
    buf.add(wide)
    items = buf._items
    for got, want in zip(items[:3], narrow):
        np.testing.assert_array_equal(got.next_fps, want.next_fps)
    np.testing.assert_array_equal(items[3].next_fps, wide.next_fps)
    assert buf._cand_cap >= 40


def test_overwrite_clears_stale_candidate_tail():
    """Evicting a wide transition with a narrow one must not leak the old
    candidate rows into samples (count drops AND bytes are zeroed)."""
    rng = np.random.default_rng(2)
    buf = ReplayBuffer(1, seed=0)
    buf.add(_transition(rng, 6))
    buf.add(_transition(rng, 1))          # overwrites the only slot
    assert buf._next_counts[0] == 1
    assert not buf._next_bits[0, 1:].any()
    batch = buf.sample(4, max_candidates=8)
    assert (batch["next_mask"].sum(-1) <= 1).all()


# ------------------------------------------------------------------ #
# sampling wider than the storage bound: fail loudly (regression)
# ------------------------------------------------------------------ #
def test_sample_at_storage_bound_matches_list():
    """Regression pin at the truncation boundary: a storage-bounded buffer
    sampled at EXACTLY its bound must equal the (unbounded) list reference
    truncated at the same C — byte for byte, packed and dense."""
    bound = 4
    rng = np.random.default_rng(3)
    soa = ReplayBuffer(8, seed=7, max_candidates=bound)
    ref = ListReplayBuffer(8, seed=7)
    for i in range(14):
        t = _transition(rng, int(rng.integers(0, 9)), done=(i % 5 == 0))
        soa.add(t)
        ref.add(t)
    a, b = soa.sample(6, max_candidates=bound), ref.sample(6, max_candidates=bound)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_sample_wider_than_storage_bound_raises():
    """Regression for the silent-divergence bug: rows past the storage
    bound were dropped at add() time, so sampling wider than the bound
    CANNOT reproduce the list reference (which stores full rows and kept
    returning the dropped candidates) — it used to zero-pad silently and
    diverge; now it must fail loudly.  Both sample flavours."""
    rng = np.random.default_rng(3)
    soa = ReplayBuffer(8, seed=7, max_candidates=4)
    soa.add(_transition(rng, 8))
    with pytest.raises(ValueError, match="storage bound"):
        soa.sample(4, max_candidates=8)
    with pytest.raises(ValueError, match="storage bound"):
        soa.sample_packed(4, max_candidates=8)
    # unbounded storage: any sample C stays legal
    free = ReplayBuffer(8, seed=7)
    free.add(_transition(np.random.default_rng(3), 8))
    free.sample(4, max_candidates=160)


# ------------------------------------------------------------------ #
# growth x ring wraparound audit (add_many past remaining capacity)
# ------------------------------------------------------------------ #
def test_add_many_growth_during_wraparound_no_stale_rows():
    """An add_many longer than the remaining capacity — forcing BOTH
    geometric row growth and candidate-axis growth mid-eviction, with the
    write head behind the read tail — must land exactly like the list
    reference: no stale interleaved rows, no leaked candidate bytes."""
    rng = np.random.default_rng(17)
    for capacity, episodes in ((8, (5, 9)), (96, (70, 130)), (7, (3, 11, 6))):
        soa = ReplayBuffer(capacity, seed=1, max_candidates=6)
        ref = ListReplayBuffer(capacity, seed=1)
        width = 1
        for n in episodes:
            # widen the candidate sets every flush so the candidate axis
            # regrows while the ring is mid-wraparound
            ts = [_transition(rng, int(rng.integers(0, width + 1)),
                              done=(i % 4 == 0)) for i in range(n)]
            width = min(width * 3, 9)
            soa.add_many(ts)
            ref.add_many(ts)
        assert len(soa) == len(ref)
        bound = soa.max_candidates
        for i, (a, b) in enumerate(zip(soa._items, ref._items)):
            assert a.state_fp.tobytes() == b.state_fp.tobytes(), f"slot {i}"
            np.testing.assert_array_equal(
                a.next_fps, b.next_fps[:bound], err_msg=f"slot {i}")
            assert a.done == b.done
        # stored rows past each count must be zero (no stale bytes a
        # wraparound + growth could resurrect into future samples)
        for i in range(len(soa)):
            k = int(soa._next_counts[i])
            assert not soa._next_bits[i, k:].any(), f"slot {i} leaked tail"


# ------------------------------------------------------------------ #
# prioritized sampling
# ------------------------------------------------------------------ #
def test_sampling_mode_validated():
    assert SAMPLING_MODES == ("uniform", "prioritized")
    with pytest.raises(ValueError, match="sampling"):
        ReplayBuffer(4, sampling="rank")


def _flat_parity_case(seed: int, n: int, batch: int, n_draws: int,
                      alpha: float) -> None:
    """Core of the parity invariant: with all-equal effective priorities a
    prioritized buffer must emit BIT-identical batches to a same-seeded
    uniform SoA buffer AND the list reference, draw after draw, with unit
    weights as the only extra key."""
    rng = np.random.default_rng(3)
    uni = ReplayBuffer(16, seed=seed, max_candidates=4)
    pri = ReplayBuffer(16, seed=seed, max_candidates=4,
                       sampling="prioritized", priority_alpha=alpha)
    ref = ListReplayBuffer(16, seed=seed)
    for i in range(n):
        t = _transition(rng, int(rng.integers(0, 7)), done=(i % 5 == 0))
        uni.add(t)
        pri.add(t)
        ref.add(t)
    for d in range(n_draws):
        a = uni.sample(batch, max_candidates=4)
        b = pri.sample(batch, max_candidates=4, beta=0.4 + 0.1 * d)
        c = ref.sample(batch, max_candidates=4)
        assert set(b) == set(a) | {"weights"}
        np.testing.assert_array_equal(b["weights"],
                                      np.ones(batch, np.float32))
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{k} draw {d}")
            np.testing.assert_array_equal(a[k], c[k], err_msg=f"{k} draw {d}")


@pytest.mark.parametrize("seed,n,batch,alpha",
                         [(0, 6, 4, 0.0), (11, 25, 8, 0.6), (99, 12, 1, 1.0)])
def test_prioritized_flat_priorities_bit_identical_to_uniform(seed, n, batch, alpha):
    """Before any update_priorities call every row holds the max-priority
    init, so the effective priorities are flat for ANY alpha — the draw
    must take the exact uniform path (same rng.integers stream)."""
    _flat_parity_case(seed, n, batch, n_draws=3, alpha=alpha)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**20), n=st.integers(1, 40),
           batch=st.integers(1, 16), alpha=st.floats(0.0, 1.0),
           W=st.sampled_from([1, 4]))
    def test_prioritized_flat_parity_property(seed, n, batch, alpha, W):
        """Hypothesis layer over the parity invariant, swept across W
        same-seeded per-worker buffer triples like the trainer owns."""
        for w in range(W):
            _flat_parity_case(seed + w, n, batch, n_draws=2, alpha=alpha)
else:
    def test_prioritized_flat_parity_property():
        pytest.importorskip("hypothesis")


def _prio_buffer(n: int = 8, alpha: float = 1.0, seed: int = 0) -> ReplayBuffer:
    rng = np.random.default_rng(5)
    buf = ReplayBuffer(16, seed=seed, max_candidates=4,
                       sampling="prioritized", priority_alpha=alpha,
                       priority_eps=1e-3)
    for i in range(n):
        buf.add(_transition(rng, 3, done=(i % 5 == 0)))
    return buf


def test_update_priorities_shifts_sampling_mass():
    """After |TD| feedback concentrates priority on a few rows, the draw
    must concentrate there too (proportional sampling actually engaged)."""
    buf = _prio_buffer(8)
    buf.sample_packed(8, max_candidates=4, beta=0.4)
    td = np.zeros(8)
    hot = [int(i) for i in buf._last_idx[:2]]
    td[:2] = 50.0                      # rows drawn first two get huge |TD|
    buf.update_priorities(td)
    counts = np.zeros(8)
    for _ in range(30):
        buf.sample_packed(16, max_candidates=4, beta=0.4)
        for i in buf._last_idx:
            counts[i] += 1
    assert counts[hot].sum() > 0.8 * counts.sum()


def test_prioritized_weights_match_formula():
    """The emitted weights must equal the max-normalised importance
    weights (N * P(i))^-beta computed from the priority state."""
    buf = _prio_buffer(6, alpha=0.7)
    buf.sample_packed(6, max_candidates=4)
    buf.update_priorities(np.arange(6, dtype=np.float64))
    # mirror the buffer's RNG to predict the next draw exactly
    shadow = np.random.default_rng()
    shadow.bit_generator.state = buf._rng.bit_generator.state
    q = buf._priorities[:len(buf)] ** 0.7
    csum = np.cumsum(q)
    u = shadow.random(5) * csum[-1]
    idx = np.minimum(np.searchsorted(csum, u, side="right"), len(buf) - 1)
    beta = 0.55
    w = (len(buf) * q[idx] / csum[-1]) ** -beta
    expect = (w / w.max()).astype(np.float32)
    batch = buf.sample_packed(5, max_candidates=4, beta=beta)
    np.testing.assert_array_equal(buf._last_idx, idx)
    np.testing.assert_array_equal(batch["weights"], expect)


def test_update_priorities_semantics():
    """|TD| + eps becomes the new priority (last write wins on duplicate
    indices), the running max feeds newly added rows, and misuse raises."""
    buf = _prio_buffer(4)
    with pytest.raises(ValueError, match="before any sample"):
        buf.update_priorities(np.ones(4))
    buf.sample_packed(4, max_candidates=4)
    with pytest.raises(ValueError, match="last sampled batch"):
        buf.update_priorities(np.ones(3))
    buf._last_idx = np.array([0, 1, 1, 2])          # duplicate draw of row 1
    buf.update_priorities(np.array([1.0, 5.0, 2.0, -3.0]))
    assert buf._priorities[0] == pytest.approx(1.0 + buf.priority_eps)
    assert buf._priorities[1] == pytest.approx(2.0 + buf.priority_eps)  # last write
    assert buf._priorities[2] == pytest.approx(3.0 + buf.priority_eps)  # |td|
    assert buf._max_priority == pytest.approx(5.0 + buf.priority_eps)
    rng = np.random.default_rng(9)
    buf.add(_transition(rng, 2))                     # new row: max-priority init
    assert buf._priorities[4] == pytest.approx(buf._max_priority)
    uni = ReplayBuffer(4, seed=0)
    uni.add(_transition(rng, 2))
    uni.sample_packed(2, max_candidates=4)
    with pytest.raises(ValueError, match="uniform"):
        uni.update_priorities(np.ones(2))


def test_uniform_batches_carry_no_weights_key():
    """The uniform byte stream must stay EXACTLY the seed layout — the
    weights key exists only in prioritized mode (and rides densify in
    both directions)."""
    soa, _ = _fill_pair(10, capacity=16, seed=23)
    assert "weights" not in soa.sample_packed(4, max_candidates=4)
    assert "weights" not in soa.sample(4, max_candidates=4)
    pri = _prio_buffer(6)
    packed = pri.sample_packed(4, max_candidates=4, beta=0.4)
    assert packed["weights"].dtype == np.float32
    dense = densify_sample(packed)
    np.testing.assert_array_equal(dense["weights"], packed["weights"])
    jit_dense = densify_batch({k: np.stack([v]) for k, v in packed.items()})
    np.testing.assert_array_equal(
        np.asarray(jit_dense["weights"])[0], packed["weights"])


# ------------------------------------------------------------------ #
# packed-batch byte accounting (the 32x H2D claim, structurally)
# ------------------------------------------------------------------ #
def test_packed_batch_is_32x_smaller_than_dense():
    soa, _ = _fill_pair(20, capacity=32, seed=4)
    packed = soa.sample_packed(16, max_candidates=8)
    dense = soa.sample(16, max_candidates=8)
    ratio = sum(v.nbytes for v in dense.values()) / packed_nbytes(packed)
    assert ratio > 30
    assert dense_nbytes_equivalent(packed) == sum(v.nbytes for v in dense.values())
