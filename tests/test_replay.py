"""SoA replay buffer vs the seed list-based reference: ring/eviction
semantics, seeded sample equivalence, packed-batch consistency (host densify
== jit densify), candidate truncation and storage growth."""

import numpy as np
import pytest

from repro.core.packed_batch import (
    dense_nbytes_equivalent, densify_batch, packed_nbytes, unpack_bits,
)
from repro.core.replay import (
    FP_BYTES, ListReplayBuffer, ReplayBuffer, Transition, densify_sample,
    pack_fp,
)

RNG = np.random.default_rng(7)


def _transition(rng, n_candidates: int, done: bool = False) -> Transition:
    fp = (rng.random(2048) > 0.7).astype(np.float32)
    nxt = (np.stack([pack_fp((rng.random(2048) > 0.5).astype(np.float32))
                     for _ in range(n_candidates)])
           if n_candidates else np.zeros((0, FP_BYTES), np.uint8))
    return Transition(pack_fp(fp), float(rng.random()),
                      float(rng.standard_normal()), done, nxt,
                      float(rng.random()))


def _fill_pair(n: int, capacity: int, seed: int = 11, max_cands: int | None = None):
    """The SoA buffer and the list reference fed the identical stream."""
    rng = np.random.default_rng(3)
    soa = ReplayBuffer(capacity, seed=seed, max_candidates=max_cands)
    ref = ListReplayBuffer(capacity, seed=seed)
    for i in range(n):
        t = _transition(rng, int(rng.integers(0, 7)), done=(i % 5 == 0))
        soa.add(t)
        ref.add(t)
    return soa, ref


# ------------------------------------------------------------------ #
# ring semantics
# ------------------------------------------------------------------ #
def test_wraparound_matches_list_eviction_order():
    """After 2.5x capacity of adds, slot i must hold exactly what the seed
    list buffer holds at _items[i] (cyclic overwrite, oldest-first)."""
    soa, ref = _fill_pair(20, capacity=8)
    assert len(soa) == len(ref) == 8
    for a, b in zip(soa._items, ref._items):
        assert a.state_fp.tobytes() == b.state_fp.tobytes()
        assert a.next_fps.tobytes() == b.next_fps.tobytes()
        assert a.done == b.done
        assert a.reward == np.float32(b.reward)          # stored as f32
        assert a.steps_left_frac == np.float32(b.steps_left_frac)


def test_partial_fill_preserves_insertion_order():
    soa, ref = _fill_pair(5, capacity=8)
    assert len(soa) == 5
    assert [a.state_fp.tobytes() for a in soa._items] == \
        [b.state_fp.tobytes() for b in ref._items]


def test_empty_buffer_raises():
    buf = ReplayBuffer(capacity=4, seed=0)
    with pytest.raises(ValueError):
        buf.sample(4)
    with pytest.raises(ValueError):
        buf.sample_packed(4)


# ------------------------------------------------------------------ #
# seeded sample equivalence to the seed list-based buffer
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("n,capacity", [(6, 16), (40, 16)])
def test_seeded_sample_equivalence(n, capacity):
    """Same seed, same adds -> byte-identical dense batches, repeatedly
    (the RNG streams must stay in lockstep draw after draw)."""
    soa, ref = _fill_pair(n, capacity)
    for _ in range(3):
        a = soa.sample(8, max_candidates=4)
        b = ref.sample(8, max_candidates=4)
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_sample_packed_draws_same_indices_as_sample():
    """sample_packed + host densify == sample, under one shared RNG
    stream (two same-seeded buffers, one call each)."""
    soa1, _ = _fill_pair(12, capacity=16, seed=23)
    soa2, _ = _fill_pair(12, capacity=16, seed=23)
    dense = soa1.sample(8, max_candidates=4)
    packed = soa2.sample_packed(8, max_candidates=4)
    round_trip = densify_sample(packed)
    for k in dense:
        np.testing.assert_array_equal(round_trip[k], dense[k], err_msg=k)


def test_jit_densify_matches_host_densify():
    """repro.core.packed_batch.densify_batch (the in-jit unpack) is the
    exact twin of the host-side densify — including a stacked [W, B, ...]
    leading axis like the trainer ships."""
    soa, _ = _fill_pair(15, capacity=16, seed=5)
    per = [soa.sample_packed(6, max_candidates=4) for _ in range(2)]
    stacked = {k: np.stack([p[k] for p in per]) for k in per[0]}
    jit_dense = {k: np.asarray(v) for k, v in densify_batch(stacked).items()}
    for w in range(2):
        host = densify_sample(per[w])
        for k in host:
            np.testing.assert_array_equal(jit_dense[k][w], host[k], err_msg=k)


def test_unpack_bits_matches_numpy():
    raw = RNG.integers(0, 256, size=(3, 5, 32), dtype=np.uint8)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(raw)),
        np.unpackbits(raw, axis=-1).astype(np.float32))


# ------------------------------------------------------------------ #
# candidate truncation + storage growth
# ------------------------------------------------------------------ #
def test_candidate_truncation_at_max_candidates():
    """A storage bound keeps only the first max_candidates successors —
    exactly the rows sample() would keep at the same cap."""
    rng = np.random.default_rng(0)
    t = _transition(rng, 10)
    bound = ReplayBuffer(4, seed=0, max_candidates=4)
    bound.add(t)
    stored = bound._items[0]
    assert stored.next_fps.shape[0] == 4
    np.testing.assert_array_equal(stored.next_fps, t.next_fps[:4])
    # and the sampled batch equals the unbounded buffer sampled at C=4
    free = ReplayBuffer(4, seed=0)
    free.add(t)
    a, b = bound.sample(4, max_candidates=4), free.sample(4, max_candidates=4)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_sample_truncates_below_stored_count():
    """max_candidates at sample time below the stored count: first-C rows,
    like the reference."""
    soa, ref = _fill_pair(10, capacity=16, seed=9)
    a = soa.sample(6, max_candidates=2)
    b = ref.sample(6, max_candidates=2)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_candidate_axis_growth_preserves_rows():
    """Adding a wide transition after narrow ones regrows the candidate
    axis without corrupting earlier rows."""
    rng = np.random.default_rng(1)
    buf = ReplayBuffer(8, seed=0)
    narrow = [_transition(rng, 2) for _ in range(3)]
    for t in narrow:
        buf.add(t)
    wide = _transition(rng, 40)
    buf.add(wide)
    items = buf._items
    for got, want in zip(items[:3], narrow):
        np.testing.assert_array_equal(got.next_fps, want.next_fps)
    np.testing.assert_array_equal(items[3].next_fps, wide.next_fps)
    assert buf._cand_cap >= 40


def test_overwrite_clears_stale_candidate_tail():
    """Evicting a wide transition with a narrow one must not leak the old
    candidate rows into samples (count drops AND bytes are zeroed)."""
    rng = np.random.default_rng(2)
    buf = ReplayBuffer(1, seed=0)
    buf.add(_transition(rng, 6))
    buf.add(_transition(rng, 1))          # overwrites the only slot
    assert buf._next_counts[0] == 1
    assert not buf._next_bits[0, 1:].any()
    batch = buf.sample(4, max_candidates=8)
    assert (batch["next_mask"].sum(-1) <= 1).all()


# ------------------------------------------------------------------ #
# packed-batch byte accounting (the 32x H2D claim, structurally)
# ------------------------------------------------------------------ #
def test_packed_batch_is_32x_smaller_than_dense():
    soa, _ = _fill_pair(20, capacity=32, seed=4)
    packed = soa.sample_packed(16, max_candidates=8)
    dense = soa.sample(16, max_candidates=8)
    ratio = sum(v.nbytes for v in dense.values()) / packed_nbytes(packed)
    assert ratio > 30
    assert dense_nbytes_equivalent(packed) == sum(v.nbytes for v in dense.values())
