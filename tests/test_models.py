"""Model zoo: per-arch reduced smoke tests + decode/forward consistency.

The assignment requires, per architecture, a REDUCED variant (<=2 layers,
d_model<=512, <=4 experts) running one forward/train step on CPU with
shape + NaN assertions.  ``test_arch_smoke`` is that test, parametrized
over all 10 assigned architectures (+ the paper's own qnet config).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.models import (
    abstract_params, count_params, forward_train, init_cache, init_params,
    loss_fn, param_pspecs, serve_step,
)
from repro.launch.steps import make_train_step

ARCHS = [a for a in list_archs() if a != "damoldqn"]


def _batch_for(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": rng.integers(1, cfg.vocab, (B, S)).astype(np.int32),
        "labels": rng.integers(1, cfg.vocab, (B, S)).astype(np.int32),
        "mask": np.ones((B, S), np.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (B, cfg.encdec.n_frames, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (B, cfg.vlm.n_patches, cfg.vlm.vision_dim)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    """Reduced config: forward + ONE real train step; shapes + finiteness."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)

    logits, aux = jax.jit(lambda p, b: forward_train(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    step, opt = make_train_step(cfg)
    opt_state = opt.init(params)
    params2, _, loss = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(loss))
    # parameters must actually change
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = init_cache(cfg, B, 64)
    tok = np.ones((B, 1), np.int32)
    logits, cache2 = jax.jit(lambda p, c, t: serve_step(p, cfg, c, t))(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_qnet_train_step():
    cfg = get_config("damoldqn")
    params = init_params(cfg, jax.random.PRNGKey(0))
    step, opt = make_train_step(cfg)
    opt_state = opt.init(params)
    rng = np.random.default_rng(0)
    batch = {
        "states": rng.random((8, 2049)).astype(np.float32),
        "rewards": rng.random(8).astype(np.float32),
        "dones": np.ones(8, np.float32),
        "next_fps": np.zeros((8, 4, 2049), np.float32),
        "next_mask": np.zeros((8, 4), np.float32),
    }
    _, _, loss = jax.jit(step)(params, params, opt_state, batch)
    assert bool(jnp.isfinite(loss))


def test_decode_matches_forward_dense():
    """Greedy decode logits must equal teacher-forced forward logits."""
    cfg = get_config("stablelm-1.6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 8
    rng = np.random.default_rng(0)
    tokens = rng.integers(1, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": tokens, "labels": tokens, "mask": np.ones((B, S), np.float32)}
    full_logits, _ = forward_train(params, cfg, batch)

    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = serve_step(params, cfg, cache, tokens[:, t : t + 1])
        outs.append(np.asarray(lg)[:, 0])
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    cfg = get_config("mamba2-2.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 8
    rng = np.random.default_rng(0)
    tokens = rng.integers(1, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": tokens, "labels": tokens, "mask": np.ones((B, S), np.float32)}
    full_logits, _ = forward_train(params, cfg, batch)
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = serve_step(params, cfg, cache, tokens[:, t : t + 1])
        outs.append(np.asarray(lg)[:, 0])
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    }
    for arch, (L, D, H, K, F, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, D, H, K, F, V), arch
    assert get_config("qwen3-moe-235b-a22b").moe.n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.top_k == 8
    assert get_config("mixtral-8x22b").moe.n_experts == 8
    assert get_config("mixtral-8x22b").moe.top_k == 2
    assert get_config("mixtral-8x22b").attn_window == 4096
    assert get_config("mamba2-2.7b").ssm.state_dim == 128
    assert get_config("zamba2-1.2b").ssm.state_dim == 64


def test_param_counts_sane():
    assert 200e9 < count_params(get_config("qwen3-moe-235b-a22b")) < 260e9
    assert 120e9 < count_params(get_config("mixtral-8x22b")) < 160e9
    assert 30e9 < count_params(get_config("yi-34b")) < 40e9
    assert 2.2e9 < count_params(get_config("mamba2-2.7b")) < 3.2e9


@pytest.mark.parametrize("arch", ARCHS)
def test_param_pspecs_cover_tree(arch):
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    specs = param_pspecs(cfg, tp=16)
    leaves_t = jax.tree_util.tree_leaves(tree)
    leaves_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: x is None or hasattr(x, "index"))
    assert len(leaves_t) == len(leaves_s)
    # every sharded dim must divide
    for leaf, spec in zip(leaves_t, jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: hasattr(x, "index") or x is None)):
        for d, part in enumerate(tuple(spec) if spec is not None else ()):
            if part == "model":
                assert leaf.shape[d] % 16 == 0, (arch, leaf.shape, spec)


def test_moe_tokens_conserved():
    """With huge capacity, MoE must route every token (gates sum to 1)."""
    from repro.models.moe import moe_forward, moe_params_init
    from repro.configs.base import ArchConfig, MoEConfig
    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
                     n_kv_heads=4, d_ff=64, vocab=64,
                     moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0,
                                   group_size=16), dtype="float32")
    p = moe_params_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 32)), jnp.float32)
    y, aux = moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 0.0


def test_decode_matches_forward_hybrid():
    """The segmented hybrid decode (per-application shared KV caches) must
    match teacher forcing — regression guard for the cond-in-scan bug."""
    cfg = get_config("zamba2-1.2b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 8
    rng = np.random.default_rng(0)
    tokens = rng.integers(1, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": tokens, "labels": tokens, "mask": np.ones((B, S), np.float32)}
    full_logits, _ = forward_train(params, cfg, batch)
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = serve_step(params, cfg, cache, tokens[:, t : t + 1])
        outs.append(np.asarray(lg)[:, 0])
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), rtol=2e-2, atol=2e-2)


def test_hybrid_cache_has_per_application_kv():
    from repro.models.model import hybrid_n_apps
    cfg = get_config("zamba2-1.2b").reduced()
    cache = init_cache(cfg, 2, 16)
    napps = hybrid_n_apps(cfg)
    assert napps >= 1
    assert cache["shared_k"].shape[0] == napps


def test_decode_matches_forward_moe():
    cfg = get_config("mixtral-8x22b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(4))
    B, S = 1, 8
    rng = np.random.default_rng(1)
    tokens = rng.integers(1, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": tokens, "labels": tokens, "mask": np.ones((B, S), np.float32)}
    full_logits, _ = forward_train(params, cfg, batch)
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = serve_step(params, cfg, cache, tokens[:, t : t + 1])
        outs.append(np.asarray(lg)[:, 0])
    dec = np.stack(outs, axis=1)
    # GShard capacity semantics: at capacity_factor=1.0 the grouped train
    # path may DROP tokens (they ride the residual); single-token decode
    # groups never drop.  Positions that weren't dropped must match
    # exactly; dropped ones differ by the expert contribution.
    per_pos = np.abs(dec - np.asarray(full_logits)).max(axis=-1)[0]
    matched = per_pos < 1e-3
    assert matched.sum() >= S // 2, per_pos
    assert matched[0], "first token can never be dropped"


def test_sliding_window_variant_matches_full_when_window_exceeds_seq():
    cfg = get_config("stablelm-1.6b").reduced()
    cfgw = cfg.with_window(64)   # window > S -> identical to full attention
    params = init_params(cfg, jax.random.PRNGKey(5))
    B, S = 1, 16
    rng = np.random.default_rng(2)
    tokens = rng.integers(1, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": tokens, "labels": tokens, "mask": np.ones((B, S), np.float32)}
    a, _ = forward_train(params, cfg, batch)
    b, _ = forward_train(params, cfgw, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
