"""Checkpoint layer: manifest-validated loads, the LATEST pointer, corrupt-
file fallback, the kill-mid-write torture case, RNG state round-trips and
the fault-injected save retries.

The robustness contract under test (docs/robustness.md): a checkpoint file
either loads COMPLETELY or raises ``CheckpointError`` — never a partial or
garbage tree — and a manager restore walks back through the rotation until
it finds a readable snapshot.
"""

import os

import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointError, CheckpointManager, load_flat, load_pytree,
    rng_state_from_array, rng_state_to_array, save_flat, save_pytree,
    unflatten_like,
)

TREE = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": {"c": np.asarray(3, np.int64),
              "d": np.ones((4,), np.uint8)}}


# ------------------------------------------------------------------ #
# manifest validation + corruption
# ------------------------------------------------------------------ #
def test_flat_roundtrip_and_manifest(tmp_path):
    path = str(tmp_path / "x.npz")
    flat = {"p/0": np.arange(4, dtype=np.float64),
            "p/1": np.asarray(7, np.int64)}
    save_flat(path, flat)
    out = load_flat(path)
    assert sorted(out) == sorted(flat)
    for k in flat:
        np.testing.assert_array_equal(out[k], flat[k])


def test_reserved_manifest_key_refused(tmp_path):
    with pytest.raises(ValueError):
        save_flat(str(tmp_path / "x.npz"), {"__manifest__": np.zeros(1)})


def test_missing_file_is_filenotfound_not_corrupt(tmp_path):
    # absent != corrupt: restore fallback walks past corrupt files but a
    # missing path must keep its standard, distinguishable exception
    with pytest.raises(FileNotFoundError):
        load_flat(str(tmp_path / "nope.npz"))


def test_truncated_checkpoint_raises_loud(tmp_path):
    path = str(tmp_path / "x.npz")
    save_pytree(path, TREE)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[: len(blob) // 2])
    with pytest.raises(CheckpointError):
        load_pytree(path, TREE)


def test_garbage_file_raises_checkpoint_error(tmp_path):
    path = str(tmp_path / "x.npz")
    with open(path, "wb") as f:
        f.write(b"not an npz archive at all")
    with pytest.raises(CheckpointError):
        load_flat(path)


def test_missing_key_vs_manifest_raises(tmp_path):
    # an archive whose key set disagrees with its own manifest is corrupt
    path = str(tmp_path / "x.npz")
    save_flat(path, {"a": np.zeros(2), "b": np.ones(2)})
    data = dict(np.load(path))
    del data["b"]
    np.savez(path, **data)   # manifest still lists "b"
    with pytest.raises(CheckpointError):
        load_flat(path)


def test_unmanifested_archive_raises(tmp_path):
    # a plain npz (no manifest at all) is not a valid checkpoint
    path = str(tmp_path / "x.npz")
    np.savez(path, a=np.zeros(2))
    with pytest.raises(CheckpointError):
        load_flat(path)


def test_kill_mid_write_torture(tmp_path):
    """Simulated kill-at-any-byte: for truncations at many offsets, the
    load either succeeds completely (only when nothing was cut) or raises
    CheckpointError — NEVER returns a partial/garbage tree."""
    path = str(tmp_path / "x.npz")
    save_pytree(path, TREE)
    blob = open(path, "rb").read()
    rng = np.random.default_rng(0)
    offsets = sorted(set(
        list(rng.integers(1, len(blob), size=40)) + [1, len(blob) - 1]))
    for off in offsets:
        with open(path, "wb") as f:
            f.write(blob[:off])
        try:
            out = load_pytree(path, TREE)
        except CheckpointError:
            continue
        np.testing.assert_array_equal(out["a"], TREE["a"])
        np.testing.assert_array_equal(out["b"]["d"], TREE["b"]["d"])
        assert off == len(blob), \
            f"truncation at {off}/{len(blob)} loaded without error"


def test_unflatten_like_validates_shape_and_missing():
    flat = {"a": np.zeros((2, 3), np.float32),
            "b/c": np.asarray(1, np.int64), "b/d": np.zeros((4,), np.uint8)}
    out = unflatten_like(dict(flat), TREE)
    assert out["a"].shape == (2, 3)
    bad = dict(flat)
    bad["a"] = np.zeros((9, 9), np.float32)
    with pytest.raises(CheckpointError):
        unflatten_like(bad, TREE)
    del flat["b/c"]
    with pytest.raises(CheckpointError):
        unflatten_like(flat, TREE)


# ------------------------------------------------------------------ #
# manager: LATEST pointer + fallback walk
# ------------------------------------------------------------------ #
def test_latest_pointer_and_stale_pointer_fallback(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=3)
    for s in (1, 2, 3):
        mgr.save(s, TREE)
    assert (tmp_path / "LATEST").read_text().strip() == "3"
    # a stale/garbage pointer must fall back to the directory scan
    (tmp_path / "LATEST").write_text("999")
    assert mgr.latest_step() == 3
    (tmp_path / "LATEST").write_text("garbage")
    assert mgr.latest_step() == 3


def test_corrupt_newest_falls_back_to_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=3)
    for s in (1, 2):
        mgr.save(s, TREE)
    # truncate the newest snapshot (simulated torn write that survived)
    newest = tmp_path / "ckpt_2.npz"
    blob = newest.read_bytes()
    newest.write_bytes(blob[: len(blob) // 3])
    step, out = mgr.restore(TREE)
    assert step == 1
    np.testing.assert_array_equal(out["a"], TREE["a"])


def test_all_corrupt_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=3)
    mgr.save(1, TREE)
    p = tmp_path / "ckpt_1.npz"
    p.write_bytes(p.read_bytes()[:10])
    with pytest.raises(CheckpointError):
        mgr.restore(TREE)


def test_save_retries_under_fault_plan(tmp_path):
    from repro.core.faults import FaultPlan, FaultRule
    # every write faults once; save() must retry and land the snapshot
    plan = FaultPlan([FaultRule(site="checkpoint", kind="transient",
                                every=1, fail_attempts=1)])
    mgr = CheckpointManager(str(tmp_path), fault_plan=plan, save_retries=2)
    mgr.save(1, TREE)
    assert mgr.latest_step() == 1
    assert mgr.n_save_retries == 1
    step, out = mgr.restore(TREE)
    np.testing.assert_array_equal(out["a"], TREE["a"])


def test_save_retries_exhausted_raise(tmp_path):
    from repro.core.faults import FaultPlan, FaultRule
    plan = FaultPlan([FaultRule(site="checkpoint", kind="transient",
                                every=1, fail_attempts=10)])
    mgr = CheckpointManager(str(tmp_path), fault_plan=plan, save_retries=2)
    with pytest.raises(CheckpointError):
        mgr.save(1, TREE)
    assert mgr.latest_step() is None   # nothing half-written became LATEST


# ------------------------------------------------------------------ #
# RNG state round-trip
# ------------------------------------------------------------------ #
def test_rng_state_roundtrip_exact():
    rng = np.random.default_rng(1234)
    rng.random(17)           # advance into an arbitrary mid-stream state
    rng.integers(0, 10, 3)
    arr = rng_state_to_array(rng)
    assert arr.dtype == np.uint64 and arr.shape == (6,)
    clone = rng_state_from_array(arr)
    np.testing.assert_array_equal(clone.random(32), rng.random(32))
    np.testing.assert_array_equal(clone.integers(0, 1000, 16),
                                  rng.integers(0, 1000, 16))
