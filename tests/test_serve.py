"""MoleculeOptService: admission, continuous batching, degradation.

The serve determinism contract this module pins (ISSUE-9 acceptance):

* every submitted request reaches EXACTLY ONE terminal status
  (completed | degraded | deadline_exceeded | shed | failed) — under
  overload, deadlines, poisoned SMILES, and an active FaultPlan;
* the identical seeded stream reproduces every request's result
  bit-for-bit, and requests the faults never touched are bit-identical
  to an unfaulted run (isolation: faults are invisible outside their
  blast radius);
* the breaker trips on correlated property-tier failures, serves
  degraded properties while open, probes half-open, and recovers;
* a churning request mix causes 0 XLA recompiles after warmup.
"""

import jax
import numpy as np
import pytest

from repro.chem.smiles import from_smiles
from repro.core.agent import QNetwork
from repro.core.faults import FaultError, FaultPlan, FaultRule
from repro.core.jit_stats import RecompileCounter
from repro.predictors.service import (DegradedPropertyService,
                                      ResilientService, RetryPolicy)
from repro.serving import (CLOSED, INVALID_SMILES, AdmissionQueue,
                           MoleculeOptService, OptimizeRequest, ServeConfig,
                           StepClock, StreamConfig, drive_open_loop,
                           resolve_objective, seeded_request_stream)

from conftest import OracleService

_NET = QNetwork(hidden=(32,))
_PARAMS = _NET.init(jax.random.PRNGKey(0))


def _service(n_slots=4, *, plan=None, prop=None, **cfg_over):
    if prop is None:
        prop = OracleService()
    if plan is not None and not isinstance(prop, ResilientService):
        prop = ResilientService(prop, RetryPolicy(max_retries=1),
                                fault_plan=plan, sleep=None)
    return MoleculeOptService(
        _NET, _PARAMS, prop,
        cfg=ServeConfig(n_slots=n_slots, **cfg_over), fault_plan=plan)


def _plan(seed=7):
    """The bench-style serve plan: predict crashes that trip the breaker,
    chem crashes that quarantine slots, transient bind faults."""
    return FaultPlan([
        FaultRule(site="predict", kind="crash", every=5, fail_attempts=6),
        FaultRule(site="chem", kind="crash", rate=0.03),
        FaultRule(site="request", kind="transient", rate=0.2,
                  fail_attempts=1),
    ], seed=seed)


def _signature(svc):
    return [(r.request_id, r.status, r.steps_used, r.degraded_steps,
             r.latency, r.best_smiles,
             None if r.best_reward is None
             else np.float64(r.best_reward).tobytes())
            for r in sorted(svc.results, key=lambda r: r.request_id)]


def _drive(svc, n=16, *, seed=3, rate=2.0, **scfg):
    drive_open_loop(svc, seeded_request_stream(
        StreamConfig(n_requests=n, seed=seed, rate=rate, **scfg)))
    return svc


# ------------------------------------------------------------------ #
# admission primitives
# ------------------------------------------------------------------ #
def test_step_clock_is_virtual():
    c = StepClock(tick=0.5)
    assert c.now() == 0.0
    c.advance(); c.advance()
    assert c.now() == 1.0


def test_admission_queue_reject_new():
    q = AdmissionQueue(2, "reject_new")
    assert q.offer("a") is None and q.offer("b") is None
    assert q.offer("c") == "c"            # full: the NEW item is the victim
    assert [q.pop(), q.pop()] == ["a", "b"]
    assert q.stats()["n_shed"] == 1


def test_admission_queue_evict_oldest():
    q = AdmissionQueue(2, "evict_oldest")
    q.offer("a"); q.offer("b")
    assert q.offer("c") == "a"            # full: the OLDEST item is evicted
    assert [q.pop(), q.pop()] == ["b", "c"]


def test_admission_queue_rejects_unknown_policy():
    with pytest.raises(ValueError):
        AdmissionQueue(2, "drop_everything")


def test_resolve_objective():
    # names resolve through THE scenario registry and compile fresh
    # per request (request-private novelty state)
    obj = resolve_objective("antioxidant_bde")
    assert obj.spec.name == "antioxidant_bde"
    assert resolve_objective("antioxidant_bde") is not obj
    assert resolve_objective("qed").spec.name == "qed"   # non-Eq.1 preset
    fn = lambda pr, initial, current, steps_left: 0.0  # noqa: E731
    assert resolve_objective(fn) is fn
    with pytest.raises(ValueError, match="registry scenarios"):
        resolve_objective("make_it_sticky")


def test_degraded_service_prefers_primary_cache_then_stub():
    svc = DegradedPropertyService(OracleService())
    mols = [from_smiles("C1=CC=CC=C1O")]
    ref = OracleService().predict(mols)[0]
    got = svc.predict(mols)[0]
    assert got.bde == ref.bde and got.ip == ref.ip
    assert svc.stats()["n_stub_serves"] == 1   # oracle stub has no cache


# ------------------------------------------------------------------ #
# terminal statuses: every request gets exactly one
# ------------------------------------------------------------------ #
def test_simple_requests_complete():
    svc = _service(2)
    assert svc.submit(OptimizeRequest("a", "C1=CC=CC=C1O", budget=4)) == "queued"
    assert svc.submit(OptimizeRequest("b", "OC1=CC=CC=C1O", budget=4)) == "queued"
    svc.run_until_idle()
    assert [r.status for r in svc.results] == ["completed", "completed"]
    for r in svc.results:
        assert r.steps_used == 4 and r.best_smiles is not None
        assert r.best_reward is not None


def test_invalid_smiles_fails_at_door_without_hurting_neighbours():
    svc = _service(2)
    assert svc.submit(OptimizeRequest("ok", "C1=CC=CC=C1O", budget=3)) == "queued"
    assert svc.submit(OptimizeRequest("bad", INVALID_SMILES)) == "failed"
    svc.run_until_idle()
    by = svc.result_by_id
    assert by["bad"].status == "failed" and by["bad"].steps_used == 0
    assert by["bad"].error is not None
    assert by["ok"].status == "completed"
    assert [i.site for i in svc.incidents] == ["parse"]


def test_duplicate_request_id_rejected():
    svc = _service(1)
    assert svc.submit(OptimizeRequest("a", "C1=CC=CC=C1O", budget=2)) == "queued"
    assert svc.submit(OptimizeRequest("a", "C1=CC=CC=C1O", budget=2)) == "failed"
    svc.run_until_idle()
    statuses = sorted(r.status for r in svc.results)
    assert statuses == ["completed", "failed"]


def test_every_submission_terminates_exactly_once():
    svc = _drive(_service(2, max_queue=4, epsilon=0.05), n=12, rate=4.0,
                 invalid_every=5)
    assert len(svc.results) == 12 == svc.n_submitted
    assert len({r.request_id for r in svc.results}) == 12
    assert sum(svc.status_counts.values()) == 12


# ------------------------------------------------------------------ #
# deadlines
# ------------------------------------------------------------------ #
def test_deadline_expires_in_queue():
    svc = _service(1)
    svc.submit(OptimizeRequest("hog", "C1=CC=CC=C1O", budget=8))
    svc.submit(OptimizeRequest("late", "OC1=CC=CC=C1O", budget=8,
                               deadline=2.0))
    svc.run_until_idle()
    late = svc.result_by_id["late"]
    assert late.status == "deadline_exceeded"
    assert late.steps_used == 0 and late.best_smiles is None
    assert late.latency == 2.0                    # virtual-clock exact


def test_deadline_reclaims_slot_midflight_with_best_so_far():
    svc = _service(1)
    svc.submit(OptimizeRequest("hurried", "C1=CC=CC=C1O", budget=10,
                               deadline=4.0))
    svc.submit(OptimizeRequest("next", "OC1=CC=CC=C1O", budget=2))
    svc.run_until_idle()
    hurried = svc.result_by_id["hurried"]
    assert hurried.status == "deadline_exceeded"
    assert 0 < hurried.steps_used < 10            # reclaimed mid-flight
    assert hurried.best_smiles is not None        # best-so-far ships back
    assert svc.result_by_id["next"].status == "completed"


# ------------------------------------------------------------------ #
# backpressure
# ------------------------------------------------------------------ #
def test_shed_reject_new_keeps_oldest():
    svc = _service(1, max_queue=2, shed_policy="reject_new")
    verdicts = [svc.submit(OptimizeRequest(f"r{i}", "C1=CC=CC=C1O", budget=2))
                for i in range(4)]
    assert verdicts == ["queued", "queued", "shed", "shed"]
    svc.run_until_idle()
    by = svc.result_by_id
    assert by["r0"].status == "completed" and by["r1"].status == "completed"
    assert by["r2"].status == "shed" and by["r3"].status == "shed"
    assert svc.queue.stats()["n_shed"] == 2


def test_shed_evict_oldest_keeps_newest():
    svc = _service(1, max_queue=2, shed_policy="evict_oldest")
    verdicts = [svc.submit(OptimizeRequest(f"r{i}", "C1=CC=CC=C1O", budget=2))
                for i in range(4)]
    assert verdicts == ["queued", "queued", "queued", "queued"]
    svc.run_until_idle()
    by = svc.result_by_id
    assert by["r0"].status == "shed" and by["r1"].status == "shed"
    assert by["r2"].status == "completed" and by["r3"].status == "completed"


# ------------------------------------------------------------------ #
# continuous batching
# ------------------------------------------------------------------ #
def test_freed_slots_rebind_immediately():
    svc = _drive(_service(2), n=10, rate=8.0)      # 10 requests, 2 slots
    assert svc.n_bound == 10 > svc.cfg.n_slots     # every slot reused
    assert all(r.status == "completed" for r in svc.results)
    # one fleet env step == one Q dispatch: co-batching is real
    assert svc._policy.n_dispatches == svc.n_service_steps


def test_zero_recompiles_after_warmup():
    counter = RecompileCounter.install()
    svc = _service(4, epsilon=0.05)
    drive_open_loop(svc, seeded_request_stream(StreamConfig(
        n_requests=8, rate=4.0, seed=5, prefix="warm")))
    svc.reserve_candidates(int(svc._policy._cap * 1.3))
    mark = counter.count
    _drive(svc, n=12, seed=9, rate=4.0, deadline_frac=0.3, invalid_every=5)
    assert counter.delta_since(mark) == 0


def test_per_request_objective_isolation():
    """A request's result is independent of who it is batched with: the
    same request solo and co-batched with a DIFFERENT objective returns
    bit-identical best molecules (per-row Q + per-request RNG)."""
    reqs = [OptimizeRequest("bde", "CC1=CC=C(O)C=C1",
                            objective="antioxidant_bde", budget=5, seed=1),
            OptimizeRequest("ip", "COC1=CC=CC=C1O",
                            objective="antioxidant_ip", budget=5, seed=2)]
    both = _service(2, epsilon=0.05)
    for r in reqs:
        both.submit(r)
    both.run_until_idle()
    for r in reqs:
        solo = _service(1, epsilon=0.05)
        solo.submit(r)
        solo.run_until_idle()
        a, b = both.result_by_id[r.request_id], solo.result_by_id[r.request_id]
        assert a.status == b.status == "completed"
        assert a.best_smiles == b.best_smiles
        assert np.float64(a.best_reward).tobytes() \
            == np.float64(b.best_reward).tobytes()


def test_custom_callable_objective():
    svc = _service(1)
    svc.submit(OptimizeRequest(
        "const", "C1=CC=CC=C1O", budget=3,
        objective=lambda pr, initial, current, steps_left: 42.0))
    svc.run_until_idle()
    assert svc.result_by_id["const"].best_reward == 42.0


# ------------------------------------------------------------------ #
# circuit breaker
# ------------------------------------------------------------------ #
class _ScriptedService:
    """Deterministic property tier that fails exactly on scripted calls."""

    def __init__(self, fail_calls):
        self.fail_calls = set(fail_calls)
        self.inner = OracleService()
        self.n_calls = 0

    def predict(self, mols):
        self.n_calls += 1
        if self.n_calls in self.fail_calls:
            raise FaultError(f"scripted outage (call {self.n_calls})")
        return self.inner.predict(mols)


def test_breaker_trips_degrades_and_recovers():
    # calls 1-4 fail: batch + isolation raises trip the breaker (threshold
    # 3), the first half-open probe re-trips (call 4), the second recovers
    svc = _service(2, prop=_ScriptedService({1, 2, 3, 4}),
                   breaker_threshold=3, breaker_cooldown=2)
    svc.submit(OptimizeRequest("a", "C1=CC=CC=C1O", budget=8, seed=1))
    svc.submit(OptimizeRequest("b", "OC1=CC=CC=C1O", budget=8, seed=2))
    svc.run_until_idle()
    bst = svc.breaker.stats()
    assert bst["n_trips"] == 2
    assert bst["n_probes"] == 2 and bst["n_probe_failures"] == 1
    assert bst["n_recoveries"] == 1 and bst["state"] == CLOSED
    statuses = sorted(r.status for r in svc.results)
    # one request's molecule was quarantined by the pre-trip raises, the
    # other rode through the outage on degraded serves
    assert statuses == ["degraded", "failed"]
    deg = next(r for r in svc.results if r.status == "degraded")
    assert deg.degraded_steps > 0


def test_degraded_results_match_oracle_fallback_values():
    """Degraded serves come from the fallback stub — same oracle here, so
    the run must equal the outage-free run bit-for-bit except the flag."""
    req = OptimizeRequest("a", "C1=CC=CC=C1O", budget=6, seed=1)
    clean = _service(1)
    clean.submit(req); clean.run_until_idle()
    flaky = _service(1, prop=_ScriptedService({1, 2, 3}),
                     breaker_threshold=2, breaker_cooldown=50)
    flaky.submit(req); flaky.run_until_idle()
    a, b = clean.result_by_id["a"], flaky.result_by_id["a"]
    assert b.status == "degraded" and b.degraded_steps > 0
    assert a.best_smiles == b.best_smiles
    assert np.float64(a.best_reward).tobytes() \
        == np.float64(b.best_reward).tobytes()


# ------------------------------------------------------------------ #
# fault plan: request site + the equivalence contract
# ------------------------------------------------------------------ #
def test_request_site_transient_faults_retry_bind():
    plan = FaultPlan([FaultRule(site="request", kind="transient", rate=1.0,
                                fail_attempts=2)], seed=0)
    svc = _service(2, plan=plan)
    svc.submit(OptimizeRequest("a", "C1=CC=CC=C1O", budget=3))
    svc.run_until_idle()
    assert svc.result_by_id["a"].status == "completed"
    assert svc.n_bind_retries == 2                 # bounded by fail_attempts


def test_request_site_crash_fails_with_incident():
    plan = FaultPlan([FaultRule(site="request", kind="crash", rate=1.0)],
                     seed=0)
    svc = _service(2, plan=plan)
    svc.submit(OptimizeRequest("a", "C1=CC=CC=C1O", budget=3))
    svc.run_until_idle()
    r = svc.result_by_id["a"]
    assert r.status == "failed" and "FaultError" in r.error
    assert [(i.site, i.key) for i in svc.incidents] == [("request", "a")]


def test_faulted_stream_is_deterministic():
    sigs = [_signature(_drive(_service(4, plan=_plan(), epsilon=0.05),
                              n=16, invalid_every=7))
            for _ in range(2)]
    assert sigs[0] == sigs[1]


def test_fault_free_requests_bit_identical_to_unfaulted_run():
    faulted = _drive(_service(4, plan=_plan(), epsilon=0.05), n=16,
                     invalid_every=7)
    clean = _drive(_service(4, epsilon=0.05), n=16, invalid_every=7)
    untouched = [r for r in faulted.results
                 if r.status == "completed" and r.degraded_steps == 0]
    assert untouched, "fault plan drowned every request — weaken it"
    for r in untouched:
        ur = clean.result_by_id[r.request_id]
        assert ur.status == "completed"
        assert ur.steps_used == r.steps_used
        assert ur.best_smiles == r.best_smiles
        assert np.float64(ur.best_reward).tobytes() \
            == np.float64(r.best_reward).tobytes()


def test_stats_are_coherent():
    svc = _drive(_service(2, plan=_plan(), max_queue=4, epsilon=0.05),
                 n=12, rate=6.0, deadline_frac=0.4, invalid_every=5)
    st = svc.stats()
    assert st["n_submitted"] == 12
    assert sum(st["status_counts"].values()) == 12
    assert st["n_q_dispatches"] == st["n_service_steps"]
    assert st["queue"]["n_offered"] <= 12
    assert st["breaker"]["state"] in ("closed", "open", "half_open")
