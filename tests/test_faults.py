"""Deterministic fault injection + the retry/quarantine machinery.

The three gates this module pins (ISSUE-8 acceptance):

* a FaultPlan's injection schedule is reproducible — and for keyed (chem)
  sites independent of thread/call order;
* retried property batches are BIT-identical to first-try batches (the
  injection point sits before the deterministic predictor);
* training under a seeded FaultPlan whose faults stay inside the retry
  budgets is bit-identical to the fault-free run, while exhausted budgets
  degrade to quarantined slots + structured incident records — never a
  crash, never silent divergence.
"""

import numpy as np
import pytest

from repro.chem.smiles import from_smiles
from repro.core import DQNConfig, EnvConfig, RewardConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer
from repro.core.faults import (
    FaultError, FaultPlan, FaultRule, FaultTimeout, TransientFault,
)
from repro.predictors.service import ResilientService, RetryPolicy

from conftest import OracleService

MOLS = [from_smiles(s) for s in
        ("C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O",
         "CC1=CC=CC=C1O", "OC1=CC=CC=C1O")]


def _trainer(fault_plan=None, service=None, **over) -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=2, mols_per_worker=2, episodes=2, updates_per_episode=2,
        train_batch_size=8, max_candidates=16,
        dqn=DQNConfig(epsilon_decay=0.9), env=EnvConfig(max_steps=3),
        seed=0, **over)
    return DistributedTrainer(
        cfg, MOLS, service if service is not None else OracleService(),
        RewardConfig(), network=QNetwork(hidden=(32,)),
        fault_plan=fault_plan)


def _fingerprints(tr) -> tuple:
    """Everything the equivalence gate compares: replay state + params."""
    import jax
    reps = tuple(tuple(sorted((k, v.tobytes()) for k, v in
                              b.state_dict().items())) for b in tr.buffers)
    params = tuple(np.asarray(l).tobytes()
                   for l in jax.tree_util.tree_leaves(tr.params))
    return reps, params


# ------------------------------------------------------------------ #
# FaultPlan semantics
# ------------------------------------------------------------------ #
def test_rule_validation():
    with pytest.raises(ValueError):
        FaultRule(site="predict", kind="nope", every=1)
    with pytest.raises(ValueError):
        FaultRule(site="predict")                    # neither every nor rate
    with pytest.raises(ValueError):
        FaultRule(site="predict", every=2, rate=0.5)  # both
    with pytest.raises(ValueError):
        FaultRule(site="predict", every=0)
    with pytest.raises(ValueError):                  # duplicate site
        FaultPlan([FaultRule(site="predict", every=1),
                   FaultRule(site="predict", every=2)])


def test_serial_schedule_counts_logical_calls():
    """every=3, fail_attempts=2: logical calls 3, 6, ... fail exactly twice
    each (each retry re-enters the checker), then succeed."""
    plan = FaultPlan([FaultRule(site="predict", kind="transient",
                                every=3, fail_attempts=2)])
    pattern = []
    for _ in range(12):          # 12 logical calls with in-place retries
        attempts = 0
        while True:
            try:
                plan.check_call("predict")
                break
            except TransientFault:
                attempts += 1
        pattern.append(attempts)
    assert pattern == [0, 0, 2, 0, 0, 2, 0, 0, 2, 0, 0, 2]
    assert plan.n_injected == 4 * 2


def test_serial_schedule_reproducible():
    def run():
        plan = FaultPlan([FaultRule(site="checkpoint", every=2)])
        out = []
        for _ in range(8):
            try:
                plan.check_call("checkpoint")
                out.append(0)
            except TransientFault:
                out.append(1)
        return out
    assert run() == run()


def test_keyed_schedule_is_call_order_independent():
    """chem faults key on CONTENT: any arrival order of the same key set
    injects the identical fault set — the pipelined threads' soundness."""
    keys = [f"mol-{i}" for i in range(50)]

    def faulted(order):
        plan = FaultPlan([FaultRule(site="chem", rate=0.3,
                                    fail_attempts=1)], seed=7)
        hit = set()
        for k in order:
            try:
                plan.check_key("chem", k)
            except TransientFault:
                hit.add(k)
        return hit

    fwd = faulted(keys)
    rev = faulted(list(reversed(keys)))
    assert fwd == rev
    assert 0 < len(fwd) < len(keys)      # the rate actually bites


def test_keyed_fail_attempts_per_key():
    plan = FaultPlan([FaultRule(site="chem", rate=1.0, fail_attempts=2)])
    n_fail = 0
    for _ in range(3):
        try:
            plan.check_key("chem", "k")
        except TransientFault:
            n_fail += 1
    assert n_fail == 2                   # third attempt succeeds


def test_fault_kinds_map_to_exceptions():
    plan = FaultPlan([FaultRule(site="a", kind="timeout", every=1),
                      FaultRule(site="b", kind="crash", every=1)])
    with pytest.raises(FaultTimeout):
        plan.check_call("a")
    with pytest.raises(FaultError):
        plan.check_call("b")


# ------------------------------------------------------------------ #
# ResilientService
# ------------------------------------------------------------------ #
def test_retried_batch_bit_identical():
    """THE retry gate: a batch that succeeded only after transient faults
    must equal the batch a fault-free service returns, bit for bit."""
    plan = FaultPlan([FaultRule(site="predict", kind="transient",
                                every=1, fail_attempts=2)])
    svc = ResilientService(OracleService(), RetryPolicy(max_retries=3),
                           fault_plan=plan, sleep=None)
    ref = OracleService().predict(MOLS)
    got = svc.predict(MOLS)
    assert svc.n_retries == 2 and plan.n_injected == 2
    for g, r in zip(got, ref, strict=True):
        assert g == r


def test_retries_exhausted_escalate_to_fault_error():
    plan = FaultPlan([FaultRule(site="predict", kind="transient",
                                every=1, fail_attempts=50)])
    svc = ResilientService(OracleService(), RetryPolicy(max_retries=2),
                           fault_plan=plan, sleep=None)
    with pytest.raises(FaultError):
        svc.predict(MOLS[:1])
    assert svc.n_retries == 2


def test_real_exceptions_propagate_unretried():
    class Broken:
        def predict(self, mols):
            raise ValueError("a bug, not weather")
    svc = ResilientService(Broken(), RetryPolicy(max_retries=3), sleep=None)
    with pytest.raises(ValueError):
        svc.predict(MOLS[:1])
    assert svc.n_retries == 0


def test_timeout_then_recovery():
    import time as _time

    class SlowOnce:
        def __init__(self):
            self.calls = 0

        def predict(self, mols):
            self.calls += 1
            if self.calls == 1:
                _time.sleep(0.6)   # in (timeout, 2*timeout): the retry's
            return ["ok"] * len(mols)  # queued call still beats deadline 2

    svc = ResilientService(SlowOnce(), RetryPolicy(max_retries=2,
                                                   timeout_s=0.4),
                           sleep=None)
    assert svc.predict(MOLS[:2]) == ["ok", "ok"]
    assert svc.n_timeouts == 1 and svc.n_retries == 1


def test_backoff_deterministic_and_capped():
    p = RetryPolicy(backoff_base_s=0.1, backoff_cap_s=0.5, seed=3)
    a = ResilientService(OracleService(), p, sleep=None)
    b = ResilientService(OracleService(), p, sleep=None)
    sa = [a._backoff_s(k) for k in range(8)]
    sb = [b._backoff_s(k) for k in range(8)]
    assert sa == sb
    assert all(0 < s <= 0.5 for s in sa)


def test_delegation_passes_through():
    inner = OracleService()
    svc = ResilientService(inner, sleep=None)
    svc.predict(MOLS)
    assert svc.n_calls == inner.n_calls >= 1   # __getattr__ delegation


# ------------------------------------------------------------------ #
# training under faults
# ------------------------------------------------------------------ #
def test_training_bit_identical_under_absorbed_faults():
    """ISSUE-8 criterion: property-service timeouts + chem transients
    inside the retry budgets leave training BIT-identical to fault-free."""
    ref = _trainer()
    ref.train(2)

    plan = FaultPlan([
        FaultRule(site="predict", kind="timeout", every=3, fail_attempts=1),
        FaultRule(site="chem", kind="transient", rate=0.4, fail_attempts=1),
    ], seed=11)
    svc = ResilientService(OracleService(), RetryPolicy(seed=11),
                           fault_plan=plan, sleep=None)
    tr = _trainer(fault_plan=plan, service=svc)
    tr.train(2)

    assert plan.n_injected > 0, "the plan never fired — vacuous test"
    assert tr.engine.fault_stats()["n_quarantined"] == 0
    assert _fingerprints(tr) == _fingerprints(ref)


def test_exhausted_chem_retries_quarantine_with_incidents():
    """Terminal chem faults drain slots to dead with structured incident
    records; training completes (no crash) and the fleet revives next
    episode."""
    plan = FaultPlan([FaultRule(site="chem", kind="transient",
                                rate=0.5, fail_attempts=50)], seed=2)
    tr = _trainer(fault_plan=plan)
    tr.train(2)
    st = tr.engine.fault_stats()
    assert st["n_quarantined"] > 0
    assert st["n_incidents"] >= st["n_quarantined"]
    inc = st["incidents"][0]
    assert inc["site"] == "chem" and inc["action"] == "quarantined"
    assert inc["worker"] >= 0 and inc["slot"] >= 0 and inc["key"]
    # quarantine is not contagious: the survivors kept training
    assert sum(len(b) for b in tr.buffers) > 0


def test_exhausted_predict_retries_quarantine_fleet_step():
    """A predict batch whose per-molecule isolation also exhausts drains
    the affected slots; the run still completes."""
    plan = FaultPlan([FaultRule(site="predict", kind="transient",
                                every=1, fail_attempts=10 ** 6)], seed=0)
    svc = ResilientService(OracleService(), RetryPolicy(max_retries=1),
                           fault_plan=plan, sleep=None)
    tr = _trainer(fault_plan=plan, service=svc)
    tr.train(1)
    st = tr.engine.fault_stats()
    assert st["n_quarantined"] == tr.cfg.n_workers * tr.cfg.mols_per_worker
    assert all(i["site"] == "predict" and i["action"] == "quarantined"
               for i in st["incidents"])
    assert all(len(b) == 0 for b in tr.buffers)   # nothing half-committed


def test_pipelined_shard_crash_restarts_bit_identical():
    """A pipelined enumeration thread dying mid-shard is restarted inline
    by the supervisor; transitions match the unfaulted pipelined run."""
    ref = _trainer(rollout="fleet_pipelined", acting="packed_async")
    ref.train(2)

    plan = FaultPlan([FaultRule(site="pipeline", kind="crash", every=4,
                                fail_attempts=1)], seed=0)
    tr = _trainer(fault_plan=plan, rollout="fleet_pipelined",
                  acting="packed_async")
    tr.train(2)
    st = tr.engine.fault_stats()
    assert st["n_pipeline_restarts"] > 0
    assert any(i["site"] == "pipeline" and i["action"] == "restarted"
               for i in st["incidents"])
    assert _fingerprints(tr) == _fingerprints(ref)


def test_multi_slot_same_step_incident_order_and_revival():
    """Several slots failing in the SAME fleet step must produce incident
    records in deterministic worker-major order, and ``reset()`` must
    revive every quarantined slot: a fleet that is fully drained each
    episode re-quarantines the SAME population next episode — proof the
    slots came back."""
    plan = FaultPlan([FaultRule(site="predict", kind="transient",
                                every=1, fail_attempts=10 ** 6)], seed=0)
    svc = ResilientService(OracleService(), RetryPolicy(max_retries=1),
                           fault_plan=plan, sleep=None)
    tr = _trainer(fault_plan=plan, service=svc)
    tr.train(2)
    st = tr.engine.fault_stats()
    n_slots = tr.cfg.n_workers * tr.cfg.mols_per_worker
    # revival: every slot died in episode 0 AND AGAIN in episode 1
    assert st["n_quarantined"] == 2 * n_slots
    episodes = {i["episode"] for i in st["incidents"]}
    assert len(episodes) == 2
    # ordering: within one (episode, step) batch-failure the per-slot
    # incidents land worker-major, slot-minor — stable across runs
    by_batch = {}
    for i in st["incidents"]:
        by_batch.setdefault((i["episode"], i["step"]), []).append(
            (i["worker"], i["slot"]))
    for batch in by_batch.values():
        assert batch == sorted(batch)
    all_pairs = sorted(p for b in by_batch.values() for p in b)
    assert all_pairs == sorted(
        [(w, s) for w in range(tr.cfg.n_workers)
         for s in range(tr.cfg.mols_per_worker)] * 2)


# ------------------------------------------------------------------ #
# reward-site faults: a raising objective quarantines ITS slot, not the
# fleet (pre-PR-10 a custom objective's exception escaped _apply_step
# and crashed every worker)
# ------------------------------------------------------------------ #
def _ok_objective(props, initial, current, steps_left):
    return 0.01 * current.num_atoms + 0.1 * steps_left


def _boom_objective(props, initial, current, steps_left):
    raise RuntimeError("objective exploded")


def test_raising_objective_quarantines_slot_not_fleet():
    """Worker 1 runs an objective that raises on every evaluation: its
    slots drain with structured ``site="reward"`` incidents, the run
    completes, worker 0's replay is bit-identical to an all-ok fleet's,
    and reset() revives worker 1 next episode (it re-quarantines — proof
    the slots came back)."""
    tr = _trainer()
    tr.engine.set_worker_objectives([_ok_objective, _boom_objective])
    tr.train(2)                                      # no crash
    st = tr.engine.fault_stats()
    # both of worker 1's slots die at step one of BOTH episodes (revival)
    assert st["n_quarantined"] == 2 * tr.cfg.mols_per_worker
    assert all(i["site"] == "reward" and i["action"] == "quarantined"
               and i["worker"] == 1 for i in st["incidents"])
    assert all("objective exploded" in i["error"] for i in st["incidents"])
    assert all(i["key"] for i in st["incidents"])    # molecule attribution
    assert {i["episode"] for i in st["incidents"]} == {1, 2}
    assert len(tr.buffers[1]) == 0                   # nothing half-committed

    ref = _trainer()
    ref.engine.set_worker_objectives([_ok_objective, _ok_objective])
    ref.train(2)
    assert ref.engine.fault_stats()["n_quarantined"] == 0

    def txns(buf):
        return [(t.state_fp.tobytes(), t.steps_left_frac, t.reward, t.done,
                 t.next_fps.tobytes(), t.next_steps_left_frac)
                for t in buf._items]

    # quarantine is not contagious: worker 0's transition stream is
    # bit-identical to the all-ok run's
    assert txns(tr.buffers[0]) and txns(tr.buffers[0]) == txns(ref.buffers[0])


def test_set_worker_objectives_validates_length():
    tr = _trainer()
    with pytest.raises(ValueError, match="objectives"):
        tr.engine.set_worker_objectives([_ok_objective])


def test_incident_trail_deterministic_across_runs():
    """The full incident trail (site/worker/slot/key/action per episode
    and step) is a pure function of the seeded plan — two identical runs
    produce identical trails, so operators can diff them."""
    def trail():
        plan = FaultPlan([FaultRule(site="chem", kind="transient",
                                    rate=0.5, fail_attempts=50)], seed=2)
        tr = _trainer(fault_plan=plan)
        tr.train(2)
        return [(i["episode"], i["step"], i["site"], i["worker"],
                 i["slot"], i["key"], i["action"])
                for i in tr.engine.fault_stats()["incidents"]]

    t1, t2 = trail(), trail()
    assert t1 and t1 == t2
