"""The committed perf-trajectory series: BENCH_*.json discovery, schema
validation (fail loudly on a mangled snapshot — the headline PR-7 bugfix),
chronological PR-number ordering, and the per-metric diff."""

import json

import pytest

from benchmarks.common import (
    BENCH_SNAPSHOT_SCHEMA, BenchTrajectoryError, diff_bench_trajectory,
    load_bench_trajectory,
)


def _snapshot(**summary) -> dict:
    return {"schema": BENCH_SNAPSHOT_SCHEMA,
            "host": {"platform": "test", "backend": "cpu", "devices": 1},
            "summary": summary, "metrics": {}}


def _write(tmp_path, name: str, data) -> None:
    (tmp_path / name).write_text(
        data if isinstance(data, str) else json.dumps(data))


# ------------------------------------------------------------------ #
# discovery + ordering
# ------------------------------------------------------------------ #
def test_loads_series_in_pr_number_order(tmp_path):
    """Numeric ordering: PR10 sorts AFTER PR9 even though the lexicographic
    glob order says otherwise."""
    _write(tmp_path, "BENCH_PR10.json", _snapshot(x=3))
    _write(tmp_path, "BENCH_PR9.json", _snapshot(x=2))
    _write(tmp_path, "BENCH_PR6.json", _snapshot(x=1))
    snaps = load_bench_trajectory(str(tmp_path))
    assert [s["pr"] for s in snaps] == [6, 9, 10]
    assert [s["name"] for s in snaps] == \
        ["BENCH_PR6.json", "BENCH_PR9.json", "BENCH_PR10.json"]
    assert all(s["schema"] == BENCH_SNAPSHOT_SCHEMA for s in snaps)


def test_empty_directory_yields_empty_series(tmp_path):
    assert load_bench_trajectory(str(tmp_path)) == []


# ------------------------------------------------------------------ #
# fail-loudly validation (the bugfix: no silent [] from a bad snapshot)
# ------------------------------------------------------------------ #
def test_malformed_json_raises(tmp_path):
    _write(tmp_path, "BENCH_PR6.json", _snapshot(x=1))
    _write(tmp_path, "BENCH_PR7.json", '{"schema": "bench-snapsh')  # truncated
    with pytest.raises(BenchTrajectoryError, match="malformed JSON"):
        load_bench_trajectory(str(tmp_path))


def test_wrong_schema_raises(tmp_path):
    bad = _snapshot(x=1)
    bad["schema"] = "bench-snapshot-v0"
    _write(tmp_path, "BENCH_PR6.json", bad)
    with pytest.raises(BenchTrajectoryError, match="bench-snapshot-v1"):
        load_bench_trajectory(str(tmp_path))


def test_unrecognised_name_raises(tmp_path):
    _write(tmp_path, "BENCH_final.json", _snapshot(x=1))
    with pytest.raises(BenchTrajectoryError, match="BENCH_PR<n>"):
        load_bench_trajectory(str(tmp_path))


def test_missing_section_raises(tmp_path):
    bad = _snapshot(x=1)
    del bad["summary"]
    _write(tmp_path, "BENCH_PR6.json", bad)
    with pytest.raises(BenchTrajectoryError, match="summary"):
        load_bench_trajectory(str(tmp_path))


def test_non_object_snapshot_raises(tmp_path):
    _write(tmp_path, "BENCH_PR6.json", [1, 2, 3])
    with pytest.raises(BenchTrajectoryError, match="not an object"):
        load_bench_trajectory(str(tmp_path))


# ------------------------------------------------------------------ #
# the serve section (PR 9): optional for old snapshots, strict when present
# ------------------------------------------------------------------ #
def _serve_cell(**over) -> dict:
    cell = {"requests_per_s": 55.0, "p50_latency_ms": 480.0,
            "p99_latency_ms": 990.0, "completed": 15, "degraded": 16,
            "shed": 22, "deadline_exceeded": 11, "failed": 10,
            "recompiles_after_warmup": 0}
    cell.update(over)
    return cell


def test_serve_section_is_optional_for_old_snapshots(tmp_path):
    _write(tmp_path, "BENCH_PR6.json", _snapshot(x=1))           # pre-serving
    with_serve = _snapshot(x=2)
    with_serve["serve"] = _serve_cell()
    _write(tmp_path, "BENCH_PR9.json", with_serve)
    snaps = load_bench_trajectory(str(tmp_path))
    assert "serve" not in snaps[0]
    assert snaps[1]["serve"]["requests_per_s"] == 55.0


def test_partial_serve_section_raises(tmp_path):
    bad = _snapshot(x=1)
    bad["serve"] = _serve_cell()
    del bad["serve"]["p99_latency_ms"], bad["serve"]["shed"]
    _write(tmp_path, "BENCH_PR9.json", bad)
    with pytest.raises(BenchTrajectoryError,
                       match=r"serve section missing.*p99_latency_ms"):
        load_bench_trajectory(str(tmp_path))


def test_non_object_serve_section_raises(tmp_path):
    bad = _snapshot(x=1)
    bad["serve"] = [1, 2]
    _write(tmp_path, "BENCH_PR9.json", bad)
    with pytest.raises(BenchTrajectoryError, match="non-object 'serve'"):
        load_bench_trajectory(str(tmp_path))


# ------------------------------------------------------------------ #
# the diff
# ------------------------------------------------------------------ #
def test_diff_rows_and_delta_pct(tmp_path):
    _write(tmp_path, "BENCH_PR6.json", _snapshot(speed=100.0, dropped=7))
    _write(tmp_path, "BENCH_PR7.json", _snapshot(speed=150.0, fresh="cpu"))
    rows = diff_bench_trajectory(load_bench_trajectory(str(tmp_path)))
    by_metric = {r["metric"]: r for r in rows}
    assert by_metric["speed"]["delta_pct"] == pytest.approx(50.0)
    assert by_metric["speed"]["from"] == "BENCH_PR6.json"
    assert by_metric["speed"]["to"] == "BENCH_PR7.json"
    assert by_metric["dropped"]["new"] is None          # metric dropped
    assert by_metric["dropped"]["delta_pct"] is None
    assert by_metric["fresh"]["old"] is None            # metric added
    assert by_metric["fresh"]["delta_pct"] is None      # non-numeric anyway


def test_diff_single_snapshot_is_empty(tmp_path):
    _write(tmp_path, "BENCH_PR6.json", _snapshot(x=1))
    assert diff_bench_trajectory(load_bench_trajectory(str(tmp_path))) == []


# ------------------------------------------------------------------ #
# the real committed series (PR-7 acceptance: non-empty, diffable)
# ------------------------------------------------------------------ #
def test_committed_series_loads_and_diffs():
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    snaps = load_bench_trajectory(root)
    assert len(snaps) >= 2, "repo must commit BENCH_PR6.json and BENCH_PR7.json"
    rows = diff_bench_trajectory(snaps)
    assert rows, "committed series produced no diff rows"
    assert any(r["delta_pct"] is not None for r in rows), \
        "no shared numeric metric between consecutive committed snapshots"
