"""Chemistry substrate: invariants, actions, fingerprints, SMILES, oracle."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # declared in pyproject [test]; degrade to a skip
    HAVE_HYPOTHESIS = False

from repro.chem import (
    ALLOWED_RING_SIZES, Molecule, enumerate_actions,
    morgan_fingerprint, IncrementalMorgan, oracle_bde, oracle_ip,
    has_valid_conformer, sa_score, qed_score, penalized_logp, tanimoto,
)
from repro.chem.actions import enumerate_actions_naive
from repro.chem.fingerprint import batch_morgan_fingerprints, morgan_fingerprint_reference
from repro.chem.molecule import iso_hash, refine_invariants
from repro.chem.smiles import canonical_smiles, from_smiles, to_smiles

PHENOL = "C1=CC=CC=C1O"
BHT_ISH = "CC1=CC(C)=CC(C)=C1O"


@pytest.fixture(scope="module")
def phenol():
    return from_smiles(PHENOL)


@pytest.fixture(scope="module")
def bht():
    return from_smiles(BHT_ISH)


# ------------------------------------------------------------------ #
# molecule basics
# ------------------------------------------------------------------ #
def test_valences_and_oh(phenol):
    phenol.check_valences()
    assert phenol.has_oh_bond()
    assert phenol.num_atoms == 7
    assert len(phenol.ring_info()) == 1
    assert len(phenol.ring_info()[0]) == 6


def test_ring_info_never_writes_bonds():
    """The pipelined rollout enumerates molecules on host threads while the
    property path computes ring_info on the same objects, so ring_info must
    not touch self.bonds even transiently (regression: it used to zero and
    restore each cycle bond, a data race under the overlap)."""
    mol = from_smiles(PHENOL)
    mol.bonds.flags.writeable = False      # any write now raises
    rings = mol.ring_info()
    assert len(rings) == 1 and len(rings[0]) == 6


def test_canonical_key_permutation_invariant(bht):
    rng = np.random.default_rng(0)
    for _ in range(5):
        perm = rng.permutation(bht.num_atoms)
        m2 = Molecule(bht.elements[perm], bht.bonds[np.ix_(perm, perm)])
        assert m2.canonical_key() == bht.canonical_key()
        assert iso_hash(m2) == iso_hash(bht)
        assert canonical_smiles(m2) == canonical_smiles(bht)


def test_iso_hash_distinguishes(phenol, bht):
    assert iso_hash(phenol) != iso_hash(bht)


def test_largest_fragment(phenol):
    # break the C-O bond: O falls off, ring is kept
    i = int(phenol.oh_oxygens()[0])
    j = int(phenol.neighbors(i)[0])
    frag = phenol.with_bond_delta(i, j, -1).largest_fragment()
    assert frag.num_atoms == 6
    assert not frag.has_oh_bond()


# ------------------------------------------------------------------ #
# actions
# ------------------------------------------------------------------ #
def test_actions_match_naive(phenol, bht):
    for mol in (phenol, bht):
        fast = {a.result.canonical_key() for a in enumerate_actions(mol)}
        slow = {a.result.canonical_key() for a in enumerate_actions_naive(mol)}
        assert fast == slow


def test_oh_protection(phenol):
    for a in enumerate_actions(phenol, protect_oh=True):
        assert a.result.has_oh_bond(), a
    unprotected = enumerate_actions(phenol, protect_oh=False)
    assert any(not a.result.has_oh_bond() for a in unprotected)


def test_ring_size_constraint(phenol):
    for a in enumerate_actions(phenol):
        for ring in a.result.ring_info():
            assert len(ring) in ALLOWED_RING_SIZES | {6}


def test_no_op_present(phenol):
    acts = enumerate_actions(phenol)
    assert acts[0].kind == "no_op"
    assert acts[0].result is phenol


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10**6))
    def test_random_walk_preserves_invariants(seed):
        rng = np.random.default_rng(seed)
        mol = from_smiles(PHENOL)
        for _ in range(4):
            acts = enumerate_actions(mol, max_atoms=14)
            a = acts[int(rng.integers(0, len(acts)))]
            mol = a.result
            mol.check_valences()
            assert mol.has_oh_bond()
            assert mol.num_atoms <= 15
else:
    def test_random_walk_preserves_invariants():
        pytest.importorskip("hypothesis")


# ------------------------------------------------------------------ #
# fingerprints
# ------------------------------------------------------------------ #
def test_incremental_equals_full(bht):
    inc = IncrementalMorgan(bht)
    assert np.array_equal(inc.fingerprint(counts=True),
                          morgan_fingerprint(bht, counts=True))
    for a in enumerate_actions(bht)[:40]:
        inc2 = inc.after_action(a.result, a.kind, a.detail)
        assert np.array_equal(inc2.fingerprint(counts=True),
                              morgan_fingerprint(a.result, counts=True)), a


def test_batch_equals_single(phenol, bht):
    mols = [a.result for a in enumerate_actions(bht)[:25]] + [phenol]
    batch = batch_morgan_fingerprints(mols, counts=True)
    for i, m in enumerate(mols):
        assert np.array_equal(batch[i], morgan_fingerprint(m, counts=True))


def test_fingerprint_permutation_invariant(bht):
    rng = np.random.default_rng(1)
    perm = rng.permutation(bht.num_atoms)
    m2 = Molecule(bht.elements[perm], bht.bonds[np.ix_(perm, perm)])
    assert np.array_equal(morgan_fingerprint(m2, counts=True),
                          morgan_fingerprint(bht, counts=True))


def test_reference_fingerprint_runs(bht):
    fp = morgan_fingerprint_reference(bht)
    assert fp.shape == (2048,) and fp.sum() > 0


# ------------------------------------------------------------------ #
# SMILES
# ------------------------------------------------------------------ #
def test_smiles_roundtrip_actions(bht):
    for a in enumerate_actions(bht):
        s = canonical_smiles(a.result)
        m = from_smiles(s)
        assert m.canonical_key() == a.result.canonical_key(), s


def test_smiles_multifragment():
    assert from_smiles("C.O").num_atoms == 2


# ------------------------------------------------------------------ #
# oracle / properties
# ------------------------------------------------------------------ #
def test_oracle_tradeoff_direction(phenol):
    """Adding an ortho amino group must lower BDE *and* lower IP (§2.1)."""
    ring_c = int(phenol.neighbors(phenol.oh_oxygens()[0])[0])
    ortho = [int(v) for v in phenol.neighbors(ring_c) if phenol.symbol(v) == "C"][0]
    sub = phenol.with_added_atom("N", ortho, 1)
    assert oracle_bde(sub) < oracle_bde(phenol)
    assert oracle_ip(sub) < oracle_ip(phenol)


def test_oracle_bde_none_without_oh():
    assert oracle_bde(from_smiles("C1=CC=CC=C1")) is None


def test_conformer_validity_rules(phenol):
    assert has_valid_conformer(phenol)
    # triple bond in a ring is invalid
    bad = from_smiles("C1=CC=CC=C1O")
    bonds = bad.bonds.copy()
    bonds[1, 2] = bonds[2, 1] = 3
    m = Molecule(bad.elements, bonds)
    assert not has_valid_conformer(m)


def test_scores_ranges(bht):
    assert 1.0 <= sa_score(bht) <= 8.0
    assert 0.0 < qed_score(bht) < 0.95
    assert penalized_logp(bht) < 5
    assert tanimoto(bht, bht) == 1.0
    assert 0.0 <= tanimoto(bht, from_smiles(PHENOL)) < 1.0
