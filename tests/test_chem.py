"""Chemistry substrate: invariants, actions, fingerprints, SMILES, oracle."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # declared in pyproject [test]; degrade to a skip
    HAVE_HYPOTHESIS = False

from repro.chem import (
    ALLOWED_RING_SIZES, Molecule, enumerate_actions,
    morgan_fingerprint, IncrementalMorgan, oracle_bde, oracle_ip,
    has_valid_conformer, sa_score, qed_score, penalized_logp, tanimoto,
)
from repro.chem.actions import enumerate_actions_naive, enumerate_actions_ref
from repro.chem.fingerprint import (
    batch_fingerprints_incremental, batch_morgan_fingerprints,
    incremental_fingerprints_grouped, morgan_fingerprint_reference)
from repro.chem.molecule import iso_hash, refine_invariants
from repro.chem.smiles import canonical_smiles, from_smiles, to_smiles

PHENOL = "C1=CC=CC=C1O"
BHT_ISH = "CC1=CC(C)=CC(C)=C1O"


@pytest.fixture(scope="module")
def phenol():
    return from_smiles(PHENOL)


@pytest.fixture(scope="module")
def bht():
    return from_smiles(BHT_ISH)


# ------------------------------------------------------------------ #
# molecule basics
# ------------------------------------------------------------------ #
def test_valences_and_oh(phenol):
    phenol.check_valences()
    assert phenol.has_oh_bond()
    assert phenol.num_atoms == 7
    assert len(phenol.ring_info()) == 1
    assert len(phenol.ring_info()[0]) == 6


def test_ring_info_never_writes_bonds():
    """The pipelined rollout enumerates molecules on host threads while the
    property path computes ring_info on the same objects, so ring_info must
    not touch self.bonds even transiently (regression: it used to zero and
    restore each cycle bond, a data race under the overlap)."""
    mol = from_smiles(PHENOL)
    mol.bonds.flags.writeable = False      # any write now raises
    rings = mol.ring_info()
    assert len(rings) == 1 and len(rings[0]) == 6


def test_canonical_key_permutation_invariant(bht):
    rng = np.random.default_rng(0)
    for _ in range(5):
        perm = rng.permutation(bht.num_atoms)
        m2 = Molecule(bht.elements[perm], bht.bonds[np.ix_(perm, perm)])
        assert m2.canonical_key() == bht.canonical_key()
        assert iso_hash(m2) == iso_hash(bht)
        assert canonical_smiles(m2) == canonical_smiles(bht)


def test_iso_hash_distinguishes(phenol, bht):
    assert iso_hash(phenol) != iso_hash(bht)


def test_largest_fragment(phenol):
    # break the C-O bond: O falls off, ring is kept
    i = int(phenol.oh_oxygens()[0])
    j = int(phenol.neighbors(i)[0])
    frag = phenol.with_bond_delta(i, j, -1).largest_fragment()
    assert frag.num_atoms == 6
    assert not frag.has_oh_bond()


# ------------------------------------------------------------------ #
# actions
# ------------------------------------------------------------------ #
def test_actions_match_naive(phenol, bht):
    for mol in (phenol, bht):
        fast = {a.result.canonical_key() for a in enumerate_actions(mol)}
        slow = {a.result.canonical_key() for a in enumerate_actions_naive(mol)}
        assert fast == slow


def test_oh_protection(phenol):
    for a in enumerate_actions(phenol, protect_oh=True):
        assert a.result.has_oh_bond(), a
    unprotected = enumerate_actions(phenol, protect_oh=False)
    assert any(not a.result.has_oh_bond() for a in unprotected)


def test_ring_size_constraint(phenol):
    for a in enumerate_actions(phenol):
        for ring in a.result.ring_info():
            assert len(ring) in ALLOWED_RING_SIZES | {6}


def test_no_op_present(phenol):
    acts = enumerate_actions(phenol)
    assert acts[0].kind == "no_op"
    assert acts[0].result is phenol


def _action_signature(a):
    r = a.result
    return (a.kind, a.detail, r.elements.tobytes(), r.bonds.tobytes())


def test_delta_enumeration_matches_ref(phenol, bht):
    """The delta enumerator must reproduce the reference action list EXACTLY
    — same order, same details, same concrete (labelled) result arrays —
    across every option combination, not just as a canonical-key set."""
    import itertools
    for mol in (phenol, bht, Molecule.empty(), from_smiles("O"),
                from_smiles("OO"), from_smiles("CC(=O)O")):
        for rem, noop, prot in itertools.product([True, False], repeat=3):
            for max_atoms in (38, 8):
                ref = enumerate_actions_ref(
                    mol, allow_removal=rem, allow_no_op=noop,
                    protect_oh=prot, max_atoms=max_atoms)
                new = enumerate_actions(
                    mol, allow_removal=rem, allow_no_op=noop,
                    protect_oh=prot, max_atoms=max_atoms)
                assert [_action_signature(a) for a in new] == \
                       [_action_signature(a) for a in ref]


def test_delta_enumeration_is_lazy(bht):
    """Only fragment-dropping removals may materialise eagerly; every other
    edit builds its Molecule on first ``result`` access (the engine only
    ever materialises the CHOSEN action)."""
    acts = enumerate_actions(bht)
    lazy = [a for a in acts if not a.materialized]
    assert len(lazy) > len(acts) // 2
    a = lazy[0]
    r1 = a.result                      # materialises now
    assert a.materialized and a.result is r1


def test_molecule_caches_are_read_only(bht):
    fv = bht.free_valences()
    assert bht.free_valences() is fv   # memoised
    sp = bht.all_pairs_shortest_paths()
    assert bht.all_pairs_shortest_paths() is sp
    with pytest.raises(ValueError):
        fv[0] = 99
    with pytest.raises(ValueError):
        sp[0, 0] = 99


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10**6))
    def test_random_walk_preserves_invariants(seed):
        rng = np.random.default_rng(seed)
        mol = from_smiles(PHENOL)
        for _ in range(4):
            acts = enumerate_actions(mol, max_atoms=14)
            a = acts[int(rng.integers(0, len(acts)))]
            mol = a.result
            mol.check_valences()
            assert mol.has_oh_bond()
            assert mol.num_atoms <= 15

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10**6),
           st.sampled_from([PHENOL, BHT_ISH, "CC(=O)O", "OO"]))
    def test_delta_enumeration_matches_ref_random_walks(seed, smiles):
        """Random-walk property layer over the exact-pinning test: at every
        visited molecule the delta enumerator equals the reference."""
        rng = np.random.default_rng(seed)
        mol = from_smiles(smiles)
        for _ in range(4):
            ref = enumerate_actions_ref(mol, max_atoms=16)
            new = enumerate_actions(mol, max_atoms=16)
            assert [_action_signature(a) for a in new] == \
                   [_action_signature(a) for a in ref]
            if not new:
                break
            mol = new[int(rng.integers(0, len(new)))].result
else:
    def test_random_walk_preserves_invariants():
        pytest.importorskip("hypothesis")

    def test_delta_enumeration_matches_ref_random_walks():
        pytest.importorskip("hypothesis")


# ------------------------------------------------------------------ #
# fingerprints
# ------------------------------------------------------------------ #
def test_incremental_equals_full(bht):
    inc = IncrementalMorgan(bht)
    assert np.array_equal(inc.fingerprint(counts=True),
                          morgan_fingerprint(bht, counts=True))
    for a in enumerate_actions(bht)[:40]:
        inc2 = inc.after_action(a.result, a.kind, a.detail)
        assert np.array_equal(inc2.fingerprint(counts=True),
                              morgan_fingerprint(a.result, counts=True)), a


def test_batched_incremental_equals_full(phenol, bht):
    """The shared-parent batched pass == full recompute, bit for bit, for
    every candidate (including no-ops and fragment-dropping removals), for
    every routing threshold, binary and counts."""
    for mol in (phenol, bht, from_smiles("OO"), from_smiles("OCC#N")):
        acts = enumerate_actions(mol)
        full = batch_morgan_fingerprints([a.result for a in acts])
        for full_ratio in (0.0, 0.6, 1.1):   # all-full / mixed / all-incremental
            inc = incremental_fingerprints_grouped(
                [mol], [acts], full_ratio=full_ratio)[0]
            assert np.array_equal(full, inc)
        fullc = batch_morgan_fingerprints([a.result for a in acts], counts=True)
        incc = batch_fingerprints_incremental(mol, acts, counts=True)
        assert np.array_equal(fullc, incc)


def test_batched_incremental_grouped_composition_independent(phenol, bht):
    """Cross-slot batching and chunking must not change any bit (the
    pipelined rollout shards slots across threads arbitrarily)."""
    parents = [phenol, bht, from_smiles("CC(=O)O")]
    groups = [enumerate_actions(p) for p in parents]
    ref = [batch_fingerprints_incremental(p, g) for p, g in zip(parents, groups)]
    for chunk in (7, 64, 0):
        got = incremental_fingerprints_grouped(parents, groups, chunk=chunk)
        for r, g in zip(ref, got):
            assert np.array_equal(r, g)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10**6),
           st.sampled_from([PHENOL, BHT_ISH, "OO", "OC1CC1"]))
    def test_incremental_fingerprints_random_edit_sequences(seed, smiles):
        """Across random edit sequences, pin BOTH §3.6 incremental paths to
        the full recompute: ``IncrementalMorgan.after_action`` (single-edit
        reference, threaded along the walk) and the batched shared-parent
        pass (all candidates of every visited state).  Removals are included,
        so fragment-dropping edits exercise the re-indexing fallbacks."""
        rng = np.random.default_rng(seed)
        mol = from_smiles(smiles)
        inc = IncrementalMorgan(mol)
        for _ in range(4):
            acts = enumerate_actions(mol, max_atoms=16)
            if not acts:
                break
            batched = batch_fingerprints_incremental(mol, acts)
            full = batch_morgan_fingerprints([a.result for a in acts])
            assert np.array_equal(batched, full)
            a = acts[int(rng.integers(0, len(acts)))]
            inc = inc.after_action(a.result, a.kind, a.detail)
            mol = a.result
            assert np.array_equal(inc.fingerprint(counts=True),
                                  morgan_fingerprint(mol, counts=True))
else:
    def test_incremental_fingerprints_random_edit_sequences():
        pytest.importorskip("hypothesis")


def test_batch_equals_single(phenol, bht):
    mols = [a.result for a in enumerate_actions(bht)[:25]] + [phenol]
    batch = batch_morgan_fingerprints(mols, counts=True)
    for i, m in enumerate(mols):
        assert np.array_equal(batch[i], morgan_fingerprint(m, counts=True))


def test_fingerprint_permutation_invariant(bht):
    rng = np.random.default_rng(1)
    perm = rng.permutation(bht.num_atoms)
    m2 = Molecule(bht.elements[perm], bht.bonds[np.ix_(perm, perm)])
    assert np.array_equal(morgan_fingerprint(m2, counts=True),
                          morgan_fingerprint(bht, counts=True))


def test_reference_fingerprint_runs(bht):
    fp = morgan_fingerprint_reference(bht)
    assert fp.shape == (2048,) and fp.sum() > 0


# ------------------------------------------------------------------ #
# SMILES
# ------------------------------------------------------------------ #
def test_smiles_roundtrip_actions(bht):
    for a in enumerate_actions(bht):
        s = canonical_smiles(a.result)
        m = from_smiles(s)
        assert m.canonical_key() == a.result.canonical_key(), s


def test_smiles_multifragment():
    assert from_smiles("C.O").num_atoms == 2


# ------------------------------------------------------------------ #
# oracle / properties
# ------------------------------------------------------------------ #
def test_oracle_tradeoff_direction(phenol):
    """Adding an ortho amino group must lower BDE *and* lower IP (§2.1)."""
    ring_c = int(phenol.neighbors(phenol.oh_oxygens()[0])[0])
    ortho = [int(v) for v in phenol.neighbors(ring_c) if phenol.symbol(v) == "C"][0]
    sub = phenol.with_added_atom("N", ortho, 1)
    assert oracle_bde(sub) < oracle_bde(phenol)
    assert oracle_ip(sub) < oracle_ip(phenol)


def test_oracle_bde_none_without_oh():
    assert oracle_bde(from_smiles("C1=CC=CC=C1")) is None


def test_conformer_validity_rules(phenol):
    assert has_valid_conformer(phenol)
    # triple bond in a ring is invalid
    bad = from_smiles("C1=CC=CC=C1O")
    bonds = bad.bonds.copy()
    bonds[1, 2] = bonds[2, 1] = 3
    m = Molecule(bad.elements, bonds)
    assert not has_valid_conformer(m)


def test_scores_ranges(bht):
    assert 1.0 <= sa_score(bht) <= 8.0
    assert 0.0 < qed_score(bht) < 0.95
    assert penalized_logp(bht) < 5
    assert tanimoto(bht, bht) == 1.0
    assert 0.0 <= tanimoto(bht, from_smiles(PHENOL)) < 1.0
