"""Distributed trainer: sync semantics, regression of the paper's claims in
miniature, and substrate (optim / checkpoint / roofline parsing)."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.chem.smiles import from_smiles
from repro.core import DQNConfig, EnvConfig, RewardConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer

from conftest import OracleService as _OracleService

MOLS = [from_smiles(s) for s in
        ("C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O", "CC1=CC=CC=C1O", "OC1=CC=CC=C1O")]


def _trainer(sync_mode: str, episodes: int = 3) -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=2, mols_per_worker=2, episodes=episodes, sync_mode=sync_mode,
        updates_per_episode=2, train_batch_size=8, max_candidates=16,
        dqn=DQNConfig(epsilon_decay=0.9), env=EnvConfig(max_steps=3), seed=0)
    return DistributedTrainer(cfg, MOLS, _OracleService(), RewardConfig(),
                              network=QNetwork(hidden=(64, 32)))


def _worker_params_equal(trainer) -> bool:
    flat = jax.tree_util.tree_leaves(trainer.params)
    return all(bool(jnp.allclose(x[0], x[i], atol=1e-6))
               for x in flat for i in range(1, x.shape[0]))


def test_episode_sync_equalises_workers():
    tr = _trainer("episode")
    tr.train(2)
    assert _worker_params_equal(tr)


def test_ddp_keeps_workers_identical():
    tr = _trainer("step")
    tr.train(2)
    assert _worker_params_equal(tr)


def test_modes_diverge_before_sync():
    """Local updates differ across workers until the episode sync."""
    tr = _trainer("episode")
    # roll + update WITHOUT sync by invoking internals
    for w, env in enumerate(tr.envs):
        env.run_episode(tr._views[w], tr.service, tr.reward_cfg, tr.buffers[w])
    batch = tr._stacked_sample()
    p2, _, _, _ = tr._local_update(tr.params, tr.target_params, tr.opt_state, batch)
    leaves = jax.tree_util.tree_leaves(p2)
    assert any(not bool(jnp.allclose(x[0], x[1], atol=1e-7)) for x in leaves)


def test_as_agent_roundtrip():
    tr = _trainer("episode")
    tr.train(1)
    agent = tr.as_agent(epsilon=0.0)
    q = agent.q_values(np.zeros((4, 2049), np.float32))
    assert q.shape == (4,) and np.isfinite(q).all()


def test_greedy_optimize_and_ofr():
    from repro.core.distributed import greedy_optimize, optimization_failure_rate
    tr = _trainer("episode")
    tr.train(1)
    recs = greedy_optimize(tr.as_agent(0.0), MOLS, _OracleService(), RewardConfig(),
                           EnvConfig(max_steps=3))
    assert len(recs) == len(MOLS)
    ofr = optimization_failure_rate(recs)
    assert 0.0 <= ofr <= 1.0


# ------------------------------------------------------------------ #
# mesh padding arithmetic + trainer accounting (nd = 1 view; the nd > 1
# equivalence itself is pinned by the tests/multidevice subprocess suite)
# ------------------------------------------------------------------ #
def test_padded_worker_count_arithmetic():
    from types import SimpleNamespace
    from repro.launch.mesh import padded_worker_count
    mesh4 = SimpleNamespace(devices=np.empty(4))
    assert padded_worker_count(6, mesh4) == 8
    assert padded_worker_count(8, mesh4) == 8
    assert padded_worker_count(1, mesh4) == 4
    mesh1 = SimpleNamespace(devices=np.empty(1))
    assert padded_worker_count(7, mesh1) == 7
    with pytest.raises(ValueError, match="positive"):
        padded_worker_count(0, mesh4)


def test_trainer_uses_host_mesh_and_pads_to_it():
    """The trainer's default mesh is launch.mesh.make_host_mesh (ONE
    construction code path) and its padded width tiles that mesh; on this
    1-device host any W — including odd ones that a multi-device mesh
    would pad — stays unpadded."""
    from repro.launch.mesh import make_host_mesh
    tr = _trainer("episode")
    assert tr.mesh.axis_names == make_host_mesh().axis_names == ("data",)
    assert tr.mesh.devices.size == make_host_mesh().devices.size
    assert tr.n_live_workers == tr.cfg.n_workers == 2
    assert tr.n_padded_workers == tr.engine.n_workers == 2
    assert tr.n_padded_workers % tr.mesh.devices.size == 0


def test_loss_scalar_ignores_dead_padding_rows():
    tr = _trainer("episode")
    tr.n_live_workers = 2                      # live prefix of a padded vector
    assert tr._loss_scalar(np.asarray([1.0, 3.0, 99.0, -7.0])) == 2.0


# ------------------------------------------------------------------ #
# optimizer / checkpoint substrate
# ------------------------------------------------------------------ #
def test_adam_minimises_quadratic():
    from repro.optim import adam
    from repro.optim.adam import apply_updates
    opt = adam(0.1)
    params = {"x": jnp.asarray(5.0), "y": jnp.asarray(-3.0)}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree_util.tree_map(lambda v: 2 * v, params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert abs(float(params["x"])) < 1e-2 and abs(float(params["y"])) < 1e-2


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray(3, np.int32)}}
    path = str(tmp_path / "x.npz")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert int(out["b"]["c"]) == 3


def test_checkpoint_manager_rotation(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    tree = {"w": np.zeros(3, np.float32)}
    for s in (1, 2, 3):
        mgr.save(s, tree)
    assert mgr.latest_step() == 3
    assert sorted(p.name for p in tmp_path.glob("ckpt_*.npz")) == \
        ["ckpt_2.npz", "ckpt_3.npz"]
    assert (tmp_path / "LATEST").read_text().strip() == "3"
    step, out = mgr.restore(tree)
    assert step == 3


# ------------------------------------------------------------------ #
# roofline HLO walker (pinned against known modules)
# ------------------------------------------------------------------ #
def test_hlo_walker_scan_trip_count():
    from repro.roofline.hlo_walk import aggregate

    def body(h, w):
        return jnp.tanh(h @ w), None

    def f(h, ws):
        h, _ = jax.lax.scan(body, h, ws)
        return h

    hs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    agg = aggregate(jax.jit(f).lower(hs, ws).compile().as_text())
    assert agg["flops"] == 7 * 2 * 128 ** 3


def test_hlo_walker_nested_scan():
    from repro.roofline.hlo_walk import aggregate

    def f(h, ws):
        def outer(h, w):
            def inner(hh, _):
                return jnp.tanh(hh @ w), None
            hh, _ = jax.lax.scan(inner, h, None, length=3)
            return hh, None
        h, _ = jax.lax.scan(outer, h, ws)
        return h

    hs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    agg = aggregate(jax.jit(f).lower(hs, ws).compile().as_text())
    assert agg["flops"] == 15 * 2 * 64 ** 3


def test_estimate_hbm_shapes():
    from repro.configs import INPUT_SHAPES, get_config
    from repro.roofline.analysis import estimate_hbm_per_chip
    cfg = get_config("yi-34b")
    est = estimate_hbm_per_chip(cfg, INPUT_SHAPES["train_4k"], tp=16, dp=16,
                                fsdp=True, microbatches=16)
    assert 0 < est["total"] < 16 * 2 ** 30
    est_d = estimate_hbm_per_chip(cfg, INPUT_SHAPES["decode_32k"], tp=16, dp=16)
    assert "cache" in est_d and est_d["total"] > 0
