"""Learner-path equivalence matrix: the packed and double-buffered update
paths must reproduce the seed dense ``_stacked_sample`` learner's loss
trajectory and parameters BIT FOR BIT, for both sync modes — the training
twin of the acting matrix in tests/test_rollout.py.

Bit equality holds on this backend because the in-jit unpack
(``packed_batch.densify_batch``) reconstructs the exact {0.0, 1.0} floats
the host densify produces, and every downstream op (dot, huber, Adam) then
sees identical operands in identical shapes.  If a future backend fuses the
unpack into the matmul with a different reduction order, relax the
assertions to fp32-reduction tolerance and document it here.
"""

import jax
import numpy as np
import pytest

from repro.chem.smiles import from_smiles
from repro.core import DQNConfig, EnvConfig, RewardConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import LEARNER_MODES, DistributedTrainer
from repro.core.jit_stats import jit_cache_size
from repro.core.packed_batch import dense_nbytes_equivalent

from conftest import OracleService as _OracleService

MOLS = [from_smiles(s) for s in
        ("C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O", "CC1=CC=CC=C1O", "OC1=CC=CC=C1O")]


def _trainer(learner: str, sync_mode: str, W: int, seed: int = 0,
             replay: str = "uniform", alpha: float = 0.6
             ) -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=W, mols_per_worker=2, episodes=2, sync_mode=sync_mode,
        learner=learner, updates_per_episode=3, train_batch_size=4,
        max_candidates=16, replay=replay, priority_alpha=alpha,
        dqn=DQNConfig(epsilon_decay=0.9),
        env=EnvConfig(max_steps=3), seed=seed)
    mols = (MOLS * ((2 * W + len(MOLS) - 1) // len(MOLS)))[: 2 * W]
    return DistributedTrainer(cfg, mols, _OracleService(), RewardConfig(),
                              network=QNetwork(hidden=(32,)))


def _run(learner: str, sync_mode: str, W: int, episodes: int = 2,
         replay: str = "uniform", alpha: float = 0.6):
    tr = _trainer(learner, sync_mode, W, replay=replay, alpha=alpha)
    stats = [tr.train_episode() for _ in range(episodes)]
    return tr, [s["loss"] for s in stats], jax.tree_util.tree_leaves(tr.params)


# ------------------------------------------------------------------ #
# the equivalence matrix: every learner mode == the seed dense path
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("sync_mode", ["episode", "step"])
@pytest.mark.parametrize("W", [1, 4])
def test_learner_mode_matrix(W, sync_mode):
    results = {m: _run(m, sync_mode, W) for m in LEARNER_MODES}
    _, ref_losses, ref_params = results["dense"]
    assert any(np.isfinite(ref_losses))          # updates actually ran
    for mode in LEARNER_MODES:
        if mode == "dense":
            continue
        _, losses, params = results[mode]
        np.testing.assert_array_equal(
            np.asarray(losses), np.asarray(ref_losses),
            err_msg=f"{mode} loss trajectory diverged from dense "
                    f"(W={W}, {sync_mode})")
        for xm, xr in zip(params, ref_params):
            np.testing.assert_array_equal(
                np.asarray(xm), np.asarray(xr),
                err_msg=f"{mode} params diverged from dense (W={W}, {sync_mode})")


def test_learner_mode_validated():
    with pytest.raises(ValueError, match="learner"):
        _trainer("bogus", "episode", 1)


def test_replay_mode_validated():
    with pytest.raises(ValueError, match="replay"):
        _trainer("dense", "episode", 1, replay="rank")


# ------------------------------------------------------------------ #
# prioritized replay through the learner paths
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("sync_mode", ["episode", "step"])
def test_prioritized_alpha0_bit_identical_to_uniform(sync_mode):
    """The uniform-parity invariant end to end: alpha=0 prioritized (flat
    effective priorities forever, since every |TD| update still yields
    p^0 = 1) must train BIT-identically to the uniform seed path, for
    every learner mode — the weights are unit, the priority feedback is a
    no-op, and the sample RNG takes the exact uniform draw."""
    _, ref_losses, ref_params = _run("dense", sync_mode, 2)
    for mode in LEARNER_MODES:
        _, losses, params = _run(mode, sync_mode, 2,
                                 replay="prioritized", alpha=0.0)
        np.testing.assert_array_equal(
            np.asarray(losses), np.asarray(ref_losses),
            err_msg=f"prioritized(alpha=0, {mode}) loss diverged from uniform")
        for xm, xr in zip(params, ref_params):
            np.testing.assert_array_equal(
                np.asarray(xm), np.asarray(xr),
                err_msg=f"prioritized(alpha=0, {mode}) params diverged")


@pytest.mark.parametrize("sync_mode", ["episode", "step"])
def test_prioritized_learner_modes_agree_and_diverge_from_uniform(sync_mode):
    """alpha>0 prioritized training is its own equivalence class: every
    learner mode must agree bit for bit with the dense prioritized
    reference (including the pipelined mode's sequential fallback), while
    ACTUALLY diverging from the uniform trajectory — otherwise the
    priority feedback is silently disconnected."""
    runs = {m: _run(m, sync_mode, 2, replay="prioritized", alpha=0.6)
            for m in LEARNER_MODES}
    _, ref_losses, ref_params = runs["dense"]
    _, uni_losses, _ = _run("dense", sync_mode, 2)
    assert not np.array_equal(np.asarray(ref_losses), np.asarray(uni_losses))
    for mode in LEARNER_MODES:
        _, losses, params = runs[mode]
        np.testing.assert_array_equal(
            np.asarray(losses), np.asarray(ref_losses),
            err_msg=f"prioritized {mode} loss diverged from dense ({sync_mode})")
        for xm, xr in zip(params, ref_params):
            np.testing.assert_array_equal(
                np.asarray(xm), np.asarray(xr),
                err_msg=f"prioritized {mode} params diverged ({sync_mode})")


def test_prioritized_beta_anneal_no_recompile():
    """beta is shipped as a host value, not baked into the trace: moving
    through the anneal schedule must reuse ONE compiled train step."""
    tr = _trainer("packed", "episode", 2, replay="prioritized")
    tr.train_episode()
    assert jit_cache_size(tr._local_update_packed) == 1
    for ep in (0, 3, 7, 11):
        tr.episode = ep
        tr.run_updates(2)
    assert jit_cache_size(tr._local_update_packed) == 1


# ------------------------------------------------------------------ #
# structural properties of the packed path
# ------------------------------------------------------------------ #
def test_packed_learner_ships_32x_fewer_bytes():
    trs = {m: _run(m, "episode", 2)[0] for m in ("dense", "packed")}
    dense_b, packed_b = trs["dense"].h2d_update_bytes, trs["packed"].h2d_update_bytes
    assert trs["packed"].n_updates == trs["dense"].n_updates > 0
    assert dense_b / packed_b > 30


def test_packed_batch_nbytes_accounting():
    tr = _trainer("packed", "episode", 2)
    tr.train_episode()
    batch = tr._stacked_sample_packed_np()
    assert dense_nbytes_equivalent(batch) == \
        sum(v.nbytes for v in tr._stacked_sample_np().values())


def test_update_step_shape_discipline():
    """Repeated update rounds reuse ONE compiled train-step shape (the
    recompile gate the train bench enforces fleet-wide)."""
    tr = _trainer("packed", "episode", 2)
    tr.train_episode()                            # fills buffers + compiles
    assert tr.n_updates > 0
    n_shapes = jit_cache_size(tr._local_update_packed)
    tr.run_updates(3)
    tr.train_episode()
    assert jit_cache_size(tr._local_update_packed) == n_shapes == 1


def test_zero_update_round_does_not_advance_sample_rngs():
    """run_updates(0) in pipelined mode must not eagerly draw (and then
    discard) a batch — that would silently desync the buffers' RNG streams
    from the other learner paths."""
    tr = _trainer("packed_pipelined", "episode", 1)
    tr.rollout_episode()
    states = [b._rng.bit_generator.state for b in tr.buffers]
    assert tr.run_updates(0) == []
    assert [b._rng.bit_generator.state for b in tr.buffers] == states


def test_pipelined_sampler_thread_is_reused():
    tr = _trainer("packed_pipelined", "episode", 1)
    tr.train_episode()
    pool = tr._sampler_pool
    assert pool is not None
    tr.train_episode()
    assert tr._sampler_pool is pool
