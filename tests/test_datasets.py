"""Dataset streaming: the seeded multi-start cursor (DatasetStream), the
dataset registry (load_dataset), and the trainer-level guarantee that one
(seed, dataset) pair yields ONE start-molecule schedule — identical across
every rollout mode, so "which molecule does worker w start episode e on"
is never a function of the execution strategy."""

import numpy as np
import pytest

from repro.chem.smiles import from_smiles
from repro.core import DQNConfig, EnvConfig, RewardConfig, TrainerConfig
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer
from repro.data import DATASETS, DatasetStream, load_dataset

from conftest import OracleService as _OracleService

POOL_SMILES = (
    "C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O", "CC1=CC=CC=C1O",
    "OC1=CC=CC=C1O", "NC1=CC=CC=C1O", "CCC1=CC=CC=C1O",
)
POOL = [from_smiles(s) for s in POOL_SMILES]


# ------------------------------------------------------------------ #
# DatasetStream: seeded shuffled-cycle semantics
# ------------------------------------------------------------------ #
def test_stream_is_deterministic_in_pool_and_seed():
    a = DatasetStream(POOL, seed=5)
    b = DatasetStream(POOL, seed=5)
    keys_a = [m.iso_key() for m in a.draw(17)]
    keys_b = [m.iso_key() for m in b.draw(17)]
    assert keys_a == keys_b
    c = DatasetStream(POOL, seed=6)
    assert [m.iso_key() for m in c.draw(17)] != keys_a


def test_stream_epoch_covers_pool_exactly_once():
    """One epoch = one fresh permutation: every pool molecule appears
    exactly once per len(pool) draws, even when a single draw() spans an
    epoch boundary."""
    s = DatasetStream(POOL, seed=0)
    n = len(POOL)
    drawn = s.draw(4) + s.draw(2 * n - 4) + s.draw(n)   # 3 epochs, ragged
    pool_keys = sorted(m.iso_key() for m in POOL)
    for e in range(3):
        epoch = drawn[e * n:(e + 1) * n]
        assert sorted(m.iso_key() for m in epoch) == pool_keys
    assert s.n_epochs == 3
    assert s.n_drawn == 3 * n


def test_stream_counts_and_small_pool_wrap():
    """A fleet wider than the pool wraps into the next permutation
    mid-draw — no repeats within an epoch, no exhaustion."""
    s = DatasetStream(POOL[:2], seed=3)
    out = s.draw(7)
    assert len(out) == 7
    assert s.n_epochs == 4
    assert len(s) == 2


def test_stream_rejects_empty_pool():
    with pytest.raises(ValueError, match="empty"):
        DatasetStream([])


# ------------------------------------------------------------------ #
# registry
# ------------------------------------------------------------------ #
def test_registry_names():
    assert set(DATASETS) == {"antioxidant", "public_antioxidant", "zinc_like"}


def test_load_dataset_unknown_name_fails_loudly():
    with pytest.raises(KeyError, match="zinc_like"):
        load_dataset("zinc")


def test_load_dataset_passes_count_and_seed():
    mols = load_dataset("antioxidant", count=8, seed=1)
    assert len(mols) == 8
    again = load_dataset("antioxidant", count=8, seed=1)
    assert [m.iso_key() for m in mols] == [m.iso_key() for m in again]


# ------------------------------------------------------------------ #
# trainer integration: the multi-start schedule
# ------------------------------------------------------------------ #
def _trainer(rollout: str, W: int = 4, mols_per_worker: int = 1,
             episodes: int = 3, seed: int = 0) -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=W, mols_per_worker=mols_per_worker, episodes=episodes,
        sync_mode="episode", rollout=rollout, chem="incremental",
        updates_per_episode=1, train_batch_size=3, max_candidates=16,
        dataset="inline", dqn=DQNConfig(epsilon_decay=0.9),
        env=EnvConfig(max_steps=2), seed=seed)
    return DistributedTrainer(cfg, molecules=None, service=_OracleService(),
                              reward_cfg=RewardConfig(), dataset_pool=POOL,
                              network=QNetwork(hidden=(32,)))


def _transitions(buf):
    return [(t.state_fp.tobytes(), t.steps_left_frac, t.reward, t.done,
             t.next_fps.tobytes(), t.next_steps_left_frac) for t in buf._items]


def test_multistart_schedule_identical_across_rollout_modes():
    """Satellite pin: same seed + dataset => identical start-molecule
    schedule AND identical replay streams across fleet/fleet_sharded/
    fleet_pipelined (the sequential reference included)."""
    logs, streams = {}, {}
    for mode in ("per_worker", "fleet", "fleet_sharded", "fleet_pipelined"):
        tr = _trainer(mode)
        for _ in range(3):
            tr.train_episode()
        logs[mode] = tr.start_log
        streams[mode] = [_transitions(b) for b in tr.buffers]
    ref = logs["per_worker"]
    assert len(ref) == 3 and len(set(ref)) > 1      # schedule actually varies
    for mode, log in logs.items():
        assert log == ref, f"{mode} start schedule diverged"
        assert streams[mode] == streams["per_worker"], \
            f"{mode} transition stream diverged"


def test_multistart_draws_follow_the_stream():
    """The trainer's episode starts are exactly the DatasetStream draws —
    W * mols_per_worker per episode, in cursor order."""
    tr = _trainer("fleet", W=3, mols_per_worker=2, episodes=2)
    shadow = DatasetStream(POOL, seed=0)
    tr.train_episode()
    tr.train_episode()
    expect = [tuple(m.iso_key() for m in shadow.draw(6)) for _ in range(2)]
    assert tr.start_log == expect


def test_dataset_seed_overrides_trainer_seed():
    a = _trainer("fleet")
    cfg = a.cfg
    b_cfg = TrainerConfig(**{**cfg.__dict__, "dataset_seed": 123})
    b = DistributedTrainer(b_cfg, molecules=None, service=_OracleService(),
                           reward_cfg=RewardConfig(), dataset_pool=POOL,
                           network=QNetwork(hidden=(32,)))
    a.rollout_episode()
    b.rollout_episode()
    assert a.start_log != b.start_log


def test_ctor_molecule_dataset_validation():
    cfg = TrainerConfig(n_workers=1, mols_per_worker=1, episodes=1,
                        env=EnvConfig(max_steps=2), seed=0)
    with pytest.raises(ValueError, match="dataset"):
        DistributedTrainer(cfg, molecules=None, service=_OracleService(),
                           reward_cfg=RewardConfig(), network=QNetwork(hidden=(8,)))
    both = TrainerConfig(**{**cfg.__dict__, "dataset": "inline"})
    with pytest.raises(ValueError, match="molecules=None"):
        DistributedTrainer(both, molecules=POOL[:1], service=_OracleService(),
                           reward_cfg=RewardConfig(), dataset_pool=POOL,
                           network=QNetwork(hidden=(8,)))


def test_dataset_by_name_resolves_registry():
    """TrainerConfig.dataset with no explicit pool loads from the registry
    (tiny count so the generator stays fast)."""
    cfg = TrainerConfig(
        n_workers=2, mols_per_worker=1, episodes=1, rollout="fleet",
        updates_per_episode=0, dataset="antioxidant", dataset_size=4,
        dataset_seed=2, env=EnvConfig(max_steps=2), seed=0)
    tr = DistributedTrainer(cfg, molecules=None, service=_OracleService(),
                            reward_cfg=RewardConfig(), network=QNetwork(hidden=(8,)))
    tr.rollout_episode()
    assert len(tr.start_log) == 1
    pool_keys = {m.iso_key() for m in load_dataset("antioxidant", count=4, seed=2)}
    assert set(tr.start_log[0]) <= pool_keys
