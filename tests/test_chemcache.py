"""ChemCache under concurrency: the fleet-wide cache is shared between the
``fleet_pipelined`` host enumeration threads and (legacy path) the
per-worker envs, so ``get``/``put``/``stats`` race by design.  These tests
hammer that surface from ``pipeline_threads``-style worker pools and pin

* counter consistency: every lookup is counted exactly once, and a
  ``stats()`` snapshot taken mid-flight is internally consistent (the
  hit/miss/relabel split sums to the lookups observed so far),
* entry integrity: a concurrently-served entry always carries the packed
  fingerprint bits of the molecule it is keyed on, read-only,
* the relabel guard under contention: an isomorphic but differently
  labelled twin never replaces the incumbent entry, no matter the
  interleaving,
* LRU bounds: eviction churn from many threads never grows the cache past
  capacity.
"""

import threading

import numpy as np
import pytest

from repro.chem.actions import enumerate_actions
from repro.chem.chemcache import ChemCache, molecule_signature
from repro.chem.fingerprint import batch_morgan_fingerprints
from repro.chem.molecule import Molecule
from repro.chem.smiles import from_smiles

SMILES = ("C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O", "CC1=CC=CC=C1O",
          "OC1=CC=CC=C1O", "CC1=C(N)C(C)=C(N)C(C)=C1O",
          "OC1=CC=C(C=C1)C(C)(C)C", "CC(C)C1=CC=CC=C1O", "NC1=CC=CC=C1O")
N_THREADS = 4          # the engine's pipeline_threads regime
OPS_PER_THREAD = 250


def _reference_entries(mols):
    """Single-threaded ground truth: (actions, packed fps) per molecule."""
    out = []
    for m in mols:
        acts = enumerate_actions(m)
        fps = batch_morgan_fingerprints([a.result for a in acts])
        out.append((acts, np.packbits(fps.astype(bool), axis=-1)))
    return out


@pytest.fixture(scope="module")
def ref():
    mols = [from_smiles(s) for s in SMILES]
    return mols, _reference_entries(mols)


def _hammer(cache, mols, entries, errors, lookup_counts, tid, barrier):
    rng = np.random.default_rng(1000 + tid)
    barrier.wait()
    n_lookups = 0
    try:
        for _ in range(OPS_PER_THREAD):
            i = int(rng.integers(len(mols)))
            entry = cache.get(mols[i])
            n_lookups += 1
            if entry is None:
                acts, packed = entries[i]
                cache.put(mols[i], acts, packed.copy())
            else:
                if entry.packed_fps.flags.writeable:
                    raise AssertionError("served entry is writable")
                if entry.signature != molecule_signature(mols[i]):
                    raise AssertionError("entry signature mismatch")
                if not np.array_equal(entry.packed_fps, entries[i][1]):
                    raise AssertionError("entry bits do not match its key")
    except Exception as e:  # noqa: BLE001 - surfaced by the main thread
        errors.append(e)
    finally:
        lookup_counts[tid] = n_lookups


@pytest.mark.parametrize("capacity", [4, 1024])
def test_concurrent_lookup_insert_counters_and_entries(ref, capacity):
    """capacity=4 (< distinct keys) forces eviction churn under contention;
    capacity=1024 exercises the warm pure-hit regime."""
    mols, entries = ref
    cache = ChemCache(capacity=capacity)
    errors, counts = [], [0] * N_THREADS
    barrier = threading.Barrier(N_THREADS)
    threads = [threading.Thread(target=_hammer,
                                args=(cache, mols, entries, errors, counts, t,
                                      barrier))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    st = cache.stats()
    assert st["hits"] + st["misses"] + st["relabel_misses"] == sum(counts)
    assert len(cache) <= capacity
    assert 0.0 <= st["hit_rate"] <= 1.0
    # the warm large cache ends up fully populated and hit-dominated
    if capacity >= len(mols):
        assert st["relabel_misses"] == 0
        assert st["hits"] > st["misses"] >= len(mols)


def test_stats_snapshot_consistent_while_hammered(ref):
    """A stats() reader racing the mutators must always see a consistent
    split: the three counters sum to a value some mutator has reached, the
    hit rate derives from the SAME snapshot, and resets are atomic."""
    mols, entries = ref
    cache = ChemCache(capacity=16)
    errors, counts = [], [0] * N_THREADS
    barrier = threading.Barrier(N_THREADS + 1)
    threads = [threading.Thread(target=_hammer,
                                args=(cache, mols, entries, errors, counts, t,
                                      barrier))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    barrier.wait()
    max_total = N_THREADS * OPS_PER_THREAD
    while any(t.is_alive() for t in threads):
        st = cache.stats()
        total = st["hits"] + st["misses"] + st["relabel_misses"]
        assert 0 <= total <= max_total
        if total:
            assert st["hit_rate"] == st["hits"] / total
    for t in threads:
        t.join()
    assert not errors, errors[0]
    cache.reset_stats()
    st = cache.stats()
    assert (st["hits"], st["misses"], st["relabel_misses"]) == (0, 0, 0)


def test_put_is_all_or_nothing_under_faulted_enumeration(ref):
    """A faulted enumeration handing ``put`` a throwing iterable or a
    fingerprint matrix that disagrees with the action count must leave the
    cache COMPLETELY untouched — no key inserted, no incumbent evicted, the
    caller's array not frozen — even while other threads hammer the same
    keys.  This is the fault-injection satellite for the chem layer: a
    crash mid-handoff can never publish a half-built entry."""
    mols, entries = ref
    cache = ChemCache(capacity=8)
    acts0, packed0 = entries[0]
    cache.put(mols[0], acts0, packed0.copy())        # the incumbent

    def exploding(n):
        """Iterable that dies after yielding n actions."""
        def gen():
            for i, a in enumerate(acts0):
                if i >= n:
                    raise RuntimeError("enumeration thread died mid-shard")
                yield a
        return gen()

    # throwing iterable: the exception propagates, nothing is inserted
    before = len(cache)
    mine = entries[1][1].copy()
    with pytest.raises(RuntimeError, match="died mid-shard"):
        cache.put(mols[1], exploding(2), mine)
    assert len(cache) == before and cache.get(mols[1]) is None
    assert mine.flags.writeable                      # caller's array untouched

    # mismatched bits-vs-actions: refused loudly, incumbent survives
    with pytest.raises(ValueError, match="half-built chem entry refused"):
        cache.put(mols[0], acts0[:2], packed0.copy())
    served = cache.get(mols[0])
    assert served is not None and np.array_equal(served.packed_fps, packed0)

    # now under contention: poisoned puts racing valid gets/puts
    errors = []
    barrier = threading.Barrier(N_THREADS)

    def storm(tid):
        rng = np.random.default_rng(tid)
        barrier.wait()
        try:
            for _ in range(OPS_PER_THREAD):
                i = int(rng.integers(len(mols)))
                acts, packed = entries[i]
                roll = rng.random()
                if roll < 0.25:
                    with pytest.raises(RuntimeError):
                        cache.put(mols[i], exploding(0), packed.copy())
                elif roll < 0.5:
                    with pytest.raises(ValueError):
                        cache.put(mols[i], acts[:1], packed.copy())
                else:
                    e = cache.get(mols[i])
                    if e is None:
                        cache.put(mols[i], acts, packed.copy())
                    elif not np.array_equal(e.packed_fps, packed):
                        raise AssertionError("half-built entry was served")
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=storm, args=(t,))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    # every surviving entry is complete and keyed on its own bits
    for i, m in enumerate(mols):
        e = cache.get(m)
        if e is not None:
            assert len(e.actions) == e.packed_fps.shape[0]
            assert np.array_equal(e.packed_fps, entries[i][1])


def test_relabel_twin_never_replaces_incumbent_under_contention(ref):
    """Threads alternately pushing a molecule and its relabelled twin: the
    first labelling in wins and every later conflicting put is refused, so
    a get for EACH labelling always recomputes or serves its own bits."""
    mols, entries = ref
    mol = mols[1]
    acts, packed = entries[1]
    perm = np.random.default_rng(3).permutation(mol.num_atoms)
    twin = Molecule(mol.elements[perm], mol.bonds[np.ix_(perm, perm)])
    assert twin.canonical_key() == mol.canonical_key()
    twin_acts = enumerate_actions(twin)
    twin_packed = np.packbits(batch_morgan_fingerprints(
        [a.result for a in twin_acts]).astype(bool), axis=-1)

    cache = ChemCache(capacity=8)
    cache.put(mol, acts, packed.copy())          # the incumbent labelling
    incumbent_sig = molecule_signature(mol)
    errors = []
    barrier = threading.Barrier(N_THREADS)

    def fight(tid):
        rng = np.random.default_rng(tid)
        barrier.wait()
        try:
            for _ in range(OPS_PER_THREAD):
                if rng.random() < 0.5:
                    assert cache.get(twin) is None      # relabel miss, always
                    cache.put(twin, twin_acts, twin_packed.copy())
                else:
                    e = cache.get(mol)
                    assert e is not None and e.signature == incumbent_sig
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=fight, args=(t,))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    st = cache.stats()
    assert st["relabel_misses"] > 0
    final = cache.get(mol)
    assert final is not None and final.signature == incumbent_sig


# ------------------------------------------------------------------ #
# serve-pool coherence (ISSUE-9 satellite): the serving tier shares ONE
# ChemCache across the request router's worker pool and reads stats()
# for its dashboards — lookups/evictions must stay coherent under that
# regime, not just under the training pipeline threads.
# ------------------------------------------------------------------ #
def test_lookup_and_eviction_counters_single_threaded(ref):
    mols, entries = ref
    cache = ChemCache(capacity=4)
    for i, m in enumerate(mols):            # 8 distinct keys into 4 slots
        assert cache.get(m) is None
        cache.put(m, *entries[i])
    st = cache.stats()
    assert st["lookups"] == st["hits"] + st["misses"] + st["relabel_misses"]
    assert st["lookups"] == len(mols) and st["misses"] == len(mols)
    assert st["evictions"] == len(mols) - 4 and len(cache) == 4
    cache.reset_stats()
    st = cache.stats()
    assert st["lookups"] == 0 and st["evictions"] == 0


def test_stat_coherence_under_serve_thread_pool(ref):
    """The serve regime: request batches fanned out over a thread pool,
    each doing lookup-or-fill against the shared cache, while a stats
    reader polls.  Every snapshot must satisfy
    ``lookups == hits + misses + relabel_misses`` with monotone lookups,
    and the final eviction count must be consistent with the bound."""
    from concurrent.futures import ThreadPoolExecutor

    mols, entries = ref
    cache = ChemCache(capacity=5)           # < distinct keys: churn

    def serve_batch(seed):
        rng = np.random.default_rng(seed)
        for _ in range(40):
            i = int(rng.integers(len(mols)))
            e = cache.get(mols[i])
            if e is None:
                acts, packed = entries[i]
                cache.put(mols[i], acts, packed.copy())
            elif not np.array_equal(e.packed_fps, entries[i][1]):
                raise AssertionError("served entry does not match its key")

    with ThreadPoolExecutor(max_workers=N_THREADS) as pool:
        futures = [pool.submit(serve_batch, s) for s in range(16)]
        prev = 0
        while any(not f.done() for f in futures):
            st = cache.stats()
            total = st["hits"] + st["misses"] + st["relabel_misses"]
            assert st["lookups"] == total
            assert st["lookups"] >= prev    # monotone under concurrency
            prev = st["lookups"]
            if st["lookups"]:
                assert st["hit_rate"] == st["hits"] / st["lookups"]
        for f in futures:
            f.result()                      # surface worker exceptions

    st = cache.stats()
    assert st["lookups"] == 16 * 40
    assert st["evictions"] > 0 and len(cache) <= 5
    # a warm over-provisioned cache under the same pool never evicts
    warm = ChemCache(capacity=64)
    errors, counts = [], [0] * N_THREADS
    with ThreadPoolExecutor(max_workers=N_THREADS) as pool:
        list(pool.map(lambda s: _hammer(warm, mols, entries, errors,
                                        counts, s, _NoBarrier()),
                      range(N_THREADS)))
    assert not errors, errors[0]
    wst = warm.stats()
    assert wst["lookups"] == sum(counts)
    assert wst["evictions"] == 0
    assert wst["hits"] > wst["misses"] >= len(mols)


class _NoBarrier:
    def wait(self):
        return None
