"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.agent import DQNAgent, DQNConfig, QNetwork
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_qnet.ops import fused_qnet
from repro.kernels.fused_qnet.ref import qnet_ref
from repro.kernels.packed_qnet.ops import pack_w1, packed_qnet
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("B,Sq,H,K,D", [
    (2, 256, 4, 2, 64),
    (1, 128, 4, 4, 128),
    (2, 256, 8, 1, 64),      # MQA
    (1, 512, 2, 2, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, Sq, H, K, D, dtype):
    q = jnp.asarray(RNG.standard_normal((B, Sq, H, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Sq, K, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Sq, K, D)), dtype)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window,prefix,causal", [
    (64, 0, True), (None, 32, True), (32, 16, True), (None, 0, False),
])
def test_flash_attention_masks(window, prefix, causal):
    B, S, H, K, D = 1, 256, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, K, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, K, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, prefix_len=prefix)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal, window=window,
                        prefix_len=prefix).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_layer():
    """The model's jnp attention and the kernel agree."""
    from repro.models.layers import gqa_attention
    B, S, H, K, D = 1, 256, 4, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, K, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, K, D)), jnp.float32)
    a = gqa_attention(q, k, v, causal=True, q_block=128)
    b = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5, rtol=3e-5)


# ------------------------------------------------------------------ #
# ssd scan
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
    (2, 256, 4, 32, 1, 16, 64),
    (1, 128, 2, 64, 2, 32, 128),
    (2, 512, 8, 16, 1, 8, 128),
    (1, 64, 4, 16, 4, 64, 32),
])
def test_ssd_scan_shapes(B, L, H, P, G, N, chunk):
    x = jnp.asarray(RNG.standard_normal((B, L, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((B, L, H))) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(np.abs(RNG.standard_normal(H)) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, L, G, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, L, G, N)) * 0.3, jnp.float32)
    y, s = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=2e-4, rtol=2e-4)


def test_ssd_scan_bf16():
    B, L, H, P, G, N = 1, 128, 2, 32, 1, 16
    x = jnp.asarray(RNG.standard_normal((B, L, H, P)) * 0.5, jnp.bfloat16)
    dt = jnp.asarray(np.abs(RNG.standard_normal((B, L, H))) * 0.1 + 0.01, jnp.bfloat16)
    A = jnp.asarray(np.abs(RNG.standard_normal(H)) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, L, G, N)) * 0.3, jnp.bfloat16)
    Cm = jnp.asarray(RNG.standard_normal((B, L, G, N)) * 0.3, jnp.bfloat16)
    y, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=64)
    yr, _ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_model_ssd_decode_consistency():
    """chunked scan final state == sequential decode final state."""
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    B, L, H, P, G, N = 1, 32, 2, 16, 1, 8
    x = jnp.asarray(RNG.standard_normal((B, L, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((B, L, H))) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(np.abs(RNG.standard_normal(H)) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, L, G, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, L, G, N)) * 0.3, jnp.float32)
    _, s_chunked = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    s = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(L):
        _, s = ssd_decode_step(s, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_chunked), atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------ #
# fused qnet
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("n", [1, 5, 128, 300])
def test_fused_qnet_rows(n):
    params = QNetwork().init(jax.random.PRNGKey(3))
    x = jnp.asarray((RNG.random((n, 2049)) > 0.8).astype(np.float32))
    qk = fused_qnet(params, x)
    qr = qnet_ref(x, [(l["w"], l["b"]) for l in params["layers"]])
    np.testing.assert_allclose(np.asarray(qk), np.asarray(qr), atol=1e-4, rtol=1e-4)


def test_fused_qnet_agrees_with_agent_path():
    params = QNetwork().init(jax.random.PRNGKey(4))
    x = jnp.asarray((RNG.random((64, 2049)) > 0.8).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fused_qnet(params, x)),
                               np.asarray(QNetwork().apply(params, x)),
                               atol=1e-4, rtol=1e-4)


def test_use_pallas_qnet_flag_matches_plain_agent():
    """The DQNConfig.use_pallas_qnet acting path (interpret mode on CPU)
    must agree with the plain jnp agent on the SAME q_values call — the
    CI-exercised equivalence check for the fused kernel behind the flag."""
    states = (RNG.random((50, 2049)) > 0.8).astype(np.float32)
    qs = {}
    for flag in (False, True):
        agent = DQNAgent(DQNConfig(use_pallas_qnet=flag), seed=6)
        qs[flag] = agent.q_values(states)
    assert qs[True].shape == (50,)
    np.testing.assert_allclose(qs[True], qs[False], atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------ #
# packed qnet: Q directly from packed uint8 fingerprints
# ------------------------------------------------------------------ #
def _packed_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 256, size=(n, 256), dtype=np.uint8)
    frac = rng.random(n).astype(np.float32)
    dense = np.concatenate(
        [np.unpackbits(bits, axis=-1).astype(np.float32), frac[:, None]], axis=-1)
    return jnp.asarray(bits), jnp.asarray(frac), jnp.asarray(dense)


@pytest.mark.parametrize("n", [1, 5, 128, 300])
def test_packed_qnet_interpret_matches_qnetwork_apply(n):
    """Acceptance gate: Pallas bit-plane kernel (interpret mode) vs the
    dense QNetwork.apply on random packed fingerprints, <= 1e-5."""
    params = QNetwork().init(jax.random.PRNGKey(3))
    bits, frac, dense = _packed_inputs(n, seed=n)
    q = packed_qnet(params, bits, frac, impl="pallas", interpret=True)
    ref = QNetwork().apply(params, dense)
    np.testing.assert_allclose(np.asarray(q), np.asarray(ref), atol=1e-5, rtol=1e-5)


def test_packed_qnet_xla_fallback_matches_dense():
    """The portable unpack-in-jit path is the same math as the dense
    forward (this is what the packed learner runs off-TPU)."""
    params = QNetwork().init(jax.random.PRNGKey(5))
    bits, frac, dense = _packed_inputs(77)
    q = packed_qnet(params, bits, frac, impl="xla")
    ref = QNetwork().apply(params, dense)
    np.testing.assert_allclose(np.asarray(q), np.asarray(ref), atol=1e-6, rtol=1e-6)


def _stacked_packed_inputs(n_workers, c, seed=0):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 256, size=(n_workers, c, 256), dtype=np.uint8)
    frac = rng.random((n_workers, c)).astype(np.float32)
    dense = np.concatenate(
        [np.unpackbits(bits, axis=-1).astype(np.float32), frac[..., None]],
        axis=-1)
    return jnp.asarray(bits), jnp.asarray(frac), jnp.asarray(dense)


@pytest.mark.parametrize("n_workers,c", [(1, 128), (4, 64), (8, 37)])
def test_packed_qnet_stacked_interpret_matches_apply_stacked(n_workers, c):
    """The fleet-acting shape [W, C, 256] (ragged C pads inside the op):
    Pallas stacked bit-plane kernel (interpret mode) vs the dense
    apply_stacked under per-worker parameters, <= 1e-5."""
    from repro.kernels.packed_qnet.ops import packed_qnet_stacked

    net = QNetwork()
    keys = jax.random.split(jax.random.PRNGKey(7), n_workers)
    params = jax.vmap(net.init)(keys)
    bits, frac, dense = _stacked_packed_inputs(n_workers, c, seed=n_workers)
    q = packed_qnet_stacked(params, bits, frac, impl="pallas", interpret=True)
    ref = net.apply_stacked(params, dense)
    assert q.shape == (n_workers, c)
    np.testing.assert_allclose(np.asarray(q), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_packed_qnet_stacked_xla_matches_apply_stacked_packed():
    """Portable path: the kernel module's vmapped unpack-in-jit fallback
    and QNetwork.apply_stacked_packed are BOTH bit-identical to the dense
    apply_stacked — the equality the packed acting equivalence rests on."""
    from repro.kernels.packed_qnet.ops import packed_qnet_stacked

    net = QNetwork()
    params = jax.vmap(net.init)(jax.random.split(jax.random.PRNGKey(9), 4))
    bits, frac, dense = _stacked_packed_inputs(4, 33, seed=11)
    ref = np.asarray(net.apply_stacked(params, dense))
    np.testing.assert_array_equal(
        np.asarray(packed_qnet_stacked(params, bits, frac, impl="xla")), ref)
    np.testing.assert_array_equal(
        np.asarray(jax.jit(net.apply_stacked_packed)(params, bits, frac)), ref)


def test_packed_qnet_stacked_dead_worker_rows():
    """Dead/padded fleet rows (all-zero planes, as the trainer's packed
    view guarantees) must evaluate exactly like explicit zero input — and
    must not perturb the live workers' Q values."""
    from repro.kernels.packed_qnet.ops import packed_qnet_stacked

    net = QNetwork()
    params = jax.vmap(net.init)(jax.random.split(jax.random.PRNGKey(13), 3))
    bits, frac, dense = _stacked_packed_inputs(3, 64, seed=17)
    bits = bits.at[1].set(0)                        # worker 1 is dead
    frac = frac.at[1].set(0.0)
    dense = dense.at[1].set(0.0)
    q = packed_qnet_stacked(params, bits, frac, impl="pallas", interpret=True)
    ref = net.apply_stacked(params, dense)
    np.testing.assert_allclose(np.asarray(q), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    # live workers match their single-worker row-kernel evaluation exactly:
    # a dead row in the batch changes nothing outside its own row
    for w in (0, 2):
        pw = jax.tree_util.tree_map(lambda x, w=w: x[w], params)
        solo = packed_qnet(pw, bits[w], frac[w], impl="pallas", interpret=True)
        np.testing.assert_array_equal(np.asarray(q[w]), np.asarray(solo))


def test_pack_w1_bit_plane_layout():
    """w1r[k, i] must hold W1 row 8*i + k — the row bit k of byte i selects
    under np.unpackbits (MSB-first) ordering."""
    w1 = jnp.asarray(RNG.standard_normal((2049, 8)), jnp.float32)
    w1r, w1f = pack_w1(w1)
    assert w1r.shape == (8, 256, 8) and w1f.shape == (1, 8)
    for k in (0, 3, 7):
        for i in (0, 100, 255):
            np.testing.assert_array_equal(np.asarray(w1r[k, i]),
                                          np.asarray(w1[8 * i + k]))
    np.testing.assert_array_equal(np.asarray(w1f[0]), np.asarray(w1[2048]))


# ------------------------------------------------------------------ #
# hypothesis shape sweeps
# ------------------------------------------------------------------ #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # declared in pyproject [test]; degrade to a skip
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 2),
        sq=st.sampled_from([64, 128, 192]),
        k=st.sampled_from([1, 2, 4]),
        rep=st.sampled_from([1, 2]),
        d=st.sampled_from([32, 64]),
        causal=st.booleans(),
    )
    def test_flash_attention_hypothesis(b, sq, k, rep, d, causal):
        h = k * rep
        rng = np.random.default_rng(b * 1000 + sq + k + d)
        q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
        kk = jnp.asarray(rng.standard_normal((b, sq, k, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, sq, k, d)), jnp.float32)
        out = flash_attention(q, kk, v, causal=causal)
        ref = attention_ref(q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)

    @settings(max_examples=8, deadline=None)
    @given(
        l=st.sampled_from([64, 128]),
        h=st.sampled_from([1, 2, 4]),
        p=st.sampled_from([16, 32]),
        n=st.sampled_from([8, 16]),
        chunk=st.sampled_from([32, 64]),
    )
    def test_ssd_scan_hypothesis(l, h, p, n, chunk):
        rng = np.random.default_rng(l + h * 10 + p + n)
        x = jnp.asarray(rng.standard_normal((1, l, h, p)) * 0.5, jnp.float32)
        dt = jnp.asarray(np.abs(rng.standard_normal((1, l, h))) * 0.1 + 0.01, jnp.float32)
        A = jnp.asarray(np.abs(rng.standard_normal(h)) + 0.5, jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((1, l, 1, n)) * 0.3, jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((1, l, 1, n)) * 0.3, jnp.float32)
        y, s = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
        yr, sr = ssd_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4, rtol=3e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=3e-4, rtol=3e-4)
else:
    def test_flash_attention_hypothesis():
        pytest.importorskip("hypothesis")

    def test_ssd_scan_hypothesis():
        pytest.importorskip("hypothesis")
