"""Shared driver for the multi-device equivalence suite.

Each scenario runs as a ``repro.launch.verify`` SUBPROCESS because
``--xla_force_host_platform_device_count`` must be set in ``XLA_FLAGS``
before jax initialises — the parent pytest process keeps its own device
count (whatever CI forced), the children always force the verifier's fixed
device pool and size their mesh with ``--nd``.  Children of one scenario
are launched concurrently: each is single-scenario and mostly compile-bound,
so overlapping them roughly halves suite wall time on a 2-core host.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[2]
SRC = REPO / "src"
CHILD_TIMEOUT_S = 600


def run_cells(tmp_path, nds, **kw) -> dict[int, dict[str, np.ndarray]]:
    """Run one scenario at every requested mesh size concurrently; return
    ``{nd: report arrays}`` (see repro.launch.verify for the report keys)."""
    procs = {}
    for nd in nds:
        out = Path(tmp_path) / f"nd{nd}.npz"
        cmd = [sys.executable, "-m", "repro.launch.verify",
               "--nd", str(nd), "--out", str(out)]
        for k, v in kw.items():
            cmd += ["--" + k.replace("_", "-"), str(v)]
        env = dict(os.environ)
        env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
        procs[nd] = (subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                                      stderr=subprocess.STDOUT, text=True), out)
    results = {}
    try:
        for nd, (p, out) in procs.items():
            stdout, _ = p.communicate(timeout=CHILD_TIMEOUT_S)
            assert p.returncode == 0, \
                f"verify child nd={nd} exited {p.returncode}:\n{stdout}"
            with np.load(out) as z:
                results[nd] = {k: z[k] for k in z.files}
    finally:
        # a hung child (and its unreaped siblings) must not outlive the
        # test and starve every later scenario of the host's cores
        for p, _ in procs.values():
            if p.poll() is None:
                p.kill()
                p.communicate()
    return results


def assert_equivalent(ref: dict, other: dict, ctx: str) -> None:
    """The nd > 1 run must reproduce the nd = 1 reference EXACTLY:
    transitions (per-worker stream digests), loss and reward trajectories,
    and every live worker's parameter bits."""
    assert list(other["transition_digests"]) == list(ref["transition_digests"]), \
        f"{ctx}: transition streams diverged from the nd=1 reference"
    np.testing.assert_array_equal(
        other["n_transitions"], ref["n_transitions"],
        err_msg=f"{ctx}: per-worker transition counts diverged")
    np.testing.assert_array_equal(
        other["losses"], ref["losses"],
        err_msg=f"{ctx}: loss trajectory diverged")
    np.testing.assert_array_equal(
        other["rewards"], ref["rewards"],
        err_msg=f"{ctx}: reward trajectory diverged")
    param_keys = sorted(k for k in ref if k.startswith("param_"))
    assert param_keys == sorted(k for k in other if k.startswith("param_"))
    for k in param_keys:
        np.testing.assert_array_equal(
            other[k], ref[k],
            err_msg=f"{ctx}: parameter leaf {k} diverged (bit equality required)")
