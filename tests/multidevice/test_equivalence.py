"""The multi-device truth run: the (rollout x learner x chem x sync)
equivalence matrix re-run at nd in {2, 4} forced host devices and pinned —
transitions, loss trajectories and parameters bit-identical to the nd = 1
reference of the same seed — with the recompiles-after-warmup gate held at
0, plus the ragged fleets (W not divisible by nd) that pad to the mesh with
dead worker slots.

Each cell spawns one ``repro.launch.verify`` subprocess per mesh size (the
XLA_FLAGS-before-jax-init constraint; see mdhelpers).  The four cells cover
every rollout mode, every learner mode, both chem modes, both sync modes
and every acting representation (packed / packed_async / dense) at least
once; the in-process tier-1 matrices (tests/test_rollout.py,
tests/test_learner.py) already pin all mode pairs against each other at
nd = 1, so cross-mode x cross-nd coverage composes.
"""

import pytest

from mdhelpers import assert_equivalent, run_cells

# every rollout mode, learner mode, chem mode, sync mode AND acting
# representation (packed / packed_async / dense) appears >= once
CELLS = (
    dict(rollout="fleet_sharded", learner="packed", chem="incremental",
         sync="episode", acting="packed"),
    dict(rollout="fleet_pipelined", learner="packed_pipelined",
         chem="incremental", sync="step", acting="packed_async"),
    dict(rollout="fleet", learner="dense", chem="full", sync="episode",
         acting="dense"),
    dict(rollout="per_worker", learner="dense", chem="full", sync="step",
         acting="dense"),
)
_GATED = ("fleet", "fleet_sharded", "fleet_pipelined")  # recompile-gated modes


@pytest.mark.parametrize(
    "cell", CELLS,
    ids=lambda c: (f"{c['rollout']}-{c['learner']}-{c['chem']}-"
                   f"{c['acting']}-{c['sync']}"))
def test_matrix_cell_identical_across_nd(tmp_path, cell):
    res = run_cells(tmp_path, (1, 2, 4), **cell)
    assert int(res[1]["warmup_compiles"]) > 0   # the counter observes children
    for nd in (2, 4):
        assert int(res[nd]["n_devices"]) == nd  # the child really ran sharded
        assert_equivalent(res[1], res[nd], f"nd={nd} {cell}")
        if cell["rollout"] in _GATED:
            assert int(res[nd]["recompiles_after_warmup"]) == 0, \
                f"nd={nd} {cell}: sharded path recompiled after warmup"
    if cell["rollout"] in _GATED:
        assert int(res[1]["recompiles_after_warmup"]) == 0


def test_prioritized_parity_across_nd(tmp_path):
    """The uniform-parity invariant at mesh scale: prioritized replay with
    alpha = 0 (flat effective priorities forever) must be BIT-identical —
    transitions, losses, parameters — to the uniform sampler's nd = 1
    reference, at nd in {1, 2, 4}, with the recompile gate held at 0."""
    cell = dict(rollout="fleet_sharded", learner="packed",
                chem="incremental", sync="episode", acting="packed")
    uni_dir, pri_dir = tmp_path / "uniform", tmp_path / "prioritized"
    uni_dir.mkdir()
    pri_dir.mkdir()
    uni = run_cells(uni_dir, (1,), replay="uniform", **cell)
    pri = run_cells(pri_dir, (1, 2, 4), replay="prioritized",
                    priority_alpha=0.0, **cell)
    for nd in (1, 2, 4):
        assert int(pri[nd]["recompiles_after_warmup"]) == 0, \
            f"prioritized nd={nd} recompiled after warmup"
        assert_equivalent(uni[1], pri[nd],
                          f"prioritized(alpha=0) nd={nd} vs uniform nd=1")


def test_prioritized_alpha_active_self_consistent_across_nd(tmp_path):
    """alpha > 0 prioritized training is its own cross-nd equivalence
    class: nd in {2, 4} must reproduce its OWN nd = 1 reference bit for
    bit (while genuinely diverging from the uniform trajectory — checked
    in-process by tests/test_learner.py)."""
    res = run_cells(tmp_path, (1, 2, 4), replay="prioritized",
                    priority_alpha=0.6, rollout="fleet_sharded",
                    learner="packed", chem="incremental", sync="episode",
                    acting="packed")
    for nd in (2, 4):
        assert int(res[nd]["n_devices"]) == nd
        assert int(res[nd]["recompiles_after_warmup"]) == 0
        assert_equivalent(res[1], res[nd], f"prioritized(alpha=0.6) nd={nd}")


@pytest.mark.parametrize("sync", ["episode", "step"])
def test_ragged_fleet_pads_to_mesh(tmp_path, sync):
    """W = 6 on a 4-device mesh: two dead padding slots, and results
    identical to the unpadded nd = 1 W = 6 run — the masked cross-worker
    means ignore the dead slots in BOTH sync regimes."""
    res = run_cells(tmp_path, (1, 4), workers=6, sync=sync)
    assert int(res[1]["n_padded_workers"]) == 6     # nd=1: no padding
    assert int(res[4]["n_live_workers"]) == 6
    assert int(res[4]["n_padded_workers"]) == 8     # padded to the mesh
    assert int(res[4]["recompiles_after_warmup"]) == 0
    assert_equivalent(res[1], res[4], f"ragged W=6 nd=4 sync={sync}")
