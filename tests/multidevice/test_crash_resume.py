"""Crash-resume truth run: SIGKILL a training child mid-run, resume it
from its last checkpoint, and pin the resumed report BIT-identical to a
straight-through reference of the same seed — at nd in {1, 2, 4}, for
uniform AND prioritized replay, on a packed learner/acting cell, with the
recompiles-after-warmup gate held at 0 on the resumed process.

Three children per cell (see repro.launch.verify):

* reference — the run that never stops;
* kill      — checkpoints after every episode, then after episode K's
  checkpoint performs MORE work (a full uncheckpointed episode) and
  SIGKILLs itself: the crash always destroys in-flight state;
* resume    — restores the newest checkpoint and finishes the run.

Equality covers the full loss/reward trajectories (pre-crash episodes
included — the trainer logs ride in the checkpoint), the per-worker
transition-stream digests, the serialised replay-state digests (SoA rings
+ priorities + cursors + sample RNG) and every parameter leaf.
"""

import os
import signal
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from mdhelpers import CHILD_TIMEOUT_S, SRC, assert_equivalent

# nd sweep x replay mode on the packed fast path; one cell also covers the
# pipelined rollout + async acting so the overlap machinery resumes too
CELLS = (
    dict(nd=1, replay="uniform", rollout="fleet_sharded", learner="packed",
         acting="packed"),
    dict(nd=2, replay="prioritized", rollout="fleet_sharded",
         learner="packed", acting="packed"),
    dict(nd=4, replay="uniform", rollout="fleet_pipelined", learner="packed",
         acting="packed_async"),
)

WARMUP, EPISODES, KILL_AT = 1, 3, 2


def _spawn(out: Path, *extra: str, **kw) -> subprocess.Popen:
    cmd = [sys.executable, "-m", "repro.launch.verify", "--out", str(out)]
    for k, v in kw.items():
        cmd += ["--" + k.replace("_", "-"), str(v)]
    cmd += list(extra)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)


def _wait(p: subprocess.Popen) -> tuple[int, str]:
    try:
        stdout, _ = p.communicate(timeout=CHILD_TIMEOUT_S)
    finally:
        if p.poll() is None:
            p.kill()
            p.communicate()
    return p.returncode, stdout


@pytest.mark.parametrize(
    "cell", CELLS,
    ids=lambda c: f"nd{c['nd']}-{c['replay']}-{c['rollout']}-{c['acting']}")
def test_killed_run_resumes_bit_identical(tmp_path, cell):
    base = dict(cell, mols_per_worker=2, warmup=WARMUP, episodes=EPISODES,
                seed=5, chem="incremental")
    ckpt = tmp_path / "ckpt"

    # reference and kill children are independent — overlap them
    p_ref = _spawn(tmp_path / "ref.npz", **base)
    p_kill = _spawn(tmp_path / "kill.npz", ckpt_dir=str(ckpt),
                    kill_at=KILL_AT, **base)
    rc_kill, out_kill = _wait(p_kill)
    rc_ref, out_ref = _wait(p_ref)
    assert rc_ref == 0, f"reference child failed:\n{out_ref}"
    # the kill child must die BY the SIGKILL, not finish or fail earlier
    assert rc_kill == -signal.SIGKILL, \
        f"kill child exited {rc_kill} (expected SIGKILL):\n{out_kill}"
    assert not (tmp_path / "kill.npz").exists(), \
        "killed child wrote a report — it survived past the crash point"
    steps = sorted(int(f.stem.split("_")[1])
                   for f in ckpt.glob("ckpt_*.npz"))
    assert KILL_AT in steps, f"no checkpoint at the kill episode: {steps}"

    rc_res, out_res = _wait(
        _spawn(tmp_path / "res.npz", "--resume", ckpt_dir=str(ckpt), **base))
    assert rc_res == 0, f"resumed child failed:\n{out_res}"

    with np.load(tmp_path / "ref.npz") as z:
        ref = {k: z[k] for k in z.files}
    with np.load(tmp_path / "res.npz") as z:
        res = {k: z[k] for k in z.files}

    ctx = f"nd={cell['nd']} replay={cell['replay']} resume"
    assert_equivalent(ref, res, ctx)
    np.testing.assert_array_equal(
        res["replay_state_digests"], ref["replay_state_digests"],
        err_msg=f"{ctx}: serialised replay state diverged "
                f"(rings/priorities/cursor/RNG)")
    # full trajectory in the resumed report: pre-crash episodes included
    assert len(res["losses"]) == WARMUP + EPISODES
    # the resumed process compiled fresh but must not recompile once its
    # first episode back (its warmup window) is done
    assert int(res["recompiles_after_warmup"]) == 0, \
        f"{ctx}: recompiles after warmup on the resumed process"
