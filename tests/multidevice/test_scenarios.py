"""Mixed-scenario fleets at mesh scale: the PR-10 determinism gates.

Three contracts, each pinned through ``repro.launch.verify`` children
(the XLA_FLAGS-before-jax-init constraint; see mdhelpers):

* a homogeneous ``--scenarios antioxidant`` fleet — the registry spec
  compiled per worker — is BIT-identical (transitions, losses, rewards,
  params) to the default scalar Eq. 1 path, at nd in {1, 2, 4}, with the
  recompiles-after-warmup gate held at 0 (the vectorized reward layer is
  NumPy-side: it must never touch XLA shapes);
* a heterogeneous scenario mix is its own cross-nd equivalence class:
  nd in {2, 4} reproduce its nd = 1 reference exactly;
* each worker of a mixed fleet reproduces the per-worker transition
  digest of the homogeneous fleet running only its scenario (updates
  off, so param sync — the one legitimate cross-worker coupling — is
  out of the picture and any divergence is a reward-layer leak).
"""

import pytest

from mdhelpers import assert_equivalent, run_cells

MIX = "antioxidant,qed,plogp,antioxidant_novel"


def test_homogeneous_antioxidant_scenario_matches_default_across_nd(tmp_path):
    base_dir, scen_dir = tmp_path / "default", tmp_path / "scenario"
    base_dir.mkdir()
    scen_dir.mkdir()
    base = run_cells(base_dir, (1,))
    scen = run_cells(scen_dir, (1, 2, 4), scenarios="antioxidant")
    for nd in (1, 2, 4):
        assert int(scen[nd]["recompiles_after_warmup"]) == 0, \
            f"scenario fleet nd={nd} recompiled after warmup"
        assert_equivalent(base[1], scen[nd],
                          f"scenarios=antioxidant nd={nd} vs default nd=1")


def test_mixed_scenario_fleet_identical_across_nd(tmp_path):
    res = run_cells(tmp_path, (1, 2, 4), scenarios=MIX)
    for nd in (2, 4):
        assert int(res[nd]["n_devices"]) == nd
        assert int(res[nd]["recompiles_after_warmup"]) == 0, \
            f"mixed fleet nd={nd} recompiled after warmup"
        assert_equivalent(res[1], res[nd], f"scenarios={MIX} nd={nd}")


@pytest.mark.parametrize("nd", [1, 4])
def test_mixed_fleet_worker_matches_solo_twin(tmp_path, nd):
    """W=4, mix 'antioxidant,qed' cycled w%2: workers 0/2 must carry the
    exact transition digests of the all-antioxidant fleet's workers 0/2,
    workers 1/3 those of the all-qed fleet's workers 1/3."""
    runs = {}
    for tag, scen in (("mixed", "antioxidant,qed"),
                      ("anti", "antioxidant"), ("qed", "qed")):
        d = tmp_path / f"{tag}-nd{nd}"
        d.mkdir()
        runs[tag] = run_cells(d, (nd,), scenarios=scen,
                              updates_per_episode=0)[nd]
    digests = {t: list(r["transition_digests"]) for t, r in runs.items()}
    counts = {t: list(r["n_transitions"]) for t, r in runs.items()}
    assert len(digests["mixed"]) == 4
    for w in range(4):
        twin = "anti" if w % 2 == 0 else "qed"
        assert digests["mixed"][w] == digests[twin][w], \
            f"nd={nd} worker {w} diverged from its solo {twin} twin"
        assert counts["mixed"][w] == counts[twin][w]
