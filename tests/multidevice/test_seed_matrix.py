"""Hypothesis random-seed layer over the multi-device matrix (the
tests/test_rollout.py seed-layer idiom applied across mesh sizes): for
random seeds and fleet sizes W in {4, 8}, a downsized scenario (short
episodes, tiny network) must produce bit-identical parameters, losses and
transition streams at nd in {1, 2, 4}.

W = 4 at nd = 4 puts ONE worker per device — the regime where a vmap'd
per-worker update lowers as a batch-1 dot and drifts (the bug the scan-
based update in core/distributed.py fixes); keeping it in the sampled set
pins that fix under seed variation.
"""

import tempfile

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # declared in pyproject [test]; degrade to a skip
    HAVE_HYPOTHESIS = False

from mdhelpers import assert_equivalent, run_cells

# downsized: 1 episode, 2 env steps, tiny net — each example still spawns
# three jax subprocesses, so the example budget stays small
_SCENARIO = dict(warmup=0, episodes=1, max_steps=2, updates_per_episode=1,
                 batch_size=2, hidden="16", rollout="fleet_sharded",
                 learner="packed", chem="incremental")


if HAVE_HYPOTHESIS:
    @settings(max_examples=2, deadline=None)
    @given(seed=st.integers(0, 2**20),
           W=st.sampled_from([4, 8]),
           sync=st.sampled_from(["episode", "step"]))
    def test_seeded_matrix_bit_identical_across_nd(seed, W, sync):
        # hypothesis reuses function-scoped fixtures across examples, so no
        # pytest tmp_path here; a self-cleaning TemporaryDirectory instead
        with tempfile.TemporaryDirectory(prefix="mdseed_") as tmp:
            res = run_cells(tmp, (1, 2, 4), workers=W, seed=seed, sync=sync,
                            **_SCENARIO)
        for nd in (2, 4):
            assert_equivalent(res[1], res[nd],
                              f"seed={seed} W={W} sync={sync} nd={nd}")
else:
    def test_seeded_matrix_bit_identical_across_nd():
        pytest.importorskip("hypothesis")
