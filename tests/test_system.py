"""End-to-end behaviour: the paper's pipeline in miniature.

General-model training on a small molecule set must (a) run the full
distributed machinery, (b) produce a model whose greedy optimization beats
a random policy — the qualitative content of Fig. 2 at CPU scale.  Uses
the REAL trained predictors from .cache/predictors (trains on first run).
"""

import numpy as np
import pytest

from repro.core import DQNConfig, EnvConfig, RewardConfig, TrainerConfig
from repro.core.agent import DQNAgent, QNetwork
from repro.core.distributed import (
    DistributedTrainer, greedy_optimize, optimization_failure_rate)
from repro.data.datasets import antioxidant_dataset, dataset_property_table, train_test_split
from repro.predictors import PropertyService
from repro.predictors.training import ensure_trained


@pytest.fixture(scope="module")
def service():
    bm, bp, im, ip_, metrics = ensure_trained(verbose=False)
    assert metrics["bde"]["rel_err_mean"] < 0.05, "paper's <5% envelope (§2.2)"
    assert metrics["ip"]["rel_err_mean"] < 0.05
    return PropertyService(bm, bp, im, ip_)


@pytest.fixture(scope="module")
def data():
    ds = antioxidant_dataset(64, seed=5)
    train, test = train_test_split(ds, n_train=8, n_test=4)
    props = dataset_property_table(train)
    return train, test, RewardConfig.from_dataset(props["bde"], props["ip"])


@pytest.fixture(scope="module")
def trained(service, data):
    train, _, rcfg = data
    cfg = TrainerConfig(
        n_workers=2, mols_per_worker=4, episodes=12, sync_mode="episode",
        updates_per_episode=3, train_batch_size=16, max_candidates=32,
        dqn=DQNConfig(epsilon_decay=0.8), env=EnvConfig(max_steps=4), seed=3)
    tr = DistributedTrainer(cfg, train, service, rcfg,
                            network=QNetwork(hidden=(256, 64)))
    stats = tr.train()
    return tr, stats


def test_training_progresses(trained):
    tr, stats = trained
    assert len(stats) == 12
    assert all(np.isfinite(s["loss"]) for s in stats[2:])


def test_general_model_beats_random(trained, service, data):
    train, _, rcfg = data
    tr, _ = trained
    env_cfg = EnvConfig(max_steps=4)

    greedy = greedy_optimize(tr.as_agent(0.0), train, service, rcfg, env_cfg, seed=11)
    random_recs = greedy_optimize(
        DQNAgent(DQNConfig(epsilon_initial=1.0), seed=99, network=QNetwork(hidden=(256, 64))),
        train, service, rcfg, env_cfg, seed=12)

    def mean_reward(recs):
        return float(np.mean([r.reward for r in recs]))

    assert mean_reward(greedy) > mean_reward(random_recs), (
        mean_reward(greedy), mean_reward(random_recs))


def test_ofr_definition(trained, service, data):
    train, _, rcfg = data
    tr, _ = trained
    recs = greedy_optimize(tr.as_agent(0.0), train, service, rcfg,
                           EnvConfig(max_steps=4), seed=13)
    ofr = optimization_failure_rate(recs)
    assert 0.0 <= ofr <= 1.0


def test_cache_hit_rate_nontrivial(service):
    """§3.6: episodes revisit molecules -> the LRU cache must be earning."""
    assert service.cache.hit_rate > 0.2, service.cache.hit_rate


def test_predictor_service_invalid_conformer(service):
    from repro.chem.molecule import Molecule
    # strained: fused 3-rings sharing an edge -> no valid conformer
    el = np.zeros(5, np.int8)
    el[4] = 2  # one O for the O-H guarantee
    b = np.zeros((5, 5), np.int8)
    for i, j in ((0, 1), (1, 2), (2, 0), (1, 3), (3, 0), (2, 4)):
        b[i, j] = b[j, i] = 1
    mol = Molecule(el, b)
    mol.check_valences()
    props = service.predict([mol])[0]
    assert props.ip is None  # -> -1000 reward upstream


def test_finetune_runs(trained, service, data):
    from repro.core.finetune import fine_tune
    train, test, rcfg = data
    tr, _ = trained
    agent = fine_tune(tr.as_agent(0.5), test[0], service, rcfg,
                      episodes=3, train_batch_size=8, updates_per_episode=1,
                      max_candidates=16, env_cfg=EnvConfig(max_steps=3), seed=7)
    recs = greedy_optimize(agent, [test[0]], service, rcfg, EnvConfig(max_steps=3))
    assert len(recs) == 1 and np.isfinite(recs[0].reward)
