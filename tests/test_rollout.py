"""Fleet rollout engine: one Q dispatch + one property batch per step,
seeded equivalence with the seed per-worker sequential path, and the
PropertyService in-batch dedupe."""

import jax
import numpy as np
import pytest

from repro.chem.smiles import from_smiles
from repro.core import (
    DQNAgent, DQNConfig, EnvConfig, ReplayBuffer, RewardConfig, RolloutEngine,
    TrainerConfig,
)
from repro.core.agent import QNetwork
from repro.core.distributed import DistributedTrainer

MOLS = [from_smiles(s) for s in
        ("C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O", "CC1=CC=CC=C1O", "OC1=CC=CC=C1O")]


class _OracleService:
    """Deterministic stand-in for PropertyService (oracle-backed)."""

    def __init__(self):
        from repro.chem.conformer import has_valid_conformer
        from repro.chem.oracle import oracle_bde, oracle_ip
        from repro.predictors.service import Properties
        self._p, self._bde, self._ip, self._ok = \
            Properties, oracle_bde, oracle_ip, has_valid_conformer
        self.n_calls = 0

    def predict(self, mols):
        self.n_calls += 1
        return [self._p(bde=self._bde(m), ip=self._ip(m) if self._ok(m) else None)
                for m in mols]


def _trainer(sync_mode: str, rollout: str) -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=2, mols_per_worker=2, episodes=2, sync_mode=sync_mode,
        rollout=rollout, updates_per_episode=2, train_batch_size=8,
        max_candidates=16, dqn=DQNConfig(epsilon_decay=0.9),
        env=EnvConfig(max_steps=3), seed=0)
    return DistributedTrainer(cfg, MOLS, _OracleService(), RewardConfig(),
                              network=QNetwork(hidden=(64, 32)))


def _transitions(buf: ReplayBuffer):
    return [(t.state_fp.tobytes(), t.steps_left_frac, t.reward, t.done,
             t.next_fps.tobytes(), t.next_steps_left_frac) for t in buf._items]


# ------------------------------------------------------------------ #
# seeded equivalence: fleet engine == seed per-worker path
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("sync_mode", ["episode", "step"])
def test_fleet_rollout_matches_per_worker(sync_mode):
    fleet = _trainer(sync_mode, "fleet")
    seq = _trainer(sync_mode, "per_worker")
    for _ in range(2):
        sf = fleet.train_episode()
        ss = seq.train_episode()
        assert sf["mean_final_reward"] == pytest.approx(
            ss["mean_final_reward"], abs=1e-6)
        assert sf["loss"] == pytest.approx(ss["loss"], abs=1e-5, nan_ok=True)
    # per-worker replay buffers hold identical transition streams
    for bf, bs in zip(fleet.buffers, seq.buffers):
        assert _transitions(bf) == _transitions(bs)
    # and the synced parameters agree
    for xf, xs in zip(jax.tree_util.tree_leaves(fleet.params),
                      jax.tree_util.tree_leaves(seq.params)):
        np.testing.assert_allclose(np.asarray(xf), np.asarray(xs), atol=1e-6)


# ------------------------------------------------------------------ #
# O(1) dispatch scaling
# ------------------------------------------------------------------ #
def test_fleet_one_q_dispatch_and_one_property_batch_per_step():
    tr = _trainer("episode", "fleet")
    tr.engine.reset()
    steps = 0
    while not tr.engine.done:
        q0, p0 = tr.n_q_dispatches, tr.service.n_calls
        tr.engine.step(tr._fleet_policy, tr.service, tr.reward_cfg, tr.buffers)
        assert tr.n_q_dispatches == q0 + 1          # regardless of n_workers
        assert tr.service.n_calls == p0 + 1
        steps += 1
    assert steps == tr.cfg.env.max_steps


def test_per_worker_path_scales_dispatches_with_workers():
    tr = _trainer("episode", "per_worker")
    env = tr.envs[0]
    env.reset()
    q0 = tr.n_q_dispatches
    env.step(tr._views[0], tr.service, tr.reward_cfg, tr.buffers[0])
    assert tr.n_q_dispatches == q0 + 1  # ... per WORKER, i.e. W per fleet step


# ------------------------------------------------------------------ #
# engine mechanics with a plain single-model agent
# ------------------------------------------------------------------ #
def test_engine_multi_worker_with_shared_agent():
    engine = RolloutEngine([[MOLS[0], MOLS[1]], [MOLS[2], MOLS[3]]],
                           EnvConfig(max_steps=2))
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    bufs = [ReplayBuffer(100, seed=2), ReplayBuffer(100, seed=3)]
    recs = engine.run_episode(agent, _OracleService(), RewardConfig(), bufs)
    assert len(recs) == 2 * 2 * 2                    # W x mols x steps
    assert {(r.worker, r.slot) for r in recs} == {(0, 0), (0, 1), (1, 0), (1, 1)}
    assert len(bufs[0]) == 4 and len(bufs[1]) == 4   # all transitions threaded
    assert agent.n_q_dispatches == 2                 # one per step, fleet-wide
    for m in engine.final_molecules():
        m.check_valences()
        assert m.has_oh_bond()


def test_slot_index_is_stored_not_scanned():
    engine = RolloutEngine([[MOLS[0], MOLS[1]]], EnvConfig(max_steps=2))
    assert [s.index for s in engine.workers[0]] == [0, 1]
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    recs = engine.step(agent, _OracleService(), RewardConfig())
    assert [r.slot for r in recs] == [0, 1]


# ------------------------------------------------------------------ #
# fleet-sized fingerprint batches: chunked pass is bit-identical
# ------------------------------------------------------------------ #
def test_chunked_fingerprints_bit_identical():
    from repro.chem.actions import enumerate_actions
    from repro.chem.fingerprint import batch_morgan_fingerprints
    cands = [a.result for m in MOLS for a in enumerate_actions(m)]
    assert len(cands) > 64  # spans several chunks below
    ref = batch_morgan_fingerprints(cands, chunk=0)
    for chunk in (17, 64):  # uneven + even chunking, distinct per-chunk m_max
        np.testing.assert_array_equal(
            batch_morgan_fingerprints(cands, chunk=chunk), ref)
    np.testing.assert_array_equal(
        batch_morgan_fingerprints(cands, counts=True, chunk=31),
        batch_morgan_fingerprints(cands, counts=True, chunk=0))


# ------------------------------------------------------------------ #
# PropertyService: duplicate molecules in one batch featurize once
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def tiny_service():
    from repro.predictors.gnn import AlfabetS
    from repro.predictors.ip_net import AIMNetS
    from repro.predictors.service import PropertyService
    bde_model, ip_model = AlfabetS(), AIMNetS()
    return PropertyService(
        bde_model, bde_model.init(jax.random.PRNGKey(0)),
        ip_model, ip_model.init(jax.random.PRNGKey(1)))


def test_service_dedupes_within_batch(tiny_service):
    svc = tiny_service
    svc.cache.reset_stats()
    n_mols0 = svc.n_predictor_mols
    a, b = MOLS[0], MOLS[1]
    props = svc.predict([a, b, a, a])                # duplicates in ONE batch
    assert svc.n_predictor_mols == n_mols0 + 2       # featurized a, b once each
    assert svc.cache.misses == 4 and svc.cache.hits == 0
    assert props[0].bde == props[2].bde == props[3].bde
    assert props[0].ip == props[2].ip == props[3].ip
    # second call is pure cache
    n_batches = svc.n_predictor_batches
    props2 = svc.predict([a, b])
    assert svc.n_predictor_batches == n_batches
    assert svc.cache.hits == 2
    assert props2[0].bde == props[0].bde
