"""Fleet rollout engine: the acting-path equivalence matrix (every rollout
mode transition-identical to the sequential reference), ragged-fleet and
zero-candidate robustness, shape discipline (no recompiles once capacity
settles), and PropertyService dedupe / bucket selection."""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # declared in pyproject [test]; degrade to a skip
    HAVE_HYPOTHESIS = False

from repro.chem.smiles import from_smiles
from repro.core import (
    CHEM_MODES, DQNAgent, DQNConfig, EnvConfig, ReplayBuffer, RewardConfig,
    RolloutEngine, TrainerConfig,
)
from repro.core.agent import QNetwork, candidate_capacity, candidate_capacity_table
from repro.core.distributed import ROLLOUT_MODES, DistributedTrainer
from repro.core.jit_stats import jit_cache_size

from conftest import OracleService as _OracleService

MOLS = [from_smiles(s) for s in
        ("C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O", "CC1=CC=CC=C1O", "OC1=CC=CC=C1O")]


def _trainer(sync_mode: str, rollout: str) -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=2, mols_per_worker=2, episodes=2, sync_mode=sync_mode,
        rollout=rollout, updates_per_episode=2, train_batch_size=8,
        max_candidates=16, dqn=DQNConfig(epsilon_decay=0.9),
        env=EnvConfig(max_steps=3), seed=0)
    return DistributedTrainer(cfg, MOLS, _OracleService(), RewardConfig(),
                              network=QNetwork(hidden=(64, 32)))


def _transitions(buf: ReplayBuffer):
    return [(t.state_fp.tobytes(), t.steps_left_frac, t.reward, t.done,
             t.next_fps.tobytes(), t.next_steps_left_frac) for t in buf._items]


# ------------------------------------------------------------------ #
# the equivalence matrix: every rollout mode == sequential reference
# ------------------------------------------------------------------ #
def _matrix_trainer(rollout: str, sync_mode: str, W: int, seed: int,
                    chem: str = "full", acting: str = "packed",
                    scenarios=None, reward_cfg=None,
                    updates_per_episode: int = 1) -> DistributedTrainer:
    cfg = TrainerConfig(
        n_workers=W, mols_per_worker=1, episodes=2, sync_mode=sync_mode,
        rollout=rollout, chem=chem, acting=acting,
        updates_per_episode=updates_per_episode,
        train_batch_size=3, max_candidates=16, dqn=DQNConfig(epsilon_decay=0.9),
        env=EnvConfig(max_steps=3), seed=seed, scenarios=scenarios)
    mols = (MOLS * ((W + len(MOLS) - 1) // len(MOLS)))[:W]
    return DistributedTrainer(cfg, mols, _OracleService(),
                              reward_cfg if reward_cfg is not None
                              else RewardConfig(),
                              network=QNetwork(hidden=(32,)))


def _assert_matrix_equivalent(seed: int, W: int, sync_mode: str,
                              episodes: int,
                              chem_modes=CHEM_MODES) -> None:
    """Every (rollout mode x chem mode) cell must produce the identical
    transition stream (and, when training updates run, identical synced
    parameters) as the sequential full-recompute reference."""
    streams, stats, params = {}, {}, {}
    for chem in chem_modes:
        for mode in ROLLOUT_MODES:
            tr = _matrix_trainer(mode, sync_mode, W, seed, chem=chem)
            cell = (mode, chem)
            stats[cell] = [tr.train_episode() for _ in range(episodes)]
            streams[cell] = [_transitions(b) for b in tr.buffers]
            params[cell] = jax.tree_util.tree_leaves(tr.params)
    ref = ("per_worker", chem_modes[0])
    for cell in streams:
        if cell == ref:
            continue
        assert streams[cell] == streams[ref], \
            f"{cell} transition stream diverged from {ref} (W={W}, {sync_mode})"
        for sm, sr in zip(stats[cell], stats[ref]):
            assert sm["mean_final_reward"] == pytest.approx(
                sr["mean_final_reward"], abs=1e-6, nan_ok=True)
            assert sm["loss"] == pytest.approx(sr["loss"], abs=1e-5, nan_ok=True)
        for xm, xr in zip(params[cell], params[ref]):
            np.testing.assert_allclose(np.asarray(xm), np.asarray(xr), atol=1e-6)


@pytest.mark.parametrize("sync_mode", ["episode", "step"])
@pytest.mark.parametrize("W", [1, 4, 8])
def test_rollout_mode_matrix(W, sync_mode):
    _assert_matrix_equivalent(seed=0, W=W, sync_mode=sync_mode, episodes=2)


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**20),
           W=st.sampled_from([1, 4, 8]),
           sync_mode=st.sampled_from(["episode", "step"]))
    def test_rollout_mode_matrix_property(seed, W, sync_mode):
        _assert_matrix_equivalent(seed=seed, W=W, sync_mode=sync_mode, episodes=1)
else:
    def test_rollout_mode_matrix_property():
        pytest.importorskip("hypothesis")


# ------------------------------------------------------------------ #
# the objective axis: scenario mixes and raw callables through every
# rollout mode (the fleet-vectorized reward layer vs the per-worker
# scalar reference)
# ------------------------------------------------------------------ #
SCENARIO_MIX = ("antioxidant", "qed", "antioxidant_novel", "plogp")


def _custom_objective(props, initial, current, steps_left):
    """A raw pluggable objective (the serving-style callable contract)."""
    if props.bde is None or props.ip is None:
        return -5.0
    return 0.01 * (props.ip - props.bde) + 0.05 * steps_left \
        + 0.001 * current.num_atoms


@pytest.mark.parametrize("sync_mode", ["episode", "step"])
@pytest.mark.parametrize("objective", ["mix", "callable"])
def test_objective_axis_matrix(objective, sync_mode):
    """Every rollout mode must produce the per_worker reference's exact
    transition stream under (a) a heterogeneous scenario mix — including
    the stateful novelty scenario — and (b) a raw callable objective.
    Worker-major row order in the fleet reward layer is what keeps the
    novelty visit sequence identical across modes."""
    kw = ({"scenarios": SCENARIO_MIX} if objective == "mix"
          else {"reward_cfg": _custom_objective})
    streams, params, losses = {}, {}, {}
    for mode in ROLLOUT_MODES:
        tr = _matrix_trainer(mode, sync_mode, 4, seed=5, chem="incremental",
                             **kw)
        st = [tr.train_episode() for _ in range(2)]
        streams[mode] = [_transitions(b) for b in tr.buffers]
        params[mode] = [np.asarray(x)
                        for x in jax.tree_util.tree_leaves(tr.params)]
        losses[mode] = [s["loss"] for s in st]
    for mode in ROLLOUT_MODES:
        assert streams[mode] == streams["per_worker"], \
            f"{mode}/{objective}: transition stream diverged ({sync_mode})"
        assert losses[mode] == pytest.approx(losses["per_worker"], nan_ok=True)
        for xm, xr in zip(params[mode], params["per_worker"]):
            np.testing.assert_array_equal(
                xm, xr, err_msg=f"{mode}/{objective}: params diverged")


def test_homogeneous_scenario_fleet_bit_identical_to_default_path():
    """THE tentpole determinism gate (single-process side; nd > 1 lives in
    tests/multidevice/test_scenarios.py): a fleet running
    scenarios=("antioxidant",) * W — the registry spec compiled against the
    trainer's RewardConfig — is bit-identical to scenarios=None (the
    pre-refactor scalar Eq. 1 path) in transitions, losses AND params."""
    runs = {}
    for scen in (None, ("antioxidant",) * 4):
        tr = _matrix_trainer("fleet", "episode", 4, seed=0,
                             chem="incremental", scenarios=scen)
        stats = [tr.train_episode() for _ in range(2)]
        runs[scen is None] = (
            [_transitions(b) for b in tr.buffers],
            [s["loss"] for s in stats],
            [np.asarray(x) for x in jax.tree_util.tree_leaves(tr.params)])
    assert runs[True][0] == runs[False][0]
    assert runs[True][1] == runs[False][1]           # exact loss equality
    for a, b in zip(runs[True][2], runs[False][2]):
        np.testing.assert_array_equal(a, b)


def test_mixed_fleet_worker_bit_identical_to_solo_twin():
    """Each worker of a mixed-scenario fleet reproduces the exact per-worker
    transition stream of a homogeneous fleet running only its scenario.
    Updates are off (updates_per_episode=0) so workers stay decoupled —
    with param sync on, every worker's actions legitimately depend on the
    whole fleet's replay; without it the only cross-worker channel left
    would be a reward-layer leak, which is what this pins against."""
    def run(scenarios):
        tr = _matrix_trainer("fleet", "episode", 4, seed=2,
                             chem="incremental", scenarios=scenarios,
                             updates_per_episode=0)
        for _ in range(2):
            tr.train_episode()
        return [_transitions(b) for b in tr.buffers]

    mix = ("antioxidant", "antioxidant_novel")       # cycled: w%2
    mixed = run(mix)
    solos = {name: run((name,)) for name in mix}
    for w in range(4):
        assert mixed[w] == solos[mix[w % 2]][w], \
            f"worker {w} ({mix[w % 2]}) diverged from its solo twin"


# ------------------------------------------------------------------ #
# acting representation matrix: packed / packed_async == dense reference
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("sync_mode", ["episode", "step"])
def test_acting_mode_matrix(sync_mode):
    """Every (fleet rollout x acting representation) cell must reproduce
    the sequential dense reference bit for bit: the packed u8 planes and
    the async dispatch / pre-drawn selection change the transport and the
    overlap, never the actions, transitions or parameters.  (The main
    rollout matrix above already pins acting="packed" — the trainer
    default — against the dense per_worker reference; this one adds the
    explicit dense and packed_async fleet cells.)"""
    from repro.core import ACTING_MODES

    def run(rollout, acting):
        tr = _matrix_trainer(rollout, sync_mode, 4, seed=3,
                             chem="incremental", acting=acting)
        stats = [tr.train_episode() for _ in range(2)]
        return ([_transitions(b) for b in tr.buffers],
                [np.asarray(x) for x in jax.tree_util.tree_leaves(tr.params)],
                [s["loss"] for s in stats])

    ref_streams, ref_params, ref_losses = run("per_worker", "dense")
    for rollout in ("fleet", "fleet_sharded", "fleet_pipelined"):
        for acting in ACTING_MODES:
            streams, params, losses = run(rollout, acting)
            cell = f"{rollout}/{acting} ({sync_mode})"
            assert streams == ref_streams, f"{cell}: transition streams diverged"
            assert losses == pytest.approx(ref_losses, nan_ok=True), \
                f"{cell}: loss trajectory diverged"
            for xm, xr in zip(params, ref_params):
                np.testing.assert_array_equal(xm, xr,
                                              err_msg=f"{cell}: params diverged")


def test_packed_view_dead_rows_stay_zero():
    """Ragged/finished slots contribute all-zero rows to the sticky packed
    acting buffer: stale bytes from an earlier (larger) step must never
    reach the Q evaluation as garbage bit planes."""
    from repro.core.replay import FP_BYTES

    tr = _trainer("episode", "fleet")               # acting defaults to packed
    view = tr._fleet_policy
    assert view.wants_packed_states
    view.reserve(8)
    view._bits[:] = 0xFF                            # poison: stale planes
    view._frac[:] = 7.0
    rng = np.random.default_rng(0)
    bits0 = rng.integers(0, 256, (3, FP_BYTES), dtype=np.uint8)
    frac0 = rng.random(3).astype(np.float32)
    q = view.fleet_q_values_packed(                 # worker 1 is dead: 0 rows
        [bits0, np.zeros((0, FP_BYTES), np.uint8)],
        [frac0, np.zeros((0,), np.float32)])
    assert q[0].shape == (3,) and q[1].shape == (0,)
    np.testing.assert_array_equal(view._bits[0, :3], bits0)
    assert not view._bits[0, 3:].any() and not view._frac[0, 3:].any()
    assert not view._bits[1].any() and not view._frac[1].any()


# ------------------------------------------------------------------ #
# O(1) dispatch scaling (reference and pipelined step loops)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("pipelined", [False, True])
def test_fleet_one_q_dispatch_and_one_property_batch_per_step(pipelined):
    tr = _trainer("episode", "fleet_pipelined" if pipelined else "fleet")
    tr.engine.reset()
    policy = tr._fleet_policy_sharded if pipelined else tr._fleet_policy
    step = tr.engine.step_pipelined if pipelined else tr.engine.step
    steps = 0
    while not tr.engine.done:
        q0, p0 = tr.n_q_dispatches, tr.service.n_calls
        step(policy, tr.service, tr.reward_cfg, tr.buffers)
        assert tr.n_q_dispatches == q0 + 1          # regardless of n_workers
        assert tr.service.n_calls == p0 + 1
        steps += 1
    assert steps == tr.cfg.env.max_steps


def test_per_worker_path_scales_dispatches_with_workers():
    tr = _trainer("episode", "per_worker")
    env = tr.envs[0]
    env.reset()
    q0 = tr.n_q_dispatches
    env.step(tr._views[0], tr.service, tr.reward_cfg, tr.buffers[0])
    assert tr.n_q_dispatches == q0 + 1  # ... per WORKER, i.e. W per fleet step


# ------------------------------------------------------------------ #
# engine mechanics with a plain single-model agent
# ------------------------------------------------------------------ #
def test_engine_multi_worker_with_shared_agent():
    engine = RolloutEngine([[MOLS[0], MOLS[1]], [MOLS[2], MOLS[3]]],
                           EnvConfig(max_steps=2))
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    bufs = [ReplayBuffer(100, seed=2), ReplayBuffer(100, seed=3)]
    recs = engine.run_episode(agent, _OracleService(), RewardConfig(), bufs)
    assert len(recs) == 2 * 2 * 2                    # W x mols x steps
    assert {(r.worker, r.slot) for r in recs} == {(0, 0), (0, 1), (1, 0), (1, 1)}
    assert len(bufs[0]) == 4 and len(bufs[1]) == 4   # all transitions threaded
    assert agent.n_q_dispatches == 2                 # one per step, fleet-wide
    for m in engine.final_molecules():
        m.check_valences()
        assert m.has_oh_bond()


def test_slot_index_is_stored_not_scanned():
    engine = RolloutEngine([[MOLS[0], MOLS[1]]], EnvConfig(max_steps=2))
    assert [s.index for s in engine.workers[0]] == [0, 1]
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    recs = engine.step(agent, _OracleService(), RewardConfig())
    assert [r.slot for r in recs] == [0, 1]


# ------------------------------------------------------------------ #
# ragged fleets: uneven worker sizes, early finishers, dead workers
# ------------------------------------------------------------------ #
def test_ragged_worker_sizes_and_early_finishers():
    """Workers may own different slot counts and slots may run out of steps
    at different times; the engine keeps stepping the survivors."""
    engine = RolloutEngine([[MOLS[0], MOLS[1]], [MOLS[2]]], EnvConfig(max_steps=3))
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    svc, bufs = _OracleService(), [ReplayBuffer(100, seed=2), ReplayBuffer(100, seed=3)]
    engine.step(agent, svc, RewardConfig(), bufs)   # also triggers first enumerate
    engine.workers[1][0].steps_left = 1             # worker 1 finishes next step
    recs2 = engine.step(agent, svc, RewardConfig(), bufs)
    assert any(r.done for r in recs2 if r.worker == 1)
    recs3 = engine.step(agent, svc, RewardConfig(), bufs)
    assert all(r.worker == 0 for r in recs3)        # only worker 0 still live
    while not engine.done:
        engine.step(agent, svc, RewardConfig(), bufs)
    assert len(bufs[0]) == 2 * 3 and len(bufs[1]) == 2  # every transition landed


def test_ragged_fleet_keeps_dense_shape_on_fleet_path():
    """A worker dying mid-episode must not change the dense [W, C, D] jit
    shape: dead rows zero out, capacity is sticky."""
    tr = _trainer("episode", "fleet")
    tr.reserve_candidates(200)                      # settle capacity up front
    engine = tr.engine
    engine.reset()
    engine.step(tr._fleet_policy, tr.service, tr.reward_cfg, tr.buffers)
    # whichever fleet jit the configured acting mode dispatches through
    # (packed by default), its shape set must not grow when workers die
    fleet_jits = (tr._fleet_q, tr._fleet_q_packed)
    n_shapes = tuple(jit_cache_size(f) for f in fleet_jits)
    assert sum(n_shapes) > 0                        # one of them actually ran
    for s in engine.workers[0]:                     # worker 0 finishes early
        s.steps_left = 0
    while not engine.done:
        engine.step(tr._fleet_policy, tr.service, tr.reward_cfg, tr.buffers)
    assert tuple(jit_cache_size(f) for f in fleet_jits) == n_shapes


def test_zero_candidate_slots_die_cleanly(monkeypatch):
    """A slot whose molecule has no legal action stops acting; its in-flight
    transition is completed with an EMPTY successor set and still reaches
    the replay buffer (the double-DQN max values it at zero)."""
    import repro.core.rollout as rollout_mod
    engine = RolloutEngine([[MOLS[0], MOLS[1]]], EnvConfig(max_steps=3))
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    svc, bufs = _OracleService(), [ReplayBuffer(100, seed=2)]
    engine.step(agent, svc, RewardConfig(), bufs)
    # every molecule now has zero candidates: both slots die at the end of
    # the next step even though steps_left would allow a third step
    monkeypatch.setattr(rollout_mod, "enumerate_actions", lambda m, **kw: [])
    engine.step(agent, svc, RewardConfig(), bufs)
    assert engine.done
    assert len(bufs[0]) == 4                        # 2 slots x 2 steps, none lost
    tail = bufs[0]._items[-2:]
    assert all(t.next_fps.shape[0] == 0 and not t.done for t in tail)
    batch = bufs[0].sample(8, max_candidates=16)    # trainable as-is
    assert np.isfinite(batch["rewards"]).all()


def test_all_slots_dead_at_reset(monkeypatch):
    """No legal action anywhere on step one: the engine finishes without a
    single Q dispatch or property batch instead of crashing."""
    import repro.core.rollout as rollout_mod
    monkeypatch.setattr(rollout_mod, "enumerate_actions", lambda m, **kw: [])
    engine = RolloutEngine([[MOLS[0]], [MOLS[1]]], EnvConfig(max_steps=3))
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    svc = _OracleService()
    assert engine.step(agent, svc, RewardConfig(), None) == []
    assert engine.done and svc.n_calls == 0 and agent.n_q_dispatches == 0


def test_pipelined_matches_reference_under_zero_candidate_deaths(monkeypatch):
    """The overlap path must keep the identical transition stream even when
    slots die mid-episode from candidate exhaustion."""
    import repro.core.rollout as rollout_mod
    real = rollout_mod.enumerate_actions

    def gated(m, **kw):   # molecules that grew past 8 heavy atoms are stuck
        return [] if len(m.elements) > 8 else real(m, **kw)

    monkeypatch.setattr(rollout_mod, "enumerate_actions", gated)
    streams = []
    for pipelined in (False, True):
        engine = RolloutEngine([[MOLS[0], MOLS[1]], [MOLS[2], MOLS[3]]],
                               EnvConfig(max_steps=4))
        agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=7,
                         network=QNetwork(hidden=(32,)))
        bufs = [ReplayBuffer(100, seed=11), ReplayBuffer(100, seed=12)]
        engine.run_episode(agent, _OracleService(), RewardConfig(), bufs,
                           pipelined=pipelined)
        streams.append([_transitions(b) for b in bufs])
    assert streams[0] == streams[1]


def test_quarantined_fleet_revives_clean_next_episode():
    """Self-healing fleet: slots quarantined by terminal chem faults are
    revived by the next episode's reset, and once the fault clears the
    revived fleet's transition stream is BIT-identical to a fresh engine's
    — quarantine leaves no residue in the engine."""
    from repro.core.faults import FaultPlan, FaultRule

    plan = FaultPlan([FaultRule(site="chem", kind="transient", rate=1.0,
                                fail_attempts=1000)], seed=0)
    engine = RolloutEngine([[MOLS[0], MOLS[1]], [MOLS[2], MOLS[3]]],
                           EnvConfig(max_steps=3), chem="incremental",
                           fault_plan=plan)
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    svc = _OracleService()
    bufs = [ReplayBuffer(100, seed=2), ReplayBuffer(100, seed=3)]
    recs = engine.run_episode(agent, svc, RewardConfig(), bufs)
    st = engine.fault_stats()
    assert st["n_quarantined"] == 4          # rate=1.0: the whole fleet died
    assert recs == [] and all(len(b) == 0 for b in bufs)
    assert all(i["site"] == "chem" and i["action"] == "quarantined"
               for i in st["incidents"])

    engine.fault_plan = None                 # the fault clears; fleet revives

    def episode(eng):
        ag = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=7,
                      network=QNetwork(hidden=(32,)))
        bs = [ReplayBuffer(100, seed=11), ReplayBuffer(100, seed=12)]
        rs = eng.run_episode(ag, _OracleService(), RewardConfig(), bs)
        return rs, [_transitions(b) for b in bs]

    recs2, streams2 = episode(engine)
    fresh = RolloutEngine([[MOLS[0], MOLS[1]], [MOLS[2], MOLS[3]]],
                          EnvConfig(max_steps=3), chem="incremental")
    recs3, streams3 = episode(fresh)
    assert {(r.worker, r.slot) for r in recs2} == \
        {(0, 0), (0, 1), (1, 0), (1, 1)}     # every slot is acting again
    assert streams2 == streams3
    assert engine.fault_stats()["n_quarantined"] == 4   # no new deaths


# ------------------------------------------------------------------ #
# mesh padding: dead workers beyond the live fleet (engine-level; the
# trainer-level nd > 1 equivalence lives in tests/multidevice)
# ------------------------------------------------------------------ #
def test_engine_mesh_padding_is_transition_invisible():
    """An engine padded to a larger mesh width (dead workers own no slots)
    must produce the exact transition stream of the unpadded engine, accept
    per-LIVE-worker buffer lists, and never write a dead worker's buffer."""
    streams = []
    for pad in (None, 4):
        engine = RolloutEngine([[MOLS[0]], [MOLS[1]]], EnvConfig(max_steps=3),
                               pad_workers_to=pad)
        agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=3,
                         network=QNetwork(hidden=(32,)))
        bufs = [ReplayBuffer(100, seed=5), ReplayBuffer(100, seed=6)]
        recs = engine.run_episode(agent, _OracleService(), RewardConfig(), bufs)
        assert engine.n_workers == (pad or 2)
        assert engine.n_live_workers == 2
        assert {r.worker for r in recs} == {0, 1}       # dead workers silent
        streams.append([_transitions(b) for b in bufs])
    assert streams[0] == streams[1]


def test_engine_pad_buffers_validates_length():
    engine = RolloutEngine([[MOLS[0]], [MOLS[1]]], EnvConfig(max_steps=2),
                           pad_workers_to=4)
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=3,
                     network=QNetwork(hidden=(32,)))
    with pytest.raises(ValueError, match="buffers"):
        engine.step(agent, _OracleService(), RewardConfig(),
                    [ReplayBuffer(10, seed=1)] * 3)     # neither live nor padded
    with pytest.raises(ValueError, match="pad_workers_to"):
        RolloutEngine([[MOLS[0]], [MOLS[1]]], pad_workers_to=1)


# ------------------------------------------------------------------ #
# capacity ladders (pure)
# ------------------------------------------------------------------ #
def test_candidate_capacity_table_scales_with_fleet():
    small, big = candidate_capacity_table(4), candidate_capacity_table(512)
    assert len(big) > len(small)                    # finer rungs at large W
    for table in (small, big):
        assert all(b > a for a, b in zip(table, table[1:]))
        assert candidate_capacity(1, table) == table[0]
        assert candidate_capacity(table[-1] + 1, table) >= table[-1] + 1
    # big-fleet rung ratio is bounded: never pads 2x past the previous rung
    ratios = [b / a for a, b in zip(big, big[1:])]
    assert max(ratios[2:]) <= 1.5


def test_service_capacity_table_snaps_to_fleet_batch():
    from repro.predictors.service import capacity_table
    table = capacity_table(512)
    assert table[-1] == 512
    # dedupe drift just below W reuses the exact reserved shape
    assert next(c for c in table if c >= 500) == 512
    assert next(c for c in table if c >= 412) == 512
    table64 = capacity_table(64)
    assert table64[-1] == 64 and table64[0] == 1


# ------------------------------------------------------------------ #
# fleet-sized fingerprint batches: chunked pass is bit-identical
# ------------------------------------------------------------------ #
def test_chunked_fingerprints_bit_identical():
    from repro.chem.actions import enumerate_actions
    from repro.chem.fingerprint import batch_morgan_fingerprints
    cands = [a.result for m in MOLS for a in enumerate_actions(m)]
    assert len(cands) > 64  # spans several chunks below
    ref = batch_morgan_fingerprints(cands, chunk=0)
    for chunk in (17, 64):  # uneven + even chunking, distinct per-chunk m_max
        np.testing.assert_array_equal(
            batch_morgan_fingerprints(cands, chunk=chunk), ref)
    np.testing.assert_array_equal(
        batch_morgan_fingerprints(cands, counts=True, chunk=31),
        batch_morgan_fingerprints(cands, counts=True, chunk=0))


# ------------------------------------------------------------------ #
# incremental candidate chemistry: engine fps, fleet-wide chem cache
# ------------------------------------------------------------------ #
def _fresh_engine(chem, mols=None, max_steps=3):
    return RolloutEngine([list(mols or MOLS[:2])], EnvConfig(max_steps=max_steps),
                         chem=chem)


def test_chem_incremental_candidate_fps_bit_identical():
    """Stepping the full-recompute and incremental engines in lockstep, the
    per-slot candidate fingerprints (dense AND packed rows) are bit-equal at
    every step — the acceptance pin for the §3.6 incremental pass."""
    engines, agents = {}, {}
    for chem in CHEM_MODES:
        engines[chem] = _fresh_engine(chem)
        agents[chem] = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=5,
                                network=QNetwork(hidden=(32,)))
    svc = _OracleService()
    while not engines["full"].done:
        for chem in CHEM_MODES:
            engines[chem].step(agents[chem], svc, RewardConfig())
        for sf, si in zip(engines["full"].workers[0],
                          engines["incremental"].workers[0]):
            np.testing.assert_array_equal(sf.cand_fps, si.cand_fps)
            np.testing.assert_array_equal(sf.cand_fps_packed, si.cand_fps_packed)
            assert [a.detail for a in sf.candidates] == \
                   [a.detail for a in si.candidates]


def test_packed_candidate_rows_match_pack_fp():
    """The one-packbits-per-batch satellite: every packed row equals the
    seed's per-candidate pack_fp, and pending successors alias those rows."""
    from repro.core.replay import pack_fp
    engine = _fresh_engine("full")
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=2,
                     network=QNetwork(hidden=(32,)))
    engine.step(agent, _OracleService(), RewardConfig())
    for s in engine.workers[0]:
        assert s.cand_fps_packed.shape == (s.cand_fps.shape[0], 2048 // 8)
        for r in range(s.cand_fps.shape[0]):
            np.testing.assert_array_equal(s.cand_fps_packed[r],
                                          pack_fp(s.cand_fps[r]))
        if s.pending is not None and s.pending.next_fps is not None:
            assert s.pending.next_fps is s.cand_fps_packed


def test_chem_cache_shared_across_slots_and_episodes():
    """Two slots starting from the SAME molecule chemistry-dedupe in batch;
    restarting the episode serves step-1 enumerations from the cache."""
    engine = RolloutEngine([[MOLS[0]], [MOLS[0]]], EnvConfig(max_steps=2),
                           chem="incremental")
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=9,
                     network=QNetwork(hidden=(32,)))
    svc = _OracleService()
    engine.run_episode(agent, svc, RewardConfig())
    st = engine.chem_stats()
    assert st["entries"] < st["hits"] + st["misses"]  # in-batch dedup worked
    # second episode revisits the shared initial molecule: pure hits at reset
    h0 = st["hits"]
    engine.reset()
    engine.step(agent, svc, RewardConfig())
    assert engine.chem_stats()["hits"] >= h0 + 2


def test_chem_cache_relabel_guard():
    """Isomorphic but differently-labelled parents share a canonical key but
    must NOT share cached candidates (enumeration order depends on the
    labelling): the signature guard forces a recompute, counted separately."""
    from repro.chem.fingerprint import batch_morgan_fingerprints
    from repro.chem.molecule import Molecule
    mol = MOLS[1]
    perm = np.random.default_rng(3).permutation(mol.num_atoms)
    twin = Molecule(mol.elements[perm], mol.bonds[np.ix_(perm, perm)])
    assert twin.canonical_key() == mol.canonical_key()
    engine = _fresh_engine("incremental")
    engine._compute_enum([mol])
    acts, fps, packed = engine._compute_enum([twin])[0]
    st = engine.chem_stats()
    assert st["misses"] == 1 and st["relabel_misses"] == 1 and st["hits"] == 0
    np.testing.assert_array_equal(
        fps, batch_morgan_fingerprints([a.result for a in acts]))


def test_chem_stats_time_accounting():
    engine = _fresh_engine("incremental")
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1,
                     network=QNetwork(hidden=(32,)))
    engine.step(agent, _OracleService(), RewardConfig())
    st = engine.chem_stats()
    assert st["mode"] == "incremental"
    assert st["enum_s"] > 0 and st["fp_s"] > 0 and st["env_steps"] == 1
    engine.reset_chem_stats()
    assert engine.chem_stats()["enum_s"] == 0.0


# ------------------------------------------------------------------ #
# PropertyService: dedupe, call accounting, collisions, bucket choice
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def tiny_service():
    from repro.predictors.gnn import AlfabetS
    from repro.predictors.ip_net import AIMNetS
    from repro.predictors.service import PropertyService
    bde_model, ip_model = AlfabetS(), AIMNetS()
    return PropertyService(
        bde_model, bde_model.init(jax.random.PRNGKey(0)),
        ip_model, ip_model.init(jax.random.PRNGKey(1)))


def test_service_dedupes_within_batch(tiny_service):
    svc = tiny_service
    svc.cache.reset_stats()
    n_mols0 = svc.n_predictor_mols
    a, b = MOLS[0], MOLS[1]
    props = svc.predict([a, b, a, a])                # duplicates in ONE batch
    assert svc.n_predictor_mols == n_mols0 + 2       # featurized a, b once each
    assert svc.cache.misses == 4 and svc.cache.hits == 0
    assert props[0].bde == props[2].bde == props[3].bde
    assert props[0].ip == props[2].ip == props[3].ip
    # second call is pure cache
    n_batches = svc.n_predictor_batches
    props2 = svc.predict([a, b])
    assert svc.n_predictor_batches == n_batches
    assert svc.cache.hits == 2
    assert props2[0].bde == props[0].bde


def test_service_predict_call_accounting(tiny_service):
    """n_predict_calls counts predict() ENTRIES (one per fleet step), not
    molecules; n_predictor_batches counts jit'd model batches (cache hits
    and empty calls run none)."""
    svc = tiny_service
    calls0, batches0 = svc.n_predict_calls, svc.n_predictor_batches
    svc.predict([MOLS[0], MOLS[1], MOLS[2]])         # possibly all cached
    svc.predict([MOLS[0]])
    svc.predict([])
    assert svc.n_predict_calls == calls0 + 3
    svc.predict([MOLS[0], MOLS[1]])                  # cached from above
    assert svc.n_predictor_batches <= batches0 + 1   # at most the first ran


def test_service_iso_key_collision_coalesces(tiny_service):
    """Colliding iso_keys coalesce: the later molecule is featurized ZERO
    times and inherits the earlier one's prediction (documented
    hash-collision semantics — iso_key is an isomorphism-invariant hash,
    not a perfect identifier)."""
    svc = tiny_service
    a = from_smiles("C1=CC=CC=C1O")
    b = from_smiles("CC1=CC(C)=CC(C)=C1O")
    assert a.iso_key() != b.iso_key()
    a._iso_cache = b._iso_cache = 0xC0111DE          # force a fresh colliding key
    n_mols0 = svc.n_predictor_mols
    pa, pb = svc.predict([a, b])
    assert svc.n_predictor_mols == n_mols0 + 1       # b never featurized
    assert pb.ip == pa.ip                            # b coalesced onto a's slot
    assert pb.bde == pa.bde                          # (both have an O-H bond)


def test_fleet_sized_batch_picks_one_bucket_no_recompile_on_second_call():
    """A W=512-sized predict batch pads to the single reserved bucket, and a
    second fleet-sized batch (slightly smaller after dedupe) reuses the same
    compiled shape — zero recompiles."""
    from repro.core.jit_stats import jit_cache_size
    from repro.predictors.gnn import AlfabetS
    from repro.predictors.ip_net import AIMNetS
    from repro.predictors.service import PropertyService
    bde_model, ip_model = AlfabetS(hidden=16, rounds=1), AIMNetS(hidden=16)
    svc = PropertyService(
        bde_model, bde_model.init(jax.random.PRNGKey(0)),
        ip_model, ip_model.init(jax.random.PRNGKey(1)),
        max_atoms=12, cache=None)
    svc.reserve(512)                                 # what the trainer does at W=512

    def fresh(n, tag):
        out = []
        for i in range(n):
            m = from_smiles("C1=CC=CC=C1O")
            m._iso_cache = tag * 10_000 + i          # force distinct iso keys
            out.append(m)
        return out

    svc.predict(fresh(512, 1))                       # the full fleet batch
    assert svc.n_predictor_batches == 1
    assert jit_cache_size(svc._bde_apply) == 1
    assert jit_cache_size(svc._ip_apply) == 1
    svc.predict(fresh(490, 2))                       # post-dedupe drift
    assert svc.n_predictor_batches == 2
    assert jit_cache_size(svc._bde_apply) == 1       # same bucket, no recompile
    assert jit_cache_size(svc._ip_apply) == 1
