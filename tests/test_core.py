"""RL core: reward, replay, agent learning, environment, filter, finetune."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import OracleService as _OracleService
from repro.chem.smiles import from_smiles
from repro.core import (
    DQNConfig, EnvConfig, INVALID_CONFORMER_REWARD, ReplayBuffer, RewardConfig,
    Transition, compute_reward, filter_molecules, FilterCriteria,
)
from repro.core.agent import DQNAgent, QNetwork
from repro.core.env import BatchedEnv
from repro.core.replay import pack_fp, unpack_fp
from repro.core.reward import gamma_term

PHENOL = from_smiles("C1=CC=CC=C1O")
BHT = from_smiles("CC1=CC(C)=CC(C)=C1O")


# ------------------------------------------------------------------ #
# reward (Eq. 1)
# ------------------------------------------------------------------ #
def test_reward_eq1():
    cfg = RewardConfig(bde_min=60, bde_max=90, ip_min=100, ip_max=200)
    r = compute_reward(cfg, bde=60.0, ip=200.0, initial=PHENOL, current=PHENOL, steps_left=0)
    # nBDE = 0, nIP = 1, gamma = 0 -> r = w2 = 0.2
    assert abs(r - 0.2) < 1e-9
    r2 = compute_reward(cfg, bde=90.0, ip=100.0, initial=PHENOL, current=PHENOL, steps_left=0)
    assert abs(r2 - (-0.8)) < 1e-9


def test_reward_invalid_conformer():
    cfg = RewardConfig()
    assert compute_reward(cfg, bde=70.0, ip=None, initial=PHENOL, current=PHENOL) \
        == INVALID_CONFORMER_REWARD


def test_gamma_rewards_shrinking():
    assert gamma_term(BHT, PHENOL) > 0
    assert gamma_term(PHENOL, BHT) < 0
    assert gamma_term(PHENOL, PHENOL) == 0


# ------------------------------------------------------------------ #
# replay
# ------------------------------------------------------------------ #
def test_pack_unpack_roundtrip():
    fp = (np.random.default_rng(0).random(2048) > 0.7).astype(np.float32)
    assert np.array_equal(unpack_fp(pack_fp(fp)), fp)


def test_replay_ring_and_sample():
    buf = ReplayBuffer(capacity=8, seed=0)
    for i in range(12):
        fp = np.zeros(2048, np.float32)
        fp[i % 100] = 1.0
        buf.add(Transition(pack_fp(fp), 0.5, float(i), i % 2 == 0,
                           np.stack([pack_fp(fp)]), 0.4))
    assert len(buf) == 8
    batch = buf.sample(16, max_candidates=4)
    assert batch["states"].shape == (16, 2049)
    assert batch["next_fps"].shape == (16, 4, 2049)
    # terminal transitions must have empty next mask
    done_rows = batch["dones"] > 0.5
    assert np.all(batch["next_mask"][done_rows].sum(-1) == 0)


# ------------------------------------------------------------------ #
# agent
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def small_net():
    return QNetwork(hidden=(64, 32))


def test_agent_learns_synthetic_targets(small_net):
    """Q(s) must regress toward r for terminal transitions."""
    agent = DQNAgent(DQNConfig(lr=3e-3), seed=0, network=small_net)
    rng = np.random.default_rng(0)
    states = rng.random((64, 2049)).astype(np.float32)
    rewards = states[:, :10].sum(axis=1)
    batch = {
        "states": states, "rewards": rewards,
        "dones": np.ones(64, np.float32),
        "next_fps": np.zeros((64, 4, 2049), np.float32),
        "next_mask": np.zeros((64, 4), np.float32),
    }
    first = agent.train_step(batch)
    for _ in range(200):
        last = agent.train_step(batch)
    assert last < first * 0.2, (first, last)


def test_epsilon_decay():
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0, epsilon_decay=0.5, epsilon_min=0.1))
    for _ in range(10):
        agent.decay_epsilon()
    assert abs(agent.epsilon - 0.1) < 1e-9


def test_greedy_action_selection(small_net):
    agent = DQNAgent(DQNConfig(epsilon_initial=0.0), seed=0, network=small_net)
    q = np.array([0.1, 5.0, -1.0])
    assert agent.select_action(q) == 1


# ------------------------------------------------------------------ #
# environment
# ------------------------------------------------------------------ #
def test_episode_mechanics(small_net):
    cfg = EnvConfig(max_steps=3)
    env = BatchedEnv([PHENOL, BHT], cfg, seed=0)
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1, network=small_net)
    buf = ReplayBuffer(100, seed=2)
    service = _OracleService()
    rcfg = RewardConfig()

    n_steps = 0
    while not env.done:
        recs = env.step(agent, service, rcfg, buf)
        n_steps += 1
        assert len(recs) == 2
    assert n_steps == 3
    # all transitions flushed: 2 molecules x 3 steps (pendings flushed on
    # next step; terminal ones added immediately)
    assert len(buf) == 6
    for m in env.final_molecules():
        m.check_valences()
        assert m.has_oh_bond()


def test_env_reset_restores_initials():
    env = BatchedEnv([PHENOL], EnvConfig(max_steps=2), seed=0)
    agent = DQNAgent(DQNConfig(epsilon_initial=1.0), seed=1, network=QNetwork(hidden=(32,)))
    env.run_episode(agent, _OracleService(), RewardConfig())
    env.reset()
    assert env.slots[0].current.canonical_key() == PHENOL.canonical_key()
    assert env.slots[0].steps_left == 2


# ------------------------------------------------------------------ #
# filter script (§3.5)
# ------------------------------------------------------------------ #
def test_filter_constraints():
    crit = FilterCriteria(bde_max=76, ip_min=145, sa_max=3.5)
    res = filter_molecules(
        [(BHT, 70.0, 150.0), (BHT, 80.0, 150.0), (BHT, 70.0, 120.0),
         (PHENOL, 70.0, 150.0)],
        known=[PHENOL], criteria=crit)
    assert res[0].passed
    assert "bde_too_high" in res[1].reasons
    assert "ip_too_low" in res[2].reasons
    assert "identical_to_known" in res[3].reasons


def test_filter_invalid_conformer_reason():
    res = filter_molecules([(BHT, 70.0, None)], known=[])
    assert not res[0].passed and "invalid_conformer" in res[0].reasons
