import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)


class OracleService:
    """Deterministic stand-in for PropertyService (oracle-backed); counts
    ``predict`` entries so dispatch-per-step tests can assert batching.
    Shared by the test modules (``from conftest import OracleService``)."""

    def __init__(self):
        from repro.chem.conformer import has_valid_conformer
        from repro.chem.oracle import oracle_bde, oracle_ip
        from repro.predictors.service import Properties
        self._p, self._bde, self._ip, self._ok = \
            Properties, oracle_bde, oracle_ip, has_valid_conformer
        self.n_calls = 0

    def predict(self, mols):
        self.n_calls += 1
        return [self._p(bde=self._bde(m), ip=self._ip(m) if self._ok(m) else None)
                for m in mols]
