import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)

# THE deterministic PropertyService stand-in, re-exported for the test
# modules (``from conftest import OracleService``).  One implementation in
# src — the multi-device truth run's bit-equality pins depend on every
# harness predicting identically.
from repro.predictors.service import OracleService  # noqa: E402,F401
