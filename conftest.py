import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)
