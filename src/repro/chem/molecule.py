"""Graph molecules over {C, N, O} with implicit hydrogens.

This is the data structure the whole RL environment edits.  The design goals
are (in order): correctness of the valence/ring bookkeeping, cheap copies
(the action enumerator materialises ~10^2 candidate molecules per step), and
a stable canonical key for caching and dedup.

Representation
--------------
``elements``  int8[n]    0=C, 1=N, 2=O
``bonds``     int8[n,n]  symmetric bond-order matrix (0..3), zero diagonal

Hydrogens are implicit: ``implicit_h(i) = valence(element) - total_order(i)``
and must stay >= 0 — every mutator enforces this.

Ring rules follow the paper (Appendix C): new rings may only have size
3, 5 or 6.  Ring size on bond addition between already-connected atoms is
``shortest_path(i, j) + 1``.
"""

from __future__ import annotations

import hashlib
from collections import deque
from typing import Iterable

import numpy as np

# Element table.  The paper restricts the action space to C, O, N (App. C).
ELEMENTS: tuple[str, ...] = ("C", "N", "O")
ELEMENT_INDEX: dict[str, int] = {e: i for i, e in enumerate(ELEMENTS)}
VALENCES: tuple[int, ...] = (4, 3, 2)  # C, N, O

# Allowed ring sizes when a bond addition closes a cycle (paper App. C).
ALLOWED_RING_SIZES: frozenset[int] = frozenset({3, 5, 6})

MAX_BOND_ORDER = 3


class Molecule:
    """A small organic molecule as an undirected bond-order graph."""

    __slots__ = ("elements", "bonds", "_canon_cache", "_iso_cache",
                 "_fv_cache", "_apsp_cache")

    def __init__(self, elements: np.ndarray, bonds: np.ndarray):
        self.elements = np.asarray(elements, dtype=np.int8)
        self.bonds = np.asarray(bonds, dtype=np.int8)
        n = self.elements.shape[0]
        if self.bonds.shape != (n, n):
            raise ValueError(f"bonds shape {self.bonds.shape} != ({n},{n})")
        self._canon_cache: str | None = None
        self._iso_cache: int | None = None
        self._fv_cache: np.ndarray | None = None
        self._apsp_cache: np.ndarray | None = None

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def from_element(cls, symbol: str) -> "Molecule":
        """Single heavy atom (e.g. methane when symbol == 'C')."""
        idx = ELEMENT_INDEX[symbol]
        return cls(np.array([idx], dtype=np.int8), np.zeros((1, 1), dtype=np.int8))

    @classmethod
    def empty(cls) -> "Molecule":
        return cls(np.zeros((0,), dtype=np.int8), np.zeros((0, 0), dtype=np.int8))

    def copy(self) -> "Molecule":
        return Molecule(self.elements.copy(), self.bonds.copy())

    # ------------------------------------------------------------------ #
    # basic queries
    # ------------------------------------------------------------------ #
    @property
    def num_atoms(self) -> int:
        return int(self.elements.shape[0])

    @property
    def num_bonds(self) -> int:
        """Number of bonded atom pairs (order ignored)."""
        return int(np.count_nonzero(np.triu(self.bonds)))

    @property
    def total_bond_order(self) -> int:
        return int(np.triu(self.bonds).sum())

    def symbol(self, i: int) -> str:
        return ELEMENTS[int(self.elements[i])]

    def valence(self, i: int) -> int:
        return VALENCES[int(self.elements[i])]

    def degree(self, i: int) -> int:
        return int(np.count_nonzero(self.bonds[i]))

    def total_order(self, i: int) -> int:
        return int(self.bonds[i].sum())

    def implicit_h(self, i: int) -> int:
        return self.valence(i) - self.total_order(i)

    def free_valence(self, i: int) -> int:
        return self.implicit_h(i)

    def free_valences(self) -> np.ndarray:
        """Vectorised free valence for every atom: int array [n].

        Memoized (molecules are immutable by convention — the enumerator
        calls this several times per step) and returned READ-ONLY; copy
        before mutating.
        """
        if self._fv_cache is None:
            vals = np.asarray(VALENCES, dtype=np.int16)[self.elements]
            fv = vals - self.bonds.sum(axis=1, dtype=np.int16)
            fv.flags.writeable = False
            self._fv_cache = fv
        return self._fv_cache

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.bonds[i])[0]

    def has_oh_bond(self) -> bool:
        """True iff some oxygen carries at least one implicit hydrogen.

        The paper's BDE property is min over O-H bonds, so molecules without
        any O-H are rejected by the protected action enumerator (§3.3).
        """
        fv = self.free_valences()
        return bool(np.any((self.elements == ELEMENT_INDEX["O"]) & (fv >= 1)))

    def oh_oxygens(self) -> np.ndarray:
        fv = self.free_valences()
        return np.nonzero((self.elements == ELEMENT_INDEX["O"]) & (fv >= 1))[0]

    def heavy_formula(self) -> str:
        counts = np.bincount(self.elements, minlength=len(ELEMENTS))
        return "".join(f"{e}{int(c)}" for e, c in zip(ELEMENTS, counts) if c)

    # ------------------------------------------------------------------ #
    # graph algorithms
    # ------------------------------------------------------------------ #
    def shortest_path_length(self, i: int, j: int) -> int:
        """BFS hop distance between atoms i and j; -1 if disconnected."""
        if i == j:
            return 0
        n = self.num_atoms
        dist = np.full(n, -1, dtype=np.int32)
        dist[i] = 0
        q = deque([i])
        while q:
            u = q.popleft()
            for v in np.nonzero(self.bonds[u])[0]:
                if dist[v] < 0:
                    dist[v] = dist[u] + 1
                    if v == j:
                        return int(dist[v])
                    q.append(int(v))
        return -1

    def all_pairs_shortest_paths(self) -> np.ndarray:
        """Hop-distance matrix via repeated BFS.  -1 for disconnected pairs.

        Memoized like :meth:`free_valences` (the action enumerator needs it
        once per enumeration for the ring-size rule, the oracle again for
        BDE); the cached array is READ-ONLY.
        """
        if self._apsp_cache is not None:
            return self._apsp_cache
        n = self.num_atoms
        out = np.full((n, n), -1, dtype=np.int32)
        for s in range(n):
            out[s, s] = 0
            q = deque([s])
            while q:
                u = q.popleft()
                for v in np.nonzero(self.bonds[u])[0]:
                    if out[s, v] < 0:
                        out[s, v] = out[s, u] + 1
                        q.append(int(v))
        out.flags.writeable = False
        self._apsp_cache = out
        return out

    def connected_components(self) -> list[np.ndarray]:
        n = self.num_atoms
        seen = np.zeros(n, dtype=bool)
        comps: list[np.ndarray] = []
        for s in range(n):
            if seen[s]:
                continue
            q = deque([s])
            seen[s] = True
            comp = [s]
            while q:
                u = q.popleft()
                for v in np.nonzero(self.bonds[u])[0]:
                    if not seen[v]:
                        seen[v] = True
                        comp.append(int(v))
                        q.append(int(v))
            comps.append(np.array(sorted(comp), dtype=np.int64))
        return comps

    def is_connected(self) -> bool:
        return self.num_atoms <= 1 or len(self.connected_components()) == 1

    def ring_info(self) -> list[list[int]]:
        """Smallest-set-of-smallest-rings approximation.

        Returns a list of rings (atom index lists).  We compute, for every
        bond in a cycle, the smallest cycle through it (BFS with the bond
        removed), then dedup.  Exact SSSR is overkill for <= 6-rings.
        """
        rings: dict[frozenset[int], list[int]] = {}
        n = self.num_atoms
        for i in range(n):
            for j in np.nonzero(self.bonds[i])[0]:
                j = int(j)
                if j <= i:
                    continue
                # shortest i->j path avoiding the (i, j) bond.  The bond is
                # EXCLUDED in the traversal, never zeroed on self.bonds: the
                # pipelined rollout reads molecules from host threads while
                # the property path calls ring_info(), so even a
                # restored-immediately mutation here is a data race.
                path = self._bfs_path(i, j, skip_edge=(i, j))
                if path is not None:
                    key = frozenset(path)
                    if key not in rings or len(path) < len(rings[key]):
                        rings[key] = path
        return list(rings.values())

    def _bfs_path(self, src: int, dst: int,
                  skip_edge: tuple[int, int] | None = None) -> list[int] | None:
        n = self.num_atoms
        a, b = skip_edge if skip_edge is not None else (-1, -1)
        prev = np.full(n, -2, dtype=np.int32)
        prev[src] = -1
        q = deque([src])
        while q:
            u = q.popleft()
            if u == dst:
                path = [dst]
                while prev[path[-1]] >= 0:
                    path.append(int(prev[path[-1]]))
                return path[::-1]
            for v in np.nonzero(self.bonds[u])[0]:
                v = int(v)
                if (u == a and v == b) or (u == b and v == a):
                    continue
                if prev[v] == -2:
                    prev[v] = u
                    q.append(v)
        return None

    def atom_ring_membership(self) -> np.ndarray:
        """int[n]: number of rings each atom belongs to."""
        counts = np.zeros(self.num_atoms, dtype=np.int32)
        for ring in self.ring_info():
            for a in ring:
                counts[a] += 1
        return counts

    # ------------------------------------------------------------------ #
    # mutators (all return NEW molecules; Molecule is treated as immutable
    # by the environment so replay-buffer entries can alias safely)
    # ------------------------------------------------------------------ #
    def with_added_atom(self, symbol: str, attach_to: int, order: int) -> "Molecule":
        """Append a new atom bonded to ``attach_to`` with ``order``."""
        e = ELEMENT_INDEX[symbol]
        if order < 1 or order > MAX_BOND_ORDER:
            raise ValueError(f"bad bond order {order}")
        if order > VALENCES[e]:
            raise ValueError(f"order {order} exceeds valence of {symbol}")
        if self.free_valence(attach_to) < order:
            raise ValueError("insufficient free valence on anchor atom")
        n = self.num_atoms
        elements = np.append(self.elements, np.int8(e))
        bonds = np.zeros((n + 1, n + 1), dtype=np.int8)
        bonds[:n, :n] = self.bonds
        bonds[n, attach_to] = bonds[attach_to, n] = order
        return Molecule(elements, bonds)

    def with_bond_delta(self, i: int, j: int, delta: int) -> "Molecule":
        """Increase (+) or decrease (-) the order of bond (i, j) by |delta|."""
        if i == j:
            raise ValueError("self bond")
        cur = int(self.bonds[i, j])
        new = cur + delta
        if new < 0 or new > MAX_BOND_ORDER:
            raise ValueError(f"bond order out of range: {cur} -> {new}")
        if delta > 0 and (self.free_valence(i) < delta or self.free_valence(j) < delta):
            raise ValueError("insufficient free valence")
        bonds = self.bonds.copy()
        bonds[i, j] = bonds[j, i] = new
        return Molecule(self.elements.copy(), bonds)

    def largest_fragment(self) -> "Molecule":
        """Keep the largest connected component (paper Fig. 6: 'unconnected
        atoms are removed').  Ties prefer the fragment with more oxygens."""
        comps = self.connected_components()
        if len(comps) <= 1:
            return self
        def score(c: np.ndarray) -> tuple[int, int]:
            return (len(c), int(np.sum(self.elements[c] == ELEMENT_INDEX["O"])))
        best = max(comps, key=score)
        return self.subgraph(best)

    def subgraph(self, atom_indices: np.ndarray) -> "Molecule":
        idx = np.asarray(atom_indices, dtype=np.int64)
        return Molecule(self.elements[idx], self.bonds[np.ix_(idx, idx)])

    # ------------------------------------------------------------------ #
    # invariants / hashing
    # ------------------------------------------------------------------ #
    def check_valences(self) -> None:
        fv = self.free_valences()
        if np.any(fv < 0):
            bad = np.nonzero(fv < 0)[0]
            raise AssertionError(f"valence violated at atoms {bad.tolist()}")
        if np.any(self.bonds < 0) or np.any(self.bonds > MAX_BOND_ORDER):
            raise AssertionError("bond order out of range")
        if np.any(np.diag(self.bonds) != 0):
            raise AssertionError("self bond present")
        if not np.array_equal(self.bonds, self.bonds.T):
            raise AssertionError("bond matrix not symmetric")

    def canonical_key(self) -> str:
        """A canonical string key: invariant under atom relabelling.

        Uses iterative Morgan-style invariant refinement, then a
        lexicographically-minimal adjacency serialisation over the refined
        classes.  Cached (molecules are immutable by convention).
        """
        if self._canon_cache is None:
            self._canon_cache = _canonical_key(self)
        return self._canon_cache

    def iso_key(self) -> int:
        """Fast isomorphism-invariant hash (see :func:`iso_hash`); cached."""
        if self._iso_cache is None:
            self._iso_cache = iso_hash(self)
        return self._iso_cache

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Molecule) and self.canonical_key() == other.canonical_key()

    def __hash__(self) -> int:
        return hash(self.canonical_key())

    def __repr__(self) -> str:
        return f"Molecule({self.heavy_formula()}, bonds={self.num_bonds})"


# ---------------------------------------------------------------------- #
# vectorised 64-bit hashing (the analogue of the paper's C++ port: the
# original per-atom cryptographic hashing was the profiled hot spot; the
# production path below is branch-free numpy over uint64 with a
# splitmix64 finaliser and a *commutative* neighbour combine, so a full
# refinement round is three masked matvecs instead of n python loops).
# ---------------------------------------------------------------------- #
_SM_C0 = np.uint64(0x9E3779B97F4A7C15)
_SM_C1 = np.uint64(0xBF58476D1CE4E5B9)
_SM_C2 = np.uint64(0x94D049BB133111EB)
# per-bond-order salts so (order, neighbour) pairs hash distinctly
_ORDER_SALT = np.array(
    [0x0, 0xA24BAED4963EE407, 0x9FB21C651E98DF25, 0xD6E8FEB86659FD93],
    dtype=np.uint64,
)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorised splitmix64 finaliser over uint64 arrays (wraps mod 2^64).

    In-place on a working copy: the whole chemistry layer is memory-bound on
    this mixer's [k, m, m] temporaries, so two allocations beat eight.
    """
    z = x.astype(np.uint64)                  # always copies
    z += _SM_C0
    t = z >> np.uint64(30)
    z ^= t
    z *= _SM_C1
    np.right_shift(z, np.uint64(27), out=t)
    z ^= t
    z *= _SM_C2
    np.right_shift(z, np.uint64(31), out=t)
    z ^= t
    return z


def initial_invariants(mol: Molecule) -> np.ndarray:
    """Degree/element/valence-derived initial atom invariants (uint64)."""
    fv = mol.free_valences().astype(np.int64)
    deg = np.count_nonzero(mol.bonds, axis=1).astype(np.int64)
    tot = mol.bonds.sum(axis=1, dtype=np.int64)
    el = mol.elements.astype(np.int64)
    packed = (((el * 64 + deg) * 64 + tot) * 64 + fv).astype(np.uint64)
    return splitmix64(packed)


def neighbor_combine(bonds: np.ndarray, inv: np.ndarray) -> np.ndarray:
    """Commutative neighbour aggregation: sum_j mix(inv_j ^ salt[order_ij]).

    Commutativity (sum) removes the per-atom neighbour sort of classic
    Morgan; 64-bit mixing keeps accidental collisions negligible.  Works on
    a single molecule (``bonds [n,n]``, ``inv [n]``) or a padded batch
    (``bonds [k,n,n]``, ``inv [k,n]``) with one splitmix64 pass either way.
    """
    salted = inv[..., None, :] ^ _ORDER_SALT[bonds]
    mixed = splitmix64(salted)
    return np.where(bonds > 0, mixed, np.uint64(0)).sum(axis=-1, dtype=np.uint64)


def refine_once(bonds: np.ndarray, inv: np.ndarray) -> np.ndarray:
    return splitmix64(splitmix64(inv) + neighbor_combine(bonds, inv))


def refine_invariants(mol: Molecule, rounds: int | None = None) -> np.ndarray:
    """Morgan refinement of atom invariants until class-stable (or ``rounds``)."""
    inv = initial_invariants(mol)
    n = mol.num_atoms
    max_rounds = rounds if rounds is not None else max(n, 1)
    n_classes = len(np.unique(inv))
    for _ in range(max_rounds):
        new = refine_once(mol.bonds, inv)
        new_classes = len(np.unique(new))
        inv = new
        if new_classes == n_classes:
            break
        n_classes = new_classes
    return inv


_PAD_VALENCE = np.array(list(VALENCES) + [0], dtype=np.int64)  # index 3 = pad


def iso_hashes_from_padded(el: np.ndarray, bonds: np.ndarray, sizes: np.ndarray,
                           rounds: int = 5) -> np.ndarray:
    """Batched iso hashes over prebuilt padded arrays (``el`` int64[k, m]
    with 3 = padding element, ``bonds`` int8[k, m, m], ``sizes`` int64[k]).

    The array-level core of :func:`iso_hashes_batch` — the delta action
    enumerator calls it directly on candidate arrays built from edit
    descriptors, skipping the per-candidate ``Molecule`` materialisation.
    Returns uint64[k].
    """
    m_max = el.shape[1]
    tot = bonds.sum(axis=2, dtype=np.int64)
    deg = np.count_nonzero(bonds, axis=2)
    fv = _PAD_VALENCE[el] - tot
    packed = (((el * 64 + deg) * 64 + tot) * 64 + (fv + 8)).astype(np.uint64)
    inv = splitmix64(packed)                              # [k, m]
    for _ in range(rounds):
        inv = splitmix64(splitmix64(inv) + neighbor_combine(bonds, inv))
    inv = np.sort(inv, axis=1)
    pos = splitmix64(np.arange(m_max, dtype=np.uint64))
    mixed = splitmix64(inv ^ pos[None, :]).sum(axis=1, dtype=np.uint64)
    return splitmix64(mixed ^ splitmix64(sizes.astype(np.uint64)))


def iso_hashes_batch(mols: list["Molecule"], rounds: int = 5) -> list[int]:
    """Isomorphism-invariant hashes for a *batch* of molecules at once.

    This is the paper's "batched modification" idea (§3.1) applied to the
    hashing hot loop: the action enumerator produces ~10^2 candidate
    molecules per environment step, and hashing them one by one pays the
    numpy dispatch overhead ~10^2 x ~20 times.  Padding every candidate to
    the batch max and running ONE vectorised refinement brings that down to
    ~10 array ops total.  Hash values equal :func:`iso_hash` semantics
    (equal iff isomorphic, up to 2^-64 collisions) but are a *different*
    hash family (padding participates), so don't mix the two.
    """
    k = len(mols)
    if k == 0:
        return []
    sizes = np.array([m.num_atoms for m in mols], dtype=np.int64)
    m_max = max(int(sizes.max()), 1)
    el = np.full((k, m_max), 3, dtype=np.int64)          # 3 = padding element
    bonds = np.zeros((k, m_max, m_max), dtype=np.int8)
    for b, mol in enumerate(mols):
        n = mol.num_atoms
        el[b, :n] = mol.elements
        bonds[b, :n, :n] = mol.bonds
    return [int(h) for h in iso_hashes_from_padded(el, bonds, sizes, rounds)]


def iso_hash(mol: Molecule) -> int:
    """Fast isomorphism-invariant molecule hash (used for action dedup and
    the property cache).  Equal graphs always hash equal; distinct graphs
    collide with ~2^-64 probability per pair."""
    if mol.num_atoms == 0:
        return 0
    # Fixed-round refinement is isomorphism-invariant regardless of class
    # stability, and 5 rounds separates everything a radius-3 fingerprint
    # can see; full stable refinement is reserved for canonical_key().
    inv = np.sort(refine_invariants(mol, rounds=5))
    pos = splitmix64(np.arange(inv.shape[0], dtype=np.uint64))
    mixed = splitmix64(inv ^ pos)
    return int(splitmix64(mixed.sum(dtype=np.uint64)[None])[0])


def _canonical_key(mol: Molecule) -> str:
    n = mol.num_atoms
    if n == 0:
        return "<empty>"
    inv = refine_invariants(mol)
    # Break remaining symmetry deterministically: order atoms by (invariant,
    # element), then by a canonical BFS from the smallest-invariant atom.
    order = _canonical_order(mol, inv)
    pos = np.empty(n, dtype=np.int64)
    pos[order] = np.arange(n)
    parts = [",".join(ELEMENTS[int(mol.elements[a])] for a in order)]
    edges = []
    for i in range(n):
        for j in np.nonzero(mol.bonds[i])[0]:
            j = int(j)
            if j > i:
                a, b = sorted((int(pos[i]), int(pos[j])))
                edges.append((a, b, int(mol.bonds[i, j])))
    edges.sort()
    parts.append(";".join(f"{a}-{b}:{o}" for a, b, o in edges))
    return "|".join(parts)


def _canonical_order(mol: Molecule, inv: np.ndarray) -> list[int]:
    """Deterministic atom ordering: BFS from the minimal invariant atom,
    expanding neighbours in (invariant, bond order) order.  Symmetric atoms
    get an arbitrary-but-deterministic order, which is fine for a key (two
    isomorphic graphs still serialise identically because expansion is driven
    purely by invariants)."""
    n = mol.num_atoms
    start = int(np.lexsort((np.arange(n), inv))[0])
    seen = [False] * n
    order: list[int] = []
    # deterministic multi-source: loop components
    pending = sorted(range(n), key=lambda a: (int(inv[a]), a))
    for src in pending:
        if seen[src]:
            continue
        q = deque([src])
        seen[src] = True
        while q:
            u = q.popleft()
            order.append(u)
            nbrs = sorted(
                (int(inv[v]), int(mol.bonds[u, v]), int(v))
                for v in np.nonzero(mol.bonds[u])[0]
                if not seen[v]
            )
            for _, _, v in nbrs:
                if not seen[v]:
                    seen[v] = True
                    q.append(v)
    return order


# ---------------------------------------------------------------------- #
# array export for the GNN predictors
# ---------------------------------------------------------------------- #
def to_graph_arrays(mol: Molecule, max_atoms: int) -> dict[str, np.ndarray]:
    """Pad a molecule to fixed-size arrays for batched GNN inference.

    Returns ``atom_feat`` f32[max_atoms, F], ``adj`` f32[max_atoms, max_atoms,
     3] (one channel per bond order), ``mask`` f32[max_atoms].
    """
    n = mol.num_atoms
    if n > max_atoms:
        raise ValueError(f"molecule has {n} atoms > max_atoms={max_atoms}")
    fv = mol.free_valences()
    feat = np.zeros((max_atoms, ATOM_FEATURE_DIM), dtype=np.float32)
    for i in range(n):
        e = int(mol.elements[i])
        feat[i, e] = 1.0                                   # element one-hot (3)
        feat[i, 3 + min(mol.degree(i), 4)] = 1.0           # degree one-hot (5)
        feat[i, 8 + min(int(fv[i]), 4)] = 1.0              # implicit H one-hot (5)
        feat[i, 13] = mol.total_order(i) / 4.0             # scaled total order
        feat[i, 14] = 1.0 if (e == ELEMENT_INDEX["O"] and fv[i] >= 1) else 0.0  # O-H flag
    rings = mol.atom_ring_membership()
    for i in range(n):
        feat[i, 15] = min(int(rings[i]), 3) / 3.0          # ring membership
    adj = np.zeros((max_atoms, max_atoms, MAX_BOND_ORDER), dtype=np.float32)
    for order in range(1, MAX_BOND_ORDER + 1):
        sel = (mol.bonds == order)
        adj[:n, :n, order - 1] = sel.astype(np.float32)
    mask = np.zeros((max_atoms,), dtype=np.float32)
    mask[:n] = 1.0
    return {"atom_feat": feat, "adj": adj, "mask": mask}


ATOM_FEATURE_DIM = 16
