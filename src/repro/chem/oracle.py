"""Closed-form BDE/IP ground truth — the framework's "DFT".

The real paper trains its predictors (Alfabet, AIMNet-NSE) on DFT data we
cannot compute here.  This module supplies a deterministic, chemically
structured oracle that reproduces the *decision structure* the paper's RL
agent must learn (§2.1):

* **BDE** (O-H bond strength, lower = better antioxidant) is a *local*
  property of each O-H oxygen: electron-donating groups (EDGs — methyl /
  alkyl carbons, amino nitrogens, ether/hydroxy oxygens) near the oxygen
  stabilise the radical and lower BDE, with ortho/para-like graph-distance
  weighting and a phenol-vs-alcohol base split.  Molecular BDE = min over
  all O-H oxygens (paper §2.1).

* **IP** (stability, higher = better) is a *global* property: every EDG in
  the molecule lowers IP, as does conjugation (6-rings).

This yields exactly the paper's Pareto trade-off: stacking donors lowers
BDE *and* IP ("it's not possible to stack five dimethyl amino groups...",
§2.1).  The optimum is a few donors placed ortho/para to one O-H and a
skeleton otherwise free of donors — a structure the DQN can discover.

A small structure-keyed jitter (BLAKE2 of the canonical key) keeps the
mapping non-trivial for the learned predictors while staying well inside
the paper's <5% predictor-error envelope.

Units are kcal/mol to match the paper's thresholds: effective antioxidant
BDE < 76, stable IP > 145.
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.chem.molecule import ELEMENT_INDEX, Molecule

# --- tunables (calibrated against repro.data.datasets distributions) ---- #
BDE_BASE_ALCOHOL = 96.0      # aliphatic O-H with no stabilisation
BDE_BASE_PHENOL = 85.0       # O-H on a 6-ring carbon (resonance base)
BDE_DONOR_GAIN = 3.1         # kcal/mol per unit of local donor score
BDE_JITTER = 1.0
BDE_CLIP = (55.0, 115.0)

IP_BASE = 200.0
IP_DONOR_GAIN = 9.0          # global donor score lowers IP (strongly: Table 5
                             # shows 30-50 kcal/mol IP swings from edits)
IP_RING6_GAIN = 6.0          # conjugation lowers IP
IP_RING5_GAIN = 3.0
IP_TRIPLE_GAIN = -2.5        # triple bonds (EWG-ish) raise IP
IP_JITTER = 2.0
IP_CLIP = (95.0, 230.0)

# ortho(2)/para(4) > adjacent(1) > meta(3) >> remote
_DIST_WEIGHT = {1: 1.20, 2: 1.00, 3: 0.30, 4: 0.90, 5: 0.15, 6: 0.10}


def _jitter(mol: Molecule, salt: bytes, amplitude: float) -> float:
    h = hashlib.blake2b(mol.canonical_key().encode() + salt, digest_size=8)
    u = int.from_bytes(h.digest(), "little") / 2 ** 64  # [0,1)
    return amplitude * (2.0 * u - 1.0)


def donor_weights(mol: Molecule) -> np.ndarray:
    """Electron-donating strength per atom (0 for non-donors)."""
    n = mol.num_atoms
    w = np.zeros(n, dtype=np.float64)
    fv = mol.free_valences()
    for i in range(n):
        e = int(mol.elements[i])
        if e == ELEMENT_INDEX["C"]:
            h = int(fv[i])
            if h >= 3:
                w[i] = 1.0       # methyl
            elif h == 2:
                w[i] = 0.55      # methylene
        elif e == ELEMENT_INDEX["N"]:
            if fv[i] >= 1 and not _has_multiple_bond(mol, i):
                w[i] = 1.6       # amino
            elif not _has_multiple_bond(mol, i):
                w[i] = 1.2       # tertiary amine
        elif e == ELEMENT_INDEX["O"]:
            if not _has_multiple_bond(mol, i):
                w[i] = 1.1       # hydroxy / ether
    return w


def _has_multiple_bond(mol: Molecule, i: int) -> bool:
    return bool(np.any(mol.bonds[i] >= 2))


def _ring_size_counts(mol: Molecule) -> dict[int, int]:
    counts: dict[int, int] = {}
    for r in mol.ring_info():
        counts[len(r)] = counts.get(len(r), 0) + 1
    return counts


def oracle_bde(mol: Molecule) -> float | None:
    """Lowest O-H BDE over the molecule, or None if no O-H bond exists."""
    oxys = mol.oh_oxygens()
    if oxys.size == 0:
        return None
    sp = mol.all_pairs_shortest_paths()
    donors = donor_weights(mol)
    ring_atoms6 = set()
    for r in mol.ring_info():
        if len(r) == 6:
            ring_atoms6.update(r)

    best = None
    for o in oxys.tolist():
        nbrs = mol.neighbors(o)
        phenol_like = any(int(v) in ring_atoms6 for v in nbrs)
        base = BDE_BASE_PHENOL if phenol_like else BDE_BASE_ALCOHOL
        local = 0.0
        for a in range(mol.num_atoms):
            if a == o or donors[a] == 0.0:
                continue
            d = int(sp[o, a])
            if d <= 0:
                continue
            local += donors[a] * _DIST_WEIGHT.get(d, 0.0)
        bde = base - BDE_DONOR_GAIN * local
        best = bde if best is None else min(best, bde)

    best += _jitter(mol, b"bde", BDE_JITTER)
    return float(np.clip(best, *BDE_CLIP))


def oracle_ip(mol: Molecule) -> float:
    """Ionisation potential of the molecule (always defined)."""
    donors = donor_weights(mol)
    rings = _ring_size_counts(mol)
    triples = int(np.sum(np.triu(mol.bonds) == 3))
    ip = (
        IP_BASE
        - IP_DONOR_GAIN * float(donors.sum())
        - IP_RING6_GAIN * rings.get(6, 0)
        - IP_RING5_GAIN * rings.get(5, 0)
        - IP_TRIPLE_GAIN * triples
    )
    ip += _jitter(mol, b"ip", IP_JITTER)
    return float(np.clip(ip, *IP_CLIP))


def oracle_properties(mol: Molecule) -> dict[str, float | None]:
    """Both properties at once (the "run DFT on this molecule" call)."""
    return {"bde": oracle_bde(mol), "ip": oracle_ip(mol)}
