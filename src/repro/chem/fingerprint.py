"""Morgan / ECFP fingerprints + the paper's incremental variant (§3.6).

The paper profiles MT-MolDQN and finds Morgan-fingerprint computation to be
one of two hot spots; it introduces a *fast incremental Morgan fingerprint*.
The key observation: a single molecule edit only perturbs the radius-R
neighbourhood of the touched atoms, so only those atoms' environment hashes
change.  ``IncrementalMorgan`` maintains per-atom per-radius environment
hashes plus a global hash multiset and updates them in O(|ball| * n) instead
of O(n^2 * R) per edit.

Both the full and the incremental paths run on the vectorised uint64
splitmix64 hashing core in ``repro.chem.molecule`` (the TPU-era analogue of
the paper's C++ port — see DESIGN.md §4).

Parameters follow Appendix C: radius 3, 2048 bits.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.chem.molecule import (
    _ORDER_SALT,
    Molecule,
    initial_invariants,
    neighbor_combine,
    splitmix64,
)

FP_RADIUS = 3
FP_BITS = 2048


def atom_env_hashes(mol: Molecule, radius: int = FP_RADIUS) -> np.ndarray:
    """uint64[n, radius+1]: environment hash of each atom at each radius."""
    n = mol.num_atoms
    out = np.zeros((n, radius + 1), dtype=np.uint64)
    if n == 0:
        return out
    out[:, 0] = initial_invariants(mol)
    for r in range(1, radius + 1):
        prev = out[:, r - 1]
        out[:, r] = splitmix64(splitmix64(prev) + neighbor_combine(mol.bonds, prev))
    return out


def fold_hashes(hashes: np.ndarray, n_bits: int, *, counts: bool = False) -> np.ndarray:
    fp = np.zeros(n_bits, dtype=np.float32)
    idx = (hashes.ravel() % np.uint64(n_bits)).astype(np.int64)
    if counts:
        np.add.at(fp, idx, 1.0)
    else:
        fp[idx] = 1.0
    return fp


def morgan_fingerprint(
    mol: Molecule,
    radius: int = FP_RADIUS,
    n_bits: int = FP_BITS,
    *,
    counts: bool = False,
) -> np.ndarray:
    """ECFP-style fingerprint: fold all (atom, radius) env hashes to n_bits.

    Returns float32[n_bits]; binary by default, counts if ``counts=True``.
    """
    return fold_hashes(atom_env_hashes(mol, radius), n_bits, counts=counts)


def batch_morgan_fingerprints(
    mols: list[Molecule],
    radius: int = FP_RADIUS,
    n_bits: int = FP_BITS,
    *,
    counts: bool = False,
    chunk: int = 256,
) -> np.ndarray:
    """Fingerprints for a batch of molecules in one padded vectorised pass.

    Bit-identical to per-molecule :func:`morgan_fingerprint` (padding atoms
    are masked out of the fold and, having no bonds, never contaminate real
    atoms' neighbourhoods).  This is the fingerprint path the batched
    environment uses: ~10^3 candidates per worker step in ~10 array ops.
    Returns float32[len(mols), n_bits].

    Fleet-sized batches (10^4+ candidates across all workers) are processed
    ``chunk`` molecules at a time: the [k, m, m] uint64 hash temporaries are
    bandwidth-bound, so keeping them cache-resident beats one huge pass
    (~3x on a 4-5k batch) while remaining bit-identical.
    """
    k = len(mols)
    if k == 0:
        return np.zeros((0, n_bits), dtype=np.float32)
    if chunk and k > chunk:
        return np.concatenate([
            batch_morgan_fingerprints(mols[i:i + chunk], radius, n_bits,
                                      counts=counts, chunk=0)
            for i in range(0, k, chunk)
        ])
    sizes = np.array([m.num_atoms for m in mols], dtype=np.int64)
    m_max = max(int(sizes.max()), 1)
    el = np.full((k, m_max), 3, dtype=np.int64)  # 3 = padding element
    bonds = np.zeros((k, m_max, m_max), dtype=np.int8)
    for b, mol in enumerate(mols):
        n = mol.num_atoms
        el[b, :n] = mol.elements
        bonds[b, :n, :n] = mol.bonds
    valid = np.arange(m_max)[None, :] < sizes[:, None]       # [k, m]

    # identical invariant formula to molecule.initial_invariants
    from repro.chem.molecule import _PAD_VALENCE
    tot = bonds.sum(axis=2, dtype=np.int64)
    deg = np.count_nonzero(bonds, axis=2)
    fv = _PAD_VALENCE[el] - tot
    packed = (((el * 64 + deg) * 64 + tot) * 64 + fv).astype(np.uint64)
    env = np.zeros((k, m_max, radius + 1), dtype=np.uint64)
    env[:, :, 0] = splitmix64(packed)
    for r in range(1, radius + 1):
        prev = env[:, :, r - 1]
        env[:, :, r] = splitmix64(splitmix64(prev) + neighbor_combine(bonds, prev))

    # masked fold: one bincount over (row, bit) flat indices
    rows = np.broadcast_to(np.arange(k)[:, None, None], env.shape)
    bits = (env % np.uint64(n_bits)).astype(np.int64)
    sel = np.broadcast_to(valid[:, :, None], env.shape)
    flat = rows[sel] * n_bits + bits[sel]
    fp = np.bincount(flat, minlength=k * n_bits).astype(np.float32).reshape(k, n_bits)
    if not counts:
        fp = (fp > 0).astype(np.float32)
    return fp


def morgan_fingerprint_reference(
    mol: Molecule,
    radius: int = FP_RADIUS,
    n_bits: int = FP_BITS,
    *,
    counts: bool = False,
) -> np.ndarray:
    """Per-atom cryptographic-hash Morgan — the pre-optimisation baseline.

    This mirrors the cost profile of the original RDKit-backed Python
    implementation the paper profiled (§3.6): one hash invocation per
    (atom, radius) with a sorted neighbour list.  Kept for
    ``benchmarks/bench_fingerprint.py``; produces the same *bit semantics*
    but a different hash family than :func:`morgan_fingerprint`.
    """
    import hashlib

    n = mol.num_atoms
    env = np.zeros((n, radius + 1), dtype=np.uint64)
    if n:
        fv = mol.free_valences()
        for i in range(n):
            h = hashlib.blake2b(digest_size=8)
            h.update(bytes([int(mol.elements[i]), mol.degree(i), mol.total_order(i), int(fv[i])]))
            env[i, 0] = np.uint64(int.from_bytes(h.digest(), "little"))
        for r in range(1, radius + 1):
            prev = env[:, r - 1]
            for i in range(n):
                nbrs = np.nonzero(mol.bonds[i])[0]
                pairs = sorted((int(mol.bonds[i, v]), int(prev[v])) for v in nbrs)
                h = hashlib.blake2b(digest_size=8)
                h.update(int(prev[i]).to_bytes(8, "little"))
                for order, niv in pairs:
                    h.update(order.to_bytes(1, "little"))
                    h.update(niv.to_bytes(8, "little"))
                env[i, r] = np.uint64(int.from_bytes(h.digest(), "little"))
    return fold_hashes(env, n_bits, counts=counts)


def fingerprint_with_steps(fp: np.ndarray, steps_left: int, max_steps: int) -> np.ndarray:
    """MolDQN state = fingerprint ++ normalised steps-left scalar."""
    return np.concatenate([fp, np.array([steps_left / max(max_steps, 1)], dtype=np.float32)])


class IncrementalMorgan:
    """Incrementally-maintained Morgan fingerprint (paper §3.6).

    Usage::

        inc  = IncrementalMorgan(mol)
        fp   = inc.fingerprint()                         # == morgan_fingerprint(mol)
        inc2 = inc.after_action(new_mol, kind, detail)   # O(|radius-ball|) update

    State is (per-atom env-hash table, folded bit-count vector); an update
    copies the 2048-float count vector (one memcpy) and scatter-adds the
    delta rows, avoiding any per-hash Python bookkeeping.  Instances are
    immutable; updates return new instances.  Edits that re-index atoms
    (fragment drops) fall back to a full recompute.
    """

    __slots__ = ("mol", "radius", "n_bits", "env", "counts")

    def __init__(
        self,
        mol: Molecule,
        radius: int = FP_RADIUS,
        n_bits: int = FP_BITS,
        _env: np.ndarray | None = None,
        _counts: np.ndarray | None = None,
    ):
        self.mol = mol
        self.radius = radius
        self.n_bits = n_bits
        if _env is None:
            self.env = atom_env_hashes(mol, radius)
            self.counts = fold_hashes(self.env, n_bits, counts=True)
        else:
            self.env = _env
            self.counts = _counts

    # -------------------------------------------------------------- #
    def fingerprint(self, *, counts: bool = False) -> np.ndarray:
        if counts:
            return self.counts.copy()
        return (self.counts > 0).astype(np.float32)

    # -------------------------------------------------------------- #
    def update(self, new_mol: Molecule, touched: list[int]) -> "IncrementalMorgan":
        """Recompute env hashes only inside the radius-ball of ``touched``.

        ``touched`` are atom indices *in new_mol* whose incident bonds (or
        existence) changed.  Requires that pre-existing atoms kept their
        indices (true for atom additions and bond edits).
        """
        n_new = new_mol.num_atoms
        n_old = self.env.shape[0]
        radius = self.radius

        # distance-limited BFS from the touched set
        dist: dict[int, int] = {t: 0 for t in touched}
        q = deque(touched)
        while q:
            u = q.popleft()
            if dist[u] >= radius:
                continue
            for v in np.nonzero(new_mol.bonds[u])[0]:
                v = int(v)
                if v not in dist:
                    dist[v] = dist[u] + 1
                    q.append(v)
        aff = np.array(sorted(dist.keys()), dtype=np.int64)

        env = np.zeros((n_new, radius + 1), dtype=np.uint64)
        env[:n_old] = self.env

        counts = self.counts.copy()
        stale_rows = aff[aff < n_old]
        if stale_rows.size:
            idx = (self.env[stale_rows].ravel() % np.uint64(self.n_bits)).astype(np.int64)
            np.subtract.at(counts, idx, 1.0)

        # radius-0: local degree/valence invariants for the affected rows only
        sub = new_mol.bonds[aff]
        el = new_mol.elements[aff].astype(np.int64)
        tot = sub.sum(axis=1, dtype=np.int64)
        deg = np.count_nonzero(sub, axis=1)
        fv = np.array([4, 3, 2], dtype=np.int64)[el] - tot
        packed = ((((el * 64 + deg) * 64 + tot) * 64) + fv).astype(np.uint64)
        env[aff, 0] = splitmix64(packed)

        # radius-r rows for atoms within distance r of an edit; rows farther
        # than r keep their old hash at this radius (already copied above)
        dist_arr = np.array([dist[int(i)] for i in aff], dtype=np.int64)
        for r in range(1, radius + 1):
            prev = env[:, r - 1]
            rows = aff[dist_arr <= r]
            if rows.size:
                sub_bonds = new_mol.bonds[rows]  # [k, n]
                mixed = splitmix64(prev[None, :] ^ _ORDER_SALT[sub_bonds])
                agg = np.where(sub_bonds > 0, mixed, np.uint64(0)).sum(axis=1, dtype=np.uint64)
                env[rows, r] = splitmix64(splitmix64(prev[rows]) + agg)

        idx = (env[aff].ravel() % np.uint64(self.n_bits)).astype(np.int64)
        np.add.at(counts, idx, 1.0)

        return IncrementalMorgan(new_mol, self.radius, self.n_bits, _env=env, _counts=counts)

    # -------------------------------------------------------------- #
    def after_action(self, new_mol: Molecule, kind: str, detail: tuple) -> "IncrementalMorgan":
        """Apply the effect of an Action (see chem.actions)."""
        if new_mol.num_atoms < self.mol.num_atoms or (
            kind == "bond_delta" and new_mol.num_atoms != self.mol.num_atoms
        ):
            # fragment drop re-indexed atoms: full recompute
            return IncrementalMorgan(new_mol, self.radius, self.n_bits)
        if kind == "no_op":
            return self
        if kind == "add_atom":
            _, anchor, _ = detail
            new_idx = new_mol.num_atoms - 1
            touched = [new_idx] if anchor < 0 else [new_idx, int(anchor)]
            return self.update(new_mol, touched)
        if kind == "bond_delta":
            i, j, _ = detail
            return self.update(new_mol, [int(i), int(j)])
        raise ValueError(f"unknown action kind {kind}")
