"""Morgan / ECFP fingerprints + the paper's incremental variant (§3.6).

The paper profiles MT-MolDQN and finds Morgan-fingerprint computation to be
one of two hot spots; it introduces a *fast incremental Morgan fingerprint*.
The key observation: a single molecule edit only perturbs the radius-R
neighbourhood of the touched atoms, so only those atoms' environment hashes
change.  ``IncrementalMorgan`` maintains per-atom per-radius environment
hashes plus a global hash multiset and updates them in O(|ball| * n) instead
of O(n^2 * R) per edit.

Both the full and the incremental paths run on the vectorised uint64
splitmix64 hashing core in ``repro.chem.molecule`` (the TPU-era analogue of
the paper's C++ port — see DESIGN.md §4).

Parameters follow Appendix C: radius 3, 2048 bits.
"""

from __future__ import annotations

from collections import deque
from typing import Sequence

import numpy as np

from repro.chem.molecule import (
    _ORDER_SALT,
    _PAD_VALENCE,
    ELEMENT_INDEX,
    Molecule,
    initial_invariants,
    neighbor_combine,
    splitmix64,
)

FP_RADIUS = 3
FP_BITS = 2048


def atom_env_hashes(mol: Molecule, radius: int = FP_RADIUS) -> np.ndarray:
    """uint64[n, radius+1]: environment hash of each atom at each radius."""
    n = mol.num_atoms
    out = np.zeros((n, radius + 1), dtype=np.uint64)
    if n == 0:
        return out
    out[:, 0] = initial_invariants(mol)
    for r in range(1, radius + 1):
        prev = out[:, r - 1]
        out[:, r] = splitmix64(splitmix64(prev) + neighbor_combine(mol.bonds, prev))
    return out


def fold_hashes(hashes: np.ndarray, n_bits: int, *, counts: bool = False) -> np.ndarray:
    fp = np.zeros(n_bits, dtype=np.float32)
    idx = (hashes.ravel() % np.uint64(n_bits)).astype(np.int64)
    if counts:
        np.add.at(fp, idx, 1.0)
    else:
        fp[idx] = 1.0
    return fp


def morgan_fingerprint(
    mol: Molecule,
    radius: int = FP_RADIUS,
    n_bits: int = FP_BITS,
    *,
    counts: bool = False,
) -> np.ndarray:
    """ECFP-style fingerprint: fold all (atom, radius) env hashes to n_bits.

    Returns float32[n_bits]; binary by default, counts if ``counts=True``.
    """
    return fold_hashes(atom_env_hashes(mol, radius), n_bits, counts=counts)


def pack_fps(fps: np.ndarray) -> np.ndarray:
    """Bit-pack {0,1}-valued fingerprint rows: f32[..., FP_BITS] ->
    u8[..., FP_BITS/8].

    THE bit-order contract for every packed fingerprint in the repo
    (replay storage, the packed learner batches, the packed acting
    planes): ``np.packbits`` big-endian-within-byte, so fingerprint bit
    ``8*i + k`` is bit ``MSB-k`` of byte ``i``.  The inverse transforms
    are pinned to it in lockstep — ``replay.unpack_fp`` /
    ``replay.densify_sample`` (host), ``core.packed_batch.unpack_bits``
    (jit-side shift/mask), and the ``kernels/packed_qnet`` bit-plane
    matmuls (plane k multiplies weight rows ``k::8``).  The round trip
    is exact because fingerprints are {0,1}-valued, which is what lets
    packed paths stay BIT-identical to their dense references."""
    return np.packbits(fps.astype(bool), axis=-1)


def batch_morgan_fingerprints(
    mols: list[Molecule],
    radius: int = FP_RADIUS,
    n_bits: int = FP_BITS,
    *,
    counts: bool = False,
    chunk: int = 256,
) -> np.ndarray:
    """Fingerprints for a batch of molecules in one padded vectorised pass.

    Bit-identical to per-molecule :func:`morgan_fingerprint` (padding atoms
    are masked out of the fold and, having no bonds, never contaminate real
    atoms' neighbourhoods).  This is the fingerprint path the batched
    environment uses: ~10^3 candidates per worker step in ~10 array ops.
    Returns float32[len(mols), n_bits].

    Fleet-sized batches (10^4+ candidates across all workers) are processed
    ``chunk`` molecules at a time: the [k, m, m] uint64 hash temporaries are
    bandwidth-bound, so keeping them cache-resident beats one huge pass
    (~3x on a 4-5k batch) while remaining bit-identical.
    """
    k = len(mols)
    if k == 0:
        return np.zeros((0, n_bits), dtype=np.float32)
    if chunk and k > chunk:
        return np.concatenate([
            batch_morgan_fingerprints(mols[i:i + chunk], radius, n_bits,
                                      counts=counts, chunk=0)
            for i in range(0, k, chunk)
        ])
    sizes = np.array([m.num_atoms for m in mols], dtype=np.int64)
    m_max = max(int(sizes.max()), 1)
    el = np.full((k, m_max), 3, dtype=np.int64)  # 3 = padding element
    bonds = np.zeros((k, m_max, m_max), dtype=np.int8)
    for b, mol in enumerate(mols):
        n = mol.num_atoms
        el[b, :n] = mol.elements
        bonds[b, :n, :n] = mol.bonds
    env = env_hashes_from_arrays(el, bonds, radius)
    fp = fold_env_hashes(env, sizes, n_bits)
    if not counts:
        fp = (fp > 0).astype(np.float32)
    return fp


def env_hashes_from_arrays(el: np.ndarray, bonds: np.ndarray,
                           radius: int = FP_RADIUS) -> np.ndarray:
    """Environment hashes for a padded molecule batch: ``el`` int64[k, m]
    (3 = padding element), ``bonds`` int8[k, m, m] -> uint64[k, m, radius+1].

    The array-level core shared by :func:`batch_morgan_fingerprints` and the
    incremental pass; real-atom rows are bit-identical to per-molecule
    :func:`atom_env_hashes` (padding atoms have no bonds, so they never
    contaminate real neighbourhoods — padding ROWS themselves are garbage
    and must be masked by the caller's fold).
    """
    # identical invariant formula to molecule.initial_invariants
    tot = bonds.sum(axis=2, dtype=np.int64)
    deg = np.count_nonzero(bonds, axis=2)
    fv = _PAD_VALENCE[el] - tot
    packed = (((el * 64 + deg) * 64 + tot) * 64 + fv).astype(np.uint64)
    env = np.zeros(el.shape + (radius + 1,), dtype=np.uint64)
    env[:, :, 0] = splitmix64(packed)
    for r in range(1, radius + 1):
        prev = env[:, :, r - 1]
        env[:, :, r] = splitmix64(splitmix64(prev) + neighbor_combine(bonds, prev))
    return env


def fold_env_hashes(env: np.ndarray, sizes: np.ndarray, n_bits: int) -> np.ndarray:
    """Masked fold of batched env hashes: COUNT vectors f32[k, n_bits]
    (rows past each molecule's ``sizes`` entry are excluded).

    Padding rows are routed to a sentinel bin instead of boolean-extracted,
    and the bincount runs over row blocks so its bin range stays cache-sized
    regardless of the batch (a flat fleet batch is 10^4+ molecules).
    """
    k, m_max = env.shape[0], env.shape[1]
    out = np.empty((k, n_bits), dtype=np.float32)
    block = 256
    for lo in range(0, k, block):
        e = env[lo:lo + block]
        b = e.shape[0]
        valid = np.arange(m_max)[None, :, None] < sizes[lo:lo + block, None, None]
        bits = (e % np.uint64(n_bits)).astype(np.int64)
        flat = np.where(valid, np.arange(b)[:, None, None] * n_bits + bits,
                        b * n_bits)
        counts = np.bincount(flat.ravel(), minlength=b * n_bits + 1)[:-1]
        out[lo:lo + b] = counts.reshape(b, n_bits)
    return out


# ---------------------------------------------------------------------- #
# shared-parent batched incremental fingerprints (paper §3.6, fleet form)
# ---------------------------------------------------------------------- #
def incremental_fingerprints_grouped(
    parents: Sequence[Molecule],
    groups: Sequence[Sequence],
    radius: int = FP_RADIUS,
    n_bits: int = FP_BITS,
    *,
    counts: bool = False,
    chunk: int = 256,
    full_ratio: float = 0.6,
) -> list[np.ndarray]:
    """Candidate fingerprints for many (parent, action set) groups at once.

    The fleet-scale form of the paper's fast incremental Morgan fingerprint:
    each parent's ``atom_env_hashes`` table is computed ONCE, then every
    candidate of every group re-hashes only the radius-``radius`` ball
    around its edit's touched atoms — one vectorised padded-array pass over
    ALL candidates of ALL groups (``IncrementalMorgan.after_action`` is the
    single-edit correctness reference).  Per candidate the work drops from
    O(n^2 * R) hash rows to O(|ball| * n * R).

    BIT-IDENTICAL to ``batch_morgan_fingerprints([a.result for a in group])``
    for every group (pinned by tests/test_chem.py): hashes of atoms outside
    the ball are unchanged by a single edit, so carrying the parent's rows
    is exact, not an approximation.  Edits that re-index atoms (fragment-
    dropping removals) and empty parents fall back to the full batched
    recompute for just those candidates.

    ``groups[g]`` holds ``chem.actions.Action``-likes (``kind``/``detail``,
    lazy ``result`` only touched for fallback candidates).  Candidates whose
    radius ball covers more than ``full_ratio`` of their atoms are routed to
    an array-level full recompute instead (identical bits, cheaper when the
    "delta" IS the whole molecule — small molecules early in an episode).
    Returns one ``f32[len(group), n_bits]`` array per group.
    """
    S = len(parents)
    if S != len(groups):
        raise ValueError(f"{S} parents but {len(groups)} action groups")
    n_of = np.array([p.num_atoms for p in parents], dtype=np.int64)
    out = [np.zeros((len(g), n_bits), dtype=np.float32) for g in groups]

    # classify: no_op / incremental-safe / fallback (re-indexing edits)
    noop_rows: list[tuple[int, int]] = []
    inc_sid: list[int] = []            # parent index per incremental cand
    inc_rows: list[tuple[int, int]] = []   # (group, position) per cand
    inc_size: list[int] = []
    inc_touch: list[tuple[int, int]] = []
    inc_edit: list[tuple[int, int, int, int]] = []  # (is_add, a, b, value)
    fb_rows: list[tuple[int, int]] = []
    fb_mols: list[Molecule] = []
    for g, (parent, actions) in enumerate(zip(parents, groups)):
        n = int(n_of[g])
        pbonds = parent.bonds
        for pos, a in enumerate(actions):
            kind = a.kind
            if kind == "no_op":
                noop_rows.append((g, pos))
                continue
            if kind == "add_atom" and n > 0 and a.detail[1] >= 0:
                sym, anchor, order = a.detail
                inc_sid.append(g)
                inc_rows.append((g, pos))
                inc_size.append(n + 1)
                inc_touch.append((n, int(anchor)))
                inc_edit.append((1, int(anchor), ELEMENT_INDEX[sym], int(order)))
                continue
            if kind == "bond_delta" and n > 0:
                i, j, delta = a.detail
                i, j, delta = int(i), int(j), int(delta)
                new_order = int(pbonds[i, j]) + delta
                # a surviving bond can't re-index atoms; a removed bond only
                # does if it was a bridge (then the result shrank)
                if new_order > 0 or a.result.num_atoms == n:
                    inc_sid.append(g)
                    inc_rows.append((g, pos))
                    inc_size.append(n)
                    inc_touch.append((i, j))
                    inc_edit.append((0, i, j, new_order))
                    continue
            fb_rows.append((g, pos))
            fb_mols.append(a.result)

    if fb_mols:
        fb = batch_morgan_fingerprints(fb_mols, radius, n_bits, counts=counts)
        for (g, pos), row in zip(fb_rows, fb):
            out[g][pos] = row

    Ci = len(inc_sid)
    sizes_all = np.array(inc_size, dtype=np.int64)
    m = max(int(sizes_all.max()) if Ci else 1, int(n_of.max()) if S else 1, 1)

    # stacked parent frames, padded to the global atom budget; ONE batched
    # env pass over all parents (the "shared parent" work of the step)
    par_el = np.full((S, m), 3, dtype=np.int64)
    par_bonds = np.zeros((S, m, m), dtype=np.int8)
    for s, p in enumerate(parents):
        k = int(n_of[s])
        par_el[s, :k] = p.elements
        par_bonds[s, :k, :k] = p.bonds
    par_env = env_hashes_from_arrays(par_el, par_bonds, radius)
    par_cnt = fold_env_hashes(par_env, n_of, n_bits)  # [S, n_bits] f32

    for g, pos in noop_rows:
        out[g][pos] = par_cnt[g] if counts else (par_cnt[g] > 0)
    if Ci == 0:
        return out

    sid_all = np.array(inc_sid, dtype=np.int64)
    touch_all = np.array(inc_touch, dtype=np.int64)   # [Ci, 2]
    edit_all = np.array(inc_edit, dtype=np.int64)     # [Ci, 4]

    step = chunk if chunk else Ci
    for lo in range(0, Ci, step):
        hi = min(lo + step, Ci)
        # per-chunk padding: candidates are group-ordered, and the engine's
        # groups are same-step slot molecules of similar size, so slicing
        # the shared frames to the chunk's own atom budget avoids paying the
        # global max for every candidate (mirrors batch_morgan's chunking)
        m_c = int(sizes_all[lo:hi].max())
        rows = _incremental_chunk(
            par_bonds, par_el, par_env, par_cnt, n_of,
            sid_all[lo:hi], sizes_all[lo:hi], touch_all[lo:hi],
            edit_all[lo:hi], m_c, radius, n_bits, full_ratio)
        if not counts:
            rows = rows > 0
        # scatter rows back per group (chunk-local candidates are group-
        # ordered, so each group's slice is contiguous)
        r = 0
        while r < hi - lo:
            g = inc_rows[lo + r][0]
            r2 = r
            while r2 < hi - lo and inc_rows[lo + r2][0] == g:
                r2 += 1
            pos = np.fromiter((inc_rows[lo + t][1] for t in range(r, r2)),
                              dtype=np.int64, count=r2 - r)
            out[g][pos] = rows[r:r2]
            r = r2
    return out


def _incremental_chunk(par_bonds, par_el, par_env, par_cnt, n_of,
                       sid, sizes, touch, edit, m, radius, n_bits,
                       full_ratio):
    """One padded pass over a chunk of incremental-safe candidates.

    Returns the candidates' COUNT vectors ``f32[c, n_bits]``: the parent's
    fold counts minus the touched ball's stale (atom, radius) hashes plus
    the re-hashed ones — exactly ``IncrementalMorgan.update`` vectorised
    over candidates.  Candidates whose ball exceeds ``full_ratio`` of their
    atoms are recomputed outright from their (already built) edited frames.
    """
    c = sid.shape[0]
    rows = np.arange(c)

    # candidate frames: parent frame + the one edit, sliced to this chunk's
    # atom budget ``m`` (advanced+basic indexing copies just the slice)
    cb = par_bonds[sid, :m, :m]                       # [c, m, m]
    ce = par_el[sid, :m]                              # [c, m]
    is_add = edit[:, 0] == 1
    r_add = rows[is_add]
    if r_add.size:
        na = n_of[sid[is_add]]                        # new-atom index = old n
        anchor = edit[is_add, 1]
        order = edit[is_add, 3].astype(np.int8)
        ce[r_add, na] = edit[is_add, 2]
        cb[r_add, na, anchor] = order
        cb[r_add, anchor, na] = order
    r_bd = rows[~is_add]
    if r_bd.size:
        bi, bj = edit[~is_add, 1], edit[~is_add, 2]
        nv = edit[~is_add, 3].astype(np.int8)
        cb[r_bd, bi, bj] = nv
        cb[r_bd, bj, bi] = nv

    valid = np.arange(m)[None, :] < sizes[:, None]    # [c, m]

    # distance-limited BFS from the touched atoms, all candidates at once
    adj = cb > 0
    dist = np.full((c, m), 127, dtype=np.int16)
    dist[rows, touch[:, 0]] = 0
    dist[rows, touch[:, 1]] = 0
    for r in range(1, radius + 1):
        frontier = dist == r - 1
        if not frontier.any():
            break
        reached = (adj & frontier[:, :, None]).any(axis=1)
        dist = np.where(reached & (dist > r), np.int16(r), dist)
    aff = (dist <= radius) & valid
    aff_cnt = aff.sum(axis=1)

    out = np.empty((c, n_bits), dtype=np.float32)

    # ball ~ whole molecule: the full recompute IS the cheaper delta
    go_full = aff_cnt > np.maximum(full_ratio * sizes, 1.0)
    f_rows = rows[go_full]
    if f_rows.size:
        env = env_hashes_from_arrays(ce[f_rows], cb[f_rows], radius)
        out[f_rows] = fold_env_hashes(env, sizes[f_rows], n_bits)
    i_rows = rows[~go_full]
    if i_rows.size == 0:
        return out
    if f_rows.size:
        sid, sizes, touch = sid[i_rows], sizes[i_rows], touch[i_rows]
        cb, aff, aff_cnt, dist = cb[i_rows], aff[i_rows], aff_cnt[i_rows], dist[i_rows]
        ce = ce[i_rows]
        c = i_rows.size

    K = int(aff_cnt.max())
    # affected atom indices, ascending, padded to K (stable sort: the False
    # entries of ~aff — i.e. affected atoms — sort first, in index order)
    aff_idx = np.argsort(~aff, axis=1, kind="stable")[:, :K]
    kmask = np.arange(K)[None, :] < aff_cnt[:, None]  # [c, K]
    dist_g = np.take_along_axis(dist, aff_idx, axis=1)

    sub_bonds = cb[np.arange(c)[:, None], aff_idx]    # [c, K, m]
    env_sid = par_env[sid, :m]                        # [c, m, radius+1]
    fresh = np.empty((c, K, radius + 1), dtype=np.uint64)

    # radius 0: local element/degree/valence invariants of the ball
    tot = sub_bonds.sum(axis=2, dtype=np.int64)
    deg = np.count_nonzero(sub_bonds, axis=2)
    elg = np.take_along_axis(ce, aff_idx, axis=1)
    fvv = _PAD_VALENCE[elg] - tot
    packed = (((elg * 64 + deg) * 64 + tot) * 64 + fvv).astype(np.uint64)
    cur = env_sid[:, :, 0].copy()
    base_g = np.take_along_axis(cur, aff_idx, axis=1)
    vals = np.where(kmask, splitmix64(packed), base_g)
    np.put_along_axis(cur, aff_idx, vals, axis=1)
    fresh[:, :, 0] = vals

    # radius r: re-hash ball rows within distance r; rows farther than r
    # keep the parent's radius-r hash (their r-ball is untouched)
    for r in range(1, radius + 1):
        mixed = splitmix64(cur[:, None, :] ^ _ORDER_SALT[sub_bonds])
        agg = np.where(sub_bonds > 0, mixed, np.uint64(0)).sum(
            axis=2, dtype=np.uint64)
        prev_aff = np.take_along_axis(cur, aff_idx, axis=1)
        new_r = splitmix64(splitmix64(prev_aff) + agg)
        base = env_sid[:, :, r].copy()
        base_g = np.take_along_axis(base, aff_idx, axis=1)
        vals = np.where(kmask & (dist_g <= r), new_r, base_g)
        np.put_along_axis(base, aff_idx, vals, axis=1)
        fresh[:, :, r] = vals
        cur = base

    # fold delta: parent counts - stale ball hashes + re-hashed ball hashes.
    # Entries where the re-hash reproduced the parent's value (rows farther
    # than r at radius r) cancel exactly — drop them up front, then segment-
    # sum the surviving sparse (candidate, bit) deltas via one sort instead
    # of a dense c*n_bits bincount.
    inc_out = par_cnt[sid]                            # [c, n_bits] copy
    row_off = (np.arange(c) * n_bits)[:, None, None]
    stale = np.take_along_axis(env_sid, aff_idx[:, :, None], axis=1)
    stale_mask = (kmask & (aff_idx < n_of[sid][:, None]))[:, :, None] \
        & np.ones((1, 1, radius + 1), dtype=bool)
    fresh_mask = kmask[:, :, None] & np.ones((1, 1, radius + 1), dtype=bool)
    unchanged = stale_mask & fresh_mask & (fresh == stale)
    stale_idx = (row_off + (stale % np.uint64(n_bits)).astype(np.int64)
                 )[stale_mask & ~unchanged]
    fresh_idx = (row_off + (fresh % np.uint64(n_bits)).astype(np.int64)
                 )[fresh_mask & ~unchanged]
    idx = np.concatenate([fresh_idx, stale_idx])
    if idx.size:
        w = np.ones(idx.size, dtype=np.float64)
        w[fresh_idx.size:] = -1.0
        uniq, inv = np.unique(idx, return_inverse=True)
        sums = np.bincount(inv, weights=w)
        nz = sums != 0
        inc_out.reshape(-1)[uniq[nz]] += sums[nz].astype(np.float32)
    out[i_rows] = inc_out
    return out


def batch_fingerprints_incremental(
    parent: Molecule,
    actions: Sequence,
    radius: int = FP_RADIUS,
    n_bits: int = FP_BITS,
    *,
    counts: bool = False,
) -> np.ndarray:
    """All candidate fingerprints of ONE parent from a single shared
    environment-hash table — see :func:`incremental_fingerprints_grouped`.
    Bit-identical to ``batch_morgan_fingerprints([a.result for a in
    actions], radius, n_bits, counts=counts)``."""
    if not len(actions):
        return np.zeros((0, n_bits), dtype=np.float32)
    return incremental_fingerprints_grouped(
        [parent], [actions], radius, n_bits, counts=counts)[0]


def morgan_fingerprint_reference(
    mol: Molecule,
    radius: int = FP_RADIUS,
    n_bits: int = FP_BITS,
    *,
    counts: bool = False,
) -> np.ndarray:
    """Per-atom cryptographic-hash Morgan — the pre-optimisation baseline.

    This mirrors the cost profile of the original RDKit-backed Python
    implementation the paper profiled (§3.6): one hash invocation per
    (atom, radius) with a sorted neighbour list.  Kept for
    ``benchmarks/bench_fingerprint.py``; produces the same *bit semantics*
    but a different hash family than :func:`morgan_fingerprint`.
    """
    import hashlib

    n = mol.num_atoms
    env = np.zeros((n, radius + 1), dtype=np.uint64)
    if n:
        fv = mol.free_valences()
        for i in range(n):
            h = hashlib.blake2b(digest_size=8)
            h.update(bytes([int(mol.elements[i]), mol.degree(i), mol.total_order(i), int(fv[i])]))
            env[i, 0] = np.uint64(int.from_bytes(h.digest(), "little"))
        for r in range(1, radius + 1):
            prev = env[:, r - 1]
            for i in range(n):
                nbrs = np.nonzero(mol.bonds[i])[0]
                pairs = sorted((int(mol.bonds[i, v]), int(prev[v])) for v in nbrs)
                h = hashlib.blake2b(digest_size=8)
                h.update(int(prev[i]).to_bytes(8, "little"))
                for order, niv in pairs:
                    h.update(order.to_bytes(1, "little"))
                    h.update(niv.to_bytes(8, "little"))
                env[i, r] = np.uint64(int.from_bytes(h.digest(), "little"))
    return fold_hashes(env, n_bits, counts=counts)


def fingerprint_with_steps(fp: np.ndarray, steps_left: int, max_steps: int) -> np.ndarray:
    """MolDQN state = fingerprint ++ normalised steps-left scalar."""
    return np.concatenate([fp, np.array([steps_left / max(max_steps, 1)], dtype=np.float32)])


class IncrementalMorgan:
    """Incrementally-maintained Morgan fingerprint (paper §3.6).

    Usage::

        inc  = IncrementalMorgan(mol)
        fp   = inc.fingerprint()                         # == morgan_fingerprint(mol)
        inc2 = inc.after_action(new_mol, kind, detail)   # O(|radius-ball|) update

    State is (per-atom env-hash table, folded bit-count vector); an update
    copies the 2048-float count vector (one memcpy) and scatter-adds the
    delta rows, avoiding any per-hash Python bookkeeping.  Instances are
    immutable; updates return new instances.  Edits that re-index atoms
    (fragment drops) fall back to a full recompute.
    """

    __slots__ = ("mol", "radius", "n_bits", "env", "counts")

    def __init__(
        self,
        mol: Molecule,
        radius: int = FP_RADIUS,
        n_bits: int = FP_BITS,
        _env: np.ndarray | None = None,
        _counts: np.ndarray | None = None,
    ):
        self.mol = mol
        self.radius = radius
        self.n_bits = n_bits
        if _env is None:
            self.env = atom_env_hashes(mol, radius)
            self.counts = fold_hashes(self.env, n_bits, counts=True)
        else:
            self.env = _env
            self.counts = _counts

    # -------------------------------------------------------------- #
    def fingerprint(self, *, counts: bool = False) -> np.ndarray:
        if counts:
            return self.counts.copy()
        return (self.counts > 0).astype(np.float32)

    # -------------------------------------------------------------- #
    def update(self, new_mol: Molecule, touched: list[int]) -> "IncrementalMorgan":
        """Recompute env hashes only inside the radius-ball of ``touched``.

        ``touched`` are atom indices *in new_mol* whose incident bonds (or
        existence) changed.  Requires that pre-existing atoms kept their
        indices (true for atom additions and bond edits).
        """
        n_new = new_mol.num_atoms
        n_old = self.env.shape[0]
        radius = self.radius

        # distance-limited BFS from the touched set
        dist: dict[int, int] = {t: 0 for t in touched}
        q = deque(touched)
        while q:
            u = q.popleft()
            if dist[u] >= radius:
                continue
            for v in np.nonzero(new_mol.bonds[u])[0]:
                v = int(v)
                if v not in dist:
                    dist[v] = dist[u] + 1
                    q.append(v)
        aff = np.array(sorted(dist.keys()), dtype=np.int64)

        env = np.zeros((n_new, radius + 1), dtype=np.uint64)
        env[:n_old] = self.env

        counts = self.counts.copy()
        stale_rows = aff[aff < n_old]
        if stale_rows.size:
            idx = (self.env[stale_rows].ravel() % np.uint64(self.n_bits)).astype(np.int64)
            np.subtract.at(counts, idx, 1.0)

        # radius-0: local degree/valence invariants for the affected rows only
        sub = new_mol.bonds[aff]
        el = new_mol.elements[aff].astype(np.int64)
        tot = sub.sum(axis=1, dtype=np.int64)
        deg = np.count_nonzero(sub, axis=1)
        fv = np.array([4, 3, 2], dtype=np.int64)[el] - tot
        packed = ((((el * 64 + deg) * 64 + tot) * 64) + fv).astype(np.uint64)
        env[aff, 0] = splitmix64(packed)

        # radius-r rows for atoms within distance r of an edit; rows farther
        # than r keep their old hash at this radius (already copied above)
        dist_arr = np.array([dist[int(i)] for i in aff], dtype=np.int64)
        for r in range(1, radius + 1):
            prev = env[:, r - 1]
            rows = aff[dist_arr <= r]
            if rows.size:
                sub_bonds = new_mol.bonds[rows]  # [k, n]
                mixed = splitmix64(prev[None, :] ^ _ORDER_SALT[sub_bonds])
                agg = np.where(sub_bonds > 0, mixed, np.uint64(0)).sum(axis=1, dtype=np.uint64)
                env[rows, r] = splitmix64(splitmix64(prev[rows]) + agg)

        idx = (env[aff].ravel() % np.uint64(self.n_bits)).astype(np.int64)
        np.add.at(counts, idx, 1.0)

        return IncrementalMorgan(new_mol, self.radius, self.n_bits, _env=env, _counts=counts)

    # -------------------------------------------------------------- #
    def after_action(self, new_mol: Molecule, kind: str, detail: tuple) -> "IncrementalMorgan":
        """Apply the effect of an Action (see chem.actions)."""
        if new_mol.num_atoms < self.mol.num_atoms or (
            kind == "bond_delta" and new_mol.num_atoms != self.mol.num_atoms
        ):
            # fragment drop re-indexed atoms: full recompute
            return IncrementalMorgan(new_mol, self.radius, self.n_bits)
        if kind == "no_op":
            return self
        if kind == "add_atom":
            _, anchor, _ = detail
            new_idx = new_mol.num_atoms - 1
            touched = [new_idx] if anchor < 0 else [new_idx, int(anchor)]
            return self.update(new_mol, touched)
        if kind == "bond_delta":
            i, j, _ = detail
            return self.update(new_mol, [int(i), int(j)])
        raise ValueError(f"unknown action kind {kind}")
