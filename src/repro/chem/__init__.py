"""Self-contained chemistry substrate (the framework's RDKit replacement).

The paper (DA-MolDQN) relies on RDKit for molecule editing, valence
bookkeeping, Morgan fingerprints, 3D conformer embedding and SA scores, and
on Alfabet/AIMNet-NSE for BDE/IP prediction.  None of those ship in this
container, so this package implements the required subset from scratch:

``molecule``     graph molecules over {C, N, O} with implicit hydrogens,
                 valence rules and ring-size constraints (paper App. C:
                 allowed atoms C/O/N, allowed rings 3/5/6).
``actions``      MolDQN action enumeration (atom add / bond add / bond
                 remove / no-op) with the paper's O-H-bond protection.
``fingerprint``  Morgan/ECFP fingerprints, radius 3 folded to 2048 bits,
                 plus the paper's *incremental* variant (§3.6).
``smiles``       a SMILES-subset codec + canonicalisation.
``conformer``    deterministic 3D-conformer validity model + spectral
                 pseudo-coordinates (the AIMNet input stand-in).
``properties``   SA score / QED / penalised-logP surrogates (App. D).
``oracle``       closed-form BDE/IP ground truth with the paper's central
                 electron-donor trade-off (plays the role of DFT).
"""

from repro.chem.molecule import Molecule, VALENCES, ELEMENTS, ALLOWED_RING_SIZES
from repro.chem.actions import enumerate_actions, enumerate_actions_ref, Action
from repro.chem.chemcache import ChemCache
from repro.chem.fingerprint import (
    morgan_fingerprint, IncrementalMorgan, batch_fingerprints_incremental)
from repro.chem.smiles import to_smiles, from_smiles, canonical_smiles
from repro.chem.conformer import has_valid_conformer, conformer_features
from repro.chem.properties import sa_score, qed_score, penalized_logp, tanimoto
from repro.chem.oracle import oracle_bde, oracle_ip, oracle_properties

__all__ = [
    "Molecule", "VALENCES", "ELEMENTS", "ALLOWED_RING_SIZES",
    "enumerate_actions", "enumerate_actions_ref", "Action", "ChemCache",
    "morgan_fingerprint", "IncrementalMorgan", "batch_fingerprints_incremental",
    "to_smiles", "from_smiles", "canonical_smiles",
    "has_valid_conformer", "conformer_features",
    "sa_score", "qed_score", "penalized_logp", "tanimoto",
    "oracle_bde", "oracle_ip", "oracle_properties",
]
