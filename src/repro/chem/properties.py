"""Cheap molecular property surrogates: SA score, QED, penalised logP, Tanimoto.

The paper uses these for (a) the filter script (§3.5: drop SA > 3.5, drop
molecules identical/too-similar to known antioxidants) and (b) the Appendix D
comparison against MolDQN/GCPN/GraphAF on QED & PlogP.  RDKit's
implementations are unavailable; these surrogates preserve the *structure*
the experiments rely on:

* ``sa_score``: grows with size, ring complexity, quaternary carbons and
  unusual motifs; typical range ~1.5-4 matching Fig. 5/Table 5 (2.4-2.9).
* ``qed_score``: in (0, 1), peaked at moderate size with a few heteroatoms
  and rings — saturates near 0.948 like the paper's Table 4 top values.
* ``penalized_logp``: logP surrogate - SA - long-ring penalty.  Crucially it
  *increases* with added carbons, reproducing MolDQN's known PlogP
  degenerate strategy (Table 4 discussion).
* ``tanimoto``: standard bit-fingerprint Tanimoto similarity.
"""

from __future__ import annotations

import numpy as np

from repro.chem.molecule import ELEMENT_INDEX, Molecule
from repro.chem.fingerprint import morgan_fingerprint


def sa_score(mol: Molecule) -> float:
    """Synthetic-accessibility surrogate in roughly [1, 8] (lower = easier)."""
    n = max(mol.num_atoms, 1)
    rings = mol.ring_info()
    ring_sizes = [len(r) for r in rings]
    membership = mol.atom_ring_membership()

    size_term = 0.035 * n
    ring_term = 0.25 * len(rings) + 0.45 * sum(1 for s in ring_sizes if s not in (5, 6))
    fused_term = 0.5 * float(np.sum(membership >= 2))
    quaternary = sum(
        1 for i in range(mol.num_atoms)
        if mol.elements[i] == ELEMENT_INDEX["C"] and mol.degree(i) == 4
    )
    sp3_n = sum(
        1 for i in range(mol.num_atoms)
        if mol.elements[i] == ELEMENT_INDEX["N"] and mol.degree(i) == 3
    )
    triples = int(np.sum(np.triu(mol.bonds) == 3))
    hetero = int(np.sum(mol.elements != ELEMENT_INDEX["C"]))
    hetero_term = 0.12 * max(hetero - 3, 0)
    score = 1.0 + size_term + ring_term + fused_term + 0.6 * quaternary \
        + 0.25 * sp3_n + 0.5 * triples + hetero_term
    return float(min(score, 8.0))


def qed_score(mol: Molecule) -> float:
    """Drug-likeness surrogate in (0, 1); ceiling ~0.948 as in Table 4."""
    n = mol.num_atoms
    if n == 0:
        return 0.0
    hetero = int(np.sum(mol.elements != ELEMENT_INDEX["C"]))
    rings = mol.ring_info()
    # desirability terms (gaussian-ish bumps)
    d_size = np.exp(-((n - 22.0) ** 2) / (2 * 9.0 ** 2))
    d_het = np.exp(-((hetero - 4.0) ** 2) / (2 * 2.5 ** 2))
    d_ring = np.exp(-((len(rings) - 2.5) ** 2) / (2 * 1.5 ** 2))
    sa = sa_score(mol)
    d_sa = 1.0 / (1.0 + np.exp(2.2 * (sa - 4.2)))
    geo = (d_size * d_het * d_ring * d_sa) ** 0.25
    return float(0.948 * geo)


def logp_surrogate(mol: Molecule) -> float:
    """Crippen-flavoured logP: carbons add lipophilicity, N/O subtract."""
    c = int(np.sum(mol.elements == ELEMENT_INDEX["C"]))
    het = int(np.sum(mol.elements != ELEMENT_INDEX["C"]))
    rings = len(mol.ring_info())
    return 0.38 * c - 0.85 * het + 0.12 * rings


def penalized_logp(mol: Molecule) -> float:
    """PlogP = logP - SA - max(ring size - 6, 0) penalty (standard def.)."""
    ring_pen = max((max((len(r) for r in mol.ring_info()), default=0) - 6), 0)
    return logp_surrogate(mol) - sa_score(mol) - float(ring_pen)


def tanimoto(a: Molecule | np.ndarray, b: Molecule | np.ndarray) -> float:
    """Tanimoto similarity of binary Morgan fingerprints."""
    fa = morgan_fingerprint(a) if isinstance(a, Molecule) else np.asarray(a)
    fb = morgan_fingerprint(b) if isinstance(b, Molecule) else np.asarray(b)
    fa = fa > 0
    fb = fb > 0
    inter = float(np.sum(fa & fb))
    union = float(np.sum(fa | fb))
    return inter / union if union else 0.0
