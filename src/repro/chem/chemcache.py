"""Fleet-wide candidate-chemistry cache (the §3.6 LRU idea, applied to
enumeration + fingerprints instead of property predictions).

MolDQN revisits the same states constantly: every episode restarts from the
same initial molecules, exploitation makes workers that share an initial
molecule walk the same edit sequences, and the replay horizon is short.
``PropertyService`` already dedupes *predictions* across the fleet;
``ChemCache`` does the same for the other host hot path — per unique parent
molecule it memoizes the full per-step candidate chemistry:

* the deduped, protection-filtered ``Action`` list (lazy edit descriptors —
  cheap to hold, and a cached chosen action re-materialises against the
  cached parent, which is concrete-identical to the requesting slot's), and
* the bit-packed candidate fingerprint matrix ``uint8[C, FP_BITS/8]``.

A typical entry (C ~ 150 candidates) holds ~40 KB of packed bits plus the
lazy action tuple (~25 KB of Python objects), so the default capacity of
8192 bounds the cache at roughly half a GB when completely full of
worst-case entries — in practice episodes revisit a far smaller hot set and
the LRU keeps exactly that.

Keys are ``Molecule.canonical_key()`` — exact up to isomorphism, no hash
collisions.  Because the rollout engine's transition stream must stay
BIT-identical to the uncached path, entries additionally carry the parent's
concrete ``(elements, bonds)`` byte signature: enumeration order is a
function of the concrete atom labelling, and two isomorphic but differently
labelled parents would otherwise swap candidate orderings mid-rollout.  A
canonical-key hit whose signature differs is counted as a ``relabel_miss``
and recomputed; the incumbent entry is kept (``put`` refuses to replace a
different labelling, so two live twins cannot evict each other every step —
and since relabel misses don't refresh LRU recency, a dead labelling still
ages out).  Relabelled twins are rare: they need two distinct edit paths to
the same isomorphism class.

Thread-safe: the pipelined rollout calls ``get``/``put`` from its host
enumeration threads.  Values are immutable by convention (tuple of Actions,
read-only packed array), so sharing entries across workers is free.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.chem.molecule import Molecule


@dataclass(frozen=True)
class ChemEntry:
    """What one parent molecule's step costs to recompute."""
    signature: bytes                 # concrete (elements ++ bonds) bytes
    actions: tuple                   # tuple[Action, ...]
    packed_fps: np.ndarray           # uint8[C, FP_BITS // 8], read-only


def molecule_signature(mol: Molecule) -> bytes:
    """Concrete-labelling signature (NOT isomorphism-invariant)."""
    return mol.elements.tobytes() + mol.bonds.tobytes()


class ChemCache:
    """LRU over per-parent candidate chemistry, shared across the fleet."""

    def __init__(self, capacity: int = 8192):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._data: OrderedDict[str, ChemEntry] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.relabel_misses = 0      # canonical hit, different atom labelling
        self.evictions = 0           # LRU capacity evictions (serve dashboards)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    # ------------------------------------------------------------ #
    def get(self, mol: Molecule) -> ChemEntry | None:
        key = mol.canonical_key()
        with self._lock:
            entry = self._data.get(key)
            if entry is None:
                self.misses += 1
                return None
            if entry.signature != molecule_signature(mol):
                self.relabel_misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return entry

    def put(self, mol: Molecule, actions, packed_fps: np.ndarray) -> None:
        # ALL-OR-NOTHING: materialise and validate the complete entry
        # before touching the lock, the dict, or the caller's array.  A
        # faulted enumeration handing over a throwing iterable or a
        # mismatched fingerprint matrix must leave the cache untouched
        # (the old order froze the caller's array and could start the
        # insert before tuple(actions) had finished materialising).
        actions = tuple(actions)
        packed_fps = np.asarray(packed_fps)
        if packed_fps.ndim != 2 or packed_fps.shape[0] != len(actions):
            raise ValueError(
                f"half-built chem entry refused: {len(actions)} actions vs "
                f"packed_fps shape {packed_fps.shape}")
        sig = molecule_signature(mol)
        key = mol.canonical_key()
        entry = ChemEntry(sig, actions, packed_fps)
        packed_fps.flags.writeable = False
        with self._lock:
            existing = self._data.get(key)
            if existing is not None and existing.signature != sig:
                # a relabelled twin is already cached: keep it (two live
                # labellings would otherwise evict each other every step —
                # first labelling wins; relabel-miss lookups don't refresh
                # recency, so a DEAD labelling still ages out of the LRU)
                return
            if existing is not None:
                self._data.move_to_end(key)
            self._data[key] = entry
            if len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    # ------------------------------------------------------------ #
    @property
    def hit_rate(self) -> float:
        return self.stats()["hit_rate"]

    def stats(self) -> dict:
        # one consistent snapshot: the pipelined rollout reads stats while
        # its enumeration threads are still inserting, and an unlocked read
        # can tear (hits already bumped, misses not yet) — every counter
        # access goes through the same lock as get/put
        with self._lock:
            total = self.hits + self.misses + self.relabel_misses
            return {
                "hits": self.hits,
                "misses": self.misses,
                "relabel_misses": self.relabel_misses,
                "lookups": total,
                "hit_rate": self.hits / total if total else 0.0,
                "entries": len(self._data),
                "evictions": self.evictions,
            }

    def reset_stats(self) -> None:
        with self._lock:
            self.hits = 0
            self.misses = 0
            self.relabel_misses = 0
            self.evictions = 0
