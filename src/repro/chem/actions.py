"""MolDQN action enumeration with the paper's O-H-bond protection (§3.3).

One environment step enumerates every *valid* single edit of the current
molecule:

* **atom addition** — attach a new C/N/O atom to any atom with free valence,
  with bond order 1..min(free valence, new-atom valence);
* **bond addition / order increase** — between two existing atoms with
  sufficient free valence; closing a new ring is only allowed for ring sizes
  in ``ALLOWED_RING_SIZES`` (3/5/6, paper App. C);
* **bond order decrease / removal** — decrease by 1..order; if the molecule
  falls apart, disconnected atoms are dropped (largest fragment kept,
  paper Fig. 6);
* **no-op** — keep the current molecule (lets the agent "stop early").

Protection (§3.3): every candidate that has *no remaining O-H bond* is
discarded, because BDE (min over O-H bonds) would be undefined.  The paper
notes this removes only a few of >100 candidates.

Two implementations are provided:

``enumerate_actions``        vectorised NumPy (the production path — the
                             analogue of the paper's C++ port, §3.6);
``enumerate_actions_naive``  a deliberately line-by-line port of the
                             original Python loop structure, kept as the
                             baseline for ``benchmarks/bench_env.py``.

Both return identical action sets (asserted by tests/property tests).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import numpy as np

from repro.chem.molecule import (
    ALLOWED_RING_SIZES,
    ELEMENTS,
    MAX_BOND_ORDER,
    VALENCES,
    Molecule,
)

ActionKind = Literal["no_op", "add_atom", "bond_delta"]


@dataclass(frozen=True)
class Action:
    """A molecule edit.  ``result`` is the post-edit molecule."""

    kind: ActionKind
    result: Molecule
    # add_atom: (element_symbol, anchor, order); bond_delta: (i, j, delta)
    detail: tuple = ()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Action({self.kind}, {self.detail}, -> {self.result.heavy_formula()})"


def enumerate_actions(
    mol: Molecule,
    *,
    allow_removal: bool = True,
    allow_no_op: bool = True,
    protect_oh: bool = True,
    allowed_ring_sizes: frozenset[int] = ALLOWED_RING_SIZES,
    max_atoms: int = 38,
) -> list[Action]:
    """Vectorised enumeration of all valid single-edit actions."""
    actions: list[Action] = []
    if allow_no_op:
        actions.append(Action("no_op", mol, ()))

    n = mol.num_atoms
    if n == 0:
        for sym in ELEMENTS:
            actions.append(Action("add_atom", Molecule.from_element(sym), (sym, -1, 0)))
        return _protect(actions, protect_oh)

    fv = mol.free_valences()

    # ---- atom additions (vectorised over anchors) ----------------------- #
    if n < max_atoms:
        anchors = np.nonzero(fv >= 1)[0]
        for a in anchors:
            a = int(a)
            for ei, sym in enumerate(ELEMENTS):
                max_order = min(int(fv[a]), VALENCES[ei], MAX_BOND_ORDER)
                for order in range(1, max_order + 1):
                    actions.append(
                        Action("add_atom", mol.with_added_atom(sym, a, order), (sym, a, order))
                    )

    # ---- bond additions / increases -------------------------------------- #
    # Candidate pairs where both ends have free valence.  For unbonded pairs
    # we must respect the ring-size rule; for already-bonded pairs an order
    # increase never creates a new ring.
    cap = np.minimum.outer(fv, fv)          # max possible delta per pair
    iu, ju = np.triu_indices(n, k=1)
    sp = None
    for i, j in zip(iu.tolist(), ju.tolist()):
        max_delta = int(min(cap[i, j], MAX_BOND_ORDER - int(mol.bonds[i, j])))
        if max_delta < 1:
            continue
        if mol.bonds[i, j] == 0:
            # would close a ring iff i..j already connected
            if sp is None:
                sp = mol.all_pairs_shortest_paths()
            d = int(sp[i, j])
            if d >= 0 and (d + 1) not in allowed_ring_sizes:
                continue
        for delta in range(1, max_delta + 1):
            actions.append(Action("bond_delta", mol.with_bond_delta(i, j, delta), (i, j, delta)))

    # ---- bond decreases / removals ---------------------------------------- #
    if allow_removal:
        for i, j in zip(*np.nonzero(np.triu(mol.bonds))):
            i, j = int(i), int(j)
            order = int(mol.bonds[i, j])
            for delta in range(1, order + 1):
                cand = mol.with_bond_delta(i, j, -delta).largest_fragment()
                if cand.num_atoms == 0:
                    continue
                actions.append(Action("bond_delta", cand, (i, j, -delta)))

    return _protect(_dedup(actions), protect_oh)


def enumerate_actions_naive(
    mol: Molecule,
    *,
    allow_removal: bool = True,
    allow_no_op: bool = True,
    protect_oh: bool = True,
    allowed_ring_sizes: frozenset[int] = ALLOWED_RING_SIZES,
    max_atoms: int = 38,
) -> list[Action]:
    """Line-by-line port of the original Python MolDQN enumeration.

    Intentionally unoptimised: per-pair BFS, per-candidate full validity
    re-checks, no vectorisation.  Kept as the performance baseline that the
    paper's C++ port (and our vectorised path) is measured against.
    """
    actions: list[Action] = []
    if allow_no_op:
        actions.append(Action("no_op", mol, ()))
    if mol.num_atoms == 0:
        for sym in ELEMENTS:
            actions.append(Action("add_atom", Molecule.from_element(sym), (sym, -1, 0)))
        return _protect(actions, protect_oh)

    # atom additions -- python loops, recomputing free valence every time
    if mol.num_atoms < max_atoms:
        for a in range(mol.num_atoms):
            for ei, sym in enumerate(ELEMENTS):
                for order in range(1, MAX_BOND_ORDER + 1):
                    if order > VALENCES[ei]:
                        continue
                    if mol.free_valence(a) < order:  # recomputed per candidate
                        continue
                    cand = mol.with_added_atom(sym, a, order)
                    cand.check_valences()
                    actions.append(Action("add_atom", cand, (sym, a, order)))

    # bond additions -- per-pair BFS instead of one all-pairs pass
    for i in range(mol.num_atoms):
        for j in range(i + 1, mol.num_atoms):
            for delta in range(1, MAX_BOND_ORDER + 1):
                if mol.free_valence(i) < delta or mol.free_valence(j) < delta:
                    continue
                if int(mol.bonds[i, j]) + delta > MAX_BOND_ORDER:
                    continue
                if mol.bonds[i, j] == 0:
                    d = mol.shortest_path_length(i, j)
                    if d >= 0 and (d + 1) not in allowed_ring_sizes:
                        continue
                cand = mol.with_bond_delta(i, j, delta)
                cand.check_valences()
                actions.append(Action("bond_delta", cand, (i, j, delta)))

    # bond removals
    if allow_removal:
        for i in range(mol.num_atoms):
            for j in range(i + 1, mol.num_atoms):
                order = int(mol.bonds[i, j])
                for delta in range(1, order + 1):
                    cand = mol.with_bond_delta(i, j, -delta).largest_fragment()
                    if cand.num_atoms == 0:
                        continue
                    cand.check_valences()
                    actions.append(Action("bond_delta", cand, (i, j, -delta)))

    return _protect(_dedup_naive(actions), protect_oh)


def _dedup_naive(actions: list[Action]) -> list[Action]:
    """Per-candidate canonical-serialisation dedup — the original MolDQN
    approach (canonical SMILES per candidate via RDKit).  Baseline for
    ``benchmarks/bench_env.py``; same output set as :func:`_dedup`."""
    seen: set[str] = set()
    out: list[Action] = []
    for a in actions:
        key = a.result.canonical_key()
        if key in seen:
            continue
        seen.add(key)
        out.append(a)
    return out


def _dedup(actions: list[Action]) -> list[Action]:
    """Drop actions yielding isomorphic molecules (keep first occurrence).

    Hashes every candidate in ONE padded batch (``iso_hashes_batch``) —
    equal graphs always collide, distinct graphs collide with ~2^-64
    probability, which is acceptable for pruning a candidate list.
    """
    from repro.chem.molecule import iso_hashes_batch

    keys = iso_hashes_batch([a.result for a in actions])
    seen: set[int] = set()
    out: list[Action] = []
    for a, key in zip(actions, keys):
        if key in seen:
            continue
        seen.add(key)
        out.append(a)
    return out


def _protect(actions: list[Action], protect_oh: bool) -> list[Action]:
    if not protect_oh:
        return actions
    kept = [a for a in actions if a.kind == "no_op" or a.result.has_oh_bond()]
    # Never return an empty action set: no-op always survives if present.
    return kept if kept else actions[:1]
