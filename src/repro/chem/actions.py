"""MolDQN action enumeration with the paper's O-H-bond protection (§3.3).

One environment step enumerates every *valid* single edit of the current
molecule:

* **atom addition** — attach a new C/N/O atom to any atom with free valence,
  with bond order 1..min(free valence, new-atom valence);
* **bond addition / order increase** — between two existing atoms with
  sufficient free valence; closing a new ring is only allowed for ring sizes
  in ``ALLOWED_RING_SIZES`` (3/5/6, paper App. C);
* **bond order decrease / removal** — decrease by 1..order; if the molecule
  falls apart, disconnected atoms are dropped (largest fragment kept,
  paper Fig. 6);
* **no-op** — keep the current molecule (lets the agent "stop early").

Protection (§3.3): every candidate that has *no remaining O-H bond* is
discarded, because BDE (min over O-H bonds) would be undefined.  The paper
notes this removes only a few of >100 candidates.

Three implementations are provided, in decreasing order of speed:

``enumerate_actions``        DELTA enumeration (the production path).  It
                             never materialises a candidate molecule up
                             front: candidates are *edit descriptors*
                             (kind + detail against the parent), the
                             valence / ring-size / O-H-protection filters
                             run as array masks over those descriptors, the
                             isomorphism dedup hashes padded candidate
                             arrays built directly from the edits, and the
                             returned ``Action``s materialise their
                             ``result`` lazily — in the rollout engine only
                             the *chosen* action ever builds a full
                             ``Molecule``.  (The only eager materialisation
                             is full bond removals, which may drop a
                             fragment and re-index atoms.)
``enumerate_actions_ref``    the previous vectorised materialise-then-filter
                             implementation — kept as the CORRECTNESS
                             REFERENCE: tests pin ``enumerate_actions`` to
                             produce the identical action list (same order,
                             same details, same concrete result arrays).
``enumerate_actions_naive``  a deliberately line-by-line port of the
                             original Python loop structure, kept as the
                             baseline for ``benchmarks/bench_env.py``.

All three return identical action sets (asserted by tests/property tests).
"""

from __future__ import annotations

from typing import Literal

import numpy as np

from repro.chem.molecule import (
    ALLOWED_RING_SIZES,
    ELEMENT_INDEX,
    ELEMENTS,
    MAX_BOND_ORDER,
    VALENCES,
    Molecule,
    iso_hashes_from_padded,
)

ActionKind = Literal["no_op", "add_atom", "bond_delta"]


def apply_edit(parent: Molecule, kind: str, detail: tuple) -> Molecule:
    """Materialise the molecule an edit descriptor produces.

    The single place that defines what (kind, detail) MEANS; both the eager
    reference enumerator and lazy ``Action.result`` go through the same
    mutators, so the two paths produce byte-identical molecules.
    """
    if kind == "no_op":
        return parent
    if kind == "add_atom":
        sym, anchor, order = detail
        if anchor < 0:                      # add to the empty molecule
            return Molecule.from_element(sym)
        return parent.with_added_atom(sym, int(anchor), int(order))
    if kind == "bond_delta":
        i, j, delta = detail
        cand = parent.with_bond_delta(int(i), int(j), int(delta))
        if delta < 0:
            cand = cand.largest_fragment()  # paper Fig. 6: drop fragments
        return cand
    raise ValueError(f"unknown action kind {kind!r}")


class Action:
    """A molecule edit.  ``result`` is the post-edit molecule.

    ``result`` may be LAZY: when constructed with a parent molecule instead
    of a result, the edit in ``detail`` is applied on first access (and
    cached).  The rollout engine exploits this — of the ~10^2 candidates per
    step only the chosen one is ever materialised.

    detail: add_atom ``(element_symbol, anchor, order)`` (anchor -1 = add to
    the empty molecule); bond_delta ``(i, j, delta)`` (negative delta =
    decrease / removal).
    """

    __slots__ = ("kind", "detail", "_result", "_parent")

    def __init__(self, kind: ActionKind, result: Molecule | None = None,
                 detail: tuple = (), *, parent: Molecule | None = None):
        if result is None and parent is None:
            raise ValueError("Action needs a result or a parent to derive it from")
        self.kind = kind
        self.detail = detail
        self._result = result
        self._parent = parent

    @property
    def result(self) -> Molecule:
        if self._result is None:
            # benign race under the pipelined rollout's host threads: both
            # compute equal molecules, the attribute write is atomic
            self._result = apply_edit(self._parent, self.kind, self.detail)
        return self._result

    @property
    def materialized(self) -> bool:
        return self._result is not None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        tail = f"-> {self._result.heavy_formula()}" if self._result is not None \
            else "(lazy)"
        return f"Action({self.kind}, {self.detail}, {tail})"


_O = ELEMENT_INDEX["O"]


def enumerate_actions(
    mol: Molecule,
    *,
    allow_removal: bool = True,
    allow_no_op: bool = True,
    protect_oh: bool = True,
    allowed_ring_sizes: frozenset[int] = ALLOWED_RING_SIZES,
    max_atoms: int = 38,
) -> list[Action]:
    """Delta enumeration of all valid single-edit actions (§3.6).

    Pinned to return the identical action list as
    :func:`enumerate_actions_ref` (same order, details and concrete result
    molecules) while doing the valence / ring / O-H-protection filtering on
    edit-descriptor arrays and deferring ``Molecule`` construction to
    ``Action.result``.
    """
    n = mol.num_atoms
    if n == 0:
        # tiny fixed case: reuse the reference path verbatim
        return enumerate_actions_ref(
            mol, allow_removal=allow_removal, allow_no_op=allow_no_op,
            protect_oh=protect_oh, allowed_ring_sizes=allowed_ring_sizes,
            max_atoms=max_atoms)

    fv = np.asarray(mol.free_valences(), dtype=np.int64)
    el = mol.elements.astype(np.int64)
    oh_mask = (el == _O) & (fv >= 1)
    n_oh = int(oh_mask.sum())

    # ---- edit descriptors, generated in the reference order -------------- #
    # columns: cat (0 no_op / 1 add_atom / 2 bond_delta / 3 frag-removal),
    # p1/p2/p3 (add: anchor, element, order; bond: i, j, signed delta),
    # oh (True = at least one O-H survives the edit)
    cats: list[np.ndarray] = []
    p1s: list[np.ndarray] = []
    p2s: list[np.ndarray] = []
    p3s: list[np.ndarray] = []
    ohs: list[np.ndarray] = []

    def _push(cat, p1, p2, p3, oh):
        k = len(p1)
        cats.append(np.full(k, cat, dtype=np.int64))
        p1s.append(np.asarray(p1, dtype=np.int64))
        p2s.append(np.asarray(p2, dtype=np.int64))
        p3s.append(np.asarray(p3, dtype=np.int64))
        ohs.append(np.asarray(oh, dtype=bool))

    def _expand(counts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(group index, 1-based position) pairs for 1..counts[g] per group."""
        counts = np.maximum(counts, 0)
        rep = np.repeat(np.arange(counts.size), counts)
        pos = np.arange(rep.size) - np.repeat(np.cumsum(counts) - counts, counts) + 1
        return rep, pos

    if allow_no_op:
        _push(0, [0], [0], [0], [True])  # no_op always survives protection

    # ---- atom additions: anchor-major, element, then order --------------- #
    if n < max_atoms:
        anchors = np.nonzero(fv >= 1)[0]
        if anchors.size:
            val = np.minimum(np.asarray(VALENCES, dtype=np.int64), MAX_BOND_ORDER)
            maxo = np.minimum(fv[anchors][:, None], val[None, :])   # [A, 3]
            rep, order = _expand(maxo.ravel())
            anchor = anchors[rep // len(ELEMENTS)]
            elem = rep % len(ELEMENTS)
            lost = oh_mask[anchor] & (fv[anchor] - order < 1)
            gained = (elem == _O) & (order == 1)    # new O keeps an H iff order 1
            _push(1, anchor, elem, order,
                  (n_oh - lost.astype(np.int64) + gained.astype(np.int64)) > 0)

    # ---- bond additions / increases: triu pair-major, delta inner -------- #
    iu, ju = np.triu_indices(n, k=1)
    if iu.size:
        bij = mol.bonds[iu, ju].astype(np.int64)
        maxd = np.minimum(np.minimum(fv[iu], fv[ju]), MAX_BOND_ORDER - bij)
        ok = maxd >= 1
        unbonded = bij == 0
        if bool(np.any(ok & unbonded)):
            # new-ring rule: bond between already-connected atoms closes a
            # ring of size (hop distance + 1), only 3/5/6 allowed
            d = mol.all_pairs_shortest_paths()[iu, ju].astype(np.int64)
            ring_ok = (d < 0) | np.isin(d + 1, sorted(allowed_ring_sizes))
            ok &= ~unbonded | ring_ok
        pairs = np.nonzero(ok)[0]
        if pairs.size:
            rep, delta = _expand(maxd[pairs])
            bi, bj = iu[pairs][rep], ju[pairs][rep]
            lost_i = oh_mask[bi] & (fv[bi] - delta < 1)
            lost_j = oh_mask[bj] & (fv[bj] - delta < 1)
            _push(2, bi, bj, delta,
                  (n_oh - lost_i.astype(np.int64) - lost_j.astype(np.int64)) > 0)

    # ---- bond decreases / removals: bonded pair-major, delta inner ------- #
    frag_results: dict[int, Molecule] = {}   # candidate row -> materialised
    if allow_removal:
        ri, rj = np.nonzero(np.triu(mol.bonds))
        if ri.size:
            orders = mol.bonds[ri, rj].astype(np.int64)
            rep, delta = _expand(orders)
            di, dj = ri[rep], rj[rep]
            full = delta == orders[rep]         # bond disappears entirely
            # partial decreases keep the bond (and therefore every atom):
            # an O at zero free valence gains an H, nothing loses one
            gain_i = (el[di] == _O) & (fv[di] == 0)
            gain_j = (el[dj] == _O) & (fv[dj] == 0)
            oh = (n_oh + gain_i.astype(np.int64) + gain_j.astype(np.int64)) > 0
            keep_rows = np.ones(di.size, dtype=bool)
            base = sum(len(c) for c in cats)
            for k in np.nonzero(full)[0]:
                # full removal may disconnect the graph: materialise (few
                # candidates, <= one per bonded pair) and check the fragment
                cand = apply_edit(mol, "bond_delta",
                                  (int(di[k]), int(dj[k]), -int(delta[k])))
                if cand.num_atoms == 0:
                    keep_rows[k] = False
                    continue
                frag_results[base + int(np.count_nonzero(keep_rows[:k]))] = cand
                oh[k] = cand.has_oh_bond()
            _push(2, di[keep_rows], dj[keep_rows], -delta[keep_rows], oh[keep_rows])
            if full[keep_rows].any():
                cat_arr = cats[-1]
                cat_arr[np.nonzero(full[keep_rows])[0]] = 3

    if not cats:
        return []
    cat = np.concatenate(cats)
    p1 = np.concatenate(p1s)
    p2 = np.concatenate(p2s)
    p3 = np.concatenate(p3s)
    oh_ok = np.concatenate(ohs)

    # ---- O-H protection on the descriptor arrays (§3.3) ------------------ #
    # Protection status is an isomorphism invariant, so filtering before the
    # dedup keeps exactly the reference's dedup-then-protect output set.
    keep = oh_ok if protect_oh else np.ones(cat.size, dtype=bool)
    if not bool(keep.any()):
        # reference fallback: nothing survives protection -> first candidate
        return [_materialize(mol, int(cat[0]), int(p1[0]), int(p2[0]),
                             int(p3[0]), frag_results.get(0))]
    surv = np.nonzero(keep)[0]

    # ---- isomorphism dedup over padded arrays built from the edits ------- #
    C = surv.size
    scat, s1, s2, s3 = cat[surv], p1[surv], p2[surv], p3[surv]
    sizes = np.full(C, n, dtype=np.int64)
    sizes[scat == 1] = n + 1
    for r, row in enumerate(surv):
        if cat[row] == 3:
            sizes[r] = frag_results[int(row)].num_atoms
    m = max(int(sizes.max()), 1)
    el_pad = np.full((C, m), 3, dtype=np.int64)          # 3 = padding element
    bonds_pad = np.zeros((C, m, m), dtype=np.int8)
    shared = scat != 3                                    # parent-frame rows
    el_pad[shared, :n] = el
    bonds_pad[shared, :n, :n] = mol.bonds
    rows = np.nonzero(scat == 1)[0]
    if rows.size:                                         # atom additions
        el_pad[rows, n] = s2[rows]
        bonds_pad[rows, n, s1[rows]] = s3[rows].astype(np.int8)
        bonds_pad[rows, s1[rows], n] = s3[rows].astype(np.int8)
    rows = np.nonzero(scat == 2)[0]
    if rows.size:                                         # bond order edits
        nv = (mol.bonds[s1[rows], s2[rows]] + s3[rows]).astype(np.int8)
        bonds_pad[rows, s1[rows], s2[rows]] = nv
        bonds_pad[rows, s2[rows], s1[rows]] = nv
    for r, row in enumerate(surv):
        if cat[row] == 3:                                 # fragment survivors
            frag = frag_results[int(row)]
            k = frag.num_atoms
            el_pad[r, :k] = frag.elements
            bonds_pad[r, :k, :k] = frag.bonds
    hashes = iso_hashes_from_padded(el_pad, bonds_pad, sizes)

    out: list[Action] = []
    seen: set[int] = set()
    for r, row in enumerate(surv.tolist()):
        h = int(hashes[r])
        if h in seen:
            continue
        seen.add(h)
        out.append(_materialize(mol, int(cat[row]), int(p1[row]), int(p2[row]),
                                int(p3[row]), frag_results.get(row)))
    return out


def _materialize(mol: Molecule, cat: int, p1: int, p2: int, p3: int,
                 frag: Molecule | None) -> Action:
    """Edit descriptor -> Action (lazy except fragment removals)."""
    if cat == 0:
        return Action("no_op", mol, ())
    if cat == 1:
        return Action("add_atom", None, (ELEMENTS[p2], p1, p3), parent=mol)
    if cat == 3:
        return Action("bond_delta", frag, (p1, p2, p3))
    return Action("bond_delta", None, (p1, p2, p3), parent=mol)


def enumerate_actions_ref(
    mol: Molecule,
    *,
    allow_removal: bool = True,
    allow_no_op: bool = True,
    protect_oh: bool = True,
    allowed_ring_sizes: frozenset[int] = ALLOWED_RING_SIZES,
    max_atoms: int = 38,
) -> list[Action]:
    """Materialise-then-filter enumeration — the CORRECTNESS REFERENCE.

    Builds every candidate ``Molecule`` eagerly, dedups, then applies the
    O-H protection on the materialised results.  ``enumerate_actions`` (the
    delta path) is pinned to this output action-for-action.
    """
    actions: list[Action] = []
    if allow_no_op:
        actions.append(Action("no_op", mol, ()))

    n = mol.num_atoms
    if n == 0:
        for sym in ELEMENTS:
            actions.append(Action("add_atom", Molecule.from_element(sym), (sym, -1, 0)))
        return _protect(actions, protect_oh)

    fv = mol.free_valences()

    # ---- atom additions (vectorised over anchors) ----------------------- #
    if n < max_atoms:
        anchors = np.nonzero(fv >= 1)[0]
        for a in anchors:
            a = int(a)
            for ei, sym in enumerate(ELEMENTS):
                max_order = min(int(fv[a]), VALENCES[ei], MAX_BOND_ORDER)
                for order in range(1, max_order + 1):
                    actions.append(
                        Action("add_atom", mol.with_added_atom(sym, a, order), (sym, a, order))
                    )

    # ---- bond additions / increases -------------------------------------- #
    # Candidate pairs where both ends have free valence.  For unbonded pairs
    # we must respect the ring-size rule; for already-bonded pairs an order
    # increase never creates a new ring.
    cap = np.minimum.outer(fv, fv)          # max possible delta per pair
    iu, ju = np.triu_indices(n, k=1)
    sp = None
    for i, j in zip(iu.tolist(), ju.tolist()):
        max_delta = int(min(cap[i, j], MAX_BOND_ORDER - int(mol.bonds[i, j])))
        if max_delta < 1:
            continue
        if mol.bonds[i, j] == 0:
            # would close a ring iff i..j already connected
            if sp is None:
                sp = mol.all_pairs_shortest_paths()
            d = int(sp[i, j])
            if d >= 0 and (d + 1) not in allowed_ring_sizes:
                continue
        for delta in range(1, max_delta + 1):
            actions.append(Action("bond_delta", mol.with_bond_delta(i, j, delta), (i, j, delta)))

    # ---- bond decreases / removals ---------------------------------------- #
    if allow_removal:
        for i, j in zip(*np.nonzero(np.triu(mol.bonds))):
            i, j = int(i), int(j)
            order = int(mol.bonds[i, j])
            for delta in range(1, order + 1):
                cand = mol.with_bond_delta(i, j, -delta).largest_fragment()
                if cand.num_atoms == 0:
                    continue
                actions.append(Action("bond_delta", cand, (i, j, -delta)))

    return _protect(_dedup(actions), protect_oh)


def enumerate_actions_naive(
    mol: Molecule,
    *,
    allow_removal: bool = True,
    allow_no_op: bool = True,
    protect_oh: bool = True,
    allowed_ring_sizes: frozenset[int] = ALLOWED_RING_SIZES,
    max_atoms: int = 38,
) -> list[Action]:
    """Line-by-line port of the original Python MolDQN enumeration.

    Intentionally unoptimised: per-pair BFS, per-candidate full validity
    re-checks, no vectorisation.  Kept as the performance baseline that the
    paper's C++ port (and our vectorised path) is measured against.
    """
    actions: list[Action] = []
    if allow_no_op:
        actions.append(Action("no_op", mol, ()))
    if mol.num_atoms == 0:
        for sym in ELEMENTS:
            actions.append(Action("add_atom", Molecule.from_element(sym), (sym, -1, 0)))
        return _protect(actions, protect_oh)

    # atom additions -- python loops, recomputing free valence every time
    if mol.num_atoms < max_atoms:
        for a in range(mol.num_atoms):
            for ei, sym in enumerate(ELEMENTS):
                for order in range(1, MAX_BOND_ORDER + 1):
                    if order > VALENCES[ei]:
                        continue
                    if mol.free_valence(a) < order:  # recomputed per candidate
                        continue
                    cand = mol.with_added_atom(sym, a, order)
                    cand.check_valences()
                    actions.append(Action("add_atom", cand, (sym, a, order)))

    # bond additions -- per-pair BFS instead of one all-pairs pass
    for i in range(mol.num_atoms):
        for j in range(i + 1, mol.num_atoms):
            for delta in range(1, MAX_BOND_ORDER + 1):
                if mol.free_valence(i) < delta or mol.free_valence(j) < delta:
                    continue
                if int(mol.bonds[i, j]) + delta > MAX_BOND_ORDER:
                    continue
                if mol.bonds[i, j] == 0:
                    d = mol.shortest_path_length(i, j)
                    if d >= 0 and (d + 1) not in allowed_ring_sizes:
                        continue
                cand = mol.with_bond_delta(i, j, delta)
                cand.check_valences()
                actions.append(Action("bond_delta", cand, (i, j, delta)))

    # bond removals
    if allow_removal:
        for i in range(mol.num_atoms):
            for j in range(i + 1, mol.num_atoms):
                order = int(mol.bonds[i, j])
                for delta in range(1, order + 1):
                    cand = mol.with_bond_delta(i, j, -delta).largest_fragment()
                    if cand.num_atoms == 0:
                        continue
                    cand.check_valences()
                    actions.append(Action("bond_delta", cand, (i, j, -delta)))

    return _protect(_dedup_naive(actions), protect_oh)


def _dedup_naive(actions: list[Action]) -> list[Action]:
    """Per-candidate canonical-serialisation dedup — the original MolDQN
    approach (canonical SMILES per candidate via RDKit).  Baseline for
    ``benchmarks/bench_env.py``; same output set as :func:`_dedup`."""
    seen: set[str] = set()
    out: list[Action] = []
    for a in actions:
        key = a.result.canonical_key()
        if key in seen:
            continue
        seen.add(key)
        out.append(a)
    return out


def _dedup(actions: list[Action]) -> list[Action]:
    """Drop actions yielding isomorphic molecules (keep first occurrence).

    Hashes every candidate in ONE padded batch (``iso_hashes_batch``) —
    equal graphs always collide, distinct graphs collide with ~2^-64
    probability, which is acceptable for pruning a candidate list.
    """
    from repro.chem.molecule import iso_hashes_batch

    keys = iso_hashes_batch([a.result for a in actions])
    seen: set[int] = set()
    out: list[Action] = []
    for a, key in zip(actions, keys):
        if key in seen:
            continue
        seen.add(key)
        out.append(a)
    return out


def _protect(actions: list[Action], protect_oh: bool) -> list[Action]:
    if not protect_oh:
        return actions
    kept = [a for a in actions if a.kind == "no_op" or a.result.has_oh_bond()]
    # Never return an empty action set: no-op always survives if present.
    return kept if kept else actions[:1]
