"""A SMILES-subset codec for {C, N, O} molecules with implicit hydrogens.

Alfabet "accepts SMILES representation of molecules as input" (§2.2), the
datasets are SMILES files, and every figure in the paper renders molecules —
so the framework needs a text codec.  We implement the subset the action
space can produce: elements C/N/O, bond orders 1-3 (``-``/``=``/``#``,
single implicit), branches ``( )``, ring closures ``1``-``9`` and ``%nn``.
No aromatics (lowercase), charges, stereo or isotopes — the MolDQN action
space never creates them.

``canonical_smiles`` serialises from the molecule's canonical atom order, so
equal graphs produce equal strings (used for dataset files, the LRU cache
key and dedup).
"""

from __future__ import annotations

import numpy as np

from repro.chem.molecule import (
    ELEMENT_INDEX,
    ELEMENTS,
    Molecule,
    refine_invariants,
    _canonical_order,
)

_BOND_CHARS = {1: "", 2: "=", 3: "#"}
_CHAR_BONDS = {"-": 1, "=": 2, "#": 3}


def to_smiles(mol: Molecule, order: list[int] | None = None) -> str:
    """Serialise a molecule (DFS with ring-closure digits)."""
    n = mol.num_atoms
    if n == 0:
        return ""
    if order is None:
        order = list(range(n))
    rank = {a: r for r, a in enumerate(order)}

    visited: set[int] = set()
    ring_bonds: dict[tuple[int, int], int] = {}   # (i,j) sorted -> closure no
    closure_counter = [0]

    # Pre-pass: find DFS tree edges vs ring-closure edges.
    tree_children: dict[int, list[int]] = {a: [] for a in range(n)}
    closures_at: dict[int, list[tuple[int, int]]] = {a: [] for a in range(n)}

    def explore(u: int, parent: int) -> None:
        visited.add(u)
        nbrs = sorted((int(v) for v in np.nonzero(mol.bonds[u])[0]), key=lambda v: rank[v])
        for v in nbrs:
            if v not in visited:
                tree_children[u].append(v)
                explore(v, u)
            elif v != parent:
                key = (min(u, v), max(u, v))
                if key not in ring_bonds:
                    closure_counter[0] += 1
                    num = closure_counter[0]
                    ring_bonds[key] = num
                    closures_at[u].append((v, num))
                    closures_at[v].append((u, num))

    roots = []
    for a in sorted(range(n), key=lambda x: rank[x]):
        if a not in visited:
            roots.append(a)
            explore(a, -1)

    emitted: set[int] = set()

    def write(u: int, parent: int) -> str:
        emitted.add(u)
        s = ""
        if parent >= 0:
            s += _BOND_CHARS[int(mol.bonds[parent, u])]
        s += ELEMENTS[int(mol.elements[u])]
        for v, num in closures_at[u]:
            key = (min(u, v), max(u, v))
            bond = _BOND_CHARS[int(mol.bonds[u, v])]
            tag = str(num) if num < 10 else f"%{num:02d}"
            # bond char goes on the first occurrence only (we put it on both
            # sides is illegal; standard allows either side — emit on opener)
            s += (bond if v not in emitted else "") + tag
        kids = tree_children[u]
        for k, v in enumerate(kids):
            if k < len(kids) - 1:
                s += "(" + write(v, u) + ")"
            else:
                s += write(v, u)
        return s

    return ".".join(write(r, -1) for r in roots)


def canonical_smiles(mol: Molecule) -> str:
    """SMILES from the canonical atom ordering — equal graphs, equal strings."""
    if mol.num_atoms == 0:
        return ""
    inv = refine_invariants(mol)
    order = _canonical_order(mol, inv)
    return to_smiles(mol, order)


def from_smiles(s: str) -> Molecule:
    """Parse the SMILES subset emitted by :func:`to_smiles`."""
    s = s.strip()
    if not s:
        return Molecule.empty()
    elements: list[int] = []
    bonds: list[tuple[int, int, int]] = []
    ring_open: dict[int, tuple[int, int]] = {}  # closure -> (atom, order)

    stack: list[int] = []
    prev = -1
    pending_order = 1
    i = 0
    while i < len(s):
        c = s[i]
        if c in _CHAR_BONDS:
            pending_order = _CHAR_BONDS[c]
            i += 1
        elif c == "(":
            stack.append(prev)
            i += 1
        elif c == ")":
            prev = stack.pop()
            i += 1
        elif c == ".":
            prev = -1
            pending_order = 1
            i += 1
        elif c.isdigit() or c == "%":
            if c == "%":
                num = int(s[i + 1 : i + 3])
                i += 3
            else:
                num = int(c)
                i += 1
            if num in ring_open:
                a, order0 = ring_open.pop(num)
                order = max(order0, pending_order)
                bonds.append((a, prev, order))
            else:
                ring_open[num] = (prev, pending_order)
            pending_order = 1
        elif c in ELEMENT_INDEX:
            idx = len(elements)
            elements.append(ELEMENT_INDEX[c])
            if prev >= 0:
                bonds.append((prev, idx, pending_order))
            prev = idx
            pending_order = 1
            i += 1
        elif c == "H":  # explicit H in brackets unsupported; skip bare H
            i += 1
        else:
            raise ValueError(f"unsupported SMILES char {c!r} in {s!r}")

    if ring_open:
        raise ValueError(f"unclosed ring closures {sorted(ring_open)} in {s!r}")
    n = len(elements)
    bm = np.zeros((n, n), dtype=np.int8)
    for a, b, o in bonds:
        bm[a, b] = bm[b, a] = o
    mol = Molecule(np.array(elements, dtype=np.int8), bm)
    mol.check_valences()
    return mol
