"""3D-conformer validity + pseudo-conformer features (the RDKit/ETKDG stand-in).

AIMNet-NSE consumes 3D conformers; MolDQN-generated molecules are only
guaranteed valid as 2D graphs, and some have *no* valid 3D embedding
(paper §3.3, Appendix B).  The paper's fix is not a rule system — it sets the
reward of conformer-less molecules to −1000 and lets the agent learn to
avoid them.  To reproduce that dynamic we need a deterministic "embedder"
that (a) fails on strained structures the way distance geometry does, and
(b) produces coordinates for the IP predictor otherwise.

Validity model (deterministic, strain-motivated — mirrors the classes of
failures App. B shows):

* any atom in >= 3 rings (bridgehead over-constraint);
* two rings of size <= 4 sharing an edge (fused cyclopropane strain);
* a triple bond inside any ring (sp centre forced to bend);
* an sp centre (two double bonds or a triple) inside a ring of size <= 5;
* a ring of size 3 containing any double bond plus a substituted atom of
  degree 4 (over-pyramidalised).

Pseudo-coordinates: spectral embedding — the 3 non-trivial eigenvectors of
the graph Laplacian scaled by bond lengths.  Deterministic, O(n^3), and
smooth under single edits, which is all the surrogate IP net needs.
"""

from __future__ import annotations

import numpy as np

from repro.chem.molecule import Molecule


def has_valid_conformer(mol: Molecule) -> bool:
    """Deterministic distance-geometry-style feasibility check."""
    n = mol.num_atoms
    if n == 0:
        return False
    rings = mol.ring_info()
    ring_sets = [frozenset(r) for r in rings]
    membership = np.zeros(n, dtype=np.int32)
    for r in ring_sets:
        for a in r:
            membership[a] += 1

    # bridgehead over-constraint
    if np.any(membership >= 3):
        return False

    # fused small rings sharing an edge
    for a in range(len(ring_sets)):
        for b in range(a + 1, len(ring_sets)):
            shared = ring_sets[a] & ring_sets[b]
            if len(shared) >= 2 and min(len(ring_sets[a]), len(ring_sets[b])) <= 4:
                return False

    in_ring_pair = np.zeros((n, n), dtype=bool)
    for r in rings:
        rs = list(r)
        for x in range(len(rs)):
            for y in range(x + 1, len(rs)):
                in_ring_pair[rs[x], rs[y]] = in_ring_pair[rs[y], rs[x]] = True

    for i in range(n):
        orders = mol.bonds[i][mol.bonds[i] > 0]
        n_double = int(np.sum(orders == 2))
        n_triple = int(np.sum(orders == 3))
        is_sp = n_triple >= 1 or n_double >= 2
        if membership[i] >= 1:
            ring_sizes = [len(r) for r in ring_sets if i in r]
            # triple bond in a ring
            if n_triple >= 1:
                return False
            # sp centre (cumulene) in small ring
            if is_sp and min(ring_sizes) <= 5:
                return False
            # strained substituted cyclopropene
            if min(ring_sizes) == 3 and n_double >= 1 and mol.degree(i) >= 4:
                return False
    return True


# idealised bond lengths (angstrom-ish), order-indexed
_BOND_LEN = {1: 1.5, 2: 1.34, 3: 1.2}


def conformer_coordinates(mol: Molecule) -> np.ndarray:
    """Deterministic pseudo-3D coordinates: weighted-Laplacian spectral embed.

    float64[n, 3].  Raises ValueError if the molecule has no valid conformer
    (mirrors an RDKit embed failure).
    """
    if not has_valid_conformer(mol):
        raise ValueError("no valid 3D conformer")
    n = mol.num_atoms
    if n == 1:
        return np.zeros((1, 3))
    w = np.zeros((n, n))
    for i in range(n):
        for j in np.nonzero(mol.bonds[i])[0]:
            w[i, j] = 1.0 / _BOND_LEN[int(mol.bonds[i, j])]
    lap = np.diag(w.sum(axis=1)) - w
    vals, vecs = np.linalg.eigh(lap)
    # skip the trivial 0-eigenvector(s); take next three, pad if tiny
    order = np.argsort(vals)
    nontrivial = [k for k in order if vals[k] > 1e-9][:3]
    coords = np.zeros((n, 3))
    for d, k in enumerate(nontrivial):
        coords[:, d] = vecs[:, k] / np.sqrt(max(vals[k], 1e-9))
    # scale to mean bond length ~1.5
    dists = [np.linalg.norm(coords[i] - coords[j])
             for i in range(n) for j in np.nonzero(mol.bonds[i])[0] if j > i]
    if dists and np.mean(dists) > 1e-12:
        coords *= 1.5 / np.mean(dists)
    return coords


CONFORMER_FEATURE_DIM = 8


def conformer_features(mol: Molecule, max_atoms: int) -> np.ndarray:
    """Per-atom geometric features for the IP predictor (AIMNet-S input).

    float32[max_atoms, CONFORMER_FEATURE_DIM]:
    radial distance from centroid, local crowding (#atoms within 2.2A),
    mean/min neighbour distance, coordination shell stats.
    Raises ValueError when no valid conformer exists (callers translate this
    to the paper's -1000 reward).
    """
    coords = conformer_coordinates(mol)
    n = mol.num_atoms
    out = np.zeros((max_atoms, CONFORMER_FEATURE_DIM), dtype=np.float32)
    centroid = coords.mean(axis=0)
    d2c = np.linalg.norm(coords - centroid, axis=1)
    pair = np.linalg.norm(coords[:, None, :] - coords[None, :, :], axis=-1)
    np.fill_diagonal(pair, np.inf)
    for i in range(n):
        out[i, 0] = d2c[i]
        out[i, 1] = float(np.sum(pair[i] < 2.2))
        finite = pair[i][np.isfinite(pair[i])]
        out[i, 2] = float(finite.mean()) if finite.size else 0.0
        out[i, 3] = float(finite.min()) if finite.size else 0.0
        bonded = np.nonzero(mol.bonds[i])[0]
        if bonded.size:
            out[i, 4] = float(pair[i, bonded].mean())
            out[i, 5] = float(pair[i, bonded].max())
        out[i, 6] = float(np.sum(pair[i] < 3.0))
        out[i, 7] = float(coords[i, 2])
    return out
