"""Three-term roofline from a compiled (dry-run) artifact.

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_bytes_per_chip / link_bw

Sources: ``compiled.cost_analysis()`` supplies flops and bytes for the
*per-partition* (post-SPMD) module, so the per-chip division is already
done.  Collective bytes are NOT in cost_analysis — we parse the partitioned
HLO text and sum the RESULT-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (result-shape convention:
for all-reduce it equals the operand; for all-gather it is the gathered
output a chip actually moves through its links; ragged variants count the
dense bound).  Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per chip, FLOP/s (bf16)
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per ICI link


HW_V5E = Hardware("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of 'bf16[128,4096]' etc.; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind from (partitioned) HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g.  %all-reduce.1 = f32[1024]{0} all-reduce(f32[1024]{0} %x), ...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        result_shape, opname = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if opname == kind or opname.startswith(kind + "-"):
                out[kind] += _shape_bytes(result_shape)
                break
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collectives: dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0          # 6*N*D (or 6*N_active*D for MoE)
    memory_per_chip: float = 0.0      # bytes (from memory_analysis)

    hw: Hardware = HW_V5E

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / self.hw.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/redundancy waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "collectives": self.collectives,
            "model_flops": self.model_flops,
            "memory_per_chip": self.memory_per_chip,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def roofline_terms(
    *, arch: str, shape: str, mesh_desc: str, chips: int,
    cost: dict, hlo_text: str, model_flops: float, memory_per_chip: float = 0.0,
) -> RooflineReport:
    """Terms from the trip-count-aware HLO walk (see ``hlo_walk``).

    ``cost_analysis()`` is kept as a cross-check input but NOT used for the
    totals: XLA counts every while body once, so layer-scanned models would
    under-report by ~n_layers (measured and unit-tested in hlo_walk)."""
    from repro.roofline.hlo_walk import aggregate
    agg = aggregate(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        flops_per_chip=float(agg["flops"]),
        bytes_per_chip=float(agg["bytes"]),
        collective_bytes_per_chip=float(agg["collective_bytes"]),
        collectives={k: int(v) for k, v in agg["collectives"].items()},
        model_flops=model_flops,
        memory_per_chip=memory_per_chip,
    )


def estimate_hbm_per_chip(cfg, shape, *, tp: int, dp: int, zero_opt: bool = False,
                          microbatches: int = 1, fsdp: bool = False) -> dict:
    """Analytic per-chip HBM occupancy for the fits-proof.

    The CPU backend legalizes bf16 arithmetic to f32 (converts + f32 copies
    of whole buffers — dissected in EXPERIMENTS.md §Dry-run), so
    ``memory_analysis()`` over-reports bf16 models by up to 2x vs a real
    TPU compile.  This estimate models what the TPU allocator would hold:

      params/chip + optimizer moments/chip (f32 x2) + token batch
      + rematted residual stack (L x B_loc x S x d_model x 2B)
      + KV/state cache (decode)
      + peak transient (attention block scores, MLP/MoE intermediates,
        loss chunk logits) x 1.5 scheduling slack
    """
    from repro.models.model import count_params
    import math

    dtype_b = 2 if cfg.dtype == "bfloat16" else 4
    n_params = count_params(cfg)
    shard = tp * (dp if fsdp else 1)
    params_b = n_params * dtype_b / shard
    B_loc = max(shape.global_batch // dp, 1)
    S = shape.seq_len
    D = cfg.d_model
    L = cfg.n_layers

    out = {"params": params_b}
    if shape.kind == "train":
        mu = max(microbatches, 1)
        B_mu = max(B_loc // mu, 1)
        out["opt"] = 2 * n_params * 4 / tp / (dp if (zero_opt or fsdp) else 1)
        out["residuals"] = L * B_mu * S * D * dtype_b
        if cfg.encdec is not None:
            out["residuals"] += cfg.encdec.n_enc_layers * B_mu * cfg.encdec.n_frames * D * dtype_b
        # transient peaks (largest of): attention score block (f32),
        # mlp/expert intermediates, loss-chunk logits (f32, vocab/tp)
        h_loc = max(cfg.n_heads // tp, 1)
        attn_t = B_mu * min(S, 1024) * S * h_loc * 4 * 2
        ff = cfg.d_ff if cfg.moe is None else cfg.d_ff * cfg.moe.top_k
        mlp_t = B_mu * S * max(ff // tp, 1) * dtype_b * 3
        loss_t = B_mu * min(S, 512) * max(cfg.vocab // tp, 1) * 4 * 3
        out["transient"] = 1.5 * max(attn_t, mlp_t, loss_t)
        out["grads"] = n_params * dtype_b / shard
        if mu > 1:
            out["grad_accum"] = n_params * dtype_b / shard
    elif shape.kind == "prefill":
        h_loc = max(cfg.n_heads // tp, 1)
        out["activations"] = B_loc * S * D * dtype_b * 4
        out["transient"] = 1.5 * B_loc * min(S, 1024) * S * h_loc * 4 * 2
    else:  # decode
        K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        Sc = S if cfg.attn_window is None else min(S, cfg.attn_window)
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            cache = L * B_loc * (Sc / (tp if Sc % tp == 0 else 1)) * K * Dh * dtype_b * 2
        else:
            d_inner = cfg.ssm.expand * D
            n_h = d_inner // cfg.ssm.head_dim
            cache = L * B_loc * max(n_h // tp, 1) * cfg.ssm.head_dim * cfg.ssm.state_dim * dtype_b
            if cfg.family == "hybrid":
                cache += B_loc * (Sc / (tp if Sc % tp == 0 else 1)) * K * Dh * dtype_b * 2
        out["cache"] = cache
        out["transient"] = B_loc * D * 64 * dtype_b
    out["total"] = float(sum(out.values()))
    return out


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D with N = active params (MoE: top-k experts only); D = tokens
    processed per step (decode: global_batch tokens)."""
    from repro.models.model import active_params
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
