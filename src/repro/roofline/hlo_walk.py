"""Trip-count-aware HLO cost walker.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so a model
that scans over 94 layers under-reports FLOPs (and collective bytes) by
~94x.  This walker parses the post-SPMD, post-optimization HLO text and
recursively aggregates per-computation costs, multiplying while-loop
bodies by their trip count (recovered from the largest integer constant in
the loop-condition computation — scan conditions are `i < constant(N)`).

Counted per executed op:
  * flops        — ``dot`` ops: 2 * prod(result_dims) * contraction_size
                   (operand shapes resolved through a per-computation
                   symbol table; this framework's HLO has no convolutions)
  * hbm bytes    — for materialising ops: result bytes + operand bytes
                   (fusion *internals* are skipped — temporaries inside a
                   fusion are not HBM traffic; the fusion op's own operands
                   and result are)
  * collectives  — result-shape bytes per kind, loop-multiplied.

Best-effort by design: it is a *roofline* model, not a simulator; tests
pin it against hand-counted modules.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops that only re-label buffers — no HBM traffic of their own
_ALIAS_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
              "after-all", "reshape", "add-dependency", "opt-barrier",
              "partition-id", "replica-id"}

_OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_ATTR_RE = re.compile(r"(body|condition|calls|to_apply|branch_computations)="
                      r"\{?([%\w.\-,\s]+?)\}?(?:,|$|\))")
_VAR_RE = re.compile(r"%([\w.\-]+)")


def _shapes_in(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Comp:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict[str, float] = field(default_factory=dict)
    whiles: list[tuple[str, str]] = field(default_factory=list)   # (body, cond)
    plain_calls: list[str] = field(default_factory=list)          # call/cond branches
    # fusion call records: (callee, result_bytes, [operand_bytes, ...])
    fusion_records: list[tuple[str, int, list[int]]] = field(default_factory=list)
    fusion_callees: list[str] = field(default_factory=list)
    max_const: int = 0
    # parameter index -> bytes actually read when the parameter is consumed
    # by a slice op inside this computation (scan weight streaming)
    sliced_params: dict[int, int] = field(default_factory=dict)
    param_vars: dict[str, int] = field(default_factory=dict)      # var -> index


def parse_hlo(text: str) -> tuple[dict[str, Comp], str]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    sym: dict[str, str] = {}
    entry = ""
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = Comp()
                comps[m.group(2)] = cur
                sym = {}
                if m.group(1):
                    entry = m.group(2)
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        var, rest = mo.group(1), mo.group(2)

        mop = _OPNAME_RE.search(rest)
        if not mop:
            continue
        opname = mop.group(1)
        result_str = rest[: mop.start()]
        args_str = rest[mop.end():]
        # cut args at the matching close-paren (attrs follow after)
        depth = 1
        for i, ch in enumerate(args_str):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    attrs_str = args_str[i + 1:]
                    args_str = args_str[:i]
                    break
        else:
            attrs_str = ""

        sym[var] = result_str

        if opname == "constant":
            mc = re.match(r"\s*(\d+)\s*", args_str)
            if mc and "s32" in result_str or "u32" in result_str or "s64" in result_str:
                if mc:
                    cur.max_const = max(cur.max_const, int(mc.group(1)))

        # sub-computation references
        attr_map: dict[str, list[str]] = {}
        for am in _ATTR_RE.finditer(attrs_str):
            names = [n.strip().lstrip("%") for n in am.group(2).split(",") if n.strip()]
            attr_map.setdefault(am.group(1), []).extend(names)
        if opname == "while":
            body = attr_map.get("body", [""])[0]
            cond = attr_map.get("condition", [""])[0]
            mt = _TRIP_RE.search(attrs_str)
            cur.whiles.append((body, cond if mt is None else f"#trips={mt.group(1)}"))
        elif opname == "fusion":
            callees = attr_map.get("calls", [])
            cur.fusion_callees.extend(callees)
            if callees:
                op_bytes = [_shape_bytes(sym.get(ov, "")) for ov in _VAR_RE.findall(args_str)]
                cur.fusion_records.append((callees[0], _shape_bytes(result_str), op_bytes))
        else:
            for key in ("calls", "to_apply", "branch_computations"):
                cur.plain_calls.extend(attr_map.get(key, []))

        if opname == "parameter":
            mi = re.match(r"\s*(\d+)\s*", args_str)
            if mi:
                cur.param_vars[var] = int(mi.group(1))
        if opname in ("dynamic-slice", "slice"):
            operands = _VAR_RE.findall(args_str)
            if operands and operands[0] in cur.param_vars:
                idx = cur.param_vars[operands[0]]
                cur.sliced_params[idx] = cur.sliced_params.get(idx, 0) + _shape_bytes(result_str)

        # dot flops
        if opname == "dot":
            res_elems = sum(
                _prod(dims) for _, dims in _shapes_in(result_str)) or 0
            operands = _VAR_RE.findall(args_str)
            contract = 1
            mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs_str)
            if mc and operands:
                lhs_shape = _shapes_in(sym.get(operands[0], ""))
                if lhs_shape:
                    dims = lhs_shape[0][1]
                    for d in mc.group(1).split(","):
                        if d and int(d) < len(dims):
                            contract *= dims[int(d)]
            cur.flops += 2.0 * res_elems * contract

        # collectives (result bytes)
        matched_coll = None
        for kind in _COLLECTIVES:
            if opname == kind or opname == kind + "-start":
                matched_coll = kind
                cur.collectives[kind] = cur.collectives.get(kind, 0.0) + _shape_bytes(result_str)
                break

        # hbm bytes (fusion ops handled via fusion_records in aggregate)
        if opname not in _ALIAS_OPS and opname != "fusion" and not opname.endswith("-done"):
            if opname == "dynamic-update-slice":
                # in-place semantics: traffic ~ 2x the updated region
                operands = _VAR_RE.findall(args_str)
                upd = _shape_bytes(sym.get(operands[1], "")) if len(operands) > 1 else 0
                cur.bytes += 2 * upd
            else:
                b = _shape_bytes(result_str)
                for ov in _VAR_RE.findall(args_str):
                    b += _shape_bytes(sym.get(ov, ""))
                cur.bytes += b
    return comps, entry


def _prod(dims: list[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def aggregate(text: str) -> dict:
    """Walk from ENTRY with while-loop multipliers.  Returns totals."""
    comps, entry = parse_hlo(text)
    memo: dict[str, tuple[float, float, dict[str, float]]] = {}

    def walk(name: str, depth: int = 0, *, fusion_ctx: bool = False):
        key = (name, fusion_ctx)
        if key in memo:
            return memo[key]
        c = comps.get(name)
        if c is None or depth > 64:
            return (0.0, 0.0, {})
        fl = c.flops
        by = 0.0 if fusion_ctx else c.bytes
        coll = dict(c.collectives)

        def acc(f2, b2, cl2, mult=1.0):
            nonlocal fl, by
            fl += mult * f2
            by += mult * b2
            for k, v in cl2.items():
                coll[k] = coll.get(k, 0.0) + mult * v

        # fusion call sites: result + operands, but operands the callee only
        # *slices* (scan weight streaming) count at the sliced size
        if not fusion_ctx:
            for callee, res_b, op_bytes in c.fusion_records:
                callee_comp = comps.get(callee, Comp())
                b = res_b
                for i, ob in enumerate(op_bytes):
                    b += min(ob, callee_comp.sliced_params[i]) \
                        if i in callee_comp.sliced_params else ob
                by += b

        for callee in c.plain_calls:
            acc(*walk(callee, depth + 1, fusion_ctx=fusion_ctx))
        for callee in c.fusion_callees:
            # fusion internals: flops only (temporaries are not HBM traffic)
            acc(*walk(callee, depth + 1, fusion_ctx=True))
        for body, cond in c.whiles:
            if cond.startswith("#trips="):
                trips = int(cond[len("#trips="):])
            else:
                trips = comps.get(cond, Comp()).max_const
            trips = max(trips, 1)
            acc(*walk(body, depth + 1, fusion_ctx=fusion_ctx), mult=trips)
            # condition itself runs trips+1 times but is negligible
        memo[key] = (fl, by, coll)
        return memo[key]

    fl, by, coll = walk(entry or next(iter(comps), ""))
    return {"flops": fl, "bytes": by, "collectives": coll,
            "collective_bytes": sum(coll.values())}
