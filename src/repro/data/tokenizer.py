"""Character-level SMILES tokenizer.

Used by the sequence-model examples (a SMILES LM as a property-predictor
backbone) and by the data pipeline.  The model-zoo configs keep their
source-paper vocab sizes for the dry-run; this tokenizer covers the actual
chem corpus and maps into the low end of any such vocab.
"""

from __future__ import annotations

import numpy as np

_FIXED = ["<pad>", "<bos>", "<eos>", "<unk>"]
_CHARS = list("CNO=#().%0123456789")


class SmilesTokenizer:
    PAD, BOS, EOS, UNK = 0, 1, 2, 3

    def __init__(self):
        self.vocab = _FIXED + _CHARS
        self.index = {t: i for i, t in enumerate(self.vocab)}

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    def encode(self, smiles: str, *, max_len: int | None = None, add_special: bool = True) -> np.ndarray:
        ids = [self.index.get(c, self.UNK) for c in smiles]
        if add_special:
            ids = [self.BOS] + ids + [self.EOS]
        if max_len is not None:
            ids = ids[:max_len]
            ids = ids + [self.PAD] * (max_len - len(ids))
        return np.asarray(ids, dtype=np.int32)

    def decode(self, ids: np.ndarray) -> str:
        out = []
        for i in np.asarray(ids).tolist():
            if i == self.EOS:
                break
            if i in (self.PAD, self.BOS):
                continue
            out.append(self.vocab[i] if 0 <= i < len(self.vocab) else "?")
        return "".join(out)
