"""Synthetic molecule datasets.

The paper's training set is "a random subset of 256 antioxidants ... from a
proprietary data set of over 500 antioxidant molecules" (§4.1) plus public
ChEMBL/AODB replays.  The proprietary set is unavailable by construction, so
this module *generates* structurally comparable sets:

* ``antioxidant_dataset`` — ~600 phenolic antioxidants (hindered phenols,
  aminophenols, bis-phenols...), the proprietary stand-in.  Split 256/128
  train/test with :func:`train_test_split` like §4.1/§4.3.
* ``public_antioxidant_dataset`` — a differently-distributed decoration mix
  (more polar groups, fewer hindered positions), the AODB/ChEMBL stand-in
  for the §4.4 replays.
* ``zinc_like_dataset`` — diverse non-phenolic drug-like molecules for the
  Appendix D QED/PlogP comparison (no O-H guarantee).

Everything is deterministic given the seed.  All generated molecules pass
``check_valences``, have a valid conformer, and (for the antioxidant sets)
contain at least one O-H bond.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.chem.conformer import has_valid_conformer
from repro.chem.molecule import ELEMENT_INDEX, Molecule
from repro.chem.oracle import oracle_bde, oracle_ip


# ------------------------------------------------------------------ #
# structural building blocks
# ------------------------------------------------------------------ #
def benzene() -> Molecule:
    """6-ring with alternating double bonds (kekulized benzene)."""
    el = np.zeros(6, dtype=np.int8)  # all C
    b = np.zeros((6, 6), dtype=np.int8)
    for k in range(6):
        b[k, (k + 1) % 6] = b[(k + 1) % 6, k] = 2 if k % 2 == 0 else 1
    return Molecule(el, b)


def cyclohexane() -> Molecule:
    el = np.zeros(6, dtype=np.int8)
    b = np.zeros((6, 6), dtype=np.int8)
    for k in range(6):
        b[k, (k + 1) % 6] = b[(k + 1) % 6, k] = 1
    return Molecule(el, b)


def _attach(mol: Molecule, anchor: int, fragment: str) -> Molecule:
    """Attach a named substituent to ``anchor``. Returns a new molecule."""
    if fragment == "hydroxy":                      # -OH
        return mol.with_added_atom("O", anchor, 1)
    if fragment == "amino":                        # -NH2
        return mol.with_added_atom("N", anchor, 1)
    if fragment == "methyl":                       # -CH3
        return mol.with_added_atom("C", anchor, 1)
    if fragment == "ethyl":                        # -CH2CH3
        m = mol.with_added_atom("C", anchor, 1)
        return m.with_added_atom("C", m.num_atoms - 1, 1)
    if fragment == "methoxy":                      # -OCH3
        m = mol.with_added_atom("O", anchor, 1)
        return m.with_added_atom("C", m.num_atoms - 1, 1)
    if fragment == "tbutyl":                       # -C(CH3)3
        m = mol.with_added_atom("C", anchor, 1)
        c = m.num_atoms - 1
        for _ in range(3):
            m = m.with_added_atom("C", c, 1)
        return m
    if fragment == "dimethylamino":                # -N(CH3)2
        m = mol.with_added_atom("N", anchor, 1)
        nn = m.num_atoms - 1
        m = m.with_added_atom("C", nn, 1)
        return m.with_added_atom("C", nn, 1)
    if fragment == "formyl":                       # -CH=O (EWG)
        m = mol.with_added_atom("C", anchor, 1)
        return m.with_added_atom("O", m.num_atoms - 1, 2)
    if fragment == "cyano":                        # -C#N (EWG)
        m = mol.with_added_atom("C", anchor, 1)
        return m.with_added_atom("N", m.num_atoms - 1, 3)
    raise ValueError(f"unknown fragment {fragment}")


_DONOR_FRAGMENTS = ["methyl", "ethyl", "methoxy", "tbutyl", "amino", "dimethylamino", "hydroxy"]
_EWG_FRAGMENTS = ["formyl", "cyano"]


def _ring_positions(n_ring: int = 6) -> list[int]:
    return list(range(n_ring))


def _make_phenol(rng: np.random.Generator, *, hindered_bias: float, polar_bias: float) -> Molecule:
    """One random phenolic antioxidant."""
    aromatic = rng.random() < 0.85
    mol = benzene() if aromatic else cyclohexane()
    # the phenolic OH
    oh_pos = 0
    mol = _attach(mol, oh_pos, "hydroxy")

    # decorate 1-4 other ring positions
    n_subs = int(rng.integers(1, 5))
    positions = rng.permutation([1, 2, 3, 4, 5])[:n_subs]
    for p in positions:
        if mol.free_valence(int(p)) < 1:
            continue
        r = rng.random()
        if r < hindered_bias:
            frag = rng.choice(["tbutyl", "methyl", "ethyl"], p=[0.5, 0.3, 0.2])
        elif r < hindered_bias + polar_bias:
            frag = rng.choice(["hydroxy", "methoxy", "amino", "dimethylamino"])
        elif r < hindered_bias + polar_bias + 0.12:
            frag = rng.choice(_EWG_FRAGMENTS)
        else:
            frag = rng.choice(["methyl", "methoxy"])
        mol = _attach(mol, int(p), str(frag))

    # occasionally fuse/append a second ring (bisphenol-like bridge)
    if rng.random() < 0.25 and mol.num_atoms <= 22:
        bridge_anchor = int(rng.choice([3, 4]))
        if mol.free_valence(bridge_anchor) >= 1:
            m = mol.with_added_atom("C", bridge_anchor, 1)
            c = m.num_atoms - 1
            ring2 = benzene()
            # splice second ring: append its atoms, bond c to its atom 0
            n0 = m.num_atoms
            el = np.concatenate([m.elements, ring2.elements])
            nb = np.zeros((el.shape[0], el.shape[0]), dtype=np.int8)
            nb[: n0, : n0] = m.bonds
            nb[n0:, n0:] = ring2.bonds
            nb[c, n0] = nb[n0, c] = 1
            mol = Molecule(el, nb)
            if rng.random() < 0.6:
                mol = _attach(mol, n0 + 3, "hydroxy")  # second phenolic OH

    return mol


def _generate(
    rng: np.random.Generator,
    count: int,
    *,
    hindered_bias: float,
    polar_bias: float,
    max_atoms: int = 34,
) -> list[Molecule]:
    out: list[Molecule] = []
    seen: set[int] = set()
    attempts = 0
    while len(out) < count and attempts < count * 60:
        attempts += 1
        mol = _make_phenol(rng, hindered_bias=hindered_bias, polar_bias=polar_bias)
        if mol.num_atoms > max_atoms:
            continue
        mol.check_valences()
        if not mol.has_oh_bond() or not has_valid_conformer(mol):
            continue
        key = mol.iso_key()
        if key in seen:
            continue
        seen.add(key)
        out.append(mol)
    if len(out) < count:
        raise RuntimeError(f"generator exhausted: {len(out)}/{count}")
    return out


def antioxidant_dataset(count: int = 600, seed: int = 20230) -> list[Molecule]:
    """The proprietary-dataset stand-in (hindered-phenol heavy)."""
    rng = np.random.default_rng(seed)
    return _generate(rng, count, hindered_bias=0.45, polar_bias=0.30)


def public_antioxidant_dataset(count: int = 256, seed: int = 20231) -> list[Molecule]:
    """AODB/ChEMBL-flavoured stand-in (more polar, less hindered)."""
    rng = np.random.default_rng(seed)
    return _generate(rng, count, hindered_bias=0.20, polar_bias=0.50)


def zinc_like_dataset(count: int = 512, seed: int = 20232) -> list[Molecule]:
    """Diverse drug-like set for App. D; O-H not guaranteed."""
    rng = np.random.default_rng(seed)
    out: list[Molecule] = []
    seen: set[int] = set()
    attempts = 0
    while len(out) < count and attempts < count * 80:
        attempts += 1
        base = benzene() if rng.random() < 0.6 else cyclohexane()
        mol = base
        n_subs = int(rng.integers(0, 5))
        for p in rng.permutation(6)[:n_subs]:
            if mol.free_valence(int(p)) < 1:
                continue
            frag = rng.choice(_DONOR_FRAGMENTS + _EWG_FRAGMENTS)
            mol = _attach(mol, int(p), str(frag))
        if mol.num_atoms > 30 or not has_valid_conformer(mol):
            continue
        key = mol.iso_key()
        if key in seen:
            continue
        seen.add(key)
        out.append(mol)
    return out


class DatasetStream:
    """Seeded multi-start cursor over a molecule pool (ROADMAP item 5).

    Shuffled-cycle semantics: each epoch visits every pool molecule exactly
    once in a fresh seeded permutation, so W workers x E episodes of draws
    are a pure function of ``(pool, seed)`` — the property the multi-start
    determinism tests pin identical across every rollout mode.  ``draw``
    crosses epoch boundaries transparently (a fleet wider than the pool
    just wraps into the next permutation mid-draw).
    """

    def __init__(self, molecules: Sequence[Molecule], seed: int = 0):
        if not molecules:
            raise ValueError("empty dataset pool")
        self._pool = list(molecules)
        self._rng = np.random.default_rng(seed)
        self._order = np.zeros((0,), np.int64)
        self._pos = 0
        self.n_drawn = 0
        self.n_epochs = 0

    def __len__(self) -> int:
        return len(self._pool)

    def draw(self, n: int) -> list[Molecule]:
        out: list[Molecule] = []
        while len(out) < n:
            if self._pos >= self._order.shape[0]:
                self._order = self._rng.permutation(len(self._pool))
                self._pos = 0
                self.n_epochs += 1
            out.append(self._pool[int(self._order[self._pos])])
            self._pos += 1
        self.n_drawn += n
        return out

    # -- checkpoint state (bit-exact resume) ---------------------------- #
    def state_dict(self) -> dict[str, np.ndarray]:
        """The full cursor: current epoch permutation + position, draw
        counters, and the permutation RNG stream.  The pool itself is NOT
        checkpointed — it is a pure function of (dataset name, count,
        seed), which live in the trainer config."""
        from repro.checkpoint.checkpoint import rng_state_to_array

        return {
            "order": np.asarray(self._order, np.int64),
            "pos": np.int64(self._pos),
            "n_drawn": np.int64(self.n_drawn),
            "n_epochs": np.int64(self.n_epochs),
            "rng": rng_state_to_array(self._rng),
        }

    def load_state_dict(self, d: dict[str, np.ndarray]) -> None:
        from repro.checkpoint.checkpoint import rng_state_from_array

        order = np.asarray(d["order"], np.int64)
        if order.shape[0] not in (0, len(self._pool)) or (
                order.size and int(order.max()) >= len(self._pool)):
            raise ValueError(
                f"dataset cursor permutation over {order.shape[0]} items "
                f"does not match pool of {len(self._pool)}")
        self._order = order
        self._pos = int(d["pos"])
        self.n_drawn = int(d["n_drawn"])
        self.n_epochs = int(d["n_epochs"])
        self._rng = rng_state_from_array(d["rng"])


# TrainerConfig.dataset names resolve here (launch/train.py --dataset too)
DATASETS = {
    "antioxidant": antioxidant_dataset,
    "public_antioxidant": public_antioxidant_dataset,
    "zinc_like": zinc_like_dataset,
}


def load_dataset(name: str, count: int | None = None,
                 seed: int | None = None) -> list[Molecule]:
    """Build a registry dataset; ``None`` keeps the dataset's own default
    count/seed.  Unknown names fail loudly with the known registry."""
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; known: {sorted(DATASETS)}")
    kwargs = {}
    if count is not None:
        kwargs["count"] = count
    if seed is not None:
        kwargs["seed"] = seed
    return DATASETS[name](**kwargs)


def train_test_split(
    mols: list[Molecule], n_train: int = 256, n_test: int = 128, seed: int = 7
) -> tuple[list[Molecule], list[Molecule]]:
    """§4.1/§4.3: random 256 train + 128 test from the remainder."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(mols))
    train = [mols[i] for i in idx[:n_train]]
    test = [mols[i] for i in idx[n_train : n_train + n_test]]
    return train, test


def dataset_property_table(mols: list[Molecule]) -> dict[str, np.ndarray]:
    """Oracle BDE/IP arrays for a molecule list (the 'DFT ground truth')."""
    bde = np.array([oracle_bde(m) for m in mols], dtype=np.float64)
    ip = np.array([oracle_ip(m) for m in mols], dtype=np.float64)
    return {"bde": bde, "ip": ip}
