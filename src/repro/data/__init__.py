"""Datasets, tokenization and input pipelines."""

from repro.data.datasets import (
    DATASETS,
    DatasetStream,
    antioxidant_dataset,
    load_dataset,
    public_antioxidant_dataset,
    zinc_like_dataset,
    train_test_split,
)
from repro.data.tokenizer import SmilesTokenizer
from repro.data.pipeline import TokenBatcher, lm_batches_from_smiles

__all__ = [
    "DATASETS", "DatasetStream", "load_dataset",
    "antioxidant_dataset", "public_antioxidant_dataset", "zinc_like_dataset",
    "train_test_split", "SmilesTokenizer", "TokenBatcher", "lm_batches_from_smiles",
]
