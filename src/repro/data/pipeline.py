"""Sharding-aware token batch pipeline.

``TokenBatcher`` produces ``{"tokens", "labels", "mask"}`` numpy batches
from an id corpus; ``shard_batch`` places a host batch onto a mesh with the
("pod","data") batch partitioning the launcher uses.  Deterministic given
the seed; infinite iterator with reshuffling per epoch.
"""

from __future__ import annotations

from typing import Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class TokenBatcher:
    def __init__(
        self,
        sequences: list[np.ndarray],
        batch_size: int,
        seq_len: int,
        *,
        pad_id: int = 0,
        seed: int = 0,
    ):
        if not sequences:
            raise ValueError("empty corpus")
        self.sequences = sequences
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.pad_id = pad_id
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            order = self.rng.permutation(len(self.sequences))
            for start in range(0, len(order) - self.batch_size + 1, self.batch_size):
                idx = order[start : start + self.batch_size]
                yield self._make_batch([self.sequences[i] for i in idx])

    def _make_batch(self, seqs: list[np.ndarray]) -> dict[str, np.ndarray]:
        L = self.seq_len
        tokens = np.full((len(seqs), L), self.pad_id, dtype=np.int32)
        for r, s in enumerate(seqs):
            s = s[: L]
            tokens[r, : len(s)] = s
        labels = np.concatenate(
            [tokens[:, 1:], np.full((len(seqs), 1), self.pad_id, dtype=np.int32)], axis=1
        )
        mask = (labels != self.pad_id).astype(np.float32)
        return {"tokens": tokens, "labels": labels, "mask": mask}


def lm_batches_from_smiles(
    smiles: list[str], tokenizer, batch_size: int, seq_len: int, seed: int = 0
) -> Iterator[dict[str, np.ndarray]]:
    seqs = [tokenizer.encode(s) for s in smiles]
    return iter(TokenBatcher(seqs, batch_size, seq_len, pad_id=tokenizer.PAD, seed=seed))


def shard_batch(batch: dict[str, np.ndarray], mesh, batch_axes: tuple[str, ...]) -> dict:
    """Place a host batch on ``mesh`` with the batch dim split over
    ``batch_axes`` (e.g. ("pod","data")) and everything else replicated."""
    def put(x):
        spec = P(batch_axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))
    return {k: put(v) for k, v in batch.items()}
