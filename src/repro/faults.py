"""Fault taxonomy for the training stack (dependency-free).

These exception types live at the package root (NOT in ``repro.core``) so
low-level layers like ``repro.predictors.service`` can raise/catch them
without importing ``repro.core`` — whose package init pulls in the trainer
and would close an import cycle.  ``repro.core.faults`` (the injection
scheduler, :class:`~repro.core.faults.FaultPlan`) re-exports them, and is
the import site the RL core uses.

:class:`TransientFault`   retryable: the next attempt may succeed (every
                          wrapped dependency is deterministic, so a retry
                          is bit-identical to a first try).
:class:`FaultTimeout`     a per-call timeout — a retryable
                          ``TransientFault`` flavour (raised both by fault
                          injection and by the real timeout path in
                          ``ResilientService``).
:class:`FaultError`       terminal: retries exhausted or a hard crash.
                          The caller must quarantine the affected unit of
                          work (slot / checkpoint write), not retry.
"""

from __future__ import annotations


class TransientFault(RuntimeError):
    """A retryable failure: the next attempt may succeed."""


class FaultTimeout(TransientFault):
    """A per-call timeout (retryable)."""


class FaultError(RuntimeError):
    """A terminal failure: retries exhausted or a hard crash — quarantine,
    don't retry."""
