"""Pytree checkpointing (npz-based, no external deps)."""

from repro.checkpoint.checkpoint import (
    CheckpointError,
    CheckpointManager,
    load_flat,
    load_pytree,
    rng_state_from_array,
    rng_state_to_array,
    save_flat,
    save_pytree,
    unflatten_like,
)

__all__ = [
    "CheckpointError",
    "CheckpointManager",
    "load_flat",
    "load_pytree",
    "rng_state_from_array",
    "rng_state_to_array",
    "save_flat",
    "save_pytree",
    "unflatten_like",
]
