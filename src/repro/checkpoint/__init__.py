"""Pytree checkpointing (npz-based, no external deps)."""

from repro.checkpoint.checkpoint import save_pytree, load_pytree, CheckpointManager

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]
