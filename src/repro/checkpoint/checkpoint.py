"""Checkpointing: arbitrary pytrees <-> a single ``.npz`` + JSON treedef.

Leaves are gathered to host (works for sharded arrays — callers on a real
cluster should checkpoint per-host shards; for this framework's scales a
single-file gather is the right call).  The tree structure is encoded as
flattened key paths so checkpoints are stable across python versions and
don't pickle code.

``CheckpointManager`` adds step-numbered rotation + a LATEST pointer, which
``launch/train.py`` and the RL trainer use for resumable episodes.
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_element_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_element_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save_pytree(path: str, tree: PyTree) -> None:
    """Save a pytree to ``path`` (.npz).  Atomic via temp-file rename."""
    flat = _flatten_with_paths(tree)
    manifest = np.frombuffer(json.dumps(sorted(flat)).encode(), dtype=np.uint8)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __manifest__=manifest, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Load a pytree saved by :func:`save_pytree` into the structure of
    ``like`` (shape/dtype validated leaf-by-leaf)."""
    data = np.load(path)
    flat_like = _flatten_with_paths(like)
    out = {}
    for key, ref in flat_like.items():
        if key not in data:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {key!r}: checkpoint shape {arr.shape} != {ref.shape}")
        out[key] = arr.astype(ref.dtype)
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    new_leaves = []
    for path_elems, _ in leaves_paths:
        key = _SEP.join(_path_element_str(p) for p in path_elems)
        new_leaves.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class CheckpointManager:
    """Step-numbered checkpoints with rotation: ``<dir>/ckpt_<step>.npz``."""

    def __init__(self, directory: str, max_to_keep: int = 3):
        self.directory = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)

    def _steps(self) -> list[int]:
        steps = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def save(self, step: int, tree: PyTree) -> str:
        path = os.path.join(self.directory, f"ckpt_{step}.npz")
        save_pytree(path, tree)
        for old in self._steps()[: -self.max_to_keep]:
            os.unlink(os.path.join(self.directory, f"ckpt_{old}.npz"))
        return path

    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, like: PyTree, step: int | None = None) -> tuple[int, PyTree]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"ckpt_{step}.npz")
        return step, load_pytree(path, like)
