"""Checkpointing: arbitrary pytrees <-> a single ``.npz`` + JSON treedef.

Leaves are gathered to host (works for sharded arrays — callers on a real
cluster should checkpoint per-host shards; for this framework's scales a
single-file gather is the right call).  The tree structure is encoded as
flattened key paths so checkpoints are stable across python versions and
don't pickle code.

Robustness contract (PR 8):

* every write is atomic (temp file in the same directory + ``os.replace``
  after ``fsync``) — a reader never observes a half-written file;
* every read validates the embedded ``__manifest__`` and materialises all
  arrays before returning — a truncated/corrupt file raises a loud
  :class:`CheckpointError`, never returns garbage;
* ``CheckpointManager`` keeps an atomic ``LATEST`` pointer beside the
  rotation and falls back to the previous rotation entry when the newest
  checkpoint is corrupt, so a crash *during* a checkpoint write cannot
  strand a resume.

``CheckpointManager`` is what ``launch/train.py`` / ``launch/verify.py``
use for the bit-exact crash-resume path; ``load_flat`` is the raw
flat-dict loader for :class:`~repro.core.distributed.DistributedTrainer`
state (whose replay arrays have grown shapes no fresh ``like`` tree can
predict).
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Callable

import jax
import numpy as np

PyTree = Any
_SEP = "/"
LATEST_NAME = "LATEST"


class CheckpointError(RuntimeError):
    """A checkpoint file is missing pieces, truncated, or corrupt."""


# ---------------------------------------------------------------------------
# host RNG state <-> array (bit-exact numpy Generator resume)
# ---------------------------------------------------------------------------

def rng_state_to_array(rng: np.random.Generator) -> np.ndarray:
    """Serialize a PCG64 ``np.random.Generator`` to a uint64[6] array.

    Layout: [state_hi, state_lo, inc_hi, inc_lo, has_uint32, uinteger].
    The 128-bit ``state``/``inc`` integers are split into two uint64 words
    each; ``has_uint32``/``uinteger`` capture the cached half-draw so a
    restored generator continues the exact output stream mid-word.
    """
    st = rng.bit_generator.state
    if st["bit_generator"] != "PCG64":
        raise CheckpointError(
            f"can only checkpoint PCG64 generators, got {st['bit_generator']}")
    mask = (1 << 64) - 1
    s, inc = st["state"]["state"], st["state"]["inc"]
    return np.array(
        [(s >> 64) & mask, s & mask, (inc >> 64) & mask, inc & mask,
         int(st["has_uint32"]), int(st["uinteger"])],
        dtype=np.uint64)


def rng_state_from_array(arr: np.ndarray) -> np.random.Generator:
    """Rebuild the ``np.random.Generator`` serialized by
    :func:`rng_state_to_array`."""
    a = np.asarray(arr, dtype=np.uint64)
    if a.shape != (6,):
        raise CheckpointError(f"rng state array has shape {a.shape}, want (6,)")
    hi = lambda i: int(a[i]) << 64  # noqa: E731
    rng = np.random.default_rng(0)
    rng.bit_generator.state = {
        "bit_generator": "PCG64",
        "state": {"state": hi(0) | int(a[1]), "inc": hi(2) | int(a[3])},
        "has_uint32": int(a[4]),
        "uinteger": int(a[5]),
    }
    return rng


# ---------------------------------------------------------------------------
# flatten / unflatten
# ---------------------------------------------------------------------------

def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_element_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_element_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def _atomic_write(path: str, write_body: Callable[[Any], None]) -> None:
    """Write ``path`` atomically: mkstemp in the same directory, write,
    fsync, ``os.replace``.  The temp file is owned exactly once — an
    exception before ``fdopen`` takes ownership closes the raw fd, and the
    cleanup never unlinks a path that was already renamed into place (the
    old ``finally: if exists(tmp): unlink(tmp)`` form could delete a
    *racing writer's* fresh temp file of the same name after our rename)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        try:
            f = os.fdopen(fd, "wb")
        except Exception:
            os.close(fd)  # fdopen never took ownership
            raise
        with f:
            write_body(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        tmp = None  # renamed away — nothing left to clean up
    finally:
        if tmp is not None:
            try:
                os.unlink(tmp)
            except FileNotFoundError:
                pass


def save_pytree(path: str, tree: PyTree) -> None:
    """Save a pytree to ``path`` (.npz).  Atomic via temp-file rename +
    fsync; see :func:`_atomic_write` for the cleanup contract."""
    flat = _flatten_with_paths(tree)
    save_flat(path, flat)


def save_flat(path: str, flat: dict[str, np.ndarray]) -> None:
    """Save an already-flat ``{key: array}`` dict (keys may contain '/')."""
    for k in flat:
        if k == "__manifest__":
            raise ValueError("'__manifest__' is a reserved checkpoint key")
    manifest = np.frombuffer(json.dumps(sorted(flat)).encode(), dtype=np.uint8)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    _atomic_write(path, lambda f: np.savez(f, __manifest__=manifest, **arrays))


def load_flat(path: str) -> dict[str, np.ndarray]:
    """Load the raw ``{key: array}`` dict saved by :func:`save_flat` /
    :func:`save_pytree`.

    Validates the embedded ``__manifest__`` (it must parse and its key set
    must match the archive's) and materialises EVERY array before
    returning, so a truncated or bit-flipped file raises
    :class:`CheckpointError` instead of surfacing garbage downstream.
    ``FileNotFoundError`` passes through untouched (absent != corrupt).
    """
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    try:
        with np.load(path) as data:
            if "__manifest__" not in data:
                raise CheckpointError(f"{path}: missing __manifest__")
            keys = json.loads(bytes(data["__manifest__"]).decode())
            if not isinstance(keys, list):
                raise CheckpointError(f"{path}: malformed __manifest__")
            present = set(data.files) - {"__manifest__"}
            if set(keys) != present:
                raise CheckpointError(
                    f"{path}: manifest/content mismatch "
                    f"(missing {sorted(set(keys) - present)[:4]}, "
                    f"extra {sorted(present - set(keys))[:4]})")
            # np.load is lazy — force every array through the decompressor
            # so truncation anywhere in the archive is caught HERE.
            return {k: np.asarray(data[k]) for k in keys}
    except CheckpointError:
        raise
    except Exception as e:  # BadZipFile, EOFError, OSError, ValueError, ...
        raise CheckpointError(f"{path}: corrupt checkpoint ({e!r})") from e


def unflatten_like(flat: dict[str, np.ndarray], like: PyTree) -> PyTree:
    """Rebuild a pytree with the structure of ``like`` from a flat dict
    (shape validated leaf-by-leaf, dtype cast to ``like``'s)."""
    flat_like = _flatten_with_paths(like)
    out = {}
    for key, ref in flat_like.items():
        if key not in flat:
            raise CheckpointError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise CheckpointError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != {ref.shape}")
        out[key] = arr.astype(ref.dtype)
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    new_leaves = []
    for path_elems, _ in leaves_paths:
        key = _SEP.join(_path_element_str(p) for p in path_elems)
        new_leaves.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Load a pytree saved by :func:`save_pytree` into the structure of
    ``like`` (manifest-validated; raises :class:`CheckpointError` on any
    corruption)."""
    return unflatten_like(load_flat(path), like)


class CheckpointManager:
    """Step-numbered checkpoints with rotation: ``<dir>/ckpt_<step>.npz``.

    A ``LATEST`` pointer file (atomic temp-file + ``os.replace`` write,
    same discipline as the checkpoints themselves) names the newest step;
    ``restore``/``restore_flat`` fall back through older rotation entries
    when the newest file turns out corrupt — a SIGKILL mid-write costs one
    checkpoint of progress, never the run.

    ``fault_plan`` (duck-typed: anything with ``check_call(site)``) lets
    the deterministic fault harness inject transient write failures;
    ``save`` retries up to ``save_retries`` times and raises
    :class:`CheckpointError` once exhausted.
    """

    def __init__(self, directory: str, max_to_keep: int = 3,
                 fault_plan=None, save_retries: int = 2):
        self.directory = directory
        self.max_to_keep = max_to_keep
        self.fault_plan = fault_plan
        self.save_retries = save_retries
        self.n_save_retries = 0
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step}.npz")

    def _steps(self) -> list[int]:
        steps = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def _write_latest(self, step: int) -> None:
        _atomic_write(os.path.join(self.directory, LATEST_NAME),
                      lambda f: f.write(f"{step}\n".encode()))

    def save(self, step: int, tree: PyTree, *, flat: bool = False) -> str:
        """Write ``ckpt_<step>.npz``, update LATEST, rotate old entries.
        With ``flat=True``, ``tree`` is an already-flat ``{key: array}``
        dict (the ``DistributedTrainer.state_dict()`` form)."""
        path = self._path(step)
        writer = save_flat if flat else save_pytree
        attempt = 0
        while True:
            try:
                if self.fault_plan is not None:
                    self.fault_plan.check_call("checkpoint")
                writer(path, tree)
                break
            except Exception as e:  # noqa: BLE001 — injected or real I/O
                if attempt >= self.save_retries:
                    raise CheckpointError(
                        f"checkpoint write {path} failed after "
                        f"{attempt + 1} attempts: {e!r}") from e
                attempt += 1
                self.n_save_retries += 1
        self._write_latest(step)
        for old in self._steps()[: -self.max_to_keep]:
            os.unlink(self._path(old))
        return path

    def latest_step(self) -> int | None:
        """Newest step per the LATEST pointer; falls back to a directory
        scan when the pointer is absent/stale/corrupt."""
        steps = self._steps()
        latest = os.path.join(self.directory, LATEST_NAME)
        try:
            with open(latest, "rb") as f:
                step = int(f.read().strip())
            if step in steps:
                return step
        except (FileNotFoundError, ValueError):
            pass
        return steps[-1] if steps else None

    def _restore_any(self, step: int | None, loader):
        if step is not None:
            return step, loader(self._path(step))
        candidates = [s for s in self._steps()]
        latest = self.latest_step()
        if latest is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        # newest first, LATEST pointer wins ties with the scan order
        ordered = [latest] + [s for s in reversed(candidates) if s != latest]
        last_err: Exception | None = None
        for s in ordered:
            try:
                return s, loader(self._path(s))
            except (CheckpointError, FileNotFoundError) as e:
                last_err = e
        raise CheckpointError(
            f"all checkpoints in {self.directory} are corrupt "
            f"(last error: {last_err!r})") from last_err

    def restore(self, like: PyTree, step: int | None = None) -> tuple[int, PyTree]:
        return self._restore_any(step, lambda p: load_pytree(p, like))

    def restore_flat(self, step: int | None = None) -> tuple[int, dict[str, np.ndarray]]:
        """Restore the raw flat dict of the newest readable checkpoint."""
        return self._restore_any(step, load_flat)
