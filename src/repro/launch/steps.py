"""Step functions (train / serve) for every architecture family.

``make_train_step(cfg)`` -> f(params, opt_state, batch) -> (params, opt,
loss); ``make_serve_step(cfg)`` -> f(params, cache, tokens) -> (logits,
cache).  The qnet family (the paper's own model) builds the double-DQN
train step instead of an LM loss.  These are the exact functions the
dry-run lowers on the production mesh and the examples run on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.optim import adam
from repro.optim.adam import apply_updates


def make_optimizer(cfg: ArchConfig, lr: float = 1e-4):
    # Adam(1e-4) is the paper's optimizer (Table 3); mu/nu in f32 for bf16
    # params to keep moments stable.
    return adam(lr, clip_norm=1.0, mu_dtype=jnp.float32)


def make_train_step(cfg: ArchConfig, optimizer=None, microbatches: int = 1):
    opt = optimizer or make_optimizer(cfg)

    if cfg.family == "qnet":
        from repro.core.agent import QNetwork, huber
        net = QNetwork()

        def qnet_train_step(params, target_params, opt_state, batch):
            def loss_fn(p):
                q_sa = net.apply(p, batch["states"])
                q_next_online = net.apply(p, batch["next_fps"])
                q_next_online = jnp.where(batch["next_mask"] > 0, q_next_online, -jnp.inf)
                a_star = jnp.argmax(q_next_online, axis=-1)
                q_next_target = net.apply(target_params, batch["next_fps"])
                v_next = jnp.take_along_axis(q_next_target, a_star[:, None], axis=-1)[:, 0]
                v_next = jnp.where(batch["next_mask"].sum(-1) > 0, v_next, 0.0)
                y = jax.lax.stop_gradient(batch["rewards"] + (1.0 - batch["dones"]) * v_next)
                return jnp.mean(huber(q_sa - y))
            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, opt_state2 = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state2, loss
        return qnet_train_step, opt

    if microbatches <= 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(M.loss_fn)(params, cfg, batch)
            updates, opt_state2 = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state2, loss
        return train_step, opt

    mb = microbatches

    def train_step(params, opt_state, batch):
        """Gradient accumulation over ``mb`` microbatches.

        Grads are computed INSIDE the scan (no outer AD), so the rematted
        residual stack only ever holds one microbatch — this is what lets
        the deep archs (94L qwen3, 88L granite) fit 16 GB/chip at
        global-batch 256.  Accumulation in the param dtype: at mb<=16 the
        bf16 accumulation error is ~0.4% relative — the f32 accumulator
        alternative costs +3.4 GiB/chip on qwen3 and breaks the fit."""
        split = lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
        mbatch = jax.tree_util.tree_map(split, batch)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, p.dtype), params)

        def body(carry, mu_b):
            loss_acc, g_acc = carry
            loss, grads = jax.value_and_grad(M.loss_fn)(params, cfg, mu_b)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: (a + g).astype(a.dtype), g_acc, grads)
            return (loss_acc + loss, g_acc), None

        (loss_sum, g_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mbatch)
        grads = jax.tree_util.tree_map(
            lambda g, p: (g / mb).astype(p.dtype), g_sum, params)
        updates, opt_state2 = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state2, loss_sum / mb

    return train_step, opt


def pick_microbatches(cfg: ArchConfig, shape, dp: int, *, budget_gib: float = 4.0) -> int:
    """Smallest power-of-2 microbatch count keeping the per-chip rematted
    residual stack under ``budget_gib`` (with batch still divisible)."""
    if shape.kind != "train" or cfg.family == "qnet":
        return 1
    dtype_b = 2 if cfg.dtype == "bfloat16" else 4
    b_loc = max(shape.global_batch // dp, 1)
    stack = cfg.n_layers * b_loc * shape.seq_len * cfg.d_model * dtype_b
    mb = 1
    while (stack / mb) > budget_gib * 2**30 \
            and shape.global_batch % (2 * mb) == 0 \
            and (shape.global_batch // (2 * mb)) % dp == 0:
        mb *= 2
    return mb


def make_serve_step(cfg: ArchConfig):
    if cfg.family == "qnet":
        from repro.core.agent import QNetwork
        net = QNetwork()

        def qnet_serve_step(params, states):
            return net.apply(params, states)
        return qnet_serve_step

    def serve_step(params, cache, tokens):
        return M.serve_step(params, cfg, cache, tokens)

    return serve_step


def make_prefill_step(cfg: ArchConfig):
    """Prefill = forward pass producing logits (cache write omitted: the
    dry-run measures the compute/collective shape of the forward)."""
    def prefill_step(params, batch):
        logits, _ = M.forward_train(params, cfg, batch)
        return logits
    return prefill_step
