"""Training launcher.

Two modes, mirroring the framework's two tiers:

* ``--mode rl`` (default; the paper): distributed DA-MolDQN over an
  antioxidant dataset — workers on the host mesh, per-episode param sync,
  checkpointing, OFR/reward logging.

* ``--mode lm --arch <id>``: train a (reduced or full) model-zoo backbone
  on the SMILES LM corpus with the same train_step the dry-run lowers —
  on CPU use ``--reduced`` (the full configs only make sense on the
  production mesh).

    PYTHONPATH=src python -m repro.launch.train --mode rl --episodes 40
    PYTHONPATH=src python -m repro.launch.train --mode lm --arch stablelm-1.6b \
        --reduced --steps 50
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


def main() -> None:
    from repro.core.distributed import LEARNER_MODES, REPLAY_MODES, ROLLOUT_MODES
    from repro.data.datasets import DATASETS

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("rl", "lm"), default="rl")
    # rl args
    ap.add_argument("--episodes", type=int, default=40)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--mols-per-worker", type=int, default=4)
    ap.add_argument("--sync", choices=("episode", "step"), default="episode")
    ap.add_argument("--rollout", choices=ROLLOUT_MODES, default="fleet",
                    help="acting path (see core.distributed)")
    ap.add_argument("--learner", choices=LEARNER_MODES, default="packed",
                    help="replay->update path (see core.distributed)")
    ap.add_argument("--replay", choices=REPLAY_MODES, default="uniform",
                    help="replay sampling: uniform (reference) or "
                         "prioritized (proportional PER)")
    ap.add_argument("--priority-alpha", type=float, default=0.6)
    ap.add_argument("--priority-beta0", type=float, default=0.4)
    ap.add_argument("--dataset", choices=sorted(DATASETS), default=None,
                    help="multi-start episode stream: draw every episode's "
                         "start molecules from this seeded dataset cursor "
                         "(default: fixed train-split batch)")
    ap.add_argument("--dataset-size", type=int, default=None,
                    help="dataset pool size (default: the dataset's own)")
    ap.add_argument("--scenarios", default=None,
                    help="comma list of scenario-registry names cycled "
                         "across workers (configs/scenarios.py), e.g. "
                         "'antioxidant,qed'; default: the Eq. 1 "
                         "antioxidant objective on every worker")
    ap.add_argument("--ckpt-dir", default=".cache/rl_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=5,
                    help="full trainer-state checkpoint every N episodes "
                         "(bit-exact resume granularity)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from --ckpt-dir and "
                         "continue; the continued run is bit-identical to "
                         "one that never stopped (docs/robustness.md)")
    # lm args
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    if args.mode == "rl":
        train_rl(args)
    else:
        train_lm(args)


def train_rl(args) -> None:
    from repro.core import DQNConfig, EnvConfig, RewardConfig, TrainerConfig
    from repro.core.distributed import DistributedTrainer, greedy_optimize, \
        optimization_failure_rate
    from repro.data.datasets import antioxidant_dataset, dataset_property_table, \
        train_test_split
    from repro.predictors import PropertyService
    from repro.predictors.training import ensure_trained

    from repro.data.datasets import load_dataset

    bm, bp, im, ip_, metrics = ensure_trained()
    service = PropertyService(bm, bp, im, ip_)
    n_mols = args.workers * args.mols_per_worker
    if args.dataset is not None:
        # multi-start: reward normalisation and evaluation come from the
        # streamed pool itself; the trainer re-draws starts every episode
        pool = load_dataset(args.dataset, count=args.dataset_size)
        train, molecules, dataset_pool = pool, None, pool
    else:
        ds = antioxidant_dataset(600)
        train, test = train_test_split(ds)
        molecules, dataset_pool = train[:n_mols], None
    props = dataset_property_table(train)
    rcfg = RewardConfig.from_dataset(props["bde"], props["ip"])

    cfg = TrainerConfig(
        n_workers=args.workers, mols_per_worker=args.mols_per_worker,
        episodes=args.episodes, sync_mode=args.sync, rollout=args.rollout,
        learner=args.learner, replay=args.replay,
        priority_alpha=args.priority_alpha, priority_beta0=args.priority_beta0,
        dataset=args.dataset, dataset_size=args.dataset_size,
        scenarios=(tuple(args.scenarios.split(","))
                   if args.scenarios else None),
        dqn=DQNConfig(epsilon_decay=0.97))
    trainer = DistributedTrainer(cfg, molecules, service, rcfg,
                                 dataset_pool=dataset_pool)
    mgr = CheckpointManager(args.ckpt_dir)
    if args.resume:
        ep0 = trainer.restore_checkpoint(mgr)
        print(f"resumed from episode {ep0} ({args.ckpt_dir})", flush=True)

    t0 = time.time()
    while trainer.episode < args.episodes:
        st = trainer.train_episode()
        ep = st["episode"]
        if ep % 5 == 0 or ep == args.episodes:
            print(f"[ep {ep:4d}] reward {st['mean_final_reward']:8.3f} "
                  f"loss {st['loss']:10.4f} eps {st['epsilon']:.3f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
        if ep % max(1, args.ckpt_every) == 0 or ep == args.episodes:
            # FULL trainer state (params, opt, replay rings, RNGs, dataset
            # cursor) — what --resume restores bit-exactly
            trainer.save_checkpoint(mgr)

    agent = trainer.as_agent(epsilon=0.0)
    recs = greedy_optimize(agent, list(train[:n_mols]), service, rcfg, cfg.env)
    print(f"train-set OFR: {optimization_failure_rate(recs):.3f}")
    print(f"cache hit rate: {service.cache.hit_rate:.3f}")


def train_lm(args) -> None:
    from repro.chem.smiles import canonical_smiles
    from repro.configs import get_config
    from repro.data.datasets import antioxidant_dataset
    from repro.data.pipeline import lm_batches_from_smiles
    from repro.data.tokenizer import SmilesTokenizer
    from repro.launch.steps import make_train_step
    from repro.models import init_params

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tok = SmilesTokenizer()
    mols = antioxidant_dataset(256)
    smiles = [canonical_smiles(m) for m in mols]
    batches = lm_batches_from_smiles(smiles, tok, args.batch, args.seq)

    params = init_params(cfg, jax.random.PRNGKey(0))
    step, opt = make_train_step(cfg)
    opt_state = opt.init(params)
    jstep = jax.jit(step)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i, batch in zip(range(args.steps), batches):
        if cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (args.batch, cfg.encdec.n_frames, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            batch["patches"] = rng.standard_normal(
                (args.batch, cfg.vlm.n_patches, cfg.vlm.vision_dim)).astype(np.float32)
        params, opt_state, loss = jstep(params, opt_state, batch)
        if (i + 1) % 10 == 0:
            print(f"[step {i+1:4d}] loss {float(loss):.4f} ({time.time()-t0:.0f}s)",
                  flush=True)
    print(json.dumps({"final_loss": float(loss), "steps": args.steps}))


if __name__ == "__main__":
    main()
