import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks the device count on first
#   init).  512 host placeholder devices let jax.make_mesh build the
#   production meshes; nothing here ever allocates real tensors.

"""Multi-pod dry-run: lower + compile every (arch x input-shape) pair on
the production mesh, prove it fits, and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

For each combination this:
  1. builds abstract params/optimizer/cache trees (ShapeDtypeStruct — no
     allocation; a 235B model "loads" in milliseconds),
  2. jits the family's train/prefill/serve step with explicit in/out
     shardings and ``.lower().compile()``s it against the mesh,
  3. records ``memory_analysis()`` (fits-on-chip proof),
     ``cost_analysis()`` (FLOPs/bytes) and the partitioned-HLO collective
     bytes into a JSON report consumed by EXPERIMENTS.md §Dry-run/§Roofline.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the matrix must be green before §Perf starts.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_prefill_step, make_serve_step, make_train_step, pick_microbatches)
from repro.models import model as M
from repro.roofline.analysis import (
    estimate_hbm_per_chip, model_flops_estimate, roofline_terms)

# long_500k policy (DESIGN.md §3): native for ssm/hybrid/SWA archs; dense
# archs run the sliding-window variant; whisper skipped (448-pos decoder).
LONG_WINDOW = 8192
SKIP: dict[tuple[str, str], str] = {
    ("whisper-large-v3", "long_500k"):
        "decoder max position is 448 (learned embedding); 500k decode is architecturally meaningless",
    ("damoldqn", "prefill_32k"): "fingerprint MLP has no sequence dim",
    ("damoldqn", "decode_32k"): "fingerprint MLP has no KV cache",
    ("damoldqn", "long_500k"): "fingerprint MLP has no sequence dim",
}
_PURE_FULL_ATTN = {"stablelm-1.6b", "granite-34b", "granite-20b", "yi-34b", "paligemma-3b"}


def prepare(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if (arch, shape_name) in SKIP:
        return None
    if shape_name == "long_500k" and arch in _PURE_FULL_ATTN:
        cfg = cfg.with_window(LONG_WINDOW)  # beyond-paper SWA variant
    return cfg, shape


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            zero_opt: bool = False, seq_shard: bool = False,
            verbose: bool = True) -> dict:
    t0 = time.time()
    prep = prepare(arch, shape_name, multi_pod)
    if prep is None:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": SKIP[(arch, shape_name)]}
    cfg, shape = prep
    if seq_shard:
        import dataclasses
        cfg = dataclasses.replace(cfg, seq_shard=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    import contextlib
    ambient = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else contextlib.nullcontext()

    params = M.abstract_params(cfg)
    # FSDP for the big archs: params+opt at TP-only exceed the HBM budget
    fsdp = M.count_params(cfg) > 8e9
    p_shard = S.param_shardings(cfg, mesh, fsdp=fsdp)
    mb = 1

    if shape.kind == "train":
        dp = chips // mesh.shape.get("model", 1)
        mb = pick_microbatches(cfg, shape, dp)
        step_fn, opt = make_train_step(cfg, microbatches=mb)
        opt_state = jax.eval_shape(opt.init, params)
        pspecs = S.param_pspecs_for(cfg, mesh, fsdp=fsdp)
        if zero_opt and not fsdp:
            opt_pspecs_tree = S.zero_opt_shardings(cfg, mesh, pspecs)
        else:
            opt_pspecs_tree = pspecs
        from repro.optim.adam import OptState
        o_shard = OptState(
            step=S._shard(mesh, jax.sharding.PartitionSpec()),
            mu=jax.tree_util.tree_map(lambda s: S._shard(mesh, s), opt_pspecs_tree),
            nu=jax.tree_util.tree_map(lambda s: S._shard(mesh, s), opt_pspecs_tree),
        )
        if cfg.family == "qnet":
            batch, b_shard = S.qnet_batch_specs(shape, mesh)
            jitted = jax.jit(step_fn,
                             in_shardings=(p_shard, p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 2))
            with ambient:
                lowered = jitted.lower(params, params, opt_state, batch)
        else:
            batch, b_shard = S.train_batch_specs(cfg, shape, mesh)
            jitted = jax.jit(step_fn,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            with ambient:
                lowered = jitted.lower(params, opt_state, batch)
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(cfg)
        batch, b_shard = S.train_batch_specs(cfg, shape, mesh)
        batch = {k: v for k, v in batch.items() if k not in ("labels", "mask")}
        b_shard = {k: v for k, v in b_shard.items() if k in batch}
        jitted = jax.jit(step_fn, in_shardings=(p_shard, b_shard))
        with ambient:
            lowered = jitted.lower(params, batch)
    else:  # decode
        step_fn = make_serve_step(cfg)
        tokens, cache, tok_shard, cache_shard = S.decode_specs(cfg, shape, mesh)
        jitted = jax.jit(step_fn,
                         in_shardings=(p_shard, cache_shard, tok_shard),
                         out_shardings=(None, cache_shard),
                         donate_argnums=(1,))
        with ambient:
            lowered = jitted.lower(params, cache, tokens)

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    mem_bytes = getattr(mem, "temp_size_in_bytes", 0) + getattr(mem, "argument_size_in_bytes", 0) \
        + getattr(mem, "output_size_in_bytes", 0) - getattr(mem, "alias_size_in_bytes", 0)
    report = roofline_terms(
        arch=arch, shape=shape_name, mesh_desc=mesh_desc, chips=chips,
        cost=cost, hlo_text=hlo,
        model_flops=model_flops_estimate(cfg, shape),
        memory_per_chip=float(mem_bytes),
    )
    out = report.to_dict()
    out.update({
        "status": "ok",
        "kind": shape.kind,
        "microbatches": mb if shape.kind == "train" else None,
        "fsdp": fsdp,
        "zero_opt": zero_opt,
        "seq_shard": seq_shard,
        "window": cfg.attn_window,
        "params_total": M.count_params(cfg),
        "params_active": M.active_params(cfg),
        "compile_s": round(time.time() - t0, 1),
        # measured (CPU backend, bf16->f32 legalization inflates ~2x)
        "hbm_gb_per_chip_cpu": round(mem_bytes / 2**30, 3),
    })
    hbm_est = estimate_hbm_per_chip(
        cfg, shape, tp=mesh.shape.get("model", 1),
        dp=chips // mesh.shape.get("model", 1), zero_opt=zero_opt,
        microbatches=mb if shape.kind == "train" else 1, fsdp=fsdp)
    out["hbm_gb_per_chip"] = round(hbm_est["total"] / 2**30, 3)
    out["hbm_breakdown_gb"] = {k: round(v / 2**30, 3) for k, v in hbm_est.items()}
    out["fits_16gb"] = out["hbm_gb_per_chip"] <= 16.0
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {mesh_desc}: OK "
              f"({out['compile_s']}s compile, {out['hbm_gb_per_chip']} GiB/chip, "
              f"dominant={out['dominant']})", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--zero-opt", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}" + \
                    ("_zero" if args.zero_opt else "") + \
                    ("_seqshard" if args.seq_shard else "")
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached", flush=True)
                    continue
                try:
                    res = run_one(arch, shape, multi_pod=mp, zero_opt=args.zero_opt,
                                  seq_shard=args.seq_shard)
                except Exception as e:  # noqa: BLE001 — must report every combo
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape, "status": "FAIL",
                           "error": f"{type(e).__name__}: {e}"}
                    n_fail += 1
                res["mesh"] = "2x16x16" if mp else "16x16"
                with open(path, "w") as f:
                    json.dump(res, f, indent=2, default=str)
    print(f"[dryrun] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
