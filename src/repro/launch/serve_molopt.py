"""Molecule-optimization serving launcher (docs/serving.md).

Stands up a ``MoleculeOptService`` — bounded admission queue, continuous
batching over RolloutEngine slots, circuit breaker over the property tier
— and replays a seeded open-loop request stream against it, printing the
per-request terminal results and the service counters.

    PYTHONPATH=src python -m repro.launch.serve_molopt \
        --slots 8 --requests 32 --rate 2.0 --deadline-frac 0.3

By default properties come from the deterministic ``OracleService`` stub
(no predictor training, seconds to start); ``--trained`` trains/loads the
real BDE+IP predictors and serves through them.  ``--faults`` arms a
seeded ``FaultPlan`` over the predict/chem/request sites, exercising the
whole degradation ladder: retries, per-request quarantine, breaker trips
into degraded serving, half-open recovery.
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core.agent import QNetwork
from repro.core.faults import FaultPlan, FaultRule
from repro.predictors.service import OracleService, ResilientService, RetryPolicy
from repro.serving import (MoleculeOptService, ServeConfig, StreamConfig,
                           drive_open_loop, latency_stats,
                           seeded_request_stream)


def build_service(args) -> MoleculeOptService:
    net = QNetwork()
    params = net.init(jax.random.PRNGKey(args.seed))
    plan = None
    if args.faults:
        plan = FaultPlan([
            FaultRule(site="predict", kind="crash", every=args.fault_every,
                      fail_attempts=args.fault_attempts),
            FaultRule(site="chem", kind="crash", rate=args.fault_rate),
            FaultRule(site="request", kind="transient", rate=args.fault_rate,
                      fail_attempts=1),
        ], seed=args.fault_seed)
    if args.trained:
        from benchmarks.common import services
        inner, *_ = services()
    else:
        inner = OracleService()
    prop = ResilientService(inner, RetryPolicy(max_retries=1, seed=args.seed),
                            fault_plan=plan, sleep=None)
    return MoleculeOptService(
        net, params, prop, fault_plan=plan,
        cfg=ServeConfig(n_slots=args.slots, max_queue=args.max_queue,
                        shed_policy=args.shed_policy, epsilon=args.epsilon,
                        seed=args.seed))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--shed-policy", choices=("reject_new", "evict_oldest"),
                    default="reject_new")
    ap.add_argument("--epsilon", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="mean arrivals per service step")
    ap.add_argument("--deadline-frac", type=float, default=0.3)
    ap.add_argument("--invalid-every", type=int, default=0,
                    help="poison every Nth request with unparseable SMILES")
    ap.add_argument("--trained", action="store_true",
                    help="serve through the trained BDE+IP predictors "
                         "instead of the oracle stub")
    ap.add_argument("--faults", action="store_true",
                    help="arm a seeded FaultPlan (predict/chem/request)")
    ap.add_argument("--fault-every", type=int, default=7)
    ap.add_argument("--fault-rate", type=float, default=0.05)
    ap.add_argument("--fault-attempts", type=int, default=4)
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true",
                    help="print machine-readable results instead of a table")
    args = ap.parse_args()

    svc = build_service(args)
    arrivals = seeded_request_stream(StreamConfig(
        n_requests=args.requests, rate=args.rate, seed=args.seed,
        deadline_frac=args.deadline_frac, invalid_every=args.invalid_every))
    svc.reserve_candidates(256)          # warmup: compile off the clock

    t0 = time.perf_counter()
    drive_open_loop(svc, arrivals)
    wall = time.perf_counter() - t0

    if args.json:
        print(json.dumps({"results": [r.as_dict() for r in svc.results],
                          "stats": svc.stats()}, indent=2, default=str))
        return
    print(f"{'request':10s} {'status':18s} {'steps':>5s} {'deg':>3s} "
          f"{'lat':>6s} {'wall_ms':>8s}  best")
    for r in sorted(svc.results, key=lambda r: r.request_id):
        best = "-" if r.best_reward is None else \
            f"{r.best_reward:+.4f} {r.best_smiles}"
        err = f"  [{r.error[:48]}]" if r.error else ""
        print(f"{r.request_id:10s} {r.status:18s} {r.steps_used:5d} "
              f"{r.degraded_steps:3d} {r.latency:6.1f} "
              f"{r.wall_latency_s * 1e3:8.1f}  {best}{err}")
    st = svc.stats()
    lat = latency_stats(svc.results)
    print(f"\n{args.requests} requests in {wall:.2f}s "
          f"({args.requests / wall:.1f} req/s) | statuses "
          f"{st['status_counts']} | p50/p99 wall "
          f"{lat['p50_wall_ms']:.1f}/{lat['p99_wall_ms']:.1f} ms")
    print(f"service steps {st['n_service_steps']} | Q dispatches "
          f"{st['n_q_dispatches']} | queue {st['queue']} | breaker "
          f"{st['breaker']}")


if __name__ == "__main__":
    main()
