"""Input ShapeDtypeStructs + activation/cache shardings per (arch, shape).

``input_specs(cfg, shape, mesh)`` returns (specs, shardings) pytrees for
the step function's data arguments: token batches for train/prefill, the
(one-token batch, KV/state cache) pair for decode, and the replay batch
for the paper's qnet.  Stubs per the assignment carve-out: whisper gets
precomputed frame embeddings, paligemma gets patch embeddings.

Sharding policy for data: batch dim over every non-"model" axis that
divides it; long sequence dims over "model" when divisible (sequence
parallelism for the 32k/500k caches); everything else replicated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import batch_axes
from repro.models import model as M

SDS = jax.ShapeDtypeStruct


def _div(n: int, axes: tuple[str, ...], mesh: Mesh) -> bool:
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return total > 0 and n % total == 0


def data_spec(shape: tuple[int, ...], mesh: Mesh, *, seq_dims: tuple[int, ...] = ()) -> P:
    """Batch dim 0 over data axes (if divisible); listed seq dims over
    "model" (if divisible); rest replicated."""
    ba = batch_axes(mesh)
    parts: list = [None] * len(shape)
    if shape and _div(shape[0], ba, mesh):
        parts[0] = ba if len(ba) > 1 else ba[0]
    for d in seq_dims:
        if "model" in mesh.axis_names and shape[d] % mesh.shape["model"] == 0 and parts[d] is None:
            parts[d] = "model"
    return P(*parts)


def _shard(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ------------------------------------------------------------------ #
def train_batch_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
        "mask": SDS((B, S), jnp.float32),
    }
    shardings = {k: _shard(mesh, data_spec(v.shape, mesh)) for k, v in specs.items()}
    if cfg.family == "encdec":
        f = SDS((B, cfg.encdec.n_frames, cfg.d_model), cfg.jnp_dtype)
        specs["frames"] = f
        shardings["frames"] = _shard(mesh, data_spec(f.shape, mesh))
    if cfg.family == "vlm":
        pshape = (B, cfg.vlm.n_patches, cfg.vlm.vision_dim)
        specs["patches"] = SDS(pshape, cfg.jnp_dtype)
        shardings["patches"] = _shard(mesh, data_spec(pshape, mesh))
    return specs, shardings


def qnet_batch_specs(shape: InputShape, mesh: Mesh, *, n_candidates: int = 160):
    """Replay batch for the paper's DQN train step (damoldqn config)."""
    from repro.core.agent import STATE_DIM
    B = shape.global_batch
    specs = {
        "states": SDS((B, STATE_DIM), jnp.float32),
        "rewards": SDS((B,), jnp.float32),
        "dones": SDS((B,), jnp.float32),
        "next_fps": SDS((B, n_candidates, STATE_DIM), jnp.float32),
        "next_mask": SDS((B, n_candidates), jnp.float32),
    }
    shardings = {k: _shard(mesh, data_spec(v.shape, mesh)) for k, v in specs.items()}
    return specs, shardings


def decode_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    """(tokens, cache) specs for serve_step with a ``seq_len`` cache."""
    B, S = shape.global_batch, shape.seq_len
    tokens = SDS((B, 1), jnp.int32)
    cache_tree = jax.eval_shape(lambda: M.init_cache(cfg, B, S))

    def cache_spec(path: tuple[str, ...], leaf) -> P:
        name = path[-1]
        shp = leaf.shape
        if name == "pos":
            return P()
        if name in ("k", "v", "cross_k", "cross_v"):          # [L,B,S,K,Dh]
            sp = [None] * 5
            ba = batch_axes(mesh)
            if _div(shp[1], ba, mesh):
                sp[1] = ba if len(ba) > 1 else ba[0]
            if "model" in mesh.axis_names and shp[2] % mesh.shape["model"] == 0:
                sp[2] = "model"
            return P(*sp)
        if name in ("shared_k", "shared_v"):                  # [A,B,S,K,Dh]
            sp = [None] * 5
            ba = batch_axes(mesh)
            if _div(shp[1], ba, mesh):
                sp[1] = ba if len(ba) > 1 else ba[0]
            if "model" in mesh.axis_names and shp[2] % mesh.shape["model"] == 0:
                sp[2] = "model"
            return P(*sp)
        if name == "state":                                   # [L,B,H,P,N]
            sp = [None] * 5
            ba = batch_axes(mesh)
            if _div(shp[1], ba, mesh):
                sp[1] = ba if len(ba) > 1 else ba[0]
            if "model" in mesh.axis_names and shp[2] % mesh.shape["model"] == 0:
                sp[2] = "model"
            return P(*sp)
        if name == "conv":                                    # [L,B,W-1,C]
            sp = [None] * 4
            ba = batch_axes(mesh)
            if _div(shp[1], ba, mesh):
                sp[1] = ba if len(ba) > 1 else ba[0]
            if "model" in mesh.axis_names and shp[3] % mesh.shape["model"] == 0:
                sp[3] = "model"
            return P(*sp)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    for path, leaf in flat:
        parts = tuple(M._key_str(p) for p in path)
        specs.append(cache_spec(parts, leaf))
    cache_pspecs = jax.tree_util.tree_unflatten(treedef, specs)
    cache_shardings = jax.tree_util.tree_map(lambda s: _shard(mesh, s), cache_pspecs)
    tok_sharding = _shard(mesh, data_spec(tokens.shape, mesh))
    return tokens, cache_tree, tok_sharding, cache_shardings


def param_pspecs_for(cfg: ArchConfig, mesh: Mesh, *, fsdp: bool = False):
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    pspecs = M.param_pspecs(cfg, tp=tp)
    if fsdp:
        ba = batch_axes(mesh)
        size = 1
        for a in ba:
            size *= mesh.shape[a]
        pspecs = M.add_fsdp(pspecs, cfg, fsdp_axes=tuple(ba), fsdp_size=size)
    return pspecs


def param_shardings(cfg: ArchConfig, mesh: Mesh, *, fsdp: bool = False):
    pspecs = param_pspecs_for(cfg, mesh, fsdp=fsdp)
    return jax.tree_util.tree_map(lambda s: _shard(mesh, s), pspecs)


def zero_opt_shardings(cfg: ArchConfig, mesh: Mesh, param_pspecs_tree):
    """ZeRO-style: additionally shard optimizer moments over the data axes
    on the first dimension not already taken (beyond-paper option)."""
    ba = batch_axes(mesh)
    axis = ba if len(ba) > 1 else (ba[0] if ba else None)
    size = 1
    for a in (ba or ()):
        size *= mesh.shape[a]
    tree = M.abstract_params(cfg)

    def widen(spec: P, leaf) -> P:
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for d, p in enumerate(parts):
            if p is None and axis is not None and leaf.shape[d] % size == 0 and leaf.shape[d] > 0:
                parts[d] = axis
                break
        return P(*parts)

    return jax.tree_util.tree_map(widen, param_pspecs_tree, tree)


def input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    """Unified entry point: ShapeDtypeStruct stand-ins + shardings for every
    model input of the (arch, input-shape) pair — the dry-run contract.

    train/prefill -> ({"tokens", "labels", "mask", [frames|patches]}, shardings)
    decode        -> ((tokens, cache), (tok_sharding, cache_shardings))
    qnet train    -> (replay batch, shardings)
    """
    if cfg.family == "qnet":
        return qnet_batch_specs(shape, mesh)
    if shape.kind in ("train", "prefill"):
        specs, shardings = train_batch_specs(cfg, shape, mesh)
        if shape.kind == "prefill":
            specs = {k: v for k, v in specs.items() if k not in ("labels", "mask")}
            shardings = {k: v for k, v in shardings.items() if k in specs}
        return specs, shardings
    tokens, cache, tok_sh, cache_sh = decode_specs(cfg, shape, mesh)
    return (tokens, cache), (tok_sh, cache_sh)
