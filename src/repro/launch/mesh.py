"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query, and smoke tests must keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU-v5e production mesh: 16x16 = 256 chips per pod ("data","model"),
    or 2 pods = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """All locally-visible devices on a single "data" axis (RL trainer)."""
    return jax.make_mesh((jax.device_count(),), ("data",))


def fleet_sharding(mesh: Mesh) -> NamedSharding:
    """Placement for fleet-stacked values (the ``[W, ...]`` parameter tree
    and the ``[W, C, D]`` acting batch): leading worker axis split over
    "data", everything else replicated."""
    return NamedSharding(mesh, P("data"))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_tp(mesh: Mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
