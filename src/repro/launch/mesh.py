"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query, and smoke tests must keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU-v5e production mesh: 16x16 = 256 chips per pod ("data","model"),
    or 2 pods = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(nd: int | None = None) -> Mesh:
    """The first ``nd`` locally-visible devices (default: all of them) on a
    single "data" axis (RL trainer).

    The ONE mesh-construction code path for single-axis data-parallel
    training: ``DistributedTrainer`` defaults to this, and the multi-device
    verification suite (``repro.launch.verify``) sizes it with ``nd``.

    ``nd`` selects a SUBMESH over the first nd visible devices.  The
    verification suite relies on this: XLA-CPU's kernel/threading choices
    depend on the *client's* device count (a plain single-device matmul
    changes its last bits between a 1-device and a 4-device client), so
    cross-nd bit-equality is only meaningful when every scenario runs in an
    identically-configured client — fixed forced device pool, varying
    submesh — rather than one client per device count.
    """
    if nd is None:
        return jax.make_mesh((jax.device_count(),), ("data",))
    devices = jax.devices()
    if nd <= 0 or nd > len(devices):
        raise ValueError(f"nd={nd} outside [1, {len(devices)}] visible devices")
    return Mesh(np.asarray(devices[:nd]), ("data",))


def padded_worker_count(n_workers: int, mesh: Mesh) -> int:
    """Smallest worker count >= ``n_workers`` that tiles the mesh evenly.

    A fleet whose worker count does not divide the device count is padded
    to this size with DEAD worker slots (no molecules, zero dense rows,
    masked out of every cross-worker mean) instead of erroring — see
    ``DistributedTrainer``.
    """
    if n_workers <= 0:
        raise ValueError(f"n_workers must be positive, got {n_workers}")
    nd = mesh.devices.size
    return -(-n_workers // nd) * nd


def fleet_sharding(mesh: Mesh) -> NamedSharding:
    """Placement for fleet-stacked values (the ``[W, ...]`` parameter tree
    and the ``[W, C, D]`` acting batch): leading worker axis split over
    "data", everything else replicated."""
    return NamedSharding(mesh, P("data"))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_tp(mesh: Mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
