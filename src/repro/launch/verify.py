"""Multi-device truth run: one (rollout x learner x chem x sync) cell of the
equivalence matrix, executed on an nd-device submesh of a forced host pool.

The sharded trainer paths (``fleet_sharded`` acting, the packed ``shard_map``
learner, the DDP/episode mean syncs) are only *believed* correct until
they run on a mesh with nd > 1 — ``--xla_force_host_platform_device_count``
makes any CPU host into that mesh, but the flag must be set in ``XLA_FLAGS``
**before jax initialises** (the ``launch/dryrun.py`` idiom), hence this
subprocess runner: each invocation is one fresh process, one scenario, one
``.npz`` report.

    PYTHONPATH=src python -m repro.launch.verify --nd 2 --out /tmp/nd2.npz \
        --rollout fleet_sharded --learner packed --chem incremental

Every invocation forces the SAME device pool (``--device-pool``, default 8)
and sizes the trainer's mesh as a SUBMESH over the first ``--nd`` devices.
This is load-bearing for bit-equality: XLA-CPU picks matmul kernels and
thread partitions per *client* device count (a plain one-device f32 GEMM
changes its last bits between a 1-device and a 4-device client), so the
nd=1 reference and the nd=4 run must share one client configuration for
their difference to be *the sharding*, not the backend.

The report carries everything the equivalence matrix pins across nd:

* a per-worker digest of the full replay transition stream,
* the loss and mean-final-reward trajectories,
* every live worker's parameter leaves (exact bits),
* compile accounting (``jit_stats``): compiles during warmup vs compiles
  during the measured episodes (the recompiles-after-warmup gate is 0).

tests/multidevice compares these reports at nd in {1, 2, 4} (plus the
ragged W-not-divisible-by-nd fleets that pad to the mesh with dead slots);
identical bits across nd is the acceptance criterion, not a tolerance.

PR-8 adds two robustness scenario families on the same runner:

* crash-resume: ``--ckpt-dir D`` checkpoints the FULL trainer state after
  every episode; ``--kill-at K`` additionally SIGKILLs the process after
  episode K's checkpoint (having first done post-checkpoint work the crash
  destroys); ``--resume`` restores the latest checkpoint and finishes the
  run, treating its first episode back as the compile-warmup window.  The
  resumed report must be BIT-identical (losses, rewards, transition
  digests, replay-state digests, parameter leaves) to a straight-through
  reference — and carry 0 recompiles after warmup on the resumed process.
* fault injection: ``--faults predict,chem`` arms a seeded FaultPlan
  (property-service timeouts, chem exceptions, pipelined-thread crashes)
  behind a ResilientService retry wrapper.  With faults inside the retry
  budgets the report must be bit-identical to the fault-free run; the
  injected/retry counters in the report prove the faults actually fired.
"""

import os
import sys


DEFAULT_DEVICE_POOL = 8


def _flag_from_argv(name: str, default: int) -> int:
    for i, a in enumerate(sys.argv):
        if a == name and i + 1 < len(sys.argv):
            return int(sys.argv[i + 1])
        if a.startswith(name + "="):
            return int(a.split("=", 1)[1])
    return default


if __name__ == "__main__":
    # MUST precede every jax-importing module (jax locks the device count
    # on first init); deliberately OVERWRITES any inherited XLA_FLAGS so a
    # parent process pinned to a different device count cannot leak it into
    # this scenario.  Gated on script execution so merely importing this
    # module (e.g. the CI import smoke-check) has no environment side
    # effects — the dryrun.py idiom, minus the import-time mutation.
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        f"{_flag_from_argv('--device-pool', DEFAULT_DEVICE_POOL)}")

import argparse
import hashlib
import json
import signal


MOLS_SMILES = ("C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O",
               "CC1=CC=CC=C1O", "OC1=CC=CC=C1O")


def _transition_digest(buf) -> str:
    """SHA-256 over the buffer's full transition stream, every field that
    the in-process equivalence matrix compares (tests/test_rollout.py)."""
    import numpy as np

    h = hashlib.sha256()
    for t in buf._items:
        h.update(t.state_fp.tobytes())
        h.update(np.float64(t.steps_left_frac).tobytes())
        h.update(np.float64(t.reward).tobytes())
        h.update(b"\x01" if t.done else b"\x00")
        h.update(t.next_fps.tobytes())
        h.update(np.float64(t.next_steps_left_frac).tobytes())
    return h.hexdigest()


def _replay_state_digest(buf) -> str:
    """SHA-256 over the buffer's FULL serialised state: the SoA rings,
    per-slot priorities, cursor (pos/size), max-priority and the sample
    RNG — what the crash-resume matrix must reproduce bit-exactly."""
    import numpy as np

    h = hashlib.sha256()
    for k, v in sorted(buf.state_dict().items()):
        h.update(k.encode())
        h.update(np.ascontiguousarray(v).tobytes())
    return h.hexdigest()


def _build_fault_plan(args):
    """Seeded FaultPlan from the --faults site list (None when unarmed)."""
    if not args.faults:
        return None
    from repro.core.faults import FaultPlan, FaultRule
    rules = []
    for site in args.faults.split(","):
        site = site.strip()
        if site == "predict":
            # property-service timeouts on a counter schedule, absorbed by
            # the ResilientService retry budget
            rules.append(FaultRule(site="predict", kind="timeout",
                                   every=args.fault_every,
                                   fail_attempts=args.fault_attempts))
        elif site == "chem":
            # content-keyed transient chem exceptions, retried in place
            rules.append(FaultRule(site="chem", kind="transient",
                                   rate=args.fault_rate,
                                   fail_attempts=args.fault_attempts))
        elif site == "pipeline":
            rules.append(FaultRule(site="pipeline", kind="transient",
                                   every=args.fault_every,
                                   fail_attempts=args.fault_attempts))
        else:
            raise SystemExit(f"FAIL: unknown fault site {site!r}")
    return FaultPlan(rules, seed=args.fault_seed)


def run_scenario(args) -> dict:
    """Build the trainer on the forced mesh, train warmup + measured
    episodes, and return the report arrays (see module docstring)."""
    import jax
    import numpy as np

    from repro.chem.smiles import from_smiles
    from repro.core.agent import DQNConfig, QNetwork
    from repro.core.distributed import DistributedTrainer, TrainerConfig
    from repro.core.jit_stats import RecompileCounter
    from repro.core.rollout import EnvConfig
    from repro.core.reward import RewardConfig
    from repro.launch.mesh import make_host_mesh
    # the SHARED deterministic property stub (same class the tier-1 test
    # matrices and chem benches use): jit-free, so the trainer's own jits
    # are the only compiles, and identical answers in every process
    from repro.predictors.service import OracleService

    if jax.device_count() != args.device_pool:
        raise SystemExit(
            f"FAIL: expected a {args.device_pool}-device forced host pool, "
            f"jax sees {jax.device_count()} — XLA_FLAGS was read after jax init?")
    if args.nd > args.device_pool:
        raise SystemExit(f"FAIL: --nd {args.nd} > --device-pool {args.device_pool}")
    mesh = make_host_mesh(args.nd)

    counter = RecompileCounter.install()
    cfg = TrainerConfig(
        n_workers=args.workers, mols_per_worker=args.mols_per_worker,
        episodes=args.warmup + args.episodes, sync_mode=args.sync,
        rollout=args.rollout, learner=args.learner, chem=args.chem,
        acting=args.acting, replay=args.replay,
        priority_alpha=args.priority_alpha, priority_beta0=args.priority_beta0,
        updates_per_episode=args.updates_per_episode,
        train_batch_size=args.batch_size, max_candidates=args.max_candidates,
        scenarios=(tuple(args.scenarios.split(","))
                   if args.scenarios else None),
        dqn=DQNConfig(epsilon_decay=args.epsilon_decay),
        env=EnvConfig(max_steps=args.max_steps), seed=args.seed)
    need = args.workers * args.mols_per_worker
    mols = [from_smiles(MOLS_SMILES[i % len(MOLS_SMILES)]) for i in range(need)]
    hidden = tuple(int(h) for h in args.hidden.split(","))

    plan = _build_fault_plan(args)
    service = OracleService()
    if plan is not None:
        # retry wrapper over the deterministic stub; sleep=None makes the
        # (deterministic, capped) backoff a no-op so scenarios stay fast
        from repro.predictors.service import ResilientService, RetryPolicy
        service = ResilientService(service, RetryPolicy(seed=args.fault_seed),
                                   fault_plan=plan, sleep=None)
    tr = DistributedTrainer(cfg, mols, service, RewardConfig(),
                            mesh=mesh, network=QNetwork(hidden=hidden),
                            fault_plan=plan)
    assert tr.mesh.devices.size == args.nd
    assert tr.engine.n_workers == tr.n_padded_workers
    assert tr.n_padded_workers % args.nd == 0

    mgr = None
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(args.ckpt_dir)
    start_ep = 0
    if args.resume:
        if mgr is None:
            raise SystemExit("FAIL: --resume requires --ckpt-dir")
        start_ep = tr.restore_checkpoint(mgr)

    total = args.warmup + args.episodes

    def run_one() -> None:
        tr.train_episode()
        if mgr is not None and not args.resume:
            # checkpoint cadence: every episode (the writer side of the
            # crash-resume matrix; the resumed side only reads)
            tr.save_checkpoint(mgr)
        if args.kill_at is not None and tr.episode == args.kill_at:
            # post-checkpoint work the crash destroys — resume must
            # reproduce it bit-identically from the last snapshot
            tr.train_episode()
            os.kill(os.getpid(), signal.SIGKILL)

    # a resumed process compiles everything fresh, so its first episode
    # back is its compile-warmup window no matter where the run stopped
    n_warm = (args.warmup - start_ep) if start_ep < args.warmup \
        else (1 if start_ep < total else 0)
    with counter.window() as warm:
        for _ in range(n_warm):
            run_one()
        # one ladder rung of candidate headroom past the warmup high-water
        # mark, so drift in the measured episodes cannot grow the jit shape
        if tr.candidate_capacity:
            tr.reserve_candidates(int(tr.candidate_capacity * 1.3))
    with counter.window() as measured:
        while tr.episode < total:
            run_one()

    fault_stats = tr.engine.fault_stats()
    out = {
        "n_devices": np.int64(tr.mesh.devices.size),
        "device_pool": np.int64(jax.device_count()),
        "n_live_workers": np.int64(tr.n_live_workers),
        "n_padded_workers": np.int64(tr.n_padded_workers),
        # the trainer's checkpointed per-episode logs, so a resumed run's
        # report carries the FULL trajectory, pre-crash episodes included
        "losses": np.asarray(tr.loss_log, np.float64),
        "rewards": np.asarray(tr.reward_log, np.float64),
        "warmup_compiles": np.int64(warm.count),
        "recompiles_after_warmup": np.int64(measured.count),
        "transition_digests": np.asarray(
            [_transition_digest(b) for b in tr.buffers]),
        "replay_state_digests": np.asarray(
            [_replay_state_digest(b) for b in tr.buffers]),
        "n_transitions": np.asarray([len(b) for b in tr.buffers], np.int64),
        "n_faults_injected": np.int64(plan.n_injected if plan is not None else 0),
        "n_retries": np.int64(getattr(service, "n_retries", 0)),
        "n_timeouts": np.int64(getattr(service, "n_timeouts", 0)),
        "n_quarantined": np.int64(fault_stats["n_quarantined"]),
        "n_chem_retries": np.int64(fault_stats["n_chem_retries"]),
        "n_pipeline_restarts": np.int64(fault_stats["n_pipeline_restarts"]),
        "n_incidents": np.int64(fault_stats["n_incidents"]),
        "meta": np.asarray(json.dumps(vars(args), sort_keys=True)),
    }
    # exact parameter bits for every LIVE worker (dead mesh-padding rows are
    # an implementation detail of the padded run; sliced off here so padded
    # and unpadded reports align leaf-for-leaf)
    for i, leaf in enumerate(jax.tree_util.tree_leaves(tr.params)):
        out[f"param_{i}"] = np.asarray(leaf)[: tr.n_live_workers]
    return out


def main() -> None:
    ap = argparse.ArgumentParser(
        description="one multi-device equivalence scenario (see module docstring)")
    ap.add_argument("--nd", type=int, required=True,
                    help="mesh size: submesh over the first nd pool devices")
    ap.add_argument("--device-pool", type=int, default=DEFAULT_DEVICE_POOL,
                    help="forced host device count (set in XLA_FLAGS pre-init; "
                         "IDENTICAL across compared scenarios — see docstring)")
    ap.add_argument("--out", required=True, help="output .npz report path")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--mols-per-worker", type=int, default=2)
    ap.add_argument("--rollout", default="fleet_sharded")
    ap.add_argument("--learner", default="packed")
    ap.add_argument("--chem", default="incremental")
    ap.add_argument("--acting", default="packed",
                    help="fleet acting representation (core.ACTING_MODES)")
    ap.add_argument("--replay", default="uniform",
                    help="replay sampling (core.REPLAY_MODES); prioritized "
                         "with --priority-alpha 0 must match uniform bit "
                         "for bit — the parity scenarios pin exactly that")
    ap.add_argument("--scenarios", default=None,
                    help="comma list of scenario-registry names cycled "
                         "across workers (configs/scenarios.py); "
                         "homogeneous 'antioxidant' must be bit-identical "
                         "to the default path, and each mixed-fleet "
                         "worker to its solo single-scenario twin")
    ap.add_argument("--priority-alpha", type=float, default=0.6)
    ap.add_argument("--priority-beta0", type=float, default=0.4)
    ap.add_argument("--sync", default="episode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warmup", type=int, default=1,
                    help="episodes before the recompile-gate window opens")
    ap.add_argument("--episodes", type=int, default=2,
                    help="measured episodes (compared across nd)")
    ap.add_argument("--max-steps", type=int, default=3)
    ap.add_argument("--updates-per-episode", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-candidates", type=int, default=16)
    ap.add_argument("--hidden", default="32",
                    help="comma-separated QNetwork hidden sizes")
    ap.add_argument("--epsilon-decay", type=float, default=0.9)
    # crash-resume scenarios (docs/robustness.md)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint the full trainer state here after "
                         "every episode")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="SIGKILL the process after episode K's checkpoint "
                         "(plus uncheckpointed post-crash work)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest --ckpt-dir checkpoint and "
                         "finish the run")
    # deterministic fault injection (core.faults.FaultPlan)
    ap.add_argument("--faults", default=None,
                    help="comma list of armed sites: predict,chem,pipeline")
    ap.add_argument("--fault-every", type=int, default=3,
                    help="serial sites: fault every Nth call")
    ap.add_argument("--fault-rate", type=float, default=0.25,
                    help="keyed sites: fraction of molecule keys that fault")
    ap.add_argument("--fault-attempts", type=int, default=1,
                    help="consecutive failures per scheduled call/key "
                         "(> the retry budget makes the fault terminal)")
    ap.add_argument("--fault-seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np

    out = run_scenario(args)
    np.savez(args.out, **out)
    print(f"[verify] nd={args.nd} W={args.workers} rollout={args.rollout} "
          f"learner={args.learner} chem={args.chem} acting={args.acting} "
          f"replay={args.replay} sync={args.sync}: "
          f"{int(out['warmup_compiles'])} warmup compiles, "
          f"{int(out['recompiles_after_warmup'])} recompiles after warmup, "
          f"{int(out['n_transitions'].sum())} transitions -> {args.out}",
          flush=True)


if __name__ == "__main__":
    main()
