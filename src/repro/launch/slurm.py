"""SLURM launch-script generation (paper §3.1: "distributed processes
(workers) are launched by SLURM").

Generates sbatch scripts for the two launch styles in Table 2 (torchrun
for individual/parallel/fine-tuned models; SLURM multi-node for the
general model) translated to JAX distributed initialization.  On a TPU
cluster the same program uses jax.distributed.initialize with the
coordinator from SLURM env vars.

    PYTHONPATH=src python -m repro.launch.slurm --nodes 4 --out run_general.sbatch
"""

from __future__ import annotations

import argparse

TEMPLATE = """#!/bin/bash
#SBATCH --job-name={job}
#SBATCH --nodes={nodes}
#SBATCH --ntasks-per-node={tasks_per_node}
#SBATCH --cpus-per-task={cpus}
#SBATCH --time={time}
#SBATCH --output=logs/%x_%j.out

# DA-MolDQN general-model training (paper Table 1: General row)
export COORD=$(scontrol show hostnames $SLURM_JOB_NODELIST | head -n 1)
export JAX_COORDINATOR_ADDRESS=$COORD:12345
export JAX_NUM_PROCESSES=$SLURM_NTASKS
export JAX_PROCESS_ID=$SLURM_PROCID

srun python -m repro.launch.train --mode rl \\
    --workers {workers} --mols-per-worker {mols_per_worker} \\
    --episodes {episodes} --sync episode
"""


def render(*, job: str = "damoldqn-general", nodes: int = 4, tasks_per_node: int = 4,
           cpus: int = 8, time: str = "02:00:00", workers: int = 16,
           mols_per_worker: int = 4, episodes: int = 250) -> str:
    return TEMPLATE.format(job=job, nodes=nodes, tasks_per_node=tasks_per_node,
                           cpus=cpus, time=time, workers=workers,
                           mols_per_worker=mols_per_worker, episodes=episodes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)       # Table 1: 4 nodes
    ap.add_argument("--episodes", type=int, default=250)  # Table 1
    ap.add_argument("--out", default="run_general.sbatch")
    args = ap.parse_args()
    script = render(nodes=args.nodes, episodes=args.episodes,
                    workers=args.nodes * 4)
    with open(args.out, "w") as f:
        f.write(script)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
