"""Serving launcher: batched greedy decoding with the serve_step path.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \
        --batch 4 --prompt-len 8 --new-tokens 16

Runs prefill (token-by-token fill of the KV/state cache — CPU-scale; real
deployments prefill with the forward path) then greedy decode, printing
tokens/s.  This is the same ``serve_step`` the dry-run lowers for
decode_32k / long_500k.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_cache, init_params, serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch
    total = args.prompt_len + args.new_tokens
    cache = init_cache(cfg, B, total)

    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab, (B, args.prompt_len)).astype(np.int32)

    step = jax.jit(lambda p, c, t: serve_step(p, cfg, c, t))

    # warmup: one decode step on a throwaway cache compiles the [B, 1]
    # decode shape OFF the clock (the timed loop below must measure
    # steady-state decode, not the XLA trace)
    warm_logits, _ = step(params, init_cache(cfg, B, total), prompt[:, :1])
    jax.block_until_ready(warm_logits)

    # prefill (sequentially through the decode path)
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, t : t + 1])

    # drain the async dispatch queue before starting the clock — the
    # prefill's last step is still in flight otherwise, and the first
    # argmax below would silently charge it to the decode timing
    logits = jax.block_until_ready(logits)
    out = []
    tok = np.asarray(np.argmax(np.asarray(logits), axis=-1), np.int32)
    t0 = time.perf_counter()
    for _ in range(args.new_tokens):
        out.append(tok[:, 0])
        logits, cache = step(params, cache, tok)
        tok = np.asarray(np.argmax(np.asarray(logits), axis=-1), np.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    toks = B * args.new_tokens
    print(f"arch={cfg.name} batch={B} decode {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    print("sample token ids:", np.stack(out, axis=1)[0][:12].tolist())


if __name__ == "__main__":
    main()
