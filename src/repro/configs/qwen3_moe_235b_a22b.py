"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]"""

from repro.configs.base import ArchConfig, MoEConfig, register


@register("qwen3-moe-235b-a22b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,                      # per-expert FFN dim
        vocab=151936,
        moe=MoEConfig(n_experts=128, top_k=8),
        rope_theta=1e6,
        source="hf:Qwen/Qwen3-30B-A3B",
    )
