"""The central scenario registry: named ObjectiveSpecs for trainer + server.

ONE table maps scenario names to term-composed objectives
(:class:`repro.core.reward.ObjectiveSpec`).  The trainer mixes them per
worker (``TrainerConfig.scenarios`` — a heterogeneous fleet optimises N
workloads in one run), the serving tier resolves request objectives
through the very same names (``serving.request.resolve_objective``), and
``launch/verify.py`` pins the mixed-fleet determinism contract over them
at nd ∈ {1, 2, 4}.

Built-ins:

=====================  ==================================================
``antioxidant``        the paper's Eq. 1 (w = 0.8/0.2/0.5, Table 3)
``antioxidant_bde``    Eq. 1, BDE-only property signal (w1=1, w2=0)
``antioxidant_ip``     Eq. 1, IP-only property signal (w1=0, w2=1)
``qed``                drug-likeness surrogate (Appendix D comparison)
``plogp``              penalised logP surrogate (Appendix D comparison)
``qed_sa``             QED with an explicit SA penalty (§3.5's filter
                       criterion folded into the objective)
``antioxidant_novel``  Eq. 1 + count-based intrinsic novelty bonus over
                       canonical keys (Thiede et al., arXiv 2012.11293)
``antioxidant_tether`` Eq. 1 + Tanimoto similarity to the slot's own
                       start molecule (MEG-style lead tether)
=====================  ==================================================

The Eq. 1-family scenarios leave their bde/ip bounds unset
(``TermSpec.lo/hi = None``): the trainer's dataset-derived
``RewardConfig`` flows in at compile time (``spec.compile(base=...)``),
while weights and step-decay factors are pinned by the spec itself.
Compiled, the ``antioxidant`` scenario is BIT-identical to
``compute_reward`` under the same config — the registry path costs no
reproducibility.
"""

from __future__ import annotations

from repro.core.reward import ObjectiveSpec, RewardConfig, TermSpec

# Eq. 1 term triple with deferred bounds; weights/factors pinned here
def _eq1_terms(bde_weight: float = 0.8, ip_weight: float = 0.2,
               gamma_weight: float = 0.5) -> tuple[TermSpec, ...]:
    return (
        TermSpec("bde", weight=-bde_weight, factor=0.9),
        TermSpec("ip", weight=ip_weight, factor=0.8),
        TermSpec("gamma", weight=gamma_weight),
    )


SCENARIOS: dict[str, ObjectiveSpec] = {}


def register_scenario(spec: ObjectiveSpec, overwrite: bool = False) -> ObjectiveSpec:
    """Add a spec to the registry under ``spec.name``.  Collisions are an
    error unless ``overwrite=True`` — silently shadowing a scenario other
    workers/requests resolve by name is how fleets diverge."""
    if not overwrite and spec.name in SCENARIOS:
        raise ValueError(f"scenario {spec.name!r} already registered")
    SCENARIOS[spec.name] = spec
    return spec


def get_scenario(name: str) -> ObjectiveSpec:
    """Resolve a scenario name; unknown names raise a ``ValueError`` that
    lists the registry (the serving door-reject message)."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; registry scenarios: "
            f"{list_scenarios()}") from None


def list_scenarios() -> list[str]:
    return sorted(SCENARIOS)


def worker_scenarios(names, n_workers: int) -> list[str]:
    """The per-worker assignment of a ``TrainerConfig.scenarios`` mix:
    the name tuple cycles across the fleet (worker w runs
    ``names[w % len(names)]``).  Validates every name up front."""
    names = list(names)
    if not names:
        raise ValueError("scenarios mix must name at least one scenario")
    for n in names:
        get_scenario(n)
    return [names[w % len(names)] for w in range(n_workers)]


def compile_worker_objectives(names, n_workers: int,
                              base: RewardConfig | None = None) -> list:
    """Per-worker compiled evaluators for a scenario mix: one FRESH
    ``CompiledObjective`` per worker (never shared — the novelty term's
    visit counts are per-worker state, which is what makes a mixed
    fleet's worker bit-identical to its solo twin)."""
    return [get_scenario(n).compile(base=base)
            for n in worker_scenarios(names, n_workers)]


register_scenario(ObjectiveSpec("antioxidant", _eq1_terms()))
register_scenario(ObjectiveSpec("antioxidant_bde", _eq1_terms(1.0, 0.0)))
register_scenario(ObjectiveSpec("antioxidant_ip", _eq1_terms(0.0, 1.0)))
register_scenario(ObjectiveSpec("qed", (TermSpec("qed", weight=1.0),)))
register_scenario(ObjectiveSpec("plogp", (TermSpec("plogp", weight=1.0),)))
register_scenario(ObjectiveSpec("qed_sa", (
    TermSpec("qed", weight=1.0),
    TermSpec("sa", weight=-0.1),
)))
register_scenario(ObjectiveSpec("antioxidant_novel",
                                _eq1_terms() + (TermSpec("novelty", weight=0.1),)))
register_scenario(ObjectiveSpec("antioxidant_tether",
                                _eq1_terms() + (TermSpec("similarity", weight=0.2),)))
