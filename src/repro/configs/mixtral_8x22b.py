"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""

from repro.configs.base import ArchConfig, MoEConfig, register


@register("mixtral-8x22b")
def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=32768,
        moe=MoEConfig(n_experts=8, top_k=2),
        attn_window=4096,               # SWA (native; makes long_500k runnable)
        source="arXiv:2401.04088",
    )
