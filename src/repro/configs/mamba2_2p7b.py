"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128; SSD (state-space duality).  [arXiv:2405.21060]"""

from repro.configs.base import ArchConfig, SSMConfig, register


@register("mamba2-2.7b")
def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=1,                      # attn-free; unused
        n_kv_heads=1,
        d_ff=0,                         # no FFN blocks (mamba2 arch)
        vocab=50280,
        ssm=SSMConfig(state_dim=128),
        tied_embeddings=True,
        source="arXiv:2405.21060",
    )
