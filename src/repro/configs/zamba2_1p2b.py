"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64; Mamba2 blocks + shared attention blocks.
[arXiv:2411.15242]"""

from repro.configs.base import ArchConfig, SSMConfig, register


@register("zamba2-1.2b")
def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32000,
        ssm=SSMConfig(state_dim=64),
        shared_attn_every=6,            # one shared attn block applied every 6 layers
        source="arXiv:2411.15242",
    )
