"""Architecture + input-shape config schema.

Every assigned architecture is expressed as an ``ArchConfig``; reduced
variants (for CPU smoke tests) come from ``cfg.reduced()``.  The four
assigned input shapes live in ``INPUT_SHAPES``.

Conventions:
* ``d_ff`` is the per-path FFN hidden dim (for MoE, the per-expert dim).
* ``n_kv_heads`` == ``n_heads`` means MHA; 1 means MQA.
* ``attn_window`` enables sliding-window attention (mixtral native; for the
  dense archs it is the opt-in variant that makes ``long_500k`` runnable,
  see DESIGN.md §3).
* ``family`` drives block assembly in repro.models.model.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.0
    group_size: int = 256           # GShard dispatch group size (tokens)
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int                  # N
    head_dim: int = 64              # P
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256                # SSD chunk length
    n_groups: int = 1               # B/C groups (Mamba2 'ngroups')


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    n_frames: int = 1500            # whisper encoder positions (stub frontend)


@dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 256            # SigLIP-stub prefix length
    vision_dim: int = 1152          # stub embedding dim before projector


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None     # default d_model // n_heads
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    shared_attn_every: int = 0      # hybrid: shared attn period (0 = none)
    attn_window: int | None = None  # sliding-window size (None = full)
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    act: str = "swiglu"             # swiglu | gelu
    tied_embeddings: bool = False
    dtype: str = "bfloat16"         # params/activations for lowering
    remat: bool = True              # activation-checkpoint each block
    use_pallas: bool = False        # route attention/ssd through kernels
    seq_shard: bool = False         # sequence-parallel activations (beyond-paper
                                    # §Perf option: shard the token dim over
                                    # "model" between attention/MLP blocks)
    source: str = ""                # citation

    # ---------------------------------------------------------- #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def reduced(self) -> "ArchConfig":
        """CPU-smoke variant: <=2 layers, d_model<=512, <=4 experts —
        same family and block structure."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, n_heads)
        changes: dict = dict(
            n_layers=min(self.n_layers, 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=kv,
            head_dim=max(d_model // n_heads, 8),
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            dtype="float32",
            remat=False,
        )
        if self.moe is not None:
            changes["moe"] = replace(self.moe, n_experts=min(self.moe.n_experts, 4),
                                     top_k=min(self.moe.top_k, 2), group_size=32)
        if self.ssm is not None:
            changes["ssm"] = replace(self.ssm, state_dim=min(self.ssm.state_dim, 16),
                                     head_dim=16, chunk=16)
        if self.encdec is not None:
            changes["encdec"] = replace(self.encdec, n_enc_layers=2, n_frames=16)
        if self.vlm is not None:
            changes["vlm"] = replace(self.vlm, n_patches=8, vision_dim=32)
        if self.shared_attn_every:
            changes["shared_attn_every"] = 2
        return replace(self, **changes)

    def with_window(self, window: int) -> "ArchConfig":
        return replace(self, attn_window=window)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
