"""paligemma-3b [vlm] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216; SigLIP vision encoder STUBBED (input_specs supplies patch
embeddings), gemma-style decoder with image-prefix attention.
[arXiv:2407.07726]"""

from repro.configs.base import ArchConfig, VLMConfig, register


@register("paligemma-3b")
def config() -> ArchConfig:
    return ArchConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,                   # gemma-style wide heads
        d_ff=16384,
        vocab=257216,
        vlm=VLMConfig(n_patches=256, vision_dim=1152),
        act="gelu",
        source="arXiv:2407.07726",
    )
