"""whisper-large-v3 [audio] — 32L d_model=1280 20H (MHA) d_ff=5120
vocab=51866; enc-dec, conv/mel frontend STUBBED (input_specs supplies
precomputed frame embeddings).  [arXiv:2212.04356]"""

from repro.configs.base import ArchConfig, EncDecConfig, register


@register("whisper-large-v3")
def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,                    # decoder layers
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51866,
        encdec=EncDecConfig(n_enc_layers=32, n_frames=1500),
        act="gelu",
        rope_theta=1e4,                 # (whisper uses learned pos; RoPE stands in)
        source="arXiv:2212.04356",
    )
