"""The paper's own model: the DA-MolDQN fingerprint Q-network.

Not one of the 10 assigned architectures but included in the dry-run matrix
so the paper's actual train step is exercised on the production mesh (the
'technique-representative' roofline row).  Expressed in ArchConfig terms as
a degenerate dense MLP: the launcher special-cases family="qnet".
"""

from repro.configs.base import ArchConfig, register


@register("damoldqn")
def config() -> ArchConfig:
    return ArchConfig(
        name="damoldqn",
        family="qnet",
        n_layers=5,                     # [1024, 512, 128, 32] + head
        d_model=2049,                   # fingerprint ++ steps-left
        n_heads=1,
        n_kv_heads=1,
        d_ff=1024,
        vocab=0,
        dtype="float32",
        remat=False,
        source="this paper (MolDQN arch, Zhou et al. 2019)",
    )
