"""Architecture configs.

One module per assigned architecture (see the assignment table in
DESIGN.md) plus the paper's own model (``damoldqn``).  ``get_config(name)``
is the registry the launcher uses; ``--arch <id>`` maps to these names.
"""

from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, EncDecConfig, VLMConfig,
    InputShape, INPUT_SHAPES, get_config, register, list_archs,
)

# import for registration side effects
import repro.configs.qwen3_moe_235b_a22b  # noqa: F401
import repro.configs.zamba2_1p2b          # noqa: F401
import repro.configs.stablelm_1p6b        # noqa: F401
import repro.configs.granite_34b          # noqa: F401
import repro.configs.mamba2_2p7b          # noqa: F401
import repro.configs.yi_34b               # noqa: F401
import repro.configs.mixtral_8x22b        # noqa: F401
import repro.configs.whisper_large_v3     # noqa: F401
import repro.configs.paligemma_3b         # noqa: F401
import repro.configs.granite_20b          # noqa: F401
import repro.configs.damoldqn             # noqa: F401

__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "EncDecConfig", "VLMConfig",
    "InputShape", "INPUT_SHAPES", "get_config", "register", "list_archs",
]
