"""Request/response types of the molecule-optimization service.

A request names a start molecule (SMILES), an objective, a step budget,
and an optional deadline; the service answers with exactly one
:class:`RequestResult` carrying a TERMINAL status:

``completed``          the episode ran its budget (or died legally on a
                       molecule with no legal edit) with every property
                       served by the primary tier.
``degraded``           the episode finished, but >= 1 step's properties
                       came from the degraded tier (tripped circuit
                       breaker: cached / oracle-stub values) — the result
                       is usable but not primary-grade.
``deadline_exceeded``  the deadline passed (queued or mid-flight); the
                       slot was reclaimed that very service step and the
                       best-so-far molecule is returned.
``shed``               admission control refused the request (bounded
                       queue full under the configured shedding policy).
``failed``             the request itself is poisoned — unparseable
                       SMILES, unknown objective, injected request fault,
                       or a terminal chem/predict fault quarantined its
                       slot.  Carries the error and an Incident on the
                       service trail; co-batched requests never notice.

Every admitted request reaches exactly one of these — none are lost or
hung, which `bench_serve.py --smoke` gates under an active FaultPlan.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.reward import ObjectiveSpec, RewardConfig

STATUSES = ("completed", "degraded", "deadline_exceeded", "shed", "failed")


def resolve_objective(objective) -> object:
    """Map a request's objective field to what the engine consumes.

    Named objectives resolve through THE scenario registry
    (:mod:`repro.configs.scenarios`) — the same table the trainer mixes
    per worker, so every trainable scenario (``antioxidant``, ``qed``,
    ``plogp``, ...) is requestable (Mol-AIR-style per-request objective
    selection, arXiv 2403.20109).  A name or an ``ObjectiveSpec`` is
    compiled FRESH per request (a novelty term's visit counts are
    request-private state); a ``RewardConfig`` or a callable
    ``(props, initial, current, steps_left) -> float`` passes through
    untouched.  Raises ``ValueError`` on anything else — caught at
    submit time, where it turns into a ``failed`` status whose message
    lists the registry names, instead of a crashed server."""
    if isinstance(objective, ObjectiveSpec):
        return objective.compile()
    if isinstance(objective, RewardConfig) or callable(objective):
        return objective
    if isinstance(objective, str):
        from repro.configs.scenarios import get_scenario
        return get_scenario(objective).compile()
    raise ValueError(
        f"objective must be a scenario name, ObjectiveSpec, RewardConfig, "
        f"or callable, got {type(objective).__name__}")


@dataclass(frozen=True)
class OptimizeRequest:
    """One user query: optimize ``smiles`` under ``objective`` for up to
    ``budget`` env steps, answering within ``deadline`` clock units of
    submission (None = no deadline).  ``seed`` feeds the request's PRIVATE
    exploration RNG stream — co-batched requests never share draws, which
    is what keeps one request's fate from perturbing another's actions."""

    request_id: str
    smiles: str
    objective: object = "antioxidant"
    budget: int = 8
    deadline: float | None = None
    seed: int = 0


@dataclass
class RequestResult:
    """The single terminal answer for one request."""

    request_id: str
    status: str                      # one of STATUSES
    best_smiles: str | None = None   # best-so-far molecule (canonical)
    best_reward: float | None = None
    steps_used: int = 0
    degraded_steps: int = 0          # env steps served by the degraded tier
    submitted_at: float = 0.0        # service-clock units
    finished_at: float = 0.0
    wall_latency_s: float = 0.0      # measured wall clock (reporting only)
    error: str | None = None         # failed: what went wrong

    @property
    def latency(self) -> float:
        """Deterministic latency in service-clock units."""
        return self.finished_at - self.submitted_at

    def as_dict(self) -> dict:
        return {
            "request_id": self.request_id, "status": self.status,
            "best_smiles": self.best_smiles, "best_reward": self.best_reward,
            "steps_used": self.steps_used,
            "degraded_steps": self.degraded_steps,
            "latency": self.latency,
            "wall_latency_s": self.wall_latency_s, "error": self.error,
        }
