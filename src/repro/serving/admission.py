"""Bounded admission queue with load shedding (the backpressure tier).

The service cannot refuse to decide: when the queue is full, ``offer``
returns a VICTIM — either the new arrival (``reject_new``, default: the
queue keeps its oldest work, classic tail-drop) or the oldest queued item
(``evict_oldest``: freshest work wins, the head-drop policy for workloads
where stale requests are worthless anyway).  The caller finalizes the
victim with status ``shed``; nothing is silently dropped.

Deterministic by construction — pure data structure, no clocks, no
threads.  The service owns all access from its driver loop.
"""

from __future__ import annotations

from collections import deque

SHED_POLICIES = ("reject_new", "evict_oldest")


class AdmissionQueue:
    """Bounded FIFO; overflow yields an explicit shed victim."""

    def __init__(self, capacity: int, policy: str = "reject_new"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if policy not in SHED_POLICIES:
            raise ValueError(
                f"policy must be one of {SHED_POLICIES}, got {policy!r}")
        self.capacity = int(capacity)
        self.policy = policy
        self._items: deque = deque()
        self.n_offered = 0
        self.n_shed = 0
        self.depth_high_water = 0

    def __len__(self) -> int:
        return len(self._items)

    def offer(self, item):
        """Enqueue ``item``; returns the shed victim (possibly ``item``
        itself) when the queue is full, else ``None``."""
        self.n_offered += 1
        if len(self._items) < self.capacity:
            self._items.append(item)
            self.depth_high_water = max(self.depth_high_water, len(self._items))
            return None
        self.n_shed += 1
        if self.policy == "reject_new":
            return item
        victim = self._items.popleft()
        self._items.append(item)
        return victim

    def push_front(self, item) -> None:
        """Re-queue an item at the head (transient admission fault retry);
        deliberately allowed to overfill by the in-flight item — the item
        was already admitted once and must not be shed by its own retry."""
        self._items.appendleft(item)

    def pop(self):
        """Dequeue the oldest item, or ``None`` when empty."""
        return self._items.popleft() if self._items else None

    def drain_if(self, pred) -> list:
        """Remove and return every queued item matching ``pred`` (deadline
        expiry sweep), preserving order among survivors."""
        taken, keep = [], deque()
        for it in self._items:
            (taken if pred(it) else keep).append(it)
        self._items = keep
        return taken

    def stats(self) -> dict:
        return {"depth": len(self._items), "capacity": self.capacity,
                "policy": self.policy, "n_offered": self.n_offered,
                "n_shed": self.n_shed,
                "depth_high_water": self.depth_high_water}
