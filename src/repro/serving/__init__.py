"""Robust molecule-optimization serving (docs/serving.md).

``MoleculeOptService`` turns the trained fleet into a request router:
bounded admission queue with load shedding, continuous batching over
``RolloutEngine`` slots, per-request deadlines/objectives/RNG streams,
a circuit breaker over the shared property tier, and structured terminal
statuses for every request.
"""

from repro.serving.admission import SHED_POLICIES, AdmissionQueue
from repro.serving.breaker import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.configs.scenarios import list_scenarios
from repro.serving.request import (STATUSES, OptimizeRequest,
                                   RequestResult, resolve_objective)
from repro.serving.service import (MoleculeOptService, ServeConfig, StepClock)
from repro.serving.stream import (DEFAULT_POOL, INVALID_SMILES, StreamConfig,
                                  drive_open_loop, latency_stats,
                                  seeded_request_stream)

__all__ = [
    "AdmissionQueue", "SHED_POLICIES",
    "CircuitBreaker", "CLOSED", "OPEN", "HALF_OPEN",
    "OptimizeRequest", "RequestResult", "STATUSES",
    "resolve_objective", "list_scenarios",
    "MoleculeOptService", "ServeConfig", "StepClock",
    "StreamConfig", "seeded_request_stream", "drive_open_loop",
    "latency_stats", "DEFAULT_POOL", "INVALID_SMILES",
]
