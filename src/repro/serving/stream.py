"""Seeded open-loop request streams + the serve driver loop.

Shared by ``benchmarks/bench_serve.py``, ``launch/serve_molopt.py``, and
``examples/serve_predictor.py`` so they all speak the same workload:
arrivals are drawn ONCE from a seeded RNG (exponential inter-arrival
times on the service's virtual clock, molecules from a SMILES pool,
mixed budgets/deadlines/objectives, optionally every Nth request
poisoned with unparseable SMILES), then replayed open-loop — the driver
submits whatever is due at the current virtual time and steps the
service, never waiting for responses.  Identical seed => identical
stream => (by the serve determinism contract) identical statuses.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.serving.request import OptimizeRequest
from repro.serving.service import MoleculeOptService

# a churn-friendly default pool: the bench_train multi-start phenols
# (Kekulé form — the subset chem/smiles.py round-trips)
DEFAULT_POOL = (
    "C1=CC=CC=C1O", "CC1=CC(C)=CC(C)=C1O", "CC1=CC=CC=C1O", "OC1=CC=CC=C1O",
    "CC1=CC=C(O)C=C1", "COC1=CC=CC=C1O", "CC(C)C1=CC=CC=C1O", "NC1=CC=CC=C1O",
    "CC1=C(O)C(C)=CC=C1", "OC1=CC=C(O)C=C1", "CCC1=CC=CC=C1O", "CC1=CC(O)=CC=C1",
)

INVALID_SMILES = "not-a-molecule!"


@dataclass(frozen=True)
class StreamConfig:
    n_requests: int = 32
    rate: float = 2.0                # mean arrivals per virtual clock tick
    seed: int = 0
    budget_lo: int = 3
    budget_hi: int = 8               # inclusive
    deadline_frac: float = 0.0       # fraction of requests carrying deadlines
    deadline_lo: float = 4.0         # drawn deadline range (clock units)
    deadline_hi: float = 16.0
    invalid_every: int = 0           # every Nth request is unparseable
    prefix: str = "req"              # request-id prefix (ids must be unique
    #                                # per service — warmup streams differ)


def seeded_request_stream(cfg: StreamConfig, pool: tuple[str, ...] = DEFAULT_POOL
                          ) -> list[tuple[float, OptimizeRequest]]:
    """Draw the whole arrival schedule up front: ``(arrival_time, request)``
    pairs sorted by time.  Pure function of (cfg, pool)."""
    rng = np.random.default_rng(cfg.seed)
    t = 0.0
    out: list[tuple[float, OptimizeRequest]] = []
    for i in range(cfg.n_requests):
        t += float(rng.exponential(1.0 / cfg.rate))
        smiles = pool[int(rng.integers(len(pool)))]
        if cfg.invalid_every and (i + 1) % cfg.invalid_every == 0:
            smiles = INVALID_SMILES
        budget = int(rng.integers(cfg.budget_lo, cfg.budget_hi + 1))
        deadline = None
        if cfg.deadline_frac > 0.0 and rng.random() < cfg.deadline_frac:
            deadline = float(np.round(
                cfg.deadline_lo
                + rng.random() * (cfg.deadline_hi - cfg.deadline_lo), 1))
        out.append((t, OptimizeRequest(
            request_id=f"{cfg.prefix}-{i:04d}", smiles=smiles, budget=budget,
            deadline=deadline, seed=i)))
    return out


def drive_open_loop(svc: MoleculeOptService,
                    arrivals: list[tuple[float, OptimizeRequest]],
                    max_steps: int = 100_000) -> list[int]:
    """Replay ``arrivals`` against the service's virtual clock: submit
    everything due, step, repeat until the stream is exhausted AND the
    service is idle.  Raises if any request hangs past ``max_steps`` —
    every admitted request must terminate.  Returns the per-step count of
    newly finalized results (the streaming trace)."""
    i = 0
    trace: list[int] = []
    for _ in range(max_steps):
        while i < len(arrivals) and arrivals[i][0] <= svc.clock.now():
            svc.submit(arrivals[i][1])
            i += 1
        if i >= len(arrivals) and svc.idle:
            return trace
        trace.append(len(svc.step()))
    raise RuntimeError(
        f"stream not drained after {max_steps} steps "
        f"({i}/{len(arrivals)} submitted, idle={svc.idle})")


def latency_stats(results) -> dict:
    """p50/p99 latency over the terminal results, virtual + wall."""
    if not results:
        return {"p50_latency": 0.0, "p99_latency": 0.0,
                "p50_wall_ms": 0.0, "p99_wall_ms": 0.0}
    lat = np.array([r.latency for r in results], np.float64)
    wall = np.array([r.wall_latency_s for r in results], np.float64) * 1e3
    return {
        "p50_latency": float(np.percentile(lat, 50)),
        "p99_latency": float(np.percentile(lat, 99)),
        "p50_wall_ms": float(np.percentile(wall, 50)),
        "p99_wall_ms": float(np.percentile(wall, 99)),
    }
