"""Circuit breaker over the shared cross-request property tier.

The ``PropertyService``/``ChemCache`` tier is shared by every co-batched
request, so a sick predictor backend is a CORRELATED failure: without a
breaker, every request burns the retry budget on every step until the
whole fleet quarantines.  The breaker converts that into graceful
degradation:

``closed``     pass-through.  Terminal ``FaultError``s count; at
               ``failure_threshold`` consecutive failures the breaker
               trips (below it, the error propagates and the engine's
               per-molecule isolation handles the single row).
``open``       every call is served by the DEGRADED tier
               (``predictors.service.DegradedPropertyService``: primary's
               LRU cache, else the deterministic oracle stub) — no
               primary traffic at all.  Served molecules are remembered
               so the service can flag the owning requests ``degraded``.
               After ``cooldown_calls`` fallback serves, the next call
               becomes a half-open probe.
``half_open``  ONE probe call goes to the primary.  Success closes the
               breaker (counts reset); failure re-opens it and the probe
               batch is served degraded.

Everything is COUNT-based, never wall-clock-based: under a seeded
FaultPlan the trip/probe/recovery sequence is a pure function of the call
stream, which is what lets ``bench_serve --smoke`` pin shed/degraded
counts run-to-run.  Any non-fault exception propagates untouched — the
breaker absorbs the fault taxonomy, not bugs.
"""

from __future__ import annotations

import threading

from repro.faults import FaultError

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    """Wraps a property service; every other attribute delegates to it."""

    def __init__(self, inner, fallback, *, failure_threshold: int = 3,
                 cooldown_calls: int = 8):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if cooldown_calls < 1:
            raise ValueError("cooldown_calls must be >= 1")
        self.inner = inner
        self.fallback = fallback
        self.failure_threshold = int(failure_threshold)
        self.cooldown_calls = int(cooldown_calls)
        self.state = CLOSED
        self._lock = threading.Lock()
        self._consecutive_failures = 0
        self._open_serves = 0           # fallback serves since the trip
        self._degraded_keys: set[str] = set()
        self.n_trips = 0
        self.n_fallback_serves = 0      # batches served by the degraded tier
        self.n_probes = 0
        self.n_probe_failures = 0
        self.n_recoveries = 0

    def __getattr__(self, name):
        # reserve(), cache, n_predict_calls, ... pass through untouched
        return getattr(self.inner, name)

    # ------------------------------------------------------------ #
    def _serve_fallback(self, mols):
        self.n_fallback_serves += 1
        self._open_serves += 1
        self._degraded_keys.update(m.canonical_key() for m in mols)
        return self.fallback.predict(mols)

    def _trip(self) -> None:
        self.state = OPEN
        self.n_trips += 1
        self._consecutive_failures = 0
        self._open_serves = 0

    def predict(self, mols):
        with self._lock:
            if self.state == OPEN:
                if self._open_serves < self.cooldown_calls:
                    return self._serve_fallback(mols)
                self.state = HALF_OPEN       # cooldown over: probe now

            if self.state == HALF_OPEN:
                self.n_probes += 1
                try:
                    out = self.inner.predict(mols)
                except FaultError:
                    self.n_probe_failures += 1
                    self._trip()
                    return self._serve_fallback(mols)
                self.state = CLOSED
                self.n_recoveries += 1
                self._consecutive_failures = 0
                return out

            try:                             # CLOSED
                out = self.inner.predict(mols)
            except FaultError:
                self._consecutive_failures += 1
                if self._consecutive_failures >= self.failure_threshold:
                    self._trip()
                    return self._serve_fallback(mols)
                raise                        # below threshold: let the
                #                            # engine isolate the one row
            self._consecutive_failures = 0
            return out

    # ------------------------------------------------------------ #
    def drain_degraded_keys(self) -> set[str]:
        """Canonical keys served by the degraded tier since the last
        drain — the service maps them back to requests after each step."""
        with self._lock:
            keys, self._degraded_keys = self._degraded_keys, set()
            return keys

    def stats(self) -> dict:
        with self._lock:
            return {
                "state": self.state,
                "n_trips": self.n_trips,
                "n_fallback_serves": self.n_fallback_serves,
                "n_probes": self.n_probes,
                "n_probe_failures": self.n_probe_failures,
                "n_recoveries": self.n_recoveries,
            }
