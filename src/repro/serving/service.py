"""MoleculeOptService: the continuously-batched request router.

The trained policy is a generalist (the paper's premise: optimize NEW
molecules without retraining), so serving is a scheduling problem, not a
learning one.  Concurrent user requests ARE fleet slots: the service owns
one ``RolloutEngine`` whose W workers each hold at most one in-flight
request, and every service step is ONE fleet env step — one Q dispatch,
one property batch — over whatever request mix is currently bound.

Continuous batching: a finished / quarantined / deadline-reclaimed slot
is rebound to the next queued request the very next service step
(``RolloutEngine.bind_slot``), while its co-batched neighbours keep
stepping undisturbed.  Request objectives resolve through THE scenario
registry (``configs/scenarios.py``) at the door — the same table the
trainer mixes per worker — so the in-flight mix is a heterogeneous
objective fleet exactly like a ``TrainerConfig.scenarios`` run.  The dense Q batch keeps ONE compiled shape
``[W, C_cap, STATE_DIM]`` via the sticky capacity-ladder buffer, so a
churning request mix causes 0 XLA recompiles after warmup.

Isolation, so one request can never hurt another:

* per-request exploration RNG streams (seeded from the request) — a
  request's action draws are independent of who it is batched with;
* per-row Q values — each candidate row's matmul result is independent of
  the other rows' values at fixed shape;
* per-molecule property isolation + quarantine (PR 8) — a poisoned
  request drains ITS slot with an Incident, siblings never notice;
* the circuit breaker (serving/breaker.py) over the SHARED property tier
  — the one genuinely correlated failure mode degrades to cached/stub
  properties flagged ``degraded`` instead of sinking the fleet.

Together these give the serve determinism contract ``bench_serve.py``
gates: under a seeded FaultPlan every admitted request reaches a terminal
status, and every request the faults never touched returns a result
BIT-identical to the unfaulted run's.

Time: the service clock is a VIRTUAL step clock (one tick per service
step) — deadlines, shedding, and reported ``latency`` are deterministic
functions of the request stream.  Wall-clock latency is measured
separately and only reported (``wall_latency_s``, the bench's p50/p99).
"""

from __future__ import annotations

import time
import zlib
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.chem.chemcache import ChemCache
from repro.chem.molecule import Molecule
from repro.chem.smiles import canonical_smiles, from_smiles
from repro.core.agent import candidate_capacity, candidate_capacity_table
from repro.core.faults import FaultError, Incident, TransientFault
from repro.core.rollout import STATE_DIM, EnvConfig, RolloutEngine, Slot
from repro.predictors.service import DegradedPropertyService
from repro.serving.admission import AdmissionQueue
from repro.serving.breaker import CircuitBreaker
from repro.serving.request import (OptimizeRequest, RequestResult,
                                   resolve_objective)


class StepClock:
    """Virtual service clock: ``tick`` units per service step.  Purely
    deterministic — the clock that deadlines and shedding run on."""

    def __init__(self, tick: float = 1.0):
        self.t = 0.0
        self.tick = float(tick)

    def now(self) -> float:
        return self.t

    def advance(self) -> None:
        self.t += self.tick


@dataclass(frozen=True)
class ServeConfig:
    """Admission / degradation knobs (docs/serving.md)."""

    n_slots: int = 8                 # fleet width = max co-batched requests
    max_queue: int = 64              # admission queue bound (backpressure)
    shed_policy: str = "reject_new"  # or "evict_oldest"
    max_steps: int = 16              # env horizon; budgets clamp to this
    epsilon: float = 0.0             # per-request exploration rate
    breaker_threshold: int = 3       # consecutive FaultErrors to trip
    breaker_cooldown: int = 8        # degraded serves before half-open probe
    chem: str = "incremental"
    seed: int = 0                    # folds into every request RNG stream


@dataclass
class _Flight:
    """One admitted request's mutable serving state."""

    req: OptimizeRequest
    molecule: Molecule | None
    objective: object
    budget: int
    submitted_at: float
    deadline_at: float | None
    wall_t0: float
    rng: np.random.Generator
    steps_used: int = 0
    degraded_steps: int = 0
    incident_mark: int = 0           # engine incident count at bind


class _ServePolicy:
    """Dense ``FleetPolicy`` with a sticky ``[W, C_cap, STATE_DIM]``
    buffer: capacity only ever climbs the candidate ladder, so a churning
    request mix reuses one compiled Q-dispatch shape (0 recompiles after
    warmup).  Parameters are SHARED across slots — serving runs one
    trained generalist policy, so the dispatch is a plain batched apply.
    Per-row results are independent of sibling rows' values at fixed
    shape, which is what makes co-batching invisible in the numbers."""

    def __init__(self, network, params, select_fn, n_workers: int):
        self.params = params
        self._select_fn = select_fn
        self.n_workers = n_workers
        self._table = candidate_capacity_table(n_workers)
        self._cap = 0
        self._buf: np.ndarray | None = None
        self._apply = jax.jit(network.apply)
        self.n_dispatches = 0

    def reserve(self, max_candidates: int) -> None:
        cap = candidate_capacity(max(1, int(max_candidates)), self._table)
        if cap > self._cap:
            self._cap = cap
            self._buf = np.zeros((self.n_workers, cap, STATE_DIM), np.float32)

    def warm_dispatch(self) -> None:
        """Compile the current capacity's shape off the serving path."""
        self.reserve(1)
        self._dispatch()

    def _dispatch(self) -> np.ndarray:
        self.n_dispatches += 1
        return np.asarray(self._apply(self.params, jnp.asarray(self._buf)))

    def fleet_q_values(self, per_worker) -> list[np.ndarray]:
        counts = [x.shape[0] for x in per_worker]
        self.reserve(max(counts))
        buf = self._buf
        for w, x in enumerate(per_worker):
            buf[w, :counts[w]] = x
            buf[w, counts[w]:] = 0.0
        q = self._dispatch()
        return [q[w, :n] for w, n in enumerate(counts)]

    def select_action(self, q: np.ndarray, worker: int) -> int:
        return self._select_fn(q, worker)


class MoleculeOptService:
    """Bounded-queue, continuously-batched molecule-optimization server.

    Drive it with ``submit`` + ``step`` (or ``run_until_idle``); every
    submitted request ends up exactly once in ``results`` with a terminal
    status (serving/request.py).  See module docstring for the contracts.
    """

    def __init__(self, network, params, property_service, *,
                 cfg: ServeConfig = ServeConfig(),
                 fault_plan=None, clock=None, fallback=None,
                 chem_cache: ChemCache | None = None):
        self.cfg = cfg
        self.clock = clock if clock is not None else StepClock()
        self.fault_plan = fault_plan
        self.engine = RolloutEngine(
            [[] for _ in range(cfg.n_slots)],
            EnvConfig(max_steps=cfg.max_steps),
            chem=cfg.chem, chem_cache=chem_cache, fault_plan=fault_plan)
        self.breaker = CircuitBreaker(
            property_service,
            fallback if fallback is not None
            else DegradedPropertyService(property_service),
            failure_threshold=cfg.breaker_threshold,
            cooldown_calls=cfg.breaker_cooldown)
        try:
            property_service.reserve(cfg.n_slots)
        except AttributeError:
            pass                     # stubs have no padding ladder
        self.queue = AdmissionQueue(cfg.max_queue, cfg.shed_policy)
        self._policy = _ServePolicy(
            network, params, self._select_action, cfg.n_slots)
        self._free: deque[int] = deque(range(cfg.n_slots))
        self._active: dict[int, _Flight] = {}
        self._retry_bind: list[_Flight] = []
        self._inflight_ids: set[str] = set()
        self.results: list[RequestResult] = []
        self.result_by_id: dict[str, RequestResult] = {}
        self.incidents: list[Incident] = []   # serve-site incident trail
        self.status_counts = {s: 0 for s in
                              ("completed", "degraded", "deadline_exceeded",
                               "shed", "failed")}
        self.n_submitted = 0
        self.n_bound = 0
        self.n_bind_retries = 0
        self.n_service_steps = 0

    # ------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------ #
    def submit(self, req: OptimizeRequest) -> str:
        """Admit one request.  Returns ``"queued"``, ``"shed"``, or
        ``"failed"`` (parse/objective rejects decided at the door).  A
        shed/failed verdict is ALSO a terminal result in ``results`` —
        submit never silently drops work."""
        self.n_submitted += 1
        now = self.clock.now()
        fl = _Flight(
            req=req, molecule=None, objective=None,
            budget=max(1, min(int(req.budget), self.cfg.max_steps)),
            submitted_at=now,
            deadline_at=(now + req.deadline
                         if req.deadline is not None else None),
            wall_t0=time.perf_counter(),
            rng=np.random.default_rng(
                [self.cfg.seed, req.seed,
                 zlib.crc32(req.request_id.encode())]))
        # poisoned requests fail AT THE DOOR — they never touch a slot,
        # so invalid SMILES cannot stall a co-batched neighbour
        try:
            if req.request_id in self._inflight_ids \
                    or req.request_id in self.result_by_id:
                raise ValueError(f"duplicate request_id {req.request_id!r}")
            fl.objective = resolve_objective(req.objective)
            fl.molecule = from_smiles(req.smiles)
            if fl.molecule.num_atoms == 0:
                raise ValueError("empty molecule")
        except Exception as e:  # noqa: BLE001 — any reject is the same story
            self._record_incident(site="parse", key=req.request_id,
                                  error=repr(e), action="rejected")
            self._finalize(fl, "failed", error=repr(e))
            return "failed"
        victim = self.queue.offer(fl)
        if victim is None:
            self._inflight_ids.add(req.request_id)
            return "queued"
        if victim is not fl:                      # evict_oldest shed
            self._inflight_ids.add(req.request_id)
            self._inflight_ids.discard(victim.req.request_id)
        self._finalize(victim, "shed")
        return "shed" if victim is fl else "queued"

    # ------------------------------------------------------------ #
    # the service step (one virtual clock tick)
    # ------------------------------------------------------------ #
    def step(self) -> list[RequestResult]:
        """One continuous-batching service step: expire deadlines, admit
        queued requests into free slots, advance the fleet ONE env step,
        finalize newly-terminal requests.  Returns the results finalized
        during this step (the streaming interface)."""
        mark = len(self.results)
        now = self.clock.now()
        for fl in reversed(self._retry_bind):     # transient bind retries
            self.queue.push_front(fl)
        self._retry_bind = []
        for fl in self.queue.drain_if(
                lambda f: f.deadline_at is not None and now >= f.deadline_at):
            self._finalize(fl, "deadline_exceeded")
        self._reclaim_deadlines(now)
        self._admit()
        stepped = [w for w, fl in self._active.items()
                   if self._slot(w).steps_left > 0]
        if stepped:
            self.engine.step(self._policy, self.breaker,
                             None, buffers=None)
            self.n_service_steps += 1
            degraded = self.breaker.drain_degraded_keys()
            for w in stepped:
                fl = self._active[w]
                fl.steps_used += 1
                if self._slot(w).current.canonical_key() in degraded:
                    fl.degraded_steps += 1
        self._collect_terminal()
        self.clock.advance()
        return self.results[mark:]

    @property
    def idle(self) -> bool:
        return not self._active and not len(self.queue) \
            and not self._retry_bind

    def run_until_idle(self, max_steps: int = 100_000) -> list[RequestResult]:
        """Step until every admitted request is terminal.  The hard cap is
        a liveness backstop: hitting it means a request hung, which the
        terminal-status contract forbids — so it raises."""
        mark = len(self.results)
        for _ in range(max_steps):
            if self.idle:
                return self.results[mark:]
            self.step()
        raise RuntimeError(
            f"service not idle after {max_steps} steps: "
            f"{len(self._active)} active, {len(self.queue)} queued")

    # ------------------------------------------------------------ #
    def _slot(self, w: int) -> Slot:
        return self.engine.workers[w][0]

    def _select_action(self, q: np.ndarray, worker: int) -> int:
        """Per-REQUEST epsilon-greedy: draws come from the bound request's
        private RNG stream, so shed/failed/reordered neighbours cannot
        shift another request's exploration sequence."""
        fl = self._active[worker]
        if self.cfg.epsilon > 0.0 and fl.rng.random() < self.cfg.epsilon:
            return int(fl.rng.integers(0, q.shape[0]))
        return int(np.argmax(q))

    def _reclaim_deadlines(self, now: float) -> None:
        """A slot is reclaimed the service step its deadline passes: the
        in-flight transition is dropped, the worker is freed for the next
        queued request, and the best-so-far molecule ships back."""
        for w in list(self._active):
            fl = self._active[w]
            if fl.deadline_at is not None and now >= fl.deadline_at:
                slot = self._slot(w)
                self.engine.kill_slot(w)
                self._release(w)
                self._finalize(fl, "deadline_exceeded", slot=slot)

    def _admit(self) -> None:
        while self._free and len(self.queue):
            fl = self.queue.pop()
            if self.fault_plan is not None \
                    and self.fault_plan.has_rule("request"):
                try:
                    self.fault_plan.check_key("request", fl.req.request_id)
                except FaultError as e:
                    self._record_incident(
                        site="request", key=fl.req.request_id,
                        error=repr(e), action="failed")
                    self._finalize(fl, "failed", error=repr(e))
                    continue
                except TransientFault:
                    # retried at the head of the queue NEXT step — the
                    # burst is bounded by the rule's fail_attempts
                    self.n_bind_retries += 1
                    self._retry_bind.append(fl)
                    continue
            w = self._free.popleft()
            fl.incident_mark = len(self.engine.incidents)
            self.engine.bind_slot(w, fl.molecule, fl.budget,
                                  objective=fl.objective)
            self._active[w] = fl
            self.n_bound += 1

    def _collect_terminal(self) -> None:
        for w in list(self._active):
            fl = self._active[w]
            slot = self._slot(w)
            if slot.steps_left > 0:
                continue
            error = None
            for inc in self.engine.incidents[fl.incident_mark:]:
                if inc.worker == w and inc.action == "quarantined":
                    error = inc.error
                    break
            self._release(w)
            if error is not None:
                self._finalize(fl, "failed", error=error, slot=slot)
            elif fl.degraded_steps > 0:
                self._finalize(fl, "degraded", slot=slot)
            else:
                self._finalize(fl, "completed", slot=slot)

    def _release(self, w: int) -> None:
        del self._active[w]
        self.engine.workers[w] = []
        self.engine.worker_initials[w] = []
        self._free.append(w)

    def _finalize(self, fl: _Flight, status: str, *, error: str | None = None,
                  slot: Slot | None = None) -> RequestResult:
        best_smiles = best_reward = None
        if slot is not None and slot.best is not None:
            best_reward, best_mol = slot.best
            best_smiles = canonical_smiles(best_mol)
        res = RequestResult(
            request_id=fl.req.request_id, status=status,
            best_smiles=best_smiles, best_reward=best_reward,
            steps_used=fl.steps_used, degraded_steps=fl.degraded_steps,
            submitted_at=fl.submitted_at, finished_at=self.clock.now(),
            wall_latency_s=time.perf_counter() - fl.wall_t0, error=error)
        self.results.append(res)
        self.result_by_id[res.request_id] = res
        self.status_counts[status] += 1
        self._inflight_ids.discard(fl.req.request_id)
        return res

    def _record_incident(self, *, site: str, key: str, error: str,
                         action: str) -> None:
        self.incidents.append(Incident(
            episode=0, step=self.n_service_steps, site=site,
            worker=-1, slot=-1, key=key, error=error, action=action))

    # ------------------------------------------------------------ #
    def reserve_candidates(self, max_candidates: int) -> None:
        """Pre-size + compile the Q-dispatch buffer (warmup): after this,
        request mixes whose candidate counts stay inside the reservation
        cause ZERO recompiles — the bench gate."""
        self._policy.reserve(max_candidates)
        self._policy.warm_dispatch()

    def stats(self) -> dict:
        """Operator counters: admission, statuses, breaker, engine faults."""
        return {
            "n_submitted": self.n_submitted,
            "n_bound": self.n_bound,
            "n_bind_retries": self.n_bind_retries,
            "n_service_steps": self.n_service_steps,
            "n_q_dispatches": self._policy.n_dispatches,
            "status_counts": dict(self.status_counts),
            "queue": self.queue.stats(),
            "breaker": self.breaker.stats(),
            "engine_faults": self.engine.fault_stats(),
            "serve_incidents": [i.as_dict() for i in self.incidents],
        }
