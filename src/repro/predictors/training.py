"""Train the surrogate predictors against the chemistry oracle.

The paper's predictors come pre-trained on >100k molecules; ours are small
enough to train here, but they must generalise to the molecules the *RL
agent* visits, not just the dataset — so the training corpus augments the
antioxidant sets with random edit-walks (the same action space the agent
uses).  Accuracy target is the paper's: <5% average relative error (§2.2).

``ensure_trained`` is the entry point everything else uses: it trains once
and caches params + a metrics json under ``.cache/predictors``.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.chem.actions import enumerate_actions
from repro.chem.molecule import Molecule
from repro.chem.oracle import oracle_bde, oracle_ip
from repro.checkpoint import load_pytree, save_pytree
from repro.data.datasets import antioxidant_dataset, public_antioxidant_dataset
from repro.optim import adam
from repro.optim.adam import apply_updates
from repro.predictors.gnn import AlfabetS, BDE_MEAN, BDE_SCALE
from repro.predictors.ip_net import AIMNetS, IP_MEAN, IP_SCALE
from repro.predictors.service import MAX_ATOMS, featurize, stack_features

DEFAULT_CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", ".cache", "predictors")


# ------------------------------------------------------------------ #
# corpus
# ------------------------------------------------------------------ #
def build_corpus(n_walk_steps: int = 3, seed: int = 11, max_mols: int = 4000) -> list[Molecule]:
    """Dataset molecules + random edit-walk intermediates (dedup'd)."""
    rng = np.random.default_rng(seed)
    base = antioxidant_dataset(600) + public_antioxidant_dataset(256)
    out: list[Molecule] = []
    seen: set[int] = set()

    def add(m: Molecule) -> None:
        key = m.iso_key()
        if key not in seen and m.num_atoms <= MAX_ATOMS:
            seen.add(key)
            out.append(m)

    for m in base:
        add(m)
    for m in base:
        cur = m
        for _ in range(n_walk_steps):
            acts = enumerate_actions(cur, protect_oh=True)
            if len(acts) <= 1:
                break
            cur = acts[int(rng.integers(1, len(acts)))].result
            add(cur)
        if len(out) >= max_mols:
            break
    return out[:max_mols]


def featurized_corpus(mols: list[Molecule]) -> tuple[dict, np.ndarray, np.ndarray, np.ndarray]:
    """Stacked features + oracle targets + validity masks."""
    feats = stack_features([featurize(m) for m in mols])
    bde = np.array([oracle_bde(m) if m.has_oh_bond() else np.nan for m in mols], np.float32)
    ip = np.array([oracle_ip(m) for m in mols], np.float32)
    has_bde = np.isfinite(bde)
    return feats, bde, ip, has_bde


# ------------------------------------------------------------------ #
# training loops
# ------------------------------------------------------------------ #
def _minibatches(rng: np.random.Generator, n: int, batch: int):
    while True:
        order = rng.permutation(n)
        for s in range(0, n - batch + 1, batch):
            yield order[s : s + batch]


def train_bde_model(
    mols: list[Molecule] | None = None,
    *,
    steps: int = 1500,
    batch_size: int = 128,
    lr: float = 3e-4,
    seed: int = 0,
    log_every: int = 0,
) -> tuple[AlfabetS, dict, dict]:
    """Returns (model, params, metrics)."""
    model = AlfabetS()
    mols = mols if mols is not None else build_corpus()
    feats, bde, _, has_bde = featurized_corpus(mols)
    idx = np.nonzero(has_bde)[0]
    n_hold = max(len(idx) // 10, 1)
    hold, train = idx[:n_hold], idx[n_hold:]

    params = model.init(jax.random.PRNGKey(seed))
    opt = adam(lr, clip_norm=1.0)
    state = opt.init(params)

    target_n = (bde - BDE_MEAN) / BDE_SCALE

    @jax.jit
    def step(params, state, batch, tgt):
        def loss_fn(p):
            _, mol_bde = model.apply(p, batch)
            pred_n = (mol_bde - BDE_MEAN) / BDE_SCALE
            return jnp.mean(jnp.square(pred_n - tgt))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, state2 = opt.update(grads, state, params)
        return apply_updates(params, updates), state2, loss

    rng = np.random.default_rng(seed)
    gen = _minibatches(rng, len(train), min(batch_size, len(train)))
    for it in range(steps):
        sel = train[next(gen)]
        batch = {k: jnp.asarray(v[sel]) for k, v in feats.items()}
        params, state, loss = step(params, state, batch, jnp.asarray(target_n[sel]))
        if log_every and (it + 1) % log_every == 0:
            print(f"[bde] step {it+1}: loss {float(loss):.4f}")

    metrics = _eval_bde(model, params, feats, bde, hold)
    return model, params, metrics


def _eval_bde(model, params, feats, bde, idx) -> dict:
    batch = {k: jnp.asarray(v[idx]) for k, v in feats.items()}
    _, pred = jax.jit(model.apply)(params, batch)
    pred = np.asarray(pred)
    rel = np.abs(pred - bde[idx]) / np.abs(bde[idx])
    return {"rel_err_mean": float(rel.mean()), "rel_err_p95": float(np.percentile(rel, 95)),
            "mae": float(np.abs(pred - bde[idx]).mean()), "n_eval": int(len(idx))}


def train_ip_model(
    mols: list[Molecule] | None = None,
    *,
    steps: int = 1500,
    batch_size: int = 128,
    lr: float = 3e-4,
    seed: int = 1,
    log_every: int = 0,
) -> tuple[AIMNetS, dict, dict]:
    model = AIMNetS()
    mols = mols if mols is not None else build_corpus()
    feats, _, ip, _ = featurized_corpus(mols)
    valid = np.nonzero(feats["conf_valid"] > 0.5)[0]
    n_hold = max(len(valid) // 10, 1)
    hold, train = valid[:n_hold], valid[n_hold:]

    params = model.init(jax.random.PRNGKey(seed))
    opt = adam(lr, clip_norm=1.0)
    state = opt.init(params)
    target_n = (ip - IP_MEAN) / IP_SCALE

    @jax.jit
    def step(params, state, batch, tgt):
        def loss_fn(p):
            pred = model.apply(p, batch)
            return jnp.mean(jnp.square((pred - IP_MEAN) / IP_SCALE - tgt))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, state2 = opt.update(grads, state, params)
        return apply_updates(params, updates), state2, loss

    rng = np.random.default_rng(seed)
    gen = _minibatches(rng, len(train), min(batch_size, len(train)))
    for it in range(steps):
        sel = train[next(gen)]
        batch = {k: jnp.asarray(v[sel]) for k, v in feats.items()}
        params, state, loss = step(params, state, batch, jnp.asarray(target_n[sel]))
        if log_every and (it + 1) % log_every == 0:
            print(f"[ip] step {it+1}: loss {float(loss):.4f}")

    batch = {k: jnp.asarray(v[hold]) for k, v in feats.items()}
    pred = np.asarray(jax.jit(model.apply)(params, batch))
    rel = np.abs(pred - ip[hold]) / np.abs(ip[hold])
    metrics = {"rel_err_mean": float(rel.mean()), "rel_err_p95": float(np.percentile(rel, 95)),
               "mae": float(np.abs(pred - ip[hold]).mean()), "n_eval": int(len(hold))}
    return model, params, metrics


# ------------------------------------------------------------------ #
# disk-cached entry point
# ------------------------------------------------------------------ #
def ensure_trained(cache_dir: str | None = None, *, steps: int = 1500, verbose: bool = True):
    """Train-or-load both predictors.  Returns (bde_model, bde_params,
    ip_model, ip_params, metrics)."""
    cache_dir = os.path.abspath(cache_dir or DEFAULT_CACHE_DIR)
    os.makedirs(cache_dir, exist_ok=True)
    bde_path = os.path.join(cache_dir, "alfabet_s.npz")
    ip_path = os.path.join(cache_dir, "aimnet_s.npz")
    meta_path = os.path.join(cache_dir, "metrics.json")

    bde_model, ip_model = AlfabetS(), AIMNetS()
    if os.path.exists(bde_path) and os.path.exists(ip_path) and os.path.exists(meta_path):
        bde_params = load_pytree(bde_path, bde_model.init(jax.random.PRNGKey(0)))
        ip_params = load_pytree(ip_path, ip_model.init(jax.random.PRNGKey(1)))
        with open(meta_path) as f:
            metrics = json.load(f)
        return bde_model, bde_params, ip_model, ip_params, metrics

    if verbose:
        print("[predictors] training Alfabet-S + AIMNet-S against the oracle ...")
    mols = build_corpus()
    bde_model, bde_params, bde_metrics = train_bde_model(mols, steps=steps)
    ip_model, ip_params, ip_metrics = train_ip_model(mols, steps=steps)
    metrics = {"bde": bde_metrics, "ip": ip_metrics}
    if verbose:
        print(f"[predictors] BDE rel err {bde_metrics['rel_err_mean']:.3%}, "
              f"IP rel err {ip_metrics['rel_err_mean']:.3%}")
    save_pytree(bde_path, bde_params)
    save_pytree(ip_path, ip_params)
    with open(meta_path, "w") as f:
        json.dump(metrics, f, indent=2)
    return bde_model, bde_params, ip_model, ip_params, metrics
