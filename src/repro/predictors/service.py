"""PropertyService: the RL loop's view of the two predictors (+ cache).

Responsibilities, mirroring §3.3/§3.6:

* features: molecule -> padded graph arrays (+ pseudo-conformer geometry);
* batched jit inference with shape bucketing (predictors are shared by all
  molecules in a worker's modification batch — the paper's stated reason
  for batched modification);
* the LRU cache, keyed by isomorphism-invariant hashes;
* the invalid-conformer protocol: molecules with no valid 3D conformer get
  ``ip = None`` (the environment maps that to reward -1000);
* molecules with no O-H bond get ``bde = None`` (protected actions should
  make this unreachable from valid starts).

``PropertyService.predict`` is the ONLY property entry point the RL core
uses, so predictor-call counting here gives the §3.6 cache statistics.

Fault tolerance (PR 8): ``ResilientService`` wraps ANY property service
(``PropertyService``, ``OracleService``, test stubs) with bounded retries,
deterministic seeded backoff, and an optional per-call timeout.  Because
every wrapped predictor is deterministic, a retried batch is bit-identical
to a first-try batch — the property that keeps the equivalence matrix
intact under injected faults (gated by tests/test_faults.py and the
``bench_train --smoke --faults`` CI cell).
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import numpy as np

from repro.faults import FaultError, FaultTimeout, TransientFault

from repro.chem.conformer import CONFORMER_FEATURE_DIM, conformer_features, has_valid_conformer
from repro.chem.molecule import ATOM_FEATURE_DIM, Molecule, to_graph_arrays
from repro.predictors.cache import LRUCache
from repro.predictors.gnn import AlfabetS
from repro.predictors.ip_net import AIMNetS

MAX_ATOMS = 40
DEFAULT_MAX_BATCH = 64  # one chosen successor per worker at the default fleet size


def capacity_table(max_batch: int, *, grain: int = 8, ratio: float = 1.5) -> tuple[int, ...]:
    """Geometric bucket ladder for predictor batch padding, ``1..max_batch``.

    Deliberately separate from ``core.agent.candidate_capacity_table``:
    this ladder terminates EXACTLY at the fleet batch size (the snap
    behaviour below), the candidate ladder is open-ended with a
    fleet-dependent ratio — and predictors must not import repro.core.

    Derived from the fleet size: ``max_batch`` should be the largest batch
    the caller expects (W workers x mols each — see ``PropertyService.reserve``).
    Interior rungs grow by ``ratio`` (padding bounded by ``ratio``x there)
    and the ladder ends EXACTLY at ``max_batch``: every batch within ~2x of
    the fleet-wide size (in-batch dedupe makes the count drift a little below
    W) snaps to the one reserved shape instead of walking its own rungs —
    at W=512 the per-step batch always reuses a single compiled predictor
    shape, where the old static table padded intermediate sizes up to ~8x.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    caps = [1]
    c = grain
    while c * ratio < max_batch:
        caps.append(c)
        c = max(c + grain, grain * round(c * ratio / grain))
    if max_batch > 1:
        caps.append(max_batch)
    return tuple(caps)


def featurize(mol: Molecule, max_atoms: int = MAX_ATOMS) -> dict[str, np.ndarray]:
    """Graph arrays + conformer features (zeros if conformer invalid)."""
    arrs = to_graph_arrays(mol, max_atoms)
    if has_valid_conformer(mol):
        arrs["conf_feat"] = conformer_features(mol, max_atoms)
        arrs["conf_valid"] = np.float32(1.0)
    else:
        arrs["conf_feat"] = np.zeros((max_atoms, CONFORMER_FEATURE_DIM), dtype=np.float32)
        arrs["conf_valid"] = np.float32(0.0)
    return arrs


def stack_features(feats: Sequence[dict[str, np.ndarray]]) -> dict[str, np.ndarray]:
    return {k: np.stack([f[k] for f in feats]) for k in feats[0]}


@dataclass
class Properties:
    bde: float | None
    ip: float | None

    @property
    def conformer_valid(self) -> bool:
        return self.ip is not None


class OracleService:
    """Deterministic, jit-free ``PropertyService`` stand-in backed by the
    chemistry oracles — identical answers in every process, no predictor
    training, no XLA compiles.

    THE shared stub for every harness that wants properties out of the
    equation: the tier-1 test matrices (tests/conftest.py re-exports it),
    the chemistry benchmarks, and the multi-device truth run
    (``repro.launch.verify``) — whose cross-process bit-equality pins
    silently depend on all of them predicting identically, which is why
    there is exactly one implementation.  ``predict`` entries are counted
    in ``n_calls`` so dispatch-per-step tests can assert batching.
    """

    def __init__(self):
        from repro.chem.oracle import oracle_bde, oracle_ip
        self._bde, self._ip, self._ok = oracle_bde, oracle_ip, has_valid_conformer
        self.n_calls = 0

    def predict(self, mols: Sequence[Molecule]) -> list[Properties]:
        self.n_calls += 1
        return [Properties(bde=self._bde(m),
                           ip=self._ip(m) if self._ok(m) else None)
                for m in mols]


@dataclass
class PropertyService:
    bde_model: AlfabetS
    bde_params: dict
    ip_model: AIMNetS
    ip_params: dict
    max_atoms: int = MAX_ATOMS
    cache: LRUCache | None = field(default_factory=lambda: LRUCache(200_000))
    max_batch_hint: int = DEFAULT_MAX_BATCH  # fleet-wide batch bound (see reserve)

    # statistics (§3.6)
    n_predict_calls: int = 0      # predict() entries (one per env step fleet-wide)
    n_predictor_batches: int = 0  # jit'd model batches actually run (cache misses)
    n_predictor_mols: int = 0

    def __post_init__(self):
        self._bde_apply = jax.jit(self.bde_model.apply)
        self._ip_apply = jax.jit(self.ip_model.apply)
        self._buckets = capacity_table(self.max_batch_hint)

    def reserve(self, max_batch: int) -> None:
        """Size the padding ladder for a fleet that predicts up to
        ``max_batch`` molecules per step (the trainer calls this with
        W x mols_per_worker).  Only ever grows the hint."""
        if max_batch > self.max_batch_hint:
            self.max_batch_hint = max_batch
            self._buckets = capacity_table(max_batch)

    # ------------------------------------------------------------ #
    def predict(self, mols: Sequence[Molecule]) -> list[Properties]:
        self.n_predict_calls += 1
        out: list[Properties | None] = [None] * len(mols)
        todo: list[int] = []
        keys = [m.iso_key() for m in mols]
        for i, key in enumerate(keys):
            if self.cache is not None:
                hit = self.cache.get(key)
                if hit is not None:
                    out[i] = hit
                    continue
            todo.append(i)

        if todo:
            # one fleet-wide batch may name the same molecule several times
            # (e.g. two workers choosing the same successor) — featurize and
            # predict each distinct iso_key once, fan results back out
            slot_of: dict = {}
            unique: list[int] = []
            for i in todo:
                if keys[i] not in slot_of:
                    slot_of[keys[i]] = len(unique)
                    unique.append(i)
            feats = [featurize(mols[i], self.max_atoms) for i in unique]
            batch = stack_features(feats)
            bde_arr, ip_arr = self._run_models(batch)
            for i in todo:
                slot = slot_of[keys[i]]
                mol = mols[i]
                bde = float(bde_arr[slot]) if mol.has_oh_bond() else None
                if bde is not None and not np.isfinite(bde):
                    bde = None
                ip = float(ip_arr[slot]) if batch["conf_valid"][slot] > 0.5 else None
                props = Properties(bde=bde, ip=ip)
                out[i] = props
                if self.cache is not None:
                    self.cache.put(keys[i], props)
        return out  # type: ignore[return-value]

    # ------------------------------------------------------------ #
    def _run_models(self, batch: dict[str, np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
        """Pad the batch dim to a bucket to bound jit recompiles."""
        b = batch["atom_feat"].shape[0]
        padded = self._pad_to(b)
        if padded != b:
            batch = {k: np.concatenate(
                [v, np.zeros((padded - b,) + v.shape[1:], v.dtype)]) for k, v in batch.items()}
            # padding rows must look like 1-atom dummies to avoid nan paths
            batch["mask"][b:, 0] = 1.0
        self.n_predictor_batches += 1
        self.n_predictor_mols += b
        _, mol_bde = self._bde_apply(self.bde_params, batch)
        ip = self._ip_apply(self.ip_params, batch)
        return np.asarray(mol_bde)[:b], np.asarray(ip)[:b]

    def _pad_to(self, b: int) -> int:
        for cap in self._buckets:
            if b <= cap:
                return cap
        # over-hint batch: grow the ladder (grain-rounded) so near-identical
        # follow-up batches reuse the same compiled shape
        self.reserve(8 * -(-b // 8))
        return self._buckets[-1]


class DegradedPropertyService:
    """The last-known-good property tier a TRIPPED circuit breaker serves
    from (serving/breaker.py): per molecule, the primary service's LRU
    cache when it holds the answer, the deterministic oracle stub
    otherwise.  Never raises, never touches the (presumed sick) primary
    predictors — responses routed through here are flagged ``degraded``
    by the serving layer.

    ``primary`` may be a ``PropertyService`` (its ``cache`` is consulted),
    a ``ResilientService`` around one (attribute delegation exposes the
    cache), or any stub without a cache (pure oracle fallback).
    """

    def __init__(self, primary=None, stub=None):
        self.primary_cache = getattr(primary, "cache", None)
        self.stub = stub if stub is not None else OracleService()
        self.n_cache_serves = 0
        self.n_stub_serves = 0

    def predict(self, mols: Sequence[Molecule]) -> list[Properties]:
        out: list[Properties] = []
        for m in mols:
            hit = (self.primary_cache.get(m.iso_key())
                   if self.primary_cache is not None else None)
            if hit is not None:
                self.n_cache_serves += 1
                out.append(hit)
            else:
                self.n_stub_serves += 1
                out.append(self.stub.predict([m])[0])
        return out

    def stats(self) -> dict:
        return {"n_cache_serves": self.n_cache_serves,
                "n_stub_serves": self.n_stub_serves}


# ------------------------------------------------------------------ #
# fault tolerance: bounded retries + deterministic backoff + timeout
# ------------------------------------------------------------------ #
@dataclass(frozen=True)
class RetryPolicy:
    """Retry budget for one property-service call.

    ``max_retries``     retries after the first attempt (so a call makes at
                        most ``max_retries + 1`` attempts).
    ``backoff_base_s``  attempt k sleeps ``min(cap, base * 2**k)`` scaled
                        by a seeded jitter in [0.5, 1.0) — deterministic
                        given the policy seed, capped, exponential.
    ``timeout_s``       per-call wall clock bound (None = no timeout).  A
                        call that overruns raises ``FaultTimeout`` and is
                        retried like any transient fault.
    """

    max_retries: int = 3
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 1.0
    timeout_s: float | None = None
    seed: int = 0


class ResilientService:
    """Bounded-retry wrapper around any property service.

    Composition over inheritance: ``inner`` is a ``PropertyService``, an
    ``OracleService``, or any object with ``predict(mols)``; every other
    attribute (``reserve``, cache counters, ...) passes through untouched.

    Retry semantics — the properties tests/test_faults.py gates:

    * only ``TransientFault`` (incl. ``FaultTimeout``) is retried; real
      exceptions propagate (they are bugs, not weather), and ``FaultError``
      stays terminal.
    * the retried batch is BIT-identical to a first-try batch, because the
      injection point sits BEFORE the inner call and the inner predictor is
      deterministic — retries are invisible to the equivalence matrix.
    * backoff is deterministic (seeded jitter, exponential, capped) and
      injectable (``sleep=``) so tests and the fault benches never
      actually wait.
    * after ``max_retries`` retries the transient escalates to a terminal
      ``FaultError`` — the fleet quarantines the affected slots instead of
      crashing (core/rollout.py).

    ``fault_plan`` arms the deterministic injection surface
    (``repro.core.faults.FaultPlan``, site ``"predict"``).

    Timeout caveat: the timed-out inner call keeps running on the worker
    thread (python threads cannot be killed); with a deterministic,
    internally-locked inner service the overlap is harmless, which is the
    only configuration the harness uses timeouts with.
    """

    def __init__(self, inner, policy: RetryPolicy = RetryPolicy(),
                 fault_plan=None,
                 sleep: Callable[[float], None] | None = time.sleep):
        self.inner = inner
        self.policy = policy
        self.fault_plan = fault_plan
        self._sleep = sleep if sleep is not None else (lambda s: None)
        self._backoff_rng = np.random.default_rng(policy.seed)
        self._timeout_pool: ThreadPoolExecutor | None = None
        self.n_retries = 0          # transient attempts absorbed
        self.n_timeouts = 0         # real (wall-clock) timeouts observed

    def __getattr__(self, name):
        # delegation target for everything predict() doesn't override
        # (reserve, n_predict_calls, cache, ...)
        return getattr(self.inner, name)

    def _backoff_s(self, attempt: int) -> float:
        base = min(self.policy.backoff_cap_s,
                   self.policy.backoff_base_s * (2.0 ** attempt))
        return base * (0.5 + 0.5 * float(self._backoff_rng.random()))

    def _call_inner(self, mols):
        if self.policy.timeout_s is None:
            return self.inner.predict(mols)
        if self._timeout_pool is None:
            self._timeout_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="predict-timeout")
        fut = self._timeout_pool.submit(self.inner.predict, mols)
        try:
            return fut.result(timeout=self.policy.timeout_s)
        except FuturesTimeout:
            self.n_timeouts += 1
            raise FaultTimeout(
                f"predict timed out after {self.policy.timeout_s}s "
                f"({len(mols)} molecules)") from None

    def predict(self, mols: Sequence[Molecule]) -> list[Properties]:
        attempt = 0
        while True:
            try:
                if self.fault_plan is not None:
                    self.fault_plan.check_call("predict")
                return self._call_inner(mols)
            except FaultError:
                raise                     # terminal — the fleet quarantines
            except TransientFault as e:
                if attempt >= self.policy.max_retries:
                    raise FaultError(
                        f"predict retries exhausted after {attempt + 1} "
                        f"attempts: {e!r}") from e
                self._sleep(self._backoff_s(attempt))
                attempt += 1
                self.n_retries += 1

    def fault_stats(self) -> dict:
        return {
            "n_retries": self.n_retries,
            "n_timeouts": self.n_timeouts,
            "n_faults_injected": (self.fault_plan.n_injected
                                  if self.fault_plan is not None else 0),
        }
