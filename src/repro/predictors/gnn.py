"""Alfabet-S: a message-passing GNN BDE predictor (the Alfabet stand-in).

Architecture (per St. John et al.'s design, scaled to this problem):
  * atom embedding: linear(ATOM_FEATURE_DIM -> d)
  * T message-passing rounds: per-bond-order linear messages, summed over
    neighbours, gated residual update with layer norm
  * per-atom BDE head: MLP(d -> d/2 -> 1), interpreted as the BDE of that
    atom's O-H bond
  * molecule BDE = min over atoms flagged as O-H oxygens (paper §2.2: "the
    lowest BDE is found among all O-H bonds")

Pure-functional JAX: ``init(key) -> params``, ``apply(params, batch) ->
(per_atom_bde, mol_bde)``.  Batch layout comes from
``repro.chem.molecule.to_graph_arrays``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.chem.molecule import ATOM_FEATURE_DIM, MAX_BOND_ORDER

# normalisation constants for the regression target (kcal/mol)
BDE_MEAN = 80.0
BDE_SCALE = 10.0
_OH_FLAG_CHANNEL = 14  # see to_graph_arrays


@dataclass(frozen=True)
class AlfabetS:
    hidden: int = 128
    rounds: int = 3

    # ------------------------------------------------------------ #
    def init(self, key: jax.Array) -> dict:
        d = self.hidden
        k = iter(jax.random.split(key, 6 + 2 * self.rounds * MAX_BOND_ORDER))
        def dense(key, fan_in, fan_out):
            w = jax.random.normal(key, (fan_in, fan_out), jnp.float32)
            return w * (2.0 / fan_in) ** 0.5
        params = {
            "embed": {"w": dense(next(k), ATOM_FEATURE_DIM, d), "b": jnp.zeros((d,))},
            "rounds": [],
            "head1": {"w": dense(next(k), d, d // 2), "b": jnp.zeros((d // 2,))},
            "head2": {"w": dense(next(k), d // 2, 1), "b": jnp.zeros((1,))},
        }
        for _ in range(self.rounds):
            params["rounds"].append({
                "msg": [
                    {"w": dense(next(k), d, d), "b": jnp.zeros((d,))}
                    for _ in range(MAX_BOND_ORDER)
                ],
                "self": {"w": dense(next(k), d, d), "b": jnp.zeros((d,))},
                "ln_scale": jnp.ones((d,)),
                "ln_bias": jnp.zeros((d,)),
            })
        return params

    # ------------------------------------------------------------ #
    def apply(self, params: dict, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
        """batch: atom_feat [B,A,F], adj [B,A,A,3], mask [B,A].

        Returns (per_atom_bde [B,A], mol_bde [B]) in kcal/mol.  Molecules
        with no O-H oxygen get ``mol_bde = +inf`` (callers must mask)."""
        feat, adj, mask = batch["atom_feat"], batch["adj"], batch["mask"]
        h = feat @ params["embed"]["w"] + params["embed"]["b"]
        h = h * mask[..., None]
        for rp in params["rounds"]:
            msg = jnp.zeros_like(h)
            for o in range(MAX_BOND_ORDER):
                m_o = h @ rp["msg"][o]["w"] + rp["msg"][o]["b"]
                msg = msg + jnp.einsum("bij,bjd->bid", adj[..., o], m_o)
            upd = msg + (h @ rp["self"]["w"] + rp["self"]["b"])
            upd = _layer_norm(upd, rp["ln_scale"], rp["ln_bias"])
            h = (h + jax.nn.relu(upd)) * mask[..., None]
        z = jax.nn.relu(h @ params["head1"]["w"] + params["head1"]["b"])
        per_atom = (z @ params["head2"]["w"] + params["head2"]["b"])[..., 0]
        per_atom = per_atom * BDE_SCALE + BDE_MEAN

        oh = batch["atom_feat"][..., _OH_FLAG_CHANNEL] * mask  # [B,A] 1.0 on O-H oxygens
        big = jnp.asarray(jnp.inf, per_atom.dtype)
        masked = jnp.where(oh > 0.5, per_atom, big)
        mol_bde = jnp.min(masked, axis=-1)
        return per_atom, mol_bde


def _layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * scale + bias
