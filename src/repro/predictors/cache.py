"""The LRU property cache of §3.6.

"a Least Recently Used (LRU) cache is introduced to store the predicted BDE
values" — predictors dominate step cost (466.8x / 32.6x slower than QED),
and RL revisits molecules constantly (every episode restarts from the same
initial molecules), so the hit rate is high.

Keys are isomorphism-invariant molecule hashes (``Molecule.iso_key``), so
relabelled duplicates hit.  Tracks hit/miss statistics for
``benchmarks/bench_cache.py``.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable


class LRUCache:
    def __init__(self, capacity: int = 100_000):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable, default: Any = None) -> Any:
        if key in self._data:
            self._data.move_to_end(key)
            self.hits += 1
            return self._data[key]
        self.misses += 1
        return default

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def put(self, key: Hashable, value: Any) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        if len(self._data) > self.capacity:
            self._data.popitem(last=False)

    def __len__(self) -> int:
        return len(self._data)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_stats(self) -> None:
        self.hits = 0
        self.misses = 0
