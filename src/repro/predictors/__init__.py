"""Learned property predictors: Alfabet-S (BDE) and AIMNet-S (IP).

The paper integrates two state-of-the-art predictors: Alfabet (a GNN over
SMILES-derived graphs predicting per-bond BDE, St. John et al. 2020) and
AIMNet-NSE (a 3D-conformer network predicting IP, Zubatyuk et al. 2021).
Neither ships here, so this package provides faithful *small* JAX
re-implementations of their interfaces ("-S" for surrogate), trained
against the chemistry oracle (repro.chem.oracle) to the paper's reported
accuracy envelope (<5% average relative error, §2.2):

``gnn``        Alfabet-S: message-passing GNN, per-atom BDE head, min over
               O-H oxygens (the paper's "BDE" = lowest O-H BDE).
``ip_net``     AIMNet-S: atom features + pseudo-conformer geometry, pooled
               MLP head.  Requires a valid 3D conformer, like the original.
``cache``      the LRU property cache of §3.6.
``service``    PropertyService: batched jit inference + cache + the paper's
               invalid-conformer protocol.
``training``   dataset building (incl. RL-trajectory augmentation) and the
               training loops; ``ensure_trained`` caches params on disk.
"""

from repro.predictors.gnn import AlfabetS
from repro.predictors.ip_net import AIMNetS
from repro.predictors.cache import LRUCache
from repro.predictors.service import PropertyService
from repro.predictors.training import ensure_trained, train_bde_model, train_ip_model

__all__ = [
    "AlfabetS", "AIMNetS", "LRUCache", "PropertyService",
    "ensure_trained", "train_bde_model", "train_ip_model",
]
