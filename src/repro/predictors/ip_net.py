"""AIMNet-S: conformer-based IP predictor (the AIMNet-NSE stand-in).

AIMNet-NSE "uses the 3D conformer of molecules to predict IP" (§2.2) — the
property that forces the whole invalid-conformer machinery of §3.3.  This
surrogate keeps that contract: its input features include the pseudo-3D
geometry from ``repro.chem.conformer`` and it cannot run on molecules whose
embedding fails (the service layer translates that into the paper's -1000
reward).

Architecture: per-atom [chem features ++ geometry features] -> MLP ->
masked sum-pool -> MLP -> scalar IP.  The paper notes AIMNet ships 5 models
and recommends ensembling, but DA-MolDQN uses ONE for speed (§3.6); we
support ``n_ensemble`` with 1 as the paper-faithful default.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.chem.conformer import CONFORMER_FEATURE_DIM
from repro.chem.molecule import ATOM_FEATURE_DIM

IP_MEAN = 150.0
IP_SCALE = 25.0


@dataclass(frozen=True)
class AIMNetS:
    hidden: int = 128
    n_ensemble: int = 1  # paper uses 1 of AIMNet's 5 (§3.6)

    @property
    def in_dim(self) -> int:
        return ATOM_FEATURE_DIM + CONFORMER_FEATURE_DIM

    def init(self, key: jax.Array) -> dict:
        def one(key):
            k1, k2, k3, k4 = jax.random.split(key, 4)
            d = self.hidden
            def dense(key, i, o):
                return {"w": jax.random.normal(key, (i, o), jnp.float32) * (2.0 / i) ** 0.5,
                        "b": jnp.zeros((o,))}
            return {
                "atom1": dense(k1, self.in_dim, d),
                "atom2": dense(k2, d, d),
                "pool1": dense(k3, d, d // 2),
                "pool2": dense(k4, d // 2, 1),
            }
        keys = jax.random.split(key, self.n_ensemble)
        return {"ensemble": [one(k) for k in keys]}

    def apply(self, params: dict, batch: dict) -> jnp.ndarray:
        """batch: atom_feat [B,A,F], conf_feat [B,A,G], mask [B,A] -> IP [B]."""
        x = jnp.concatenate([batch["atom_feat"], batch["conf_feat"]], axis=-1)
        mask = batch["mask"]
        preds = []
        for p in params["ensemble"]:
            h = jax.nn.relu(x @ p["atom1"]["w"] + p["atom1"]["b"])
            h = jax.nn.relu(h @ p["atom2"]["w"] + p["atom2"]["b"])
            h = h * mask[..., None]
            pooled = h.sum(axis=1) / jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
            z = jax.nn.relu(pooled @ p["pool1"]["w"] + p["pool1"]["b"])
            out = (z @ p["pool2"]["w"] + p["pool2"]["b"])[..., 0]
            preds.append(out * IP_SCALE + IP_MEAN)
        return jnp.stack(preds, axis=0).mean(axis=0)
