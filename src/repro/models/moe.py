"""Mixture-of-Experts layer (GShard-style capacity dispatch).

Chosen over loop-over-experts or megablocks-style sorting because capacity
einsum dispatch is (a) fully expressible in pjit-partitionable einsums,
(b) produces the canonical expert-parallel all-to-all when the expert dim
is sharded on "model" and tokens on ("pod","data") — the collective the
roofline analysis wants to see, and (c) has bounded memory:
dispatch tensor is [groups, group_size, E, C] with C = group_size*top_k/E
* capacity_factor, i.e. O(tokens * group_size * top_k) bits total.

Top-k routing with softmax-renormalised gates (Mixtral convention), token
priority by gate weight within a group, dropped tokens pass through the
residual (standard capacity semantics).  Aux load-balance loss follows
Shazeer et al.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def moe_params_init(key, cfg, dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (D, E), jnp.float32),   # router math in f32
        "w1": dense_init(k2, (E, D, F), dtype),
        "w3": dense_init(k3, (E, D, F), dtype),
        "w2": dense_init(k4, (E, F, D), dtype),
    }


def moe_forward(p: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    mcfg = cfg.moe
    B, S, D = x.shape
    E, K = mcfg.n_experts, mcfg.top_k
    G_tok = min(mcfg.group_size, B * S)
    T = B * S
    assert T % G_tok == 0, f"tokens {T} not divisible by group size {G_tok}"
    G = T // G_tok
    C = max(int(G_tok * K * mcfg.capacity_factor) // E, 1)

    xt = x.reshape(G, G_tok, D)

    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # [G,S,E]

    # top-k gates, renormalised (Mixtral)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # [G,S,K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (fraction-routed x mean-prob, scaled by E)
    me = probs.mean(axis=(0, 1))                                 # [E]
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((G * G_tok * K,), jnp.float32)) / (G * G_tok * K)
    aux = E * jnp.sum(me * ce) * mcfg.aux_loss_weight

    # capacity slots: position of each (token, k) choice in its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)      # [G,S,K,E]
    flat_choice = onehot.reshape(G, G_tok * K, E)                # priority: token-major
    pos_in_expert = jnp.cumsum(flat_choice, axis=1) - flat_choice
    pos_in_expert = pos_in_expert.reshape(G, G_tok, K, E)
    within_cap = pos_in_expert < C                               # [G,S,K,E]
    slot = jnp.where(within_cap, pos_in_expert, 0).astype(jnp.int32)

    # [G,S,K,E,C] one-hot of the capacity slot, zeroed for over-capacity and
    # for non-chosen experts (slot values are garbage there)
    slot_oh = (jax.nn.one_hot(slot, C, dtype=x.dtype)
               * within_cap[..., None].astype(x.dtype)
               * onehot[..., None].astype(x.dtype))
    dispatch = slot_oh.sum(axis=2)                               # [G,S,E,C]
    gate_per_e = jnp.einsum("gske,gsk->gse", onehot, gate_vals)  # [G,S,E]
    combine = dispatch * gate_per_e[..., None].astype(x.dtype)   # [G,S,E,C]

    # expert compute: all-to-all appears when e is model-sharded, g data-sharded
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xt)       # [E,G,C,D]
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("egcd,edf->egcf", expert_in, p["w3"])
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["w2"])        # [E,G,C,D]

    y = jnp.einsum("gsec,egcd->gsd", combine, expert_out)
    return y.reshape(B, S, D), aux
