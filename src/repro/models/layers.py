"""Shared neural building blocks: norms, RoPE, attention (GQA/MQA, causal /
sliding-window / prefix-LM / cross), dense MLPs.

Everything is a pure function over explicit param dicts.  Attention has two
compute paths: the pure-jnp reference (default — also what the dry-run
lowers, so roofline numbers come from transparent HLO) and the Pallas
flash-attention kernel (``use_pallas``), validated against the reference in
interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ------------------------------------------------------------------ #
# init helpers
# ------------------------------------------------------------------ #
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------ #
# norms
# ------------------------------------------------------------------ #
import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm with f32 statistics and a dtype-controlled backward.

    A naive implementation upcasts the activations to f32; its backward
    then contains ``convert(dynamic-slice(residual_stack))``, which XLA
    rewrites to ``dynamic-slice(convert(stack))`` and hoists — keeping a
    full f32 copy of every layer's saved activations alive (+12 GiB/chip
    measured on stablelm train_4k).  The custom VJP below keeps every
    full-size tensor in the input dtype; only per-position scalars and the
    cross-feature reductions run in f32."""
    return _rms_fwd(x, scale, eps)[0]


def _rms_stats(x: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    return jax.lax.rsqrt(var + eps)          # f32 [...]


def _rms_fwd(x, scale, eps):
    inv = _rms_stats(x, eps)
    y = x * inv[..., None].astype(x.dtype) * scale.astype(x.dtype)
    return y, (x, scale)


def _rms_bwd(eps, res, g):
    x, scale = res
    inv = _rms_stats(x, eps)                                   # recompute: cheap
    gs = g * scale.astype(g.dtype)                             # bf16 [... , d]
    # m = mean_d(gs * x) in f32 (reduction), per-position scalar
    m = jnp.einsum("...d,...d->...", gs, x,
                   preferred_element_type=jnp.float32) / x.shape[-1]
    c1 = inv[..., None].astype(x.dtype)                        # bf16 scalars
    c2 = (inv ** 3 * m)[..., None].astype(x.dtype)
    dx = gs * c1 - x * c2
    dscale = jnp.einsum("...d,...->d", g * x,
                        inv.astype(g.dtype),
                        preferred_element_type=jnp.float32).astype(scale.dtype)
    return dx, dscale


rms_norm.defvjp(_rms_fwd, _rms_bwd)


# ------------------------------------------------------------------ #
# rotary position embedding
# ------------------------------------------------------------------ #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, n, d]; positions [..., S] (broadcastable int32)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                               # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ #
# attention
# ------------------------------------------------------------------ #
def make_attn_mask(
    q_len: int,
    k_len: int,
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    """bool[q_len, k_len]; True = attend.  ``q_offset`` shifts query
    positions (decode: q_offset = pos)."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(k_len)[None, :]
    mask = jnp.ones((q_len, k_len), dtype=bool)
    if causal:
        mask = kj <= qi
    if window is not None:
        mask = mask & (kj > qi - window)
    if prefix_len > 0:
        mask = mask | (kj < prefix_len)
    return mask


def gqa_attention(
    q: jnp.ndarray,          # [B, Sq, H, Dh]
    k: jnp.ndarray,          # [B, Sk, K, Dh]
    v: jnp.ndarray,          # [B, Sk, K, Dh]
    mask: jnp.ndarray | None = None,   # explicit [Sq,Sk]/[B,Sq,Sk] (decode path)
    *,
    causal: bool = False,
    window: int | None = None,
    prefix_len: int = 0,
    q_offset: int = 0,
    q_block: int = 1024,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Grouped-query attention; returns [B, Sq, H, Dh].

    Masks are built on the fly per query block (never materialising an
    [Sq, Sk] tensor — at 32k that alone is 1 GiB) and the scores tensor is
    blocked over queries, bounding the f32 logits working set to
    ``B x heads x q_block x Sk`` — the XLA-expressible half of flash
    attention.  The Pallas kernel replaces this entirely on real TPUs.
    """
    if use_pallas and mask is None:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                      prefix_len=prefix_len)
    B, Sq, H, Dh = q.shape
    if mask is not None or Sq <= q_block or Sq % q_block != 0:
        return _attn_block(q, k, v, mask, causal=causal, window=window,
                           prefix_len=prefix_len, q_start=q_offset)

    nb = Sq // q_block
    qb = q.reshape(B, nb, q_block, H, Dh)

    @jax.checkpoint  # recompute block scores in bwd: peak = ONE block
    def block_fn(qblk, i):
        return _attn_block(qblk, k, v, None, causal=causal, window=window,
                           prefix_len=prefix_len, q_start=q_offset + i * q_block)

    def block(carry, inp):
        i, qblk = inp
        return carry, block_fn(qblk, i)

    _, outs = jax.lax.scan(block, (), (jnp.arange(nb), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, Dh)


def _attn_block(
    q: jnp.ndarray,          # [B, Sq, H, Dh]
    k: jnp.ndarray, v: jnp.ndarray,
    mask: jnp.ndarray | None,
    *, causal: bool, window: int | None, prefix_len: int, q_start,
) -> jnp.ndarray:
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    R = H // K
    qg = q.reshape(B, Sq, K, R, Dh)
    scale = Dh ** -0.5
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if mask is None:
        qi = q_start + jnp.arange(Sq)[:, None]
        kj = jnp.arange(Sk)[None, :]
        m = jnp.ones((Sq, Sk), bool)
        if causal:
            m = kj <= qi
        if window is not None:
            m = m & (kj > qi - window)
        if prefix_len > 0:
            m = m | (kj < prefix_len)
        logits = jnp.where(m[None, None, None], logits, -1e30)
    else:
        m = mask if mask.ndim == 3 else mask[None]
        logits = jnp.where(m[:, None, None], logits, -1e30)
    # f32 softmax math, bf16 PV matmul (halves score-tensor HBM traffic)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def attn_params_init(key, cfg, dtype) -> dict:
    D, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (D, H, Dh), dtype),
        "wk": dense_init(k2, (D, K, Dh), dtype),
        "wv": dense_init(k3, (D, K, Dh), dtype),
        "wo": dense_init(k4, (H, Dh, D), dtype, scale=(1.0 / (H * Dh)) ** 0.5),
    }


def attn_forward(
    p: dict,
    x: jnp.ndarray,                 # [B, S, D]
    positions: jnp.ndarray,         # [B, S] (or [S])
    *,
    theta: float,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
    mask: jnp.ndarray | None = None,
    kv_override: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    rope: bool = True,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Self-attention (or cross when kv_override=(k, v) precomputed)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dke->bske", x, p["wk"])
        v = jnp.einsum("bsd,dke->bske", x, p["wv"])
        if rope:
            q = apply_rope(q, positions, theta)
            k = apply_rope(k, positions, theta)
    else:
        k, v = kv_override
        if rope:
            q = apply_rope(q, positions, theta)
    out = gqa_attention(q, k, v, mask, causal=causal, window=window,
                        prefix_len=prefix_len, use_pallas=use_pallas)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def cross_kv(p: dict, memory: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute cross-attention K/V from encoder memory [B, T, D]."""
    k = jnp.einsum("btd,dke->btke", memory, p["wk"])
    v = jnp.einsum("btd,dke->btke", memory, p["wv"])
    return k, v


# ------------------------------------------------------------------ #
# dense MLPs
# ------------------------------------------------------------------ #
def mlp_params_init(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w1": dense_init(k1, (d_model, d_ff), dtype),
        "w2": dense_init(k2, (d_ff, d_model), dtype),
    }
    if act == "swiglu":
        p["w3"] = dense_init(k3, (d_model, d_ff), dtype)
    return p


def mlp_forward(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = x @ p["w1"]
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown act {act}")
    return h @ p["w2"]
