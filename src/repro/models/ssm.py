"""Mamba2 (SSD — state-space duality) block, chunked-parallel form.

Follows Dao & Gu 2024 (arXiv:2405.21060): per-head scalar decay
``a_t = exp(-softplus(A) * dt_t)``, rank-1 state update

    S_t = a_t * S_{t-1} + dt_t * x_t B_t^T          (S in R^{P x N})
    y_t = C_t S_t + D * x_t

computed in O(L) via the chunked algorithm: within a chunk of length Q the
quadratic "attention form" is used (the matmul-heavy part the Pallas
``ssd_scan`` kernel targets); chunk states are passed with a
``jax.lax.scan`` — sequence-parallel-friendly and the reason the ssm/hybrid
archs can run ``long_500k``.

Tensor conventions (B=batch, L=seq, H=heads, P=head_dim, G=BC-groups,
N=state_dim):  x [B,L,H,P], dt [B,L,H], B/C [B,L,G,N].

The block (mamba2 arch): in_proj -> (z, xBC, dt); causal depthwise conv
over xBC; SSD; gated RMSNorm; out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


# ------------------------------------------------------------------ #
# core SSD math (pure jnp reference; kernels/ssd_scan mirrors this)
# ------------------------------------------------------------------ #
def ssd_chunked(
    x: jnp.ndarray,      # [B, L, H, P]
    dt: jnp.ndarray,     # [B, L, H]   (softplus'd, positive)
    A: jnp.ndarray,      # [H]         (positive decay rates)
    B_: jnp.ndarray,     # [B, L, G, N]
    C_: jnp.ndarray,     # [B, L, G, N]
    chunk: int,
    initial_state: jnp.ndarray | None = None,   # [B, H, P, N]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,L,H,P], final_state [B,H,P,N])."""
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert L % chunk == 0, f"L={L} % chunk={chunk}"
    nc = L // chunk
    rep = H // G

    xc = x.reshape(Bb, nc, chunk, H, P)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = B_.reshape(Bb, nc, chunk, G, N)
    Cc = C_.reshape(Bb, nc, chunk, G, N)

    # log-decay within chunk: l[t] = sum_{u<=t} log a_u  (per head)
    log_a = (-A[None, None, None, :] * dtc).astype(jnp.float32)   # [B,nc,Q,H]
    cum = jnp.cumsum(log_a, axis=2)                               # [B,nc,Q,H]
    total = cum[:, :, -1, :]                                      # [B,nc,H]

    # intra-chunk (quadratic) term:
    # y_t += sum_{u<=t} C_t.B_u * exp(cum_t - cum_u) * dt_u * x_u
    Bh = jnp.repeat(Bc, rep, axis=3)                              # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bnqhk,bnshk->bnhqs", Ch.astype(jnp.float32),
                        Bh.astype(jnp.float32))                   # [B,nc,H,Q,S]
    decay = cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3) - \
        cum[:, :, None, :, :].transpose(0, 1, 4, 2, 3)            # [B,nc,H,Q,S] = cum_q - cum_s
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    gate = jnp.where(causal[None, None, None], jnp.exp(decay), 0.0)
    weights = scores * gate                                       # [B,nc,H,Q,S]
    xdt = xc.astype(jnp.float32) * dtc[..., None].astype(jnp.float32)
    y_intra = jnp.einsum("bnhqs,bnshp->bnqhp", weights, xdt)

    # chunk summary states: S_chunk = sum_u exp(total - cum_u) dt_u x_u B_u^T
    state_decay = jnp.exp(total[:, :, None, :] - cum)             # [B,nc,Q,H]
    contrib = jnp.einsum("bnqhp,bnqhk,bnqh->bnhpk", xdt, Bh.astype(jnp.float32),
                         state_decay)                             # [B,nc,H,P,N]

    # inter-chunk scan: S_{c} = exp(total_c) * S_{c-1} + contrib_c
    def scan_fn(S_prev, inp):
        tot_c, contrib_c = inp                                    # [B,H], [B,H,P,N]
        S = jnp.exp(tot_c)[:, :, None, None] * S_prev + contrib_c
        return S, S_prev                                          # emit state ENTERING chunk

    S0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((Bb, H, P, N), jnp.float32))
    final, entering = jax.lax.scan(
        scan_fn,
        S0,
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(contrib, 1, 0)),
    )
    entering = jnp.moveaxis(entering, 0, 1)                       # [B,nc,H,P,N]

    # inter-chunk contribution: y_t += C_t S_entering * exp(cum_t)
    y_inter = jnp.einsum("bnqhk,bnhpk,bnqh->bnqhp", Ch.astype(jnp.float32),
                         entering, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bb, L, H, P)
    return y.astype(x.dtype), final.astype(x.dtype)


def ssd_decode_step(
    state: jnp.ndarray,  # [B, H, P, N]
    x: jnp.ndarray,      # [B, H, P]
    dt: jnp.ndarray,     # [B, H]
    A: jnp.ndarray,      # [H]
    B_: jnp.ndarray,     # [B, G, N]
    C_: jnp.ndarray,     # [B, G, N]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One-token recurrent update.  Returns (y [B,H,P], new_state)."""
    H = x.shape[1]
    G = B_.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=1)     # [B,H,N]
    Ch = jnp.repeat(C_, rep, axis=1)
    a = jnp.exp((-A[None, :] * dt).astype(jnp.float32))           # [B,H]
    upd = jnp.einsum("bhp,bhk,bh->bhpk", x.astype(jnp.float32),
                     Bh.astype(jnp.float32), dt.astype(jnp.float32))
    new_state = a[:, :, None, None] * state.astype(jnp.float32) + upd
    y = jnp.einsum("bhk,bhpk->bhp", Ch.astype(jnp.float32), new_state)
    return y.astype(x.dtype), new_state.astype(state.dtype)


# ------------------------------------------------------------------ #
# the mamba2 block
# ------------------------------------------------------------------ #
def ssm_dims(cfg) -> dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.state_dim
    return {"d_inner": d_inner, "n_heads": n_heads, "conv_dim": conv_dim,
            "proj_out": 2 * d_inner + 2 * s.n_groups * s.state_dim + n_heads}


def ssm_params_init(key, cfg, dtype) -> dict:
    s = cfg.ssm
    dims = ssm_dims(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    H = dims["n_heads"]
    # separate projections (not mamba2's fused in_proj) so each output dim
    # shards cleanly on the "model" axis -- see DESIGN.md hardware notes
    return {
        "in_z": dense_init(k1, (cfg.d_model, dims["d_inner"]), dtype),
        "in_xbc": dense_init(k5, (cfg.d_model, dims["conv_dim"]), dtype),
        "in_dt": dense_init(k6, (cfg.d_model, H), dtype),
        "conv_w": dense_init(k2, (s.conv_width, dims["conv_dim"]), dtype, scale=0.5),
        "conv_b": jnp.zeros((dims["conv_dim"],), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = exp(A_log) in (0, inf)
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": jnp.ones((dims["d_inner"],), dtype),
        "out_proj": dense_init(k3, (dims["d_inner"], cfg.d_model), dtype),
    }


def _project_in(cfg, p: dict, x: jnp.ndarray):
    s = cfg.ssm
    dims = ssm_dims(cfg)
    z = x @ p["in_z"]
    xbc = x @ p["in_xbc"]
    dt_raw = x @ p["in_dt"]
    return z, xbc, dt_raw, dims["d_inner"], dims["n_heads"], s.n_groups * s.state_dim


def ssm_forward(
    p: dict, x: jnp.ndarray, cfg, *,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Full-sequence mamba2 block: x [B,L,D] -> [B,L,D]."""
    s = cfg.ssm
    B, L, D = x.shape
    z, xbc, dt_raw, d_inner, H, gn = _project_in(cfg, p, x)

    # causal depthwise conv over the sequence (width W)
    xbc = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
    xs, B_, C_ = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)

    P_ = s.head_dim
    xh = xs.reshape(B, L, H, P_)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])    # [B,L,H]
    A = jnp.exp(p["A_log"])
    Bm = B_.reshape(B, L, s.n_groups, s.state_dim)
    Cm = C_.reshape(B, L, s.n_groups, s.state_dim)

    if use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, _ = ssd_ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=s.chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, chunk=min(s.chunk, L))
    y = y + xh * p["D_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, L, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x [B,L,C], w [W,C] -> [B,L,C] (silu)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for t in range(W):  # W=4: unrolled shifts beat conv_general on TPU here
        out = out + pad[:, t : t + x.shape[1], :] * w[t][None, None, :]
    return jax.nn.silu(out + b[None, None, :])


# ------------------------------------------------------------------ #
# decode path
# ------------------------------------------------------------------ #
def ssm_decode_step(
    p: dict, x: jnp.ndarray, cfg,
    conv_cache: jnp.ndarray,   # [B, W-1, conv_dim] (last W-1 inputs)
    state: jnp.ndarray,        # [B, H, P, N]
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token mamba2 step: x [B,1,D] -> (y [B,1,D], conv_cache, state)."""
    s = cfg.ssm
    B = x.shape[0]
    z, xbc, dt_raw, d_inner, H, gn = _project_in(cfg, p, x[:, 0])

    # rolling conv window
    W = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_cache, xbc[:, None, :]], axis=1)   # [B,W,C]
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"])
    new_conv_cache = window[:, 1:, :]

    xs, B_, C_ = jnp.split(conv, [d_inner, d_inner + gn], axis=-1)
    xh = xs.reshape(B, H, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])    # [B,H]
    A = jnp.exp(p["A_log"])
    Bm = B_.reshape(B, s.n_groups, s.state_dim)
    Cm = C_.reshape(B, s.n_groups, s.state_dim)

    y, new_state = ssd_decode_step(state, xh, dt, A, Bm, Cm)
    y = y + xh * p["D_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(B, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None, :], new_conv_cache, new_state
