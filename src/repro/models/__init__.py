"""Model zoo: every assigned architecture as a pure-JAX pytree model.

All families share the same contract (see ``repro.models.model``):

    init_params(cfg, key)            real params (smoke tests)
    abstract_params(cfg)             ShapeDtypeStruct tree (dry-run)
    forward_train(params, cfg, batch)        -> logits
    loss_fn(params, cfg, batch)              -> scalar
    init_cache(cfg, batch, seq_len)          -> decode cache tree
    serve_step(params, cfg, cache, tokens, pos) -> logits, cache
    param_pspecs(cfg, mesh_axes)     PartitionSpec tree (launch/dryrun)

Per-layer parameters are stacked on a leading axis and the forward pass is
a single ``jax.lax.scan``, so HLO size / compile time is depth-independent
(a 94-layer MoE lowers like a 1-layer model) — essential for the 80-config
dry-run matrix.
"""

from repro.models.model import (
    init_params, abstract_params, forward_train, loss_fn,
    init_cache, serve_step, param_pspecs, count_params,
)

__all__ = [
    "init_params", "abstract_params", "forward_train", "loss_fn",
    "init_cache", "serve_step", "param_pspecs", "count_params",
]
