"""Model assembly: every family behind one contract.

Families: dense, moe, ssm, hybrid (zamba2: mamba2 + shared attn block),
encdec (whisper: stub frame embeddings -> encoder -> decoder w/ cross-attn),
vlm (paligemma: stub patch embeddings -> projector -> prefix-LM decoder),
qnet (the paper's DQN — handled by repro.core; only abstract shapes here).

Parameters are stacked per layer ([L, ...] leading axis) and the forward
pass is one ``lax.scan``.  The hybrid family's shared attention block is a
single (non-stacked) param group closed over by the scan body and applied
every ``shared_attn_every`` layers behind ``lax.cond``.

Dry-run support: ``abstract_params`` builds the ShapeDtypeStruct tree via
``jax.eval_shape`` (no allocation); ``param_pspecs`` assigns a
PartitionSpec to every leaf by key path (tensor-parallel over "model",
expert-parallel for MoE, replicated norms/scalars).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as Lyr
from repro.models import moe as Moe
from repro.models import ssm as Ssm

PyTree = Any


# ================================================================== #
# parameter construction
# ================================================================== #
def _block_init(key, cfg: ArchConfig, dtype, *, cross: bool = False) -> dict:
    """One transformer block (attn + mlp/moe) param group."""
    ks = jax.random.split(key, 4)
    p = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": Lyr.attn_params_init(ks[0], cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
    }
    if cross:
        p["norm_x"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = Lyr.attn_params_init(ks[1], cfg, dtype)
    if cfg.family in ("moe",):
        p["moe"] = Moe.moe_params_init(ks[2], cfg, dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = Lyr.mlp_params_init(ks[3], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _mamba_block_init(key, cfg: ArchConfig, dtype) -> dict:
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "ssm": Ssm.ssm_params_init(key, cfg, dtype),
    }


def _hybrid_shared_init(key, cfg: ArchConfig, dtype) -> dict:
    """Zamba2's shared attention(+MLP) block — ONE copy reused."""
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": Lyr.attn_params_init(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": Lyr.mlp_params_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> PyTree:
    dtype = cfg.jnp_dtype
    keys = jax.random.split(key, 8)
    params: dict = {}

    if cfg.family == "qnet":
        from repro.core.agent import QNetwork
        return QNetwork().init(key)

    params["embed"] = Lyr.dense_init(keys[0], (cfg.vocab, cfg.d_model), dtype, scale=0.02)
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tied_embeddings:
        params["unembed"] = Lyr.dense_init(keys[1], (cfg.d_model, cfg.vocab), dtype)

    L = cfg.n_layers
    lkeys = jax.random.split(keys[2], L)
    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = jax.vmap(lambda k: _block_init(k, cfg, dtype))(lkeys)
    elif cfg.family == "ssm":
        params["blocks"] = jax.vmap(lambda k: _mamba_block_init(k, cfg, dtype))(lkeys)
    elif cfg.family == "hybrid":
        params["blocks"] = jax.vmap(lambda k: _mamba_block_init(k, cfg, dtype))(lkeys)
        params["shared_attn"] = _hybrid_shared_init(keys[3], cfg, dtype)
    elif cfg.family == "encdec":
        params["blocks"] = jax.vmap(lambda k: _block_init(k, cfg, dtype, cross=True))(lkeys)
        ekeys = jax.random.split(keys[4], cfg.encdec.n_enc_layers)
        params["enc_blocks"] = jax.vmap(lambda k: _block_init(k, cfg, dtype))(ekeys)
        params["enc_pos"] = Lyr.dense_init(keys[5], (cfg.encdec.n_frames, cfg.d_model),
                                           dtype, scale=0.02)
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
    else:
        raise ValueError(f"unknown family {cfg.family}")

    if cfg.family == "vlm":
        params["vision_proj"] = {
            "w": Lyr.dense_init(keys[6], (cfg.vlm.vision_dim, cfg.d_model), dtype),
            "b": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


def abstract_params(cfg: ArchConfig) -> PyTree:
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def count_params(cfg: ArchConfig) -> int:
    tree = abstract_params(cfg)
    import math
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(tree))


def active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top_k of E experts)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    # expert weights are [E, D, F] x3 per layer
    E, K = cfg.moe.n_experts, cfg.moe.top_k
    expert_per_layer = 3 * cfg.d_model * cfg.d_ff * E
    expert_total = cfg.n_layers * expert_per_layer
    return total - expert_total + expert_total * K // E


# ================================================================== #
# forward passes
# ================================================================== #
def _seq_shard(h: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Sequence-parallel activation constraint (cfg.seq_shard).

    Megatron-style tensor parallelism all-reduces the FULL activation
    [B, S, D] after attention and MLP; with 56-head archs (yi-34b) whose
    heads don't divide TP=16 GSPMD even falls back to replicated-compute
    attention (useful-FLOPs ratio 0.07 measured).  Constraining the token
    dim to "model" between blocks turns those all-reduces into
    reduce-scatter + all-gather pairs and shards the attention compute by
    sequence — the classic sequence-parallel schedule, here applied as a
    GSPMD constraint rather than explicit collectives."""
    if not cfg.seq_shard or h.ndim != 3:
        return h
    U = P.UNCONSTRAINED
    try:
        return jax.lax.with_sharding_constraint(h, P(U, "model", U))
    except Exception:           # no ambient mesh (plain CPU runs)
        return h


def _dense_block_fwd(cfg: ArchConfig, p: dict, h: jnp.ndarray, positions,
                     aux: jnp.ndarray, *, causal: bool = True,
                     prefix_len: int = 0) -> tuple[jnp.ndarray, jnp.ndarray]:
    h = _seq_shard(h, cfg)
    x = Lyr.rms_norm(h, p["norm1"], cfg.norm_eps)
    h = h + Lyr.attn_forward(p["attn"], x, positions, theta=cfg.rope_theta,
                             causal=causal, window=cfg.attn_window,
                             prefix_len=prefix_len, use_pallas=cfg.use_pallas)
    h = _seq_shard(h, cfg)
    x = Lyr.rms_norm(h, p["norm2"], cfg.norm_eps)
    if "moe" in p:
        y, a = Moe.moe_forward(p["moe"], x, cfg)
        h = h + y
        aux = aux + a
    elif "mlp" in p:
        h = h + Lyr.mlp_forward(p["mlp"], x, cfg.act)
    return h, aux


def _mamba_block_fwd(cfg: ArchConfig, p: dict, h: jnp.ndarray) -> jnp.ndarray:
    x = Lyr.rms_norm(h, p["norm1"], cfg.norm_eps)
    return h + Ssm.ssm_forward(p["ssm"], x, cfg, use_pallas=cfg.use_pallas)


def _shared_attn_fwd(cfg: ArchConfig, p: dict, h: jnp.ndarray, positions) -> jnp.ndarray:
    x = Lyr.rms_norm(h, p["norm1"], cfg.norm_eps)
    h = h + Lyr.attn_forward(p["attn"], x, positions, theta=cfg.rope_theta,
                             causal=True, window=cfg.attn_window,
                             use_pallas=cfg.use_pallas)
    x = Lyr.rms_norm(h, p["norm2"], cfg.norm_eps)
    return h + Lyr.mlp_forward(p["mlp"], x, cfg.act)


def _stack_scan(body, h0, stacked_params, cfg: ArchConfig, *extra_carry):
    """scan over stacked layer params with optional remat.

    prevent_cse=False per the jax docs: inside scan the extra optimization
    barriers are unnecessary and (measured here) leave a hoisted f32 copy
    of the whole residual stack alive — 2x activation memory."""
    fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body

    def scan_body(carry, lp):
        return fn(carry, lp), None

    carry, _ = jax.lax.scan(scan_body, (h0, *extra_carry), stacked_params)
    return carry


def forward_train(params: PyTree, cfg: ArchConfig, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B,S,V], aux_loss scalar)."""
    h, aux = forward_hidden(params, cfg, batch)
    return _unembed(params, cfg, h), aux


def forward_hidden(params: PyTree, cfg: ArchConfig, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Final-norm hidden states [B,S,D] (text positions only for VLM)."""
    if cfg.family == "encdec":
        return _forward_encdec_hidden(params, cfg, batch)

    tokens = batch["tokens"]
    B, S = tokens.shape
    h = params["embed"][tokens]
    prefix_len = 0

    if cfg.family == "vlm":
        patches = batch["patches"].astype(h.dtype)
        vp = params["vision_proj"]
        himg = patches @ vp["w"] + vp["b"]
        h = jnp.concatenate([himg, h], axis=1)
        prefix_len = cfg.vlm.n_patches
        S = h.shape[1]

    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            h, aux = carry
            h, aux = _dense_block_fwd(cfg, lp, h, positions, aux,
                                      prefix_len=prefix_len)
            return h, aux
        (h, aux) = _stack_scan(body, h, params["blocks"], cfg, aux0)
    elif cfg.family == "ssm":
        def body(carry, lp):
            (h,) = carry
            return (_mamba_block_fwd(cfg, lp, h),)
        (h,) = _stack_scan(body, h, params["blocks"], cfg)
        aux = aux0
    elif cfg.family == "hybrid":
        # Segmented: scan each k-layer mamba group, then the shared attn
        # block (python loop over the ~L/k segments).  Keeping the shared
        # block OUT of the layer scan matters twice over: (a) decode needs
        # one KV cache PER APPLICATION (weights are shared, activations are
        # not — a single cache slot overwritten k times per token breaks
        # train/decode equivalence), and (b) lax.cond-in-scan carries the
        # shared cache through every one of the L iterations (measured
        # +tens of GB/step of copy traffic on long_500k).
        shared = params["shared_attn"]

        def body(carry, lp):
            (h,) = carry
            return (_mamba_block_fwd(cfg, lp, h),)

        for lo, hi, with_attn in _hybrid_segments(cfg):
            seg = jax.tree_util.tree_map(lambda x: x[lo:hi], params["blocks"])
            (h,) = _stack_scan(body, h, seg, cfg)
            if with_attn:
                h = _shared_attn_fwd(cfg, shared, h, positions)
        aux = aux0
    else:
        raise ValueError(cfg.family)

    h = Lyr.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm":
        h = h[:, prefix_len:]
    return h, aux


def _with_index(blocks: PyTree, n_layers: int) -> tuple:
    return (blocks, jnp.arange(n_layers, dtype=jnp.int32))


def _hybrid_segments(cfg: ArchConfig) -> list[tuple[int, int, bool]]:
    """(layer_lo, layer_hi, apply_shared_attn) segments: the shared block
    runs after layers k-1, 2k-1, ... (matching the original cond-in-scan
    schedule)."""
    k = cfg.shared_attn_every
    out = []
    lo = 0
    while lo < cfg.n_layers:
        hi = min(lo + k, cfg.n_layers)
        out.append((lo, hi, hi - lo == k))
        lo = hi
    return out


def hybrid_n_apps(cfg: ArchConfig) -> int:
    return sum(1 for _, _, a in _hybrid_segments(cfg) if a)


def _unembed(params, cfg, h):
    if cfg.tied_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"])
    return h @ params["unembed"]


def _forward_encdec_hidden(params, cfg, batch) -> tuple[jnp.ndarray, jnp.ndarray]:
    frames = batch["frames"].astype(cfg.jnp_dtype)     # [B, T, D] stub embeddings
    B, T, _ = frames.shape
    hm = frames + params["enc_pos"][None, :T]
    pos_e = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def enc_body(carry, lp):
        h, aux = carry
        h, aux = _dense_block_fwd(cfg, lp, h, pos_e, aux, causal=False)  # bidirectional
        return h, aux
    hm, _ = _stack_scan(enc_body, hm, params["enc_blocks"], cfg, jnp.zeros((), jnp.float32))
    memory = Lyr.rms_norm(hm, params["enc_final_norm"], cfg.norm_eps)

    tokens = batch["tokens"]
    B, S = tokens.shape
    h = params["embed"][tokens]
    pos_d = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def dec_body(carry, lp):
        h, aux = carry
        x = Lyr.rms_norm(h, lp["norm1"], cfg.norm_eps)
        h = h + Lyr.attn_forward(lp["attn"], x, pos_d, theta=cfg.rope_theta,
                                 causal=True, use_pallas=cfg.use_pallas)
        x = Lyr.rms_norm(h, lp["norm_x"], cfg.norm_eps)
        kv = Lyr.cross_kv(lp["cross"], memory)
        h = h + Lyr.attn_forward(lp["cross"], x, pos_d, causal=False,
                                 theta=cfg.rope_theta, kv_override=kv, rope=False)
        x = Lyr.rms_norm(h, lp["norm2"], cfg.norm_eps)
        h = h + Lyr.mlp_forward(lp["mlp"], x, cfg.act)
        return h, aux

    h, aux = _stack_scan(dec_body, h, params["blocks"], cfg, jnp.zeros((), jnp.float32))
    return Lyr.rms_norm(h, params["final_norm"], cfg.norm_eps), aux


_LOSS_CHUNK = 512


def _xent_chunk(params, cfg, h, labels, mask):
    """f32 cross-entropy for one sequence chunk.

    One-hot contraction instead of take_along_axis: gathering along a
    "model"-sharded vocab dim would force an all-gather of the logits;
    the iota-compare contraction partitions cleanly (GSPMD keeps the
    vocab dim sharded and psums the scalar)."""
    logits = _unembed(params, cfg, h).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = (labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, len(logits.shape) - 1)).astype(logits.dtype)
    ll = jnp.sum(logits * onehot, axis=-1)
    return jnp.sum((logz - ll) * mask)


def loss_fn(params: PyTree, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    """Masked LM cross-entropy + MoE aux.

    The unembed+softmax runs in sequence chunks (rematted scan) so the f32
    logits working set is [B, chunk, V] instead of [B, S, V] — at 100k
    vocab the full tensor alone would blow the per-chip HBM budget."""
    h, aux = forward_hidden(params, cfg, batch)
    labels = batch["labels"]
    mask = batch["mask"].astype(jnp.float32)
    B, S = labels.shape
    chunk = _LOSS_CHUNK if (S % _LOSS_CHUNK == 0 and S > _LOSS_CHUNK) else S
    if chunk == S:
        total = _xent_chunk(params, cfg, h, labels, mask)
    else:
        nc = S // chunk
        hs = jnp.moveaxis(h.reshape(B, nc, chunk, -1), 1, 0)
        ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
        ms = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

        @jax.checkpoint
        def chunk_loss(hc, lc, mc):
            return _xent_chunk(params, cfg, hc, lc, mc)

        def body(acc, xs):
            hc, lc, mc = xs
            return acc + chunk_loss(hc, lc, mc), None
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls, ms))
    return total / jnp.maximum(mask.sum(), 1.0) + aux


# ================================================================== #
# decode (serve_step)
# ================================================================== #
def cache_len(cfg: ArchConfig, seq_len: int) -> int:
    """Ring-buffer length: the window for SWA archs, else the full seq."""
    if cfg.attn_window is not None and cfg.attn_window < seq_len:
        return cfg.attn_window
    return seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> PyTree:
    """Abstract-shaped zero cache (real zeros; use eval_shape for dry-run)."""
    dtype = cfg.jnp_dtype
    L, K, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    Sc = cache_len(cfg, seq_len)
    if cfg.family in ("dense", "moe", "vlm"):
        S_tot = Sc + (cfg.vlm.n_patches if cfg.family == "vlm" else 0)
        return {
            "k": jnp.zeros((L, batch, S_tot, K, Dh), dtype),
            "v": jnp.zeros((L, batch, S_tot, K, Dh), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "ssm":
        d = Ssm.ssm_dims(cfg)
        return {
            "conv": jnp.zeros((L, batch, cfg.ssm.conv_width - 1, d["conv_dim"]), dtype),
            "state": jnp.zeros((L, batch, d["n_heads"], cfg.ssm.head_dim,
                                cfg.ssm.state_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        d = Ssm.ssm_dims(cfg)
        napps = hybrid_n_apps(cfg)
        return {
            "conv": jnp.zeros((L, batch, cfg.ssm.conv_width - 1, d["conv_dim"]), dtype),
            "state": jnp.zeros((L, batch, d["n_heads"], cfg.ssm.head_dim,
                                cfg.ssm.state_dim), dtype),
            # ONE KV cache per shared-block application: weights are shared,
            # the attended activations are not
            "shared_k": jnp.zeros((napps, batch, Sc, K, Dh), dtype),
            "shared_v": jnp.zeros((napps, batch, Sc, K, Dh), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "encdec":
        T = cfg.encdec.n_frames
        return {
            "k": jnp.zeros((L, batch, Sc, K, Dh), dtype),
            "v": jnp.zeros((L, batch, Sc, K, Dh), dtype),
            "cross_k": jnp.zeros((L, batch, T, K, Dh), dtype),
            "cross_v": jnp.zeros((L, batch, T, K, Dh), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.family)


def _decode_attn(cfg, p, x, pos, ck, cv, Sc, *, prefix_len: int = 0):
    """One-token attention against a (ring) cache.

    x [B,1,D]; ck/cv [B,Sc(+prefix),K,Dh]; pos scalar absolute position.
    Keys are stored ALREADY rotated.  Returns (out [B,1,D], new ck, cv).
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k_new = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v_new = jnp.einsum("bsd,dke->bske", x, p["wv"])
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = Lyr.apply_rope(q, posv, cfg.rope_theta)
    k_new = Lyr.apply_rope(k_new, posv, cfg.rope_theta)

    slot = prefix_len + (pos % Sc)
    ck = jax.lax.dynamic_update_slice(ck, k_new, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v_new, (0, slot, 0, 0))

    # validity: ring slots hold absolute positions p' = slot + floor stuff;
    # a slot s (s>=prefix) is valid iff its absolute position <= pos and
    # > pos - Sc (ring overwrite guarantees the latter); before wrap-around
    # slots with s' > pos are empty.
    s_idx = jnp.arange(ck.shape[1])
    ring = s_idx >= prefix_len
    abs_pos = jnp.where(ring, _ring_abs_pos(s_idx - prefix_len, pos, Sc), 0)
    # a ring slot is valid iff it holds a real position: 0 <= abs <= pos
    valid = jnp.where(ring, (abs_pos <= pos) & (abs_pos >= 0), True)
    if cfg.attn_window is not None:
        valid = valid & jnp.where(ring, abs_pos > pos - cfg.attn_window, True)
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, ck.shape[1]))
    out = Lyr.gqa_attention(q, ck, cv, mask)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), ck, cv


def _ring_abs_pos(slot: jnp.ndarray, pos: jnp.ndarray, Sc: int) -> jnp.ndarray:
    """Absolute position stored in ring slot ``slot`` after writing ``pos``."""
    cur_slot = pos % Sc
    base = pos - cur_slot
    return jnp.where(slot <= cur_slot, base + slot, base - Sc + slot)


def serve_step(params: PyTree, cfg: ArchConfig, cache: PyTree,
               tokens: jnp.ndarray) -> tuple[jnp.ndarray, PyTree]:
    """Decode ONE token: tokens [B,1] -> (logits [B,1,V], new cache)."""
    pos = cache["pos"]
    h = params["embed"][tokens]
    B = tokens.shape[0]
    Sc = cache["k"].shape[2] if "k" in cache else None
    prefix = cfg.vlm.n_patches if cfg.family == "vlm" else 0
    if prefix:
        Sc = cache["k"].shape[2] - prefix

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            h = carry
            lp, ck, cv = xs
            x = Lyr.rms_norm(h, lp["norm1"], cfg.norm_eps)
            a, ck, cv = _decode_attn(cfg, lp["attn"], x, pos, ck, cv, Sc, prefix_len=prefix)
            h = h + a
            x = Lyr.rms_norm(h, lp["norm2"], cfg.norm_eps)
            if "moe" in lp:
                y, _ = Moe.moe_forward(lp["moe"], x, cfg)
                h = h + y
            else:
                h = h + Lyr.mlp_forward(lp["mlp"], x, cfg.act)
            return h, (ck, cv)
        h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "pos": pos + 1}

    elif cfg.family == "ssm":
        def body(carry, xs):
            h = carry
            lp, conv, state = xs
            x = Lyr.rms_norm(h, lp["norm1"], cfg.norm_eps)
            y, conv, state = Ssm.ssm_decode_step(lp["ssm"], x, cfg, conv, state)
            return h + y, (conv, state)
        h, (convs, states) = jax.lax.scan(
            body, h, (params["blocks"], cache["conv"], cache["state"]))
        new_cache = {"conv": convs, "state": states, "pos": pos + 1}

    elif cfg.family == "hybrid":
        # segmented like forward_hidden: per-application shared KV caches,
        # carried only across their own segment boundary (no per-layer
        # cond/copy traffic)
        shared = params["shared_attn"]

        def body(carry, xs):
            h = carry
            lp, conv, state = xs
            x = Lyr.rms_norm(h, lp["norm1"], cfg.norm_eps)
            y, conv, state = Ssm.ssm_decode_step(lp["ssm"], x, cfg, conv, state)
            return h + y, (conv, state)

        convs, states, sks, svs = [], [], [], []
        app = 0
        for lo, hi, with_attn in _hybrid_segments(cfg):
            seg = jax.tree_util.tree_map(lambda x: x[lo:hi], params["blocks"])
            h, (conv_s, state_s) = jax.lax.scan(
                body, h, (seg, cache["conv"][lo:hi], cache["state"][lo:hi]))
            convs.append(conv_s)
            states.append(state_s)
            if with_attn:
                x = Lyr.rms_norm(h, shared["norm1"], cfg.norm_eps)
                a, sk, sv = _decode_attn(cfg, shared["attn"], x, pos,
                                         cache["shared_k"][app],
                                         cache["shared_v"][app],
                                         cache["shared_k"].shape[2])
                h = h + a
                x = Lyr.rms_norm(h, shared["norm2"], cfg.norm_eps)
                h = h + Lyr.mlp_forward(shared["mlp"], x, cfg.act)
                sks.append(sk)
                svs.append(sv)
                app += 1

        new_cache = {
            "conv": jnp.concatenate(convs, axis=0),
            "state": jnp.concatenate(states, axis=0),
            "shared_k": jnp.stack(sks), "shared_v": jnp.stack(svs),
            "pos": pos + 1,
        }

    elif cfg.family == "encdec":
        def body(carry, xs):
            h = carry
            lp, ck, cv, xk, xv = xs
            x = Lyr.rms_norm(h, lp["norm1"], cfg.norm_eps)
            a, ck, cv = _decode_attn(cfg, lp["attn"], x, pos, ck, cv, ck.shape[1])
            h = h + a
            x = Lyr.rms_norm(h, lp["norm_x"], cfg.norm_eps)
            a = Lyr.attn_forward(lp["cross"], x, jnp.zeros((B, 1), jnp.int32),
                                 causal=False, theta=cfg.rope_theta,
                                 kv_override=(xk, xv), rope=False)
            h = h + a
            x = Lyr.rms_norm(h, lp["norm2"], cfg.norm_eps)
            h = h + Lyr.mlp_forward(lp["mlp"], x, cfg.act)
            return h, (ck, cv)
        h, (ks, vs) = jax.lax.scan(
            body, h, (params["blocks"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache)
        new_cache.update({"k": ks, "v": vs, "pos": pos + 1})
    else:
        raise ValueError(cfg.family)

    h = Lyr.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, h), new_cache


# ================================================================== #
# sharding
# ================================================================== #
def _axis_for(dim: int, tp: int) -> bool:
    return dim % tp == 0


def add_fsdp(pspecs: PyTree, cfg: ArchConfig, *, fsdp_axes: tuple[str, ...],
             fsdp_size: int, min_elements: int = 1_000_000) -> PyTree:
    """FSDP/ZeRO-3: additionally shard every large leaf over the data axes.

    Picks the first unassigned dim divisible by the data-axis product
    (skipping the stacked-layer dim 0 — that's the scan axis).  GSPMD then
    all-gathers each layer's weights inside the scan and reduce-scatters
    its grads — the standard FSDP schedule, visible in the §Roofline
    collective table.  Required for the >=20B archs: params+opt at TP=16
    alone exceed 16 GB/chip."""
    import math
    tree = abstract_params(cfg)
    axis = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]

    def widen(spec: P, leaf) -> P:
        if math.prod(leaf.shape) < min_elements:
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        start = 1 if len(leaf.shape) >= 2 and leaf.shape[0] <= 256 else 0
        for d in range(start, len(parts)):
            if parts[d] is None and leaf.shape[d] % fsdp_size == 0:
                parts[d] = axis
                return P(*parts)
        return spec

    return jax.tree_util.tree_map(widen, pspecs, tree)


def param_pspecs(cfg: ArchConfig, tp: int = 16, model_axis: str = "model") -> PyTree:
    """PartitionSpec tree matching ``abstract_params(cfg)``.

    Policy (tensor/expert parallel over ``model_axis``; everything batch-
    like handled by activation shardings):
      * embed [V,D] -> (model, None); unembed [D,V] -> (None, model)
      * attention: shard the head dim when divisible by tp, else the
        d_model input dim (row parallel), else replicate
      * mlp w1/w3 [D,F] -> (None, model); w2 [F,D] -> (model, None)
      * moe experts [E,D,F] -> (model, None, None) when E%tp==0 (expert
        parallel: qwen3) else (None, None, model) (mixtral: 8 experts)
      * ssm projections: inner dim on model
      * norms / scalars replicated
    """
    M = model_axis

    def attn_spec(name: str, shape: tuple[int, ...]) -> P:
        if name == "wo":  # [H, Dh, D]
            if _axis_for(shape[0], tp):
                return P(M, None, None)
            if cfg.seq_shard:
                return P()   # replicated compute; FSDP shards storage.
                             # Row-parallel D-sharding under seq-sharded
                             # activations makes GSPMD emit partial-logits
                             # all-reduce [B,K,R,q,k] per layer (measured
                             # 29.8 TB/chip on yi-34b prefill) — replicated
                             # weights + sequence-parallel compute is the
                             # right schedule for indivisible head counts.
            if _axis_for(shape[2], tp):
                return P(None, None, M)
            return P()
        # wq/wk/wv [D, H_or_K, Dh]
        if _axis_for(shape[1], tp):
            return P(None, M, None)
        if cfg.seq_shard:
            return P()       # see wo comment
        if _axis_for(shape[0], tp):
            return P(M, None, None)
        return P()

    def spec_for(path: tuple[str, ...], leaf) -> P:
        # drop integer path parts (stacked list indices shouldn't appear:
        # blocks are stacked arrays with leading L dim)
        name = path[-1]
        parent = path[-2] if len(path) >= 2 else ""
        shape = leaf.shape
        stacked = parent in ("attn", "cross", "mlp", "moe", "ssm") and path[0] in (
            "blocks", "enc_blocks")
        off = 1 if (path[0] in ("blocks", "enc_blocks")) else 0  # leading L dim

        def pad(spec: P) -> P:
            return P(*([None] * off), *spec) if off else spec

        if name == "embed":
            return P(M, None) if _axis_for(shape[0], tp) else (
                P(None, M) if _axis_for(shape[1], tp) else P())
        if name == "unembed":
            if _axis_for(shape[1], tp):
                return P(None, M)
            return P(M, None) if _axis_for(shape[0], tp) else P()
        if name == "enc_pos":
            return P()
        if parent in ("attn", "cross") or (parent == "shared_attn" and name in
                                           ("wq", "wk", "wv", "wo")):
            return pad(attn_spec(name, shape[off:]))
        if parent == "mlp" or (parent == "shared_attn" and name in ("w1", "w2", "w3")):
            if name in ("w1", "w3"):
                return pad(P(None, M))
            return pad(P(M, None))
        if parent == "moe":
            if name == "router":
                return pad(P())
            E = shape[off]
            if _axis_for(E, tp):
                return pad(P(M, None, None))
            return pad(P(None, None, M)) if name in ("w1", "w3") else pad(P(None, M, None))
        if parent == "ssm":
            if name in ("in_z", "in_xbc"):
                return pad(P(None, M))
            if name == "in_dt":
                return pad(P(None, M) if _axis_for(shape[off + 1], tp) else P())
            if name in ("conv_w", "conv_b"):
                return pad(P(*([None] * (len(shape) - off - 1)), M))
            if name == "out_proj":
                return pad(P(M, None))
            if name == "gate_norm":
                return pad(P(M) if _axis_for(shape[off], tp) else P())
            return pad(P(*([None] * (len(shape) - off))))
        if parent == "vision_proj":
            return P(None, M) if name == "w" else P(M)
        # norms, scalars, biases
        return pad(P(*([None] * (len(shape) - off))))

    tree = abstract_params(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        parts = tuple(_key_str(pp) for pp in path)
        specs.append(spec_for(parts, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    return str(getattr(p, "name", p))
