"""jit-side unpack of bit-packed replay batches.

The packed learner path ships ``ReplayBuffer.sample_packed`` output to the
device as uint8 bit planes (32x less H2D traffic than the dense float32
layout) and reconstructs the dense train-step arrays INSIDE the jit'd
update — XLA fuses the unpack into the consumers, so the full ``[W, B, C,
FP_BITS+1]`` float32 tensor never crosses the host/device boundary.

``unpack_bits`` reproduces ``np.unpackbits`` (big-endian within each byte)
with shifts + masks, and ``densify_batch`` is the exact jnp twin of
``repro.core.replay.densify_sample`` — the equivalence tests pin the two to
produce bit-identical training batches, which is what makes the packed
learner's loss trajectory match the seed path bit for bit.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.chem.fingerprint import FP_BITS


def unpack_bits(packed: jnp.ndarray, n_bits: int | None = None) -> jnp.ndarray:
    """uint8 [..., n_bytes] -> float32 [..., n_bytes*8] of exact {0.0, 1.0}.

    Bit order matches ``np.unpackbits`` (MSB of byte i becomes bit 8i)."""
    shifts = jnp.arange(7, -1, -1, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)
    out = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * 8,))
    if n_bits is not None:
        out = out[..., :n_bits]
    return out.astype(jnp.float32)


def densify_batch(packed: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
    """Packed batch -> the dense layout the double-DQN loss consumes.

    Works for any leading batch dims (the trainer passes ``[W, B, ...]``
    stacked batches through ``shard_map``, so each device unpacks only its
    resident worker shard).  Candidate rows past each transition's count —
    and every row of terminal transitions — are zeroed, exactly like the
    host-side ``densify_sample``.
    """
    states = jnp.concatenate(
        [unpack_bits(packed["state_bits"]), packed["state_frac"][..., None]],
        axis=-1)
    C = packed["next_bits"].shape[-2]
    eff = jnp.where(packed["dones"] > 0, 0,
                    jnp.minimum(packed["next_counts"], C))
    next_mask = (jnp.arange(C) < eff[..., None]).astype(jnp.float32)
    next_fps = jnp.concatenate(
        [unpack_bits(packed["next_bits"]) * next_mask[..., None],
         (packed["next_frac"][..., None] * next_mask)[..., None]],
        axis=-1)
    out = {"states": states, "rewards": packed["rewards"],
           "dones": packed["dones"], "next_fps": next_fps,
           "next_mask": next_mask}
    if "weights" in packed:          # prioritized replay importance weights
        out["weights"] = packed["weights"]
    return out


def packed_nbytes(packed: dict) -> int:
    """Host->device bytes a packed (or dense) batch dict ships."""
    return int(sum(v.nbytes for v in packed.values()))


def dense_nbytes_equivalent(packed: dict) -> int:
    """What the same batch would ship in the seed dense float32 layout
    (states/rewards/dones/next_fps/next_mask) — the H2D-reduction metric."""
    b_shape = packed["state_bits"].shape[:-1]      # [..., B]
    C = packed["next_bits"].shape[-2]
    rows = 1
    for d in b_shape:
        rows *= d
    n = 4 * (rows * (FP_BITS + 1)             # states
             + rows + rows                    # rewards, dones
             + rows * C * (FP_BITS + 1)       # next_fps
             + rows * C)                      # next_mask
    if "weights" in packed:                   # prioritized: weights ship in
        n += 4 * rows                         # both layouts identically
    return n
