"""The filter script (§3.5).

"an extra script filters out molecules without good BDE and IP properties.
The molecules are also filtered out if their SA scores are higher than 3.5
or if they are identical to existing antioxidants."

Constraints implemented (see §4.1 A-E):
  (A) BDE  < bde_max   (76 kcal/mol)
  (B) IP   > ip_min    (145 kcal/mol)
  (D) similar-but-not-identical: canonical-key inequality vs every known
      antioxidant, plus an optional Tanimoto ceiling
  (E) SA score <= sa_max (3.5)

Property values come from the *predictors* (as in the paper's pipeline);
the DFT-validation benchmark re-scores survivors with the oracle.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.chem.molecule import Molecule
from repro.chem.properties import sa_score, tanimoto


@dataclass(frozen=True)
class FilterCriteria:
    bde_max: float = 76.0
    ip_min: float = 145.0
    sa_max: float = 3.5
    tanimoto_max: float = 0.999   # < 1.0 means "not identical" only
    require_oh: bool = True


@dataclass(frozen=True)
class FilterResult:
    molecule: Molecule
    bde: float
    ip: float
    sa: float
    max_similarity: float
    passed: bool
    reasons: tuple[str, ...]


def filter_molecules(
    candidates: list[tuple[Molecule, float | None, float | None]],
    known: list[Molecule],
    criteria: FilterCriteria = FilterCriteria(),
) -> list[FilterResult]:
    """``candidates`` are (molecule, predicted_bde, predicted_ip) triples."""
    known_keys = {m.canonical_key() for m in known}
    out: list[FilterResult] = []
    for mol, bde, ip in candidates:
        reasons: list[str] = []
        if bde is None or (criteria.require_oh and not mol.has_oh_bond()):
            reasons.append("no_oh_bond")
            bde = float("inf") if bde is None else bde
        if ip is None:
            reasons.append("invalid_conformer")
            ip = float("-inf")
        if bde >= criteria.bde_max:
            reasons.append("bde_too_high")
        if ip <= criteria.ip_min:
            reasons.append("ip_too_low")
        sa = sa_score(mol)
        if sa > criteria.sa_max:
            reasons.append("sa_too_high")
        if mol.canonical_key() in known_keys:
            reasons.append("identical_to_known")
        max_sim = max((tanimoto(mol, k) for k in known), default=0.0)
        if max_sim > criteria.tanimoto_max:
            reasons.append("too_similar")
        out.append(FilterResult(
            molecule=mol, bde=float(bde), ip=float(ip), sa=float(sa),
            max_similarity=float(max_sim), passed=not reasons,
            reasons=tuple(reasons),
        ))
    return out
