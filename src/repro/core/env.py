"""Molecule-optimisation environments.

``MoleculeEnv``  one molecule, MolDQN semantics: every episode restarts
                 from the initial molecule; each step picks one valid edit;
                 Q states are candidate-next-state fingerprints ++ a
                 normalised steps-left feature.

``BatchedEnv``   the paper's *batched modification* (§3.1): a worker owns a
                 batch of molecules and advances them in lockstep — "it
                 will not go to the next step until all molecules in the
                 current step finished their operations".  The payoff, as
                 in the paper, is batching: ONE Q-network jit call over all
                 candidates of all molecules, and ONE property-predictor
                 call over all chosen successors.

The environment never calls predictors per molecule; the property batch is
the only predictor entry point (see PropertyService).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.chem.actions import Action, enumerate_actions
from repro.chem.fingerprint import FP_BITS, batch_morgan_fingerprints
from repro.chem.molecule import ALLOWED_RING_SIZES, Molecule
from repro.core.agent import DQNAgent
from repro.core.replay import ReplayBuffer, Transition, pack_fp
from repro.core.reward import RewardConfig, compute_reward
from repro.predictors.service import PropertyService


@dataclass(frozen=True)
class EnvConfig:
    max_steps: int = 10                       # Table 3
    max_atoms: int = 38
    allow_removal: bool = True
    protect_oh: bool = True                   # §3.3
    allowed_ring_sizes: frozenset = ALLOWED_RING_SIZES


@dataclass
class StepRecord:
    """What one molecule produced in one environment step."""
    slot: int
    molecule: Molecule
    reward: float
    done: bool
    conformer_valid: bool
    bde: float | None
    ip: float | None


@dataclass(eq=False)
class _Slot:
    initial: Molecule
    current: Molecule
    steps_left: int
    candidates: list[Action] = field(default_factory=list)
    cand_fps: np.ndarray | None = None        # f32[C, FP_BITS] (no steps col)
    pending: Transition | None = None         # waiting for next-state candidates
    best: tuple[float, Molecule] | None = None

    def steps_frac(self, cfg: EnvConfig) -> float:
        return self.steps_left / cfg.max_steps


class BatchedEnv:
    """Lockstep batch of molecule episodes (one per 'slot')."""

    def __init__(self, molecules: list[Molecule], cfg: EnvConfig = EnvConfig(), seed: int = 0):
        self.cfg = cfg
        self.initials = list(molecules)
        self.slots: list[_Slot] = []
        self._rng = np.random.default_rng(seed)
        self.reset()

    # ------------------------------------------------------------ #
    def reset(self) -> None:
        self.slots = [
            _Slot(initial=m, current=m, steps_left=self.cfg.max_steps) for m in self.initials
        ]
        self._enumerate_all()

    @property
    def done(self) -> bool:
        return all(s.steps_left <= 0 for s in self.slots)

    # ------------------------------------------------------------ #
    def _enumerate_all(self) -> None:
        """Enumerate candidates + fingerprints for every live slot, and
        complete any pending transitions with the fresh candidate sets."""
        todo = [s for s in self.slots if s.steps_left > 0]
        all_cands: list[Molecule] = []
        spans: list[tuple[_Slot, int, int]] = []
        for s in todo:
            s.candidates = enumerate_actions(
                s.current,
                allow_removal=self.cfg.allow_removal,
                protect_oh=self.cfg.protect_oh,
                allowed_ring_sizes=self.cfg.allowed_ring_sizes,
                max_atoms=self.cfg.max_atoms,
            )
            spans.append((s, len(all_cands), len(all_cands) + len(s.candidates)))
            all_cands.extend(a.result for a in s.candidates)
        if not all_cands:
            return
        fps = batch_morgan_fingerprints(all_cands)
        for s, lo, hi in spans:
            s.cand_fps = fps[lo:hi]
            if s.pending is not None:
                # successor candidates are exactly this step's candidates
                s.pending.next_fps = np.stack([pack_fp(f) for f in s.cand_fps])
                s.pending.next_steps_left_frac = (s.steps_left - 1) / self.cfg.max_steps

    # ------------------------------------------------------------ #
    def step(
        self,
        agent: DQNAgent,
        service: PropertyService,
        reward_cfg: RewardConfig,
        buffer: ReplayBuffer | None = None,
    ) -> list[StepRecord]:
        """One lockstep environment step for every live slot."""
        live = [s for s in self.slots if s.steps_left > 0]
        if not live:
            return []

        # flush completed pending transitions into the buffer
        if buffer is not None:
            for s in live:
                if s.pending is not None and s.pending.next_fps is not None:
                    buffer.add(s.pending)
                    s.pending = None

        # ---- ONE Q call over all candidates of all molecules ---------- #
        stacked = []
        for s in live:
            steps_after = (s.steps_left - 1) / self.cfg.max_steps
            col = np.full((s.cand_fps.shape[0], 1), steps_after, dtype=np.float32)
            stacked.append(np.concatenate([s.cand_fps, col], axis=1))
        lens = [x.shape[0] for x in stacked]
        q_all = agent.q_values(np.concatenate(stacked, axis=0))

        # ---- per-slot eps-greedy selection ----------------------------- #
        chosen: list[tuple[_Slot, Action, np.ndarray]] = []
        off = 0
        for s, ln in zip(live, lens):
            q = q_all[off : off + ln]
            off += ln
            a_idx = agent.select_action(q)
            chosen.append((s, s.candidates[a_idx], s.cand_fps[a_idx]))

        # ---- ONE property call over the chosen successors -------------- #
        props = service.predict([a.result for _, a, _ in chosen])

        records: list[StepRecord] = []
        for (s, act, fp), pr in zip(chosen, props, strict=True):
            s.current = act.result
            s.steps_left -= 1
            done = s.steps_left <= 0
            if callable(reward_cfg):
                # pluggable objective (e.g. QED / PlogP, Appendix D)
                reward = reward_cfg(pr, s.initial, s.current, s.steps_left)
            else:
                reward = compute_reward(
                    reward_cfg, bde=pr.bde, ip=pr.ip,
                    initial=s.initial, current=s.current, steps_left=s.steps_left,
                )
            if s.best is None or reward > s.best[0]:
                s.best = (reward, s.current)
            t = Transition(
                state_fp=pack_fp(fp),
                steps_left_frac=s.steps_left / self.cfg.max_steps,
                reward=reward,
                done=done,
                next_fps=np.zeros((0, FP_BITS // 8), dtype=np.uint8),
                next_steps_left_frac=0.0,
            )
            if done:
                if buffer is not None:
                    buffer.add(t)            # terminal: no successor needed
            else:
                t.next_fps = None            # filled by the next enumerate
                s.pending = t
            records.append(StepRecord(
                slot=self.slots.index(s), molecule=s.current, reward=reward,
                done=done, conformer_valid=pr.conformer_valid, bde=pr.bde, ip=pr.ip,
            ))

        self._enumerate_all()
        return records

    # ------------------------------------------------------------ #
    def run_episode(
        self,
        agent: DQNAgent,
        service: PropertyService,
        reward_cfg: RewardConfig,
        buffer: ReplayBuffer | None = None,
    ) -> list[StepRecord]:
        """Reset + roll a full episode; returns ALL step records (the
        final step's records are those with ``done=True``)."""
        self.reset()
        all_recs: list[StepRecord] = []
        while not self.done:
            all_recs.extend(self.step(agent, service, reward_cfg, buffer))
        return all_recs

    def final_molecules(self) -> list[Molecule]:
        return [s.current for s in self.slots]

    def best_molecules(self) -> list[tuple[float, Molecule]]:
        return [s.best if s.best is not None else (-np.inf, s.current) for s in self.slots]


class MoleculeEnv(BatchedEnv):
    """Single-molecule environment (original MolDQN) = batch of one."""

    def __init__(self, molecule: Molecule, cfg: EnvConfig = EnvConfig(), seed: int = 0):
        super().__init__([molecule], cfg, seed)
