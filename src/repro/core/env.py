"""Molecule-optimisation environments — thin adapters over RolloutEngine.

``MoleculeEnv``  one molecule, MolDQN semantics: every episode restarts
                 from the initial molecule; each step picks one valid edit;
                 Q states are candidate-next-state fingerprints ++ a
                 normalised steps-left feature.

``BatchedEnv``   the paper's *batched modification* (§3.1): a worker owns a
                 batch of molecules and advances them in lockstep — "it
                 will not go to the next step until all molecules in the
                 current step finished their operations".

Since the fleet-level refactor both are single-worker views over
``repro.core.rollout.RolloutEngine``; the slot machinery, the one-Q-call /
one-property-batch step loop, and replay threading all live there.  The
environment never calls predictors per molecule; the property batch is the
only predictor entry point (see PropertyService).
"""

from __future__ import annotations

from repro.chem.chemcache import ChemCache
from repro.chem.molecule import Molecule
from repro.core.replay import ReplayBuffer
from repro.core.reward import RewardConfig
from repro.core.rollout import (
    EnvConfig, RolloutEngine, Slot, StepRecord, as_fleet_policy)

__all__ = ["EnvConfig", "StepRecord", "BatchedEnv", "MoleculeEnv"]


class BatchedEnv:
    """Lockstep batch of molecule episodes (one per 'slot'): a one-worker
    fleet.  ``agent`` may be anything with ``q_values``/``select_action``
    (DQNAgent, a trainer worker view) or a full FleetPolicy.

    ``chem``/``chem_cache`` select the engine's candidate-chemistry path;
    the trainer shares ONE ChemCache across all its per-worker envs, so the
    legacy ``rollout="per_worker"`` loop still dedupes chemistry fleet-wide.
    """

    def __init__(self, molecules: list[Molecule], cfg: EnvConfig = EnvConfig(),
                 seed: int = 0, chem: str = "full",
                 chem_cache: ChemCache | None = None):
        # ``seed`` is kept for API stability; the environment is
        # deterministic — action stochasticity lives in the agent's RNG
        self.cfg = cfg
        self.initials = list(molecules)
        self._engine = RolloutEngine([self.initials], cfg, chem=chem,
                                     chem_cache=chem_cache)

    # ------------------------------------------------------------ #
    @property
    def slots(self) -> list[Slot]:
        return self._engine.workers[0]

    def reset(self) -> None:
        self._engine.reset()

    @property
    def done(self) -> bool:
        return self._engine.done

    # ------------------------------------------------------------ #
    def step(
        self,
        agent,
        service,
        reward_cfg: "RewardConfig | object",
        buffer: ReplayBuffer | None = None,
    ) -> list[StepRecord]:
        """One lockstep environment step for every live slot.

        ``reward_cfg`` accepts any fleet objective the engine resolves:
        a ``RewardConfig`` (Eq. 1 scalar path), an ``ObjectiveSpec`` /
        registry scenario name (compiled + vectorised), a
        ``CompiledObjective``, or an arbitrary callable
        ``f(props, initial, current, steps_left) -> float``.
        """
        return self._engine.step(
            as_fleet_policy(agent), service, reward_cfg, [buffer])

    def run_episode(
        self,
        agent,
        service,
        reward_cfg: "RewardConfig | object",
        buffer: ReplayBuffer | None = None,
    ) -> list[StepRecord]:
        """Reset + roll a full episode; returns ALL step records (the
        final step's records are those with ``done=True``)."""
        return self._engine.run_episode(
            as_fleet_policy(agent), service, reward_cfg, [buffer])

    def final_molecules(self) -> list[Molecule]:
        return self._engine.final_molecules(worker=0)

    def best_molecules(self) -> list[tuple[float, Molecule]]:
        return self._engine.best_molecules(worker=0)


class MoleculeEnv(BatchedEnv):
    """Single-molecule environment (original MolDQN) = batch of one."""

    def __init__(self, molecule: Molecule, cfg: EnvConfig = EnvConfig(), seed: int = 0,
                 chem: str = "full", chem_cache: ChemCache | None = None):
        super().__init__([molecule], cfg, seed, chem=chem, chem_cache=chem_cache)
