"""The DQN agent: fingerprint MLP Q-network, double-DQN loss, eps-greedy.

Faithful to MolDQN/DA-MolDQN:
  * Q(s, a) is evaluated on the *fingerprint of the candidate next state*
    (Morgan radius 3, 2048 bits) concatenated with a steps-left feature;
  * hidden sizes [1024, 512, 128, 32] (MolDQN's published architecture);
  * double Q-learning with a target network, Adam(1e-4), discount 1.0,
    decaying epsilon-greedy exploration (Table 3 / Appendix C);
  * the Q evaluation over all candidates of all molecules in a worker's
    modification batch happens in ONE jit call (batched modification) —
    optionally through the Pallas ``fused_qnet`` kernel.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.chem.fingerprint import FP_BITS
from repro.optim import adam
from repro.optim.adam import OptState, apply_updates

HIDDEN_SIZES = (1024, 512, 128, 32)
STATE_DIM = FP_BITS + 1  # fingerprint ++ steps-left


@dataclass(frozen=True)
class QNetwork:
    """MLP over fingerprint states; pure init/apply."""

    hidden: tuple[int, ...] = HIDDEN_SIZES
    in_dim: int = STATE_DIM

    def init(self, key: jax.Array) -> dict:
        sizes = (self.in_dim,) + self.hidden + (1,)
        keys = jax.random.split(key, len(sizes) - 1)
        layers = []
        for k, (i, o) in zip(keys, zip(sizes[:-1], sizes[1:])):
            layers.append({
                "w": (jax.random.normal(k, (i, o), jnp.float32) * (2.0 / i) ** 0.5),
                "b": jnp.zeros((o,), jnp.float32),
            })
        return {"layers": layers}

    def apply(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        """x [..., in_dim] -> q [...]."""
        h = x
        n = len(params["layers"])
        for li, layer in enumerate(params["layers"]):
            h = h @ layer["w"] + layer["b"]
            if li < n - 1:
                h = jax.nn.relu(h)
        return h[..., 0]

    def apply_stacked(self, stacked_params: dict, x: jnp.ndarray) -> jnp.ndarray:
        """Per-worker parameter selection for the fleet rollout engine.

        ``stacked_params`` leaves are ``[W, ...]`` (one parameter tree per
        worker), ``x`` is ``[W, C, in_dim]`` (worker-major candidate states)
        -> q ``[W, C]``.  One dispatch evaluates every worker's candidates
        under that worker's own parameters.
        """
        return jax.vmap(self.apply)(stacked_params, x)

    def apply_stacked_packed(self, stacked_params: dict, bits: jnp.ndarray,
                             frac: jnp.ndarray) -> jnp.ndarray:
        """``apply_stacked`` fed PACKED candidate fingerprints.

        ``bits`` u8 ``[W, C, FP_BITS/8]`` (one ``pack_fps`` plane per
        candidate row), ``frac`` f32 ``[W, C]`` (steps-left feature) ->
        q ``[W, C]``.  The unpack runs INSIDE the jit (``packed_batch.
        unpack_bits`` shift/mask, the one fingerprint bit-order contract),
        so only the ~32x smaller planes cross the host/device boundary;
        XLA then sees the exact ``[W, C, in_dim]`` operand values the
        dense ``apply_stacked`` would, which is what keeps packed acting's
        Q values — and the actions chosen from them — bit-identical to the
        dense reference (tests/test_rollout.py).
        """
        from repro.core.packed_batch import unpack_bits

        x = jnp.concatenate([unpack_bits(bits), frac[..., None]], axis=-1)
        return jax.vmap(self.apply)(stacked_params, x)


@dataclass(frozen=True)
class DQNConfig:
    lr: float = 1e-4                 # Table 3
    discount: float = 1.0            # Table 3
    epsilon_initial: float = 1.0     # Table 2 (individual/parallel/general)
    epsilon_decay: float = 0.999     # per-episode; 0.97 for the general model
    epsilon_min: float = 0.01
    batch_size: int = 128            # max training batch (Table 2)
    grad_clip: float = 10.0
    target_update_episodes: int = 1  # Table 3 "Update Episodes 1"
    use_pallas_qnet: bool = False    # route Q eval through the fused kernel


class DQNAgent:
    """Holds online + target params and exposes numpy-facing helpers.

    The jit'd internals (``_q_fn``, ``_train_fn``) are shared across agents
    with the same config (cached at class level) so the 256-individual-model
    benchmark doesn't retrace 256 times.
    """

    _fn_cache: dict = {}

    def __init__(self, cfg: DQNConfig, seed: int = 0, network: QNetwork | None = None):
        self.cfg = cfg
        self.network = network or QNetwork()
        key = jax.random.PRNGKey(seed)
        self.params = self.network.init(key)
        self.target_params = jax.tree_util.tree_map(jnp.copy, self.params)
        self.opt = adam(cfg.lr, clip_norm=cfg.grad_clip)
        self.opt_state: OptState = self.opt.init(self.params)
        self.epsilon = cfg.epsilon_initial
        self._rng = np.random.default_rng(seed + 1)
        self.n_q_dispatches = 0  # jit dispatches issued for acting
        self._q_fn, self._train_fn = self._build_fns()

    # ------------------------------------------------------------ #
    def _build_fns(self):
        cache_key = (self.network, self.cfg.lr, self.cfg.grad_clip, self.cfg.discount,
                     self.cfg.use_pallas_qnet)
        if cache_key in DQNAgent._fn_cache:
            return DQNAgent._fn_cache[cache_key]

        network, opt, discount = self.network, self.opt, self.cfg.discount
        use_pallas = self.cfg.use_pallas_qnet

        def q_apply(params, x):
            if use_pallas:
                from repro.kernels.fused_qnet import ops as qops
                return qops.fused_qnet(params, x)
            return network.apply(params, x)

        @jax.jit
        def q_fn(params, states):
            return q_apply(params, states)

        @jax.jit
        def train_fn(params, target_params, opt_state, batch):
            def loss_fn(p):
                q_sa = network.apply(p, batch["states"])                      # [B]
                # double DQN: argmax via online net, value via target net
                q_next_online = network.apply(p, batch["next_fps"])           # [B,C]
                q_next_online = jnp.where(batch["next_mask"] > 0, q_next_online, -jnp.inf)
                a_star = jnp.argmax(q_next_online, axis=-1)                   # [B]
                q_next_target = network.apply(target_params, batch["next_fps"])
                v_next = jnp.take_along_axis(q_next_target, a_star[:, None], axis=-1)[:, 0]
                v_next = jnp.where(batch["next_mask"].sum(-1) > 0, v_next, 0.0)
                y = batch["rewards"] + discount * (1.0 - batch["dones"]) * v_next
                y = jax.lax.stop_gradient(y)
                td = q_sa - y
                return jnp.mean(huber(td))
            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, opt_state2 = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state2, loss

        DQNAgent._fn_cache[cache_key] = (q_fn, train_fn)
        return q_fn, train_fn

    # ------------------------------------------------------------ #
    # acting
    # ------------------------------------------------------------ #
    def q_values(self, states: np.ndarray) -> np.ndarray:
        """states f32[N, STATE_DIM] -> q f32[N]; one jit call, bucketed."""
        n = states.shape[0]
        padded = pad_rows(n)
        if padded != n:
            states = np.concatenate(
                [states, np.zeros((padded - n, states.shape[1]), states.dtype)])
        self.n_q_dispatches += 1
        q = np.asarray(self._q_fn(self.params, jnp.asarray(states)))
        return q[:n]

    def select_action(self, q: np.ndarray) -> int:
        """Decaying eps-greedy (§3.1)."""
        if self._rng.random() < self.epsilon:
            return int(self._rng.integers(0, q.shape[0]))
        return int(np.argmax(q))

    def decay_epsilon(self) -> None:
        self.epsilon = max(self.epsilon * self.cfg.epsilon_decay, self.cfg.epsilon_min)

    # ------------------------------------------------------------ #
    # learning
    # ------------------------------------------------------------ #
    def train_step(self, batch: dict[str, np.ndarray]) -> float:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        self.params, self.opt_state, loss = self._train_fn(
            self.params, self.target_params, self.opt_state, batch)
        return float(loss)

    def update_target(self) -> None:
        self.target_params = jax.tree_util.tree_map(jnp.copy, self.params)

    # state dict for checkpoint / sync
    def get_state(self) -> dict:
        return {"params": self.params, "target": self.target_params,
                "opt": self.opt_state}

    def set_state(self, state: dict) -> None:
        self.params = state["params"]
        self.target_params = state["target"]
        self.opt_state = state["opt"]


def huber(x: jnp.ndarray, delta: float = 1.0) -> jnp.ndarray:
    a = jnp.abs(x)
    return jnp.where(a <= delta, 0.5 * x * x, delta * (a - 0.5 * delta))


def pad_rows(n: int, sizes=(64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    """Row-count padding bucket for per-worker Q dispatches (the shared
    helper — ``agent.q_values`` and the trainer's ``_WorkerView`` both
    bucket through this one ladder, so they always hit the same jit
    shapes)."""
    for s in sizes:
        if n <= s:
            return s
    return ((n + 4095) // 4096) * 4096


def candidate_capacity_table(n_workers: int, max_candidates: int = 1024,
                             *, grain: int = 32) -> tuple[int, ...]:
    """Padded candidate-axis capacities for the dense ``[W, C, D]`` fleet
    Q batch (``QNetwork.apply_stacked``).

    Each padded candidate row costs ``W x D`` floats, so the rung ratio
    shrinks as the fleet grows: 2x rungs up to W=64 (recompiles are the
    scarce resource), 1.5x up to W=256, 1.25x beyond — at W=512 the dense
    batch never pads the candidate axis more than ~25% past the fleet's
    observed max.  Combined with the sticky high-water buffer in the fleet
    view (capacity only ever grows), jit shapes change O(log C) times per
    run instead of every time the per-step max drifts across a grain line.
    """
    ratio = 2.0 if n_workers <= 64 else 1.5 if n_workers <= 256 else 1.25
    caps, c = [], grain
    while c < max_candidates:
        caps.append(c)
        c = max(c + grain, grain * round(c * ratio / grain))
    caps.append(c)
    return tuple(caps)


def candidate_capacity(n: int, table: tuple[int, ...]) -> int:
    """Smallest rung >= n (grain-rounded past the table's end)."""
    for cap in table:
        if n <= cap:
            return cap
    return 32 * -(-n // 32)
