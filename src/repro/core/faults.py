"""Deterministic fault injection for the training stack.

One injection surface — a seeded :class:`FaultPlan` — shared by tests,
benches, and the ``launch/verify.py`` fault scenarios, instead of ad-hoc
monkeypatching per harness.  The hook sites:

``predict``      ``ResilientService`` consults the plan before each
                 underlying ``PropertyService.predict`` call (serial —
                 counter-scheduled via :meth:`FaultPlan.check_call`).
``chem``         ``RolloutEngine`` consults the plan per *molecule* before
                 enumeration (threaded under ``fleet_pipelined`` —
                 content-keyed via :meth:`FaultPlan.check_key` so the
                 schedule is a pure function of the molecule, independent
                 of thread interleaving).
``checkpoint``   ``CheckpointManager.save`` consults the plan before each
                 write (serial, counter-scheduled).
``request``      serve site — ``serving.MoleculeOptService`` consults the
                 plan per *request* at bind time (content-keyed on the
                 request id, so the faulted request set is independent of
                 admission order).  Transient → the bind is retried next
                 service step; crash → the request fails with an Incident,
                 its co-batched neighbours untouched.

Fault taxonomy (what the hooks raise):

:class:`TransientFault`   retryable — the retry layer absorbs it; a
                          retried call is bit-identical to a first-try
                          call because every wrapped dependency is
                          deterministic.
:class:`FaultTimeout`     retryable — a ``TransientFault`` flavoured as a
                          per-call timeout (also raised by the real
                          timeout path in ``ResilientService``).
:class:`FaultError`       terminal — retries exhausted or an injected
                          slot crash; the fleet quarantines the affected
                          slot (structured :class:`Incident` record, slot
                          drains to dead, revived from the dataset cursor
                          at the next episode boundary).

Determinism contract: with the same plan (rules + seed) and the same
work content, the set of injected faults is identical run-to-run — for
serial sites because the call order is the program order, for threaded
sites because injection keys on *content* with fail-first-N-attempts
semantics rather than on arrival order.
"""

from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field

# the exception taxonomy lives dependency-free at the package root (see
# repro.faults for why); this module is the RL core's import site for it
from repro.faults import FaultError, FaultTimeout, TransientFault

__all__ = [
    "FaultError", "FaultTimeout", "TransientFault",
    "FaultPlan", "FaultRule", "Incident",
]


_KINDS = {
    "transient": TransientFault,
    "timeout": FaultTimeout,
    "crash": FaultError,
}


@dataclass(frozen=True)
class Incident:
    """Structured record of one handled fault (the operator-facing trail).

    ``action`` is what the stack did about it: ``"retried"`` (absorbed by
    the retry layer), ``"quarantined"`` (slot drained to dead, revived
    next episode), ``"checkpoint_skipped"`` (write abandoned, previous
    rotation entry remains authoritative), ``"restarted"`` (supervised
    pipelined shard re-run inline).
    """

    episode: int
    step: int
    site: str          # "predict" | "chem" | "checkpoint" | "pipeline"
                       # | "reward" (a custom/callable objective raised;
                       #   slot quarantined, fleet survives)
                       # | serve sites: "request" | "parse"
    worker: int        # -1 when not slot-attributable
    slot: int          # -1 when not slot-attributable
    key: str           # molecule canonical key / path / "" when n/a
    error: str         # repr of the triggering exception
    action: str

    def as_dict(self) -> dict:
        return {
            "episode": self.episode, "step": self.step, "site": self.site,
            "worker": self.worker, "slot": self.slot, "key": self.key,
            "error": self.error, "action": self.action,
        }


@dataclass(frozen=True)
class FaultRule:
    """One injection rule.

    ``site``           hook site this rule arms ("predict" / "chem" /
                       "checkpoint" / "pipeline" / "request").
    ``kind``           "transient" | "timeout" | "crash" (what is raised).
    ``every``          serial sites: fault every Nth logical call
                       (1-based: ``every=3`` faults calls 3, 6, 9, ...).
    ``rate``           keyed sites: fault fraction of keys (pure function
                       of (seed, site, key) — thread-order independent).
    ``fail_attempts``  consecutive failures per scheduled call/key before
                       it succeeds; set it above the retry budget to make
                       the fault terminal.
    ``max_injections`` stop injecting after this many faults (None =
                       unlimited).
    """

    site: str
    kind: str = "transient"
    every: int | None = None
    rate: float | None = None
    fail_attempts: int = 1
    max_injections: int | None = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if (self.every is None) == (self.rate is None):
            raise ValueError("exactly one of every/rate must be set")
        if self.every is not None and self.every < 1:
            raise ValueError("every must be >= 1")


@dataclass
class _SiteState:
    n_logical: int = 0          # completed logical calls (serial sites)
    burst: int = 0              # failures so far for the in-flight call
    n_injected: int = 0
    key_attempts: dict = field(default_factory=dict)   # keyed sites


class FaultPlan:
    """Seeded, reproducible fault schedule over the three hook sites.

    Thread-safe: ``check_key`` is called from the pipelined enumeration
    threads; all mutable state sits behind one lock.
    """

    def __init__(self, rules: tuple[FaultRule, ...] | list[FaultRule],
                 seed: int = 0):
        self.rules = tuple(rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._sites: dict[str, _SiteState] = {}
        by_site: dict[str, FaultRule] = {}
        for r in self.rules:
            if r.site in by_site:
                raise ValueError(f"duplicate rule for site {r.site!r}")
            by_site[r.site] = r
        self._by_site = by_site

    def _state(self, site: str) -> _SiteState:
        return self._sites.setdefault(site, _SiteState())

    def _raise(self, rule: FaultRule, st: _SiteState, detail: str):
        st.n_injected += 1
        exc = _KINDS[rule.kind]
        raise exc(f"injected {rule.kind} fault at {rule.site} ({detail})")

    # -- serial sites (predict / checkpoint) --------------------------------

    def check_call(self, site: str) -> None:
        """Consult the schedule for the next *serial* call at ``site``;
        raises the rule's exception when that call is scheduled to fail.

        Semantics: a scheduled logical call fails ``fail_attempts`` times
        in a row (each retry re-enters here), then succeeds — so the same
        retry budget sees the same failure burst on every run.
        """
        rule = self._by_site.get(site)
        if rule is None or rule.every is None:
            return
        with self._lock:
            st = self._state(site)
            if st.burst > 0:                       # mid-burst: retry arrives
                if st.burst < rule.fail_attempts:
                    st.burst += 1
                    self._raise(rule, st, f"call {st.n_logical + 1}, "
                                          f"attempt {st.burst}")
                st.burst = 0                       # burst over: succeed
                st.n_logical += 1
                return
            n = st.n_logical + 1                   # 1-based logical index
            due = (n % rule.every == 0) and (
                rule.max_injections is None or st.n_injected < rule.max_injections)
            if due:
                st.burst = 1
                self._raise(rule, st, f"call {n}, attempt 1")
            st.n_logical += 1

    # -- content-keyed sites (chem, threaded) -------------------------------

    def _key_hash01(self, site: str, key: str) -> float:
        h = hashlib.sha256(
            f"{self.seed}|{site}|{key}".encode()).digest()
        return int.from_bytes(h[:8], "little") / 2.0 ** 64

    def check_key(self, site: str, key: str) -> None:
        """Consult the schedule for content ``key`` at ``site``.  A pure
        function of (seed, site, key) decides WHETHER the key faults; a
        per-key attempt counter makes the first ``fail_attempts`` attempts
        fail and later attempts succeed — deterministic regardless of
        which thread gets there first."""
        rule = self._by_site.get(site)
        if rule is None or rule.rate is None:
            return
        if self._key_hash01(site, key) >= rule.rate:
            return
        with self._lock:
            st = self._state(site)
            if (rule.max_injections is not None
                    and st.n_injected >= rule.max_injections
                    and key not in st.key_attempts):
                return
            seen = st.key_attempts.get(key, 0)
            if seen < rule.fail_attempts:
                st.key_attempts[key] = seen + 1
                self._raise(rule, st, f"key {key[:40]!r}, attempt {seen + 1}")

    def has_rule(self, site: str) -> bool:
        """Whether any rule arms ``site`` — lets a hook skip key hashing
        entirely when the site is cold."""
        return site in self._by_site

    # -- accounting ---------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            per_site = {s: st.n_injected for s, st in self._sites.items()}
            return {
                "n_injected": sum(per_site.values()),
                "per_site": per_site,
            }

    @property
    def n_injected(self) -> int:
        return self.stats()["n_injected"]
