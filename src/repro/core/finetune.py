"""Per-molecule fine-tuning from the general model (§3.5).

"The fine-tuning starts with the pre-trained general model, and the initial
epsilon threshold is 0.5" — 100-200 extra episodes specialise the general
model to one (possibly outlier) molecule with trivial overhead compared to
the 8000-episode individual models (Fig. 3).  Appendix C Table 2: epsilon
0.5, decay 0.961, batch 128, torchrun (single process) — i.e. a plain
single-worker DQN loop seeded from the general parameters.
"""

from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.chem.molecule import Molecule
from repro.core.agent import DQNAgent, DQNConfig
from repro.core.env import BatchedEnv, EnvConfig
from repro.core.replay import ReplayBuffer
from repro.core.reward import RewardConfig
from repro.predictors.service import PropertyService


def fine_tune(
    general_agent: DQNAgent,
    molecule: Molecule,
    service: PropertyService,
    reward_cfg: RewardConfig,
    *,
    episodes: int = 200,           # Table 1 (Fine-Tuned: 200 episodes)
    epsilon_initial: float = 0.5,  # Table 2
    epsilon_decay: float = 0.961,  # Table 2
    train_batch_size: int = 32,
    updates_per_episode: int = 4,
    max_candidates: int = 64,
    env_cfg: EnvConfig = EnvConfig(),
    seed: int = 0,
    scenario: "str | object | None" = None,
) -> DQNAgent:
    """Returns a NEW agent fine-tuned on ``molecule`` (general untouched).

    ``scenario`` optionally overrides the objective: a registry name or an
    ``ObjectiveSpec`` is compiled ONCE (fresh novelty state for this run)
    against ``reward_cfg``'s Eq. 1 bounds; any other object is used as the
    engine objective directly.  ``None`` keeps the plain ``reward_cfg``
    scalar path.
    """
    cfg = replace(
        general_agent.cfg,
        epsilon_initial=epsilon_initial,
        epsilon_decay=epsilon_decay,
    )
    agent = DQNAgent(cfg, seed=seed, network=general_agent.network)
    agent.params = jax.tree_util.tree_map(jnp.copy, general_agent.params)
    agent.target_params = jax.tree_util.tree_map(jnp.copy, general_agent.params)
    agent.opt_state = agent.opt.init(agent.params)
    agent.epsilon = epsilon_initial

    objective: object = reward_cfg
    if scenario is not None:
        from repro.core.reward import ObjectiveSpec
        if isinstance(scenario, str):
            from repro.configs.scenarios import get_scenario
            objective = get_scenario(scenario).compile(base=reward_cfg)
        elif isinstance(scenario, ObjectiveSpec):
            objective = scenario.compile(base=reward_cfg)
        else:
            objective = scenario

    env = BatchedEnv([molecule], env_cfg, seed=seed + 1)
    buffer = ReplayBuffer(capacity=4000, seed=seed + 2)

    for _ in range(episodes):
        env.run_episode(agent, service, objective, buffer)
        if len(buffer) >= train_batch_size:
            for _ in range(updates_per_episode):
                agent.train_step(buffer.sample(train_batch_size, max_candidates))
        agent.update_target()
        agent.decay_epsilon()
    return agent
