"""The distributed DA-MolDQN trainer (§3.1/§3.2).

The paper extends MT-MolDQN's DDP to a SLURM-launched multi-node setup:
N worker processes, each owning a *batch* of initial molecules and a
private replay buffer, cooperating on ONE general model that is
"synchronized among all processes at the end of every episode".

JAX mapping (DESIGN.md §5): workers are a stacked leading axis sharded over
the mesh's "data" axis via ``shard_map``; the two synchronisation regimes
become two collective placements:

* ``sync_mode="step"``   — MT-MolDQN/DDP: gradients are mean-reduced across
  workers at EVERY optimiser step (params stay replicated across workers).
* ``sync_mode="episode"`` — DA-MolDQN: every worker updates its OWN params
  locally (no per-step collective); parameters (and optimizer moments) are
  mean-reduced once per episode boundary.

Both cross-worker means are implemented as all_gather + an identical
full-worker-axis reduction on every device (``fleet_mean``) rather than
``pmean`` of per-shard means: the reduction order is then independent of
the mesh size, which is what lets tests/multidevice pin nd > 1 runs
BIT-identical to the nd = 1 reference (and lets dead mesh-padding workers
be masked out exactly).  The roofline benchmark quantifies the traffic:
episode-sync moves (param_bytes) once per episode instead of (grad_bytes x
updates_per_episode) — the paper's communication-efficiency claim in
collective-bytes form.

Acting (environment rollout, candidate Q evaluation, property prediction)
is host-driven.  Since the fleet-level refactor it is batched across ALL
workers per step through ``repro.core.rollout.RolloutEngine``: one jit'd Q
dispatch over every worker's candidates (per-worker parameters selected by
a vmap'd apply over the stacked ``[W, ...]`` tree) and one property batch
over every worker's chosen successors — O(1) dispatches per step instead
of O(W).  Four acting paths, all pinned seeded-transition-identical by
tests/test_rollout.py:

* ``rollout="per_worker"``      the paper's sequential per-process loop
                                (W dispatches/step) — kept for comparison;
* ``rollout="fleet"``           one vmap'd Q dispatch per step (PR-1 path);
* ``rollout="fleet_sharded"``   the same dispatch through ``shard_map``
                                over the mesh "data" axis: each device
                                evaluates only its resident workers'
                                ``[W/nd, C, D]`` slice under its resident
                                ``[W/nd, ...]`` params (no collective —
                                acting is embarrassingly data-parallel);
* ``rollout="fleet_pipelined"`` the sharded dispatch + the engine's
                                double-buffered step: step t+1's candidate
                                enumeration/fingerprinting overlaps step
                                t's property batch (the 512-worker path).

Orthogonally, ``TrainerConfig.acting`` (``ACTING_MODES``) picks the fleet
acting-batch REPRESENTATION: ``"packed"`` ships u8 bit planes assembled
straight from the slots' packed candidate fingerprints and unpacks inside
the jit (~32x less acting H2D traffic; no host f32 candidate buffer at
all), ``"packed_async"`` additionally overlaps the Q round-trip with
pre-drawn action selection and early next-step chemistry, and ``"dense"``
keeps the seed f32 path as the correctness reference.  All pinned
transition-identical by tests/test_rollout.py.

Learning (replay sample -> update step) is the acting refactor's twin,
selected by ``TrainerConfig.learner`` (``LEARNER_MODES``), all three paths
pinned loss-trajectory-identical by tests/test_learner.py:

* ``learner="dense"``            the seed path: host-side dense float32
                                 batches (``ReplayBuffer.sample``), shipped
                                 as ``[W, B, C, FP_BITS+1]`` floats;
* ``learner="packed"``           ``sample_packed`` ships uint8 bit planes
                                 (32x less H2D traffic) and the unpack runs
                                 INSIDE the jit'd update (``packed_batch.
                                 densify_batch``, per device shard);
* ``learner="packed_pipelined"`` packed + double-buffered sampling: a host
                                 sampler thread prepares update k+1's batch
                                 while update k runs on device (the same
                                 overlap idiom as the engine's
                                 ``step_pipelined``; batches are identical
                                 because the buffers are not written between
                                 updates and the single sampler thread draws
                                 the per-buffer RNG streams in order).
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.chem.chemcache import ChemCache
from repro.chem.molecule import Molecule
from repro.core.agent import (
    DQNAgent, DQNConfig, QNetwork, candidate_capacity, candidate_capacity_table,
    huber, pad_rows,
)
from repro.core.env import BatchedEnv, EnvConfig, StepRecord
from repro.core.packed_batch import densify_batch, packed_nbytes
from repro.core.replay import FP_BYTES, ReplayBuffer
from repro.core.rollout import CHEM_MODES, STATE_DIM, RolloutEngine
from repro.core.reward import RewardConfig
from repro.launch.mesh import fleet_sharding, make_host_mesh, padded_worker_count
from repro.optim import adam
from repro.optim.adam import apply_updates
from repro.predictors.service import PropertyService

try:  # jax >= 0.5: public API, replication check kwarg renamed to check_vma
    from jax import shard_map as _shard_map
    _SHARD_MAP_CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool | None = None):
    kwargs = {}
    if check_rep is not None:
        kwargs[_SHARD_MAP_CHECK_KW] = check_rep
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


ROLLOUT_MODES = ("fleet", "fleet_sharded", "fleet_pipelined", "per_worker")
_FLEET_MODES = ("fleet", "fleet_sharded", "fleet_pipelined")
LEARNER_MODES = ("packed", "packed_pipelined", "dense")
# replay sampling (core.replay.SAMPLING_MODES): "uniform" is the seed path
# and the pinned reference; "prioritized" is proportional PER (Schaul et
# al. 2015) with per-slot priority arrays in the SoA buffers, importance
# weights folded into the loss, and |TD| feedback after every update.
# With all-equal effective priorities (priority_alpha = 0, or before any
# TD feedback differentiates them) prioritized is BIT-identical to
# uniform — same RNG stream, unit weights (tests/test_learner.py,
# tests/multidevice).
REPLAY_MODES = ("uniform", "prioritized")
# fleet acting-batch representation (the learner refactor's acting twin),
# all pinned transition/param-identical by tests/test_rollout.py:
#   "packed"        u8 bit planes assembled straight from the slots'
#                   cand_fps_packed; unpack runs inside the jit (~32x less
#                   acting H2D traffic than dense)
#   "packed_async"  packed + the async Q protocol: the dispatch returns a
#                   device handle, eps-greedy decisions are pre-drawn and
#                   step t+1 chemistry of exploring slots starts while the
#                   device computes (fleet_pipelined covers the Q
#                   round-trip, not just the property batch)
#   "dense"         the seed [W, C, STATE_DIM] f32 path, kept as the
#                   correctness reference
ACTING_MODES = ("packed", "packed_async", "dense")


@dataclass(frozen=True)
class TrainerConfig:
    n_workers: int = 4
    mols_per_worker: int = 4          # "Modification Batch" (Table 1)
    episodes: int = 250               # general model (Table 1)
    sync_mode: str = "episode"        # "episode" (DA-MolDQN) | "step" (DDP)
    rollout: str = "fleet"            # see ROLLOUT_MODES (module docstring)
    learner: str = "packed"           # see LEARNER_MODES (module docstring)
    acting: str = "packed"            # see ACTING_MODES (fleet modes only;
                                      # per_worker always acts dense)
    chem: str = "incremental"         # candidate chemistry: rollout.CHEM_MODES
                                      # ("full" = per-step recompute reference)
    updates_per_episode: int = 4
    train_batch_size: int = 32        # <= Table 2's 512 cap; CPU-scaled
    max_candidates: int = 64          # replay target max truncation
    replay_capacity: int = 4000       # Table 3
    replay: str = "uniform"           # replay sampling: see REPLAY_MODES
    priority_alpha: float = 0.6       # PER proportional exponent (0 = flat)
    priority_beta0: float = 0.4       # importance-weight anneal start
    priority_beta_episodes: int | None = None  # episodes for beta -> 1.0
                                               # (None: cfg.episodes)
    priority_eps: float = 1e-3        # |TD| priority floor
    dataset: str | None = None        # multi-start episode stream: draw each
                                      # episode's start molecules from a
                                      # seeded data.datasets cursor (DATASETS
                                      # name); None = fixed ctor molecules
    dataset_size: int | None = None   # pool size (None: dataset default)
    dataset_seed: int | None = None   # pool+cursor seed (None: cfg.seed)
    scenarios: tuple[str, ...] | None = None
                                      # heterogeneous scenario fleet: registry
                                      # names (configs/scenarios) cycled across
                                      # workers — worker w optimises
                                      # scenarios[w % len]; Eq.1-family names
                                      # take their bde/ip bounds from the
                                      # trainer's reward_cfg.  None = every
                                      # worker runs reward_cfg (the seed path,
                                      # bit-identical to pre-scenario builds)
    pipeline_threads: int | None = None  # fleet_pipelined host pool (None: auto)
    dqn: DQNConfig = field(default_factory=lambda: DQNConfig(epsilon_decay=0.97))
    env: EnvConfig = field(default_factory=EnvConfig)
    seed: int = 0


class _WorkerView:
    """Adapter giving BatchedEnv the per-worker agent interface (the
    pre-fleet sequential path: one jit dispatch PER WORKER per step)."""

    def __init__(self, trainer: "DistributedTrainer", w: int):
        self.t = trainer
        self.w = w

    def q_values(self, states: np.ndarray) -> np.ndarray:
        n = states.shape[0]
        padded = pad_rows(n)
        if padded != n:
            states = np.concatenate(
                [states, np.zeros((padded - n, states.shape[1]), states.dtype)])
        self.t.n_q_dispatches += 1
        q = self.t._q_one(self.t.params, jnp.asarray(states), self.w)
        return np.asarray(q)[:n]

    def select_action(self, q: np.ndarray) -> int:
        return self.t._select_action(q, self.w)


class _FleetView:
    """FleetPolicy over the trainer's stacked per-worker parameters: ONE
    jit dispatch evaluates every worker's candidates under that worker's
    own parameters (vmap'd apply, dense ``[W, Cmax, D]`` layout).

    The candidate axis is padded to a rung of the fleet-adaptive capacity
    ladder (``candidate_capacity_table``) and the batch buffer is a STICKY
    high-water mark: capacity only ever grows, and the jit always sees the
    full buffer, so shapes change O(log C) times per run instead of every
    time the per-step max drifts — the property that keeps W=512 free of
    per-step recompiles.  With ``sharded=True`` the dispatch goes through
    the ``shard_map`` fleet fn with the batch placed on the mesh's "data"
    axis next to the (already-sharded) parameters.

    ``acting`` picks the batch representation (``ACTING_MODES``): the
    dense f32 reference, or the packed u8 bit planes (optionally with the
    async dispatch/fetch split) — the packed modes never materialise a
    dense f32 candidate buffer on the host.
    """

    def __init__(self, trainer: "DistributedTrainer", sharded: bool = False,
                 acting: str = "dense"):
        self.t = trainer
        self.sharded = sharded
        self.acting = acting
        # engine-facing protocol switches (see rollout.FleetPolicy)
        self.wants_packed_states = acting != "dense"
        self.async_q = acting == "packed_async"
        self._table = candidate_capacity_table(trainer.cfg.n_workers)
        self._dense: np.ndarray | None = None
        self._bits: np.ndarray | None = None
        self._frac: np.ndarray | None = None
        self._cap = 0

    def reserve(self, max_candidates: int) -> None:
        """Pre-grow the batch buffers (ladder-rounded) so a known candidate
        bound never triggers a mid-run growth recompile."""
        cap = candidate_capacity(max_candidates, self._table)
        if cap > self._cap:
            self._cap = cap
            # rows for the PADDED fleet: dead mesh-padding workers keep
            # all-zero rows, so the [W_pad, C, ...] batch tiles the mesh
            W_pad = self.t.n_padded_workers
            if self.wants_packed_states:
                self._bits = np.zeros((W_pad, cap, FP_BYTES), np.uint8)
                self._frac = np.zeros((W_pad, cap), np.float32)
            else:
                self._dense = np.zeros((W_pad, cap, STATE_DIM), np.float32)

    def warm_dispatch(self) -> None:
        """Issue one dummy dispatch so the CURRENT capacity's jit shape is
        compiled eagerly (reserve_candidates counts this as warmup)."""
        n = self.t.engine.n_workers
        if self.wants_packed_states:
            self.fleet_q_fetch(self.fleet_q_dispatch_packed(
                [np.zeros((1, FP_BYTES), np.uint8)] * n,
                [np.zeros((1,), np.float32)] * n))
        else:
            self.fleet_q_values([np.zeros((1, STATE_DIM), np.float32)] * n)

    # ---- dense reference ---------------------------------------- #
    def fleet_q_values(self, per_worker: list[np.ndarray]) -> list[np.ndarray]:
        counts = [x.shape[0] for x in per_worker]
        if not any(counts):
            return [np.zeros((0,), np.float32) for _ in per_worker]
        self.reserve(max(counts))
        dense = self._dense  # never sliced down: shapes only change on growth
        for w, x in enumerate(per_worker):
            dense[w, : x.shape[0]] = x
            dense[w, x.shape[0]:] = 0.0  # clear rows left by the last step
        self.t.n_q_dispatches += 1
        self.t.acting_h2d_bytes += dense.nbytes
        if self.sharded:
            x = jax.device_put(dense, self.t._fleet_in_sharding)
            q = np.asarray(self.t._fleet_q_sharded(self.t.params, x))
        else:
            q = np.asarray(self.t._fleet_q(self.t.params, jnp.asarray(dense)))
        return [q[w, :n] for w, n in enumerate(counts)]

    # ---- packed protocol (rollout.FleetPolicy) ------------------- #
    def fleet_q_dispatch_packed(self, bits_pw: list[np.ndarray],
                                frac_pw: list[np.ndarray]):
        """Copy the per-worker packed planes into the sticky buffers and
        dispatch WITHOUT blocking: the returned handle holds the on-device
        ``jax.Array`` (XLA computes asynchronously; ``fleet_q_fetch`` is
        the only synchronisation point)."""
        counts = [b.shape[0] for b in bits_pw]
        if not any(counts):
            return None, counts
        self.reserve(max(counts))
        bits, frac = self._bits, self._frac
        for w, (b, f) in enumerate(zip(bits_pw, frac_pw)):
            n = b.shape[0]
            bits[w, :n] = b
            bits[w, n:] = 0   # dead/finished rows: zero planes, never garbage
            frac[w, :n] = f
            frac[w, n:] = 0.0
        self.t.n_q_dispatches += 1
        self.t.acting_h2d_bytes += bits.nbytes + frac.nbytes
        if self.sharded:
            xb = jax.device_put(bits, self.t._fleet_in_sharding)
            xf = jax.device_put(frac, self.t._fleet_in_sharding)
            q = self.t._fleet_q_packed_sharded(self.t.params, xb, xf)
        else:
            q = self.t._fleet_q_packed(
                self.t.params, jnp.asarray(bits), jnp.asarray(frac))
        return q, counts

    def fleet_q_fetch(self, handle) -> list[np.ndarray]:
        """Block on the device result and slice it back per worker."""
        q, counts = handle
        if q is None:
            return [np.zeros((0,), np.float32) for _ in counts]
        qh = np.asarray(q)
        return [qh[w, :n] for w, n in enumerate(counts)]

    def fleet_q_values_packed(self, bits_pw: list[np.ndarray],
                              frac_pw: list[np.ndarray]) -> list[np.ndarray]:
        return self.fleet_q_fetch(self.fleet_q_dispatch_packed(bits_pw, frac_pw))

    def plan_action(self, n_candidates: int, worker: int) -> int:
        return self.t._plan_action(n_candidates, worker)

    def select_action(self, q: np.ndarray, worker: int) -> int:
        return self.t._select_action(q, worker)


class DistributedTrainer:
    """Trains ONE general model over many molecules with W workers.

    Runs on any single-axis "data" mesh (``launch.mesh.make_host_mesh`` by
    default).  A worker count that does not divide the device count is
    padded to the mesh with dead worker slots: ``n_live_workers`` is the
    configured fleet, ``n_padded_workers`` the stacked/sharded width.  Dead
    slots own no molecules (zero rows in every dense acting batch), ship
    all-zero update batches whose masked gradients are exact no-ops, and
    are excluded from every cross-worker mean — so the live results are
    identical to the unpadded run.  The multi-device equivalence suite
    (tests/multidevice, driven by ``repro.launch.verify`` subprocesses)
    pins transitions, loss trajectories and parameters bit-identical
    across nd in {1, 2, 4} forced host devices.
    """

    def __init__(
        self,
        cfg: TrainerConfig,
        molecules: list[Molecule] | None,
        service: PropertyService,
        reward_cfg: RewardConfig,
        mesh: Mesh | None = None,
        network: QNetwork | None = None,
        dataset_pool: list[Molecule] | None = None,
        fault_plan=None,
    ):
        self.cfg = cfg
        self.service = service
        self.reward_cfg = reward_cfg
        self.fault_plan = fault_plan
        self.network = network or QNetwork()
        W = cfg.n_workers
        need = W * cfg.mols_per_worker

        # multi-start dataset streaming (ROADMAP item 5): with cfg.dataset
        # set, every episode draws its start molecules from a seeded
        # DatasetStream cursor instead of re-using the fixed ctor batch.
        # ``dataset_pool`` lets callers (tests, benches) inject the pool
        # directly; otherwise cfg.dataset names a data.datasets registry
        # entry.  The cursor is drawn ON THE HOST before any rollout-mode
        # branch, so the start schedule is identical across fleet /
        # fleet_sharded / fleet_pipelined / per_worker (tests/test_datasets).
        self._dataset_stream = None
        if cfg.dataset is not None:
            if molecules is not None:
                raise ValueError(
                    "pass molecules=None when cfg.dataset streams the "
                    "episode starts (the fixed batch would be ignored)")
            from repro.data.datasets import DatasetStream, load_dataset
            pool = dataset_pool if dataset_pool is not None else load_dataset(
                cfg.dataset, count=cfg.dataset_size, seed=cfg.dataset_seed)
            dseed = cfg.seed if cfg.dataset_seed is None else cfg.dataset_seed
            self._dataset_stream = DatasetStream(pool, seed=dseed)
            # episode-0 placeholder assignment (rollout_episode re-draws
            # from the cursor before every episode, including the first)
            molecules = [pool[i % len(pool)] for i in range(need)]
        elif molecules is None:
            raise ValueError("molecules=None requires cfg.dataset")
        if len(molecules) < need:
            raise ValueError(f"need {need} molecules for {W}x{cfg.mols_per_worker}, got {len(molecules)}")
        self.molecules = molecules[:need]
        self.start_log: list[tuple[str, ...]] = []  # per-episode start keys
                                                    # (dataset mode only)

        if mesh is None:
            mesh = make_host_mesh()   # the one mesh-construction code path
        self.mesh = mesh
        nd = mesh.devices.size
        # fleets that do not divide the mesh pad to it with DEAD worker
        # slots: a W=6 fleet on a 4-device mesh trains as a padded W=8
        # fleet whose two dead slots own no molecules, zero out of every
        # dense row, and are masked out of every cross-worker mean — the
        # live workers' transitions, losses and parameters are identical
        # to the unpadded run (tests/multidevice pins this at nd in {2,4})
        self.n_live_workers = W
        self.n_padded_workers = padded_worker_count(W, mesh)

        if cfg.rollout not in ROLLOUT_MODES:
            raise ValueError(f"rollout must be one of {ROLLOUT_MODES}, got {cfg.rollout!r}")
        if cfg.learner not in LEARNER_MODES:
            raise ValueError(f"learner must be one of {LEARNER_MODES}, got {cfg.learner!r}")
        if cfg.sync_mode not in ("episode", "step"):
            raise ValueError(f"sync_mode must be 'episode' or 'step', got {cfg.sync_mode!r}")
        if cfg.chem not in CHEM_MODES:
            raise ValueError(f"chem must be one of {CHEM_MODES}, got {cfg.chem!r}")
        if cfg.acting not in ACTING_MODES:
            raise ValueError(f"acting must be one of {ACTING_MODES}, got {cfg.acting!r}")
        if cfg.replay not in REPLAY_MODES:
            raise ValueError(f"replay must be one of {REPLAY_MODES}, got {cfg.replay!r}")

        # size the predictor padding ladder for the fleet-wide per-step batch
        # (one chosen successor per live slot)
        if hasattr(service, "reserve"):
            service.reserve(W * cfg.mols_per_worker)

        # ONE chemistry cache for the whole trainer: entries are shared
        # across workers, episodes and steps (and, for the legacy
        # per_worker path, across its per-worker envs)
        self.chem_cache = ChemCache() if cfg.chem == "incremental" else None
        # fleet engine over the worker molecule partition: one Q dispatch
        # and one property batch per step across ALL workers
        self.engine = RolloutEngine(
            [self.molecules[w * cfg.mols_per_worker : (w + 1) * cfg.mols_per_worker]
             for w in range(W)],
            cfg.env, pipeline_threads=cfg.pipeline_threads,
            chem=cfg.chem, chem_cache=self.chem_cache,
            pad_workers_to=self.n_padded_workers,
            packed_states=cfg.acting != "dense",
            fault_plan=fault_plan)
        # heterogeneous scenario fleet: compile ONE objective per worker
        # (fresh instances — the novelty term's visit counts are per-worker
        # state) and install them as the engine's per-slot defaults.  The
        # per_worker rollout path passes the same instances as each env's
        # reward_cfg, so every mode sees identical objective resolution.
        self.worker_objectives = None
        self.scenario_names: tuple[str, ...] | None = None
        if cfg.scenarios:
            from repro.configs.scenarios import (
                compile_worker_objectives, worker_scenarios)
            base = reward_cfg if isinstance(reward_cfg, RewardConfig) else None
            self.scenario_names = tuple(worker_scenarios(cfg.scenarios, W))
            self.worker_objectives = compile_worker_objectives(
                cfg.scenarios, W, base=base)
            self.engine.set_worker_objectives(self.worker_objectives)
        self._envs: list[BatchedEnv] | None = None  # built lazily (legacy path)
        # storage truncates where sample() would anyway (cfg.max_candidates),
        # so the SoA candidate axis never outgrows what training can see
        self.buffers = [ReplayBuffer(cfg.replay_capacity, seed=cfg.seed + 200 + w,
                                     max_candidates=cfg.max_candidates,
                                     sampling=cfg.replay,
                                     priority_alpha=cfg.priority_alpha,
                                     priority_eps=cfg.priority_eps)
                        for w in range(W)]
        self._worker_rngs = [np.random.default_rng(cfg.seed + 300 + w) for w in range(W)]
        self.n_q_dispatches = 0  # acting-side jit dispatches (both paths)
        self.n_updates = 0       # learner update steps issued
        self.h2d_update_bytes = 0  # host->device bytes shipped by update batches
        self.acting_h2d_bytes = 0  # host->device bytes shipped by fleet Q batches
        self._sampler_pool: ThreadPoolExecutor | None = None  # packed_pipelined

        # stacked per-worker params [W_pad, ...] sharded over "data"
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), W)
        params = jax.vmap(self.network.init)(keys)
        # all workers start from the same weights (like DDP broadcast);
        # padding rows replicate worker 0's weights too, so the initial
        # stacked tree is independent of how far the mesh padded the fleet
        params = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                x[0], (self.n_padded_workers,) + x.shape[1:]), params)
        self.opt = adam(cfg.dqn.lr, clip_norm=cfg.dqn.grad_clip)
        opt_state = jax.vmap(self.opt.init)(params)

        shard = lambda tree: jax.device_put(
            tree, NamedSharding(self.mesh, P("data")))
        self.params = jax.tree_util.tree_map(shard, params)
        self.target_params = jax.tree_util.tree_map(jnp.copy, self.params)
        self.opt_state = jax.tree_util.tree_map(shard, opt_state)

        self.epsilon = cfg.dqn.epsilon_initial
        self.episode = 0
        # per-episode scalar trajectories, checkpointed with the trainer so
        # a resumed run's report carries the FULL history (crash-resume
        # equivalence diffs these against the straight-through reference)
        self.loss_log: list[float] = []
        self.reward_log: list[float] = []
        self._views = [_WorkerView(self, w) for w in range(W)]
        self._fleet_in_sharding = fleet_sharding(self.mesh)
        self._fleet_policy = _FleetView(self, acting=cfg.acting)
        self._fleet_policy_sharded = _FleetView(self, sharded=True,
                                                acting=cfg.acting)
        self._build_fns()

    @property
    def envs(self) -> list[BatchedEnv]:
        """Per-worker single-worker envs for the legacy ``per_worker``
        rollout (and external benchmarks).  Built on first access so the
        default fleet path doesn't enumerate every initial molecule's
        candidates twice at construction."""
        if self._envs is None:
            cfg = self.cfg
            self._envs = [
                BatchedEnv(
                    self.molecules[w * cfg.mols_per_worker : (w + 1) * cfg.mols_per_worker],
                    cfg.env, chem=cfg.chem, chem_cache=self.chem_cache)
                for w in range(cfg.n_workers)
            ]
        return self._envs

    # ------------------------------------------------------------ #
    # jit'd compute
    # ------------------------------------------------------------ #
    def _build_fns(self) -> None:
        net, opt, cfg = self.network, self.opt, self.cfg
        discount = cfg.dqn.discount
        mesh = self.mesh

        def per_worker_loss(p, tp, batch):
            # Returns (loss, |td|): the aux |TD| vector feeds prioritized
            # replay's priority refresh.  Adding the stop_gradient'd aux
            # leaves loss and grads bitwise unchanged, and uniform batches
            # carry no "weights" key, so the uniform jits trace EXACTLY
            # the seed loss — both properties the parity tests pin.
            q_sa = net.apply(p, batch["states"])
            q_next_online = net.apply(p, batch["next_fps"])
            q_next_online = jnp.where(batch["next_mask"] > 0, q_next_online, -jnp.inf)
            a_star = jnp.argmax(q_next_online, axis=-1)
            q_next_target = net.apply(tp, batch["next_fps"])
            v_next = jnp.take_along_axis(q_next_target, a_star[:, None], axis=-1)[:, 0]
            v_next = jnp.where(batch["next_mask"].sum(-1) > 0, v_next, 0.0)
            y = jax.lax.stop_gradient(
                batch["rewards"] + discount * (1.0 - batch["dones"]) * v_next)
            td = q_sa - y
            h = huber(td)
            if "weights" in batch:   # prioritized: importance-weighted mean
                h = h * batch["weights"]
            return jnp.mean(h), jax.lax.stop_gradient(jnp.abs(td))

        spec_w = P("data")
        n_live = self.n_live_workers
        W_pad = self.n_padded_workers
        W_local = W_pad // mesh.devices.size  # workers resident per device

        def fleet_mean(x, keepdims: bool = False):
            """Mean over the LIVE workers of a ``[W_local, ...]`` shard.

            The reduction order must not depend on the mesh size (mean-of-
            in-shard-means drifts in the last bit between nd=1 and nd>1),
            so every device gathers the FULL worker axis and runs the
            identical ``[W_pad, ...]`` reduction locally.  Dead padding
            rows are zeroed before the sum; summing trailing exact zeros
            is a bitwise no-op, which keeps a padded W=6-on-4-devices run
            identical to the unpadded nd=1 W=6 reference.
            """
            full = jax.lax.all_gather(x, "data", axis=0, tiled=True)
            if n_live != W_pad:
                m = (jnp.arange(W_pad) < n_live).astype(x.dtype)
                full = full * m.reshape((-1,) + (1,) * (full.ndim - 1))
            return jnp.sum(full, axis=0, keepdims=keepdims) / n_live

        def shard_live_mask():
            """f32 ``[W_local]``: 1 for live workers resident in this
            shard, 0 for dead mesh-padding workers."""
            rows = jax.lax.axis_index("data") * W_local + jnp.arange(W_local)
            return (rows < n_live).astype(jnp.float32)

        def scan_workers(f, xs):
            """Map ``f`` over the shard's resident workers via ``lax.scan``
            instead of ``vmap``: the per-iteration program is independent of
            W_local, which is what makes the update bit-identical across
            mesh sizes.  (A vmap'd per-worker matmul lowers as a BATCHED
            dot, and XLA lowers batch 1 — one worker per device, nd == W —
            differently from batch n, drifting the gradients' last bits
            between nd = 1 and nd = W; pinned by tests/multidevice.)"""
            def step(carry, x):
                return carry, f(*x)
            return jax.lax.scan(step, None, xs)[1]

        def local_update_body(params, target, opt_state, batch):
            # per resident worker, serially within the shard; NO collective
            mask = shard_live_mask()

            def one(p, tp, s, b, m):
                (loss, td), grads = jax.value_and_grad(
                    per_worker_loss, has_aux=True)(p, tp, b)
                if n_live != W_pad:
                    # dead padding slots must not move: zero their grads
                    # (Adam with zero grads and zero moments is an exact
                    # no-op on the params)
                    grads = jax.tree_util.tree_map(lambda g: g * m, grads)
                updates, s2 = opt.update(grads, s, p)
                return apply_updates(p, updates), s2, loss, td
            return scan_workers(one, (params, target, opt_state, batch, mask))

        def ddp_update_body(params, target, opt_state, batch):
            # grads averaged across all LIVE workers (nd-invariant masked
            # mean); every worker — dead padding included — applies the
            # same mean update, so the stacked tree stays replicated
            def gfn(p, tp, b):
                (loss, td), grads = jax.value_and_grad(
                    per_worker_loss, has_aux=True)(p, tp, b)
                return loss, td, grads
            losses, tds, grads = scan_workers(gfn, (params, target, batch))
            gmean = jax.tree_util.tree_map(fleet_mean, grads)
            def one(p, s):
                updates, s2 = opt.update(gmean, s, p)
                return apply_updates(p, updates), s2
            new_p, new_s = scan_workers(one, (params, opt_state))
            return new_p, new_s, losses, tds

        def sync_body(tree):
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(fleet_mean(x, keepdims=True), x.shape),
                tree)

        # packed twins: identical update bodies, but the batch arrives as
        # uint8 bit planes and each device unpacks ONLY its resident
        # [W/nd, B, ...] shard inside the jit (no dense H2D transfer)
        def local_update_packed_body(params, target, opt_state, packed):
            return local_update_body(params, target, opt_state,
                                     densify_batch(packed))

        def ddp_update_packed_body(params, target, opt_state, packed):
            return ddp_update_body(params, target, opt_state,
                                   densify_batch(packed))

        # outputs pinned to the canonical worker-sharded placement: without
        # this the compiler may mark some update outputs replicated, and the
        # NEXT update (params/opt re-entering as inputs) retraces on the
        # sharding flip — one compiled train-step shape, not two
        out_w = NamedSharding(mesh, P("data"))
        self._local_update = jax.jit(shard_map(
            local_update_body, mesh=mesh,
            in_specs=(spec_w, spec_w, spec_w, spec_w),
            out_specs=(spec_w, spec_w, spec_w, spec_w),
        ), out_shardings=out_w)
        self._ddp_update = jax.jit(shard_map(
            ddp_update_body, mesh=mesh,
            in_specs=(spec_w, spec_w, spec_w, spec_w),
            out_specs=(spec_w, spec_w, spec_w, spec_w),
            check_rep=False,
        ), out_shardings=out_w)
        self._local_update_packed = jax.jit(shard_map(
            local_update_packed_body, mesh=mesh,
            in_specs=(spec_w, spec_w, spec_w, spec_w),
            out_specs=(spec_w, spec_w, spec_w, spec_w),
        ), out_shardings=out_w)
        self._ddp_update_packed = jax.jit(shard_map(
            ddp_update_packed_body, mesh=mesh,
            in_specs=(spec_w, spec_w, spec_w, spec_w),
            out_specs=(spec_w, spec_w, spec_w, spec_w),
            check_rep=False,
        ), out_shardings=out_w)
        self._sync = jax.jit(shard_map(
            sync_body, mesh=mesh, in_specs=(spec_w,), out_specs=spec_w,
        ), out_shardings=NamedSharding(mesh, P("data")))

        @jax.jit
        def q_one(params, states, w):
            p = jax.tree_util.tree_map(lambda x: x[w], params)
            return net.apply(p, states)
        self._q_one = q_one

        # fleet acting: [W, C, D] states under the stacked [W, ...] params,
        # per-worker parameter selection via the vmap'd apply — ONE dispatch
        # per environment step regardless of n_workers
        self._fleet_q = jax.jit(net.apply_stacked)

        # the same dispatch sharded over "data": each device evaluates its
        # resident [W/nd, C, D] slice under its resident [W/nd, ...] params;
        # acting is embarrassingly data-parallel, so there is no collective.
        # out_shardings is pinned like the update fns: at nd > 1 the
        # compiler may otherwise mark the output replicated, and the flip
        # retraces the dispatch (the recompile counter gates this)
        self._fleet_q_sharded = jax.jit(shard_map(
            net.apply_stacked, mesh=mesh,
            in_specs=(spec_w, spec_w), out_specs=spec_w,
        ), out_shardings=out_w)

        # packed twins of the two fleet dispatches: [W, C, FP_BITS/8] u8
        # planes + [W, C] f32 steps-left, unpacked INSIDE the jit (~32x
        # less acting H2D traffic).  With use_pallas_qnet the evaluation
        # routes through the stacked bit-plane kernel (pallas on TPU;
        # unpack-in-jit XLA math everywhere else — identical bits to
        # apply_stacked on the densified input either way)
        def fleet_q_packed_body(params, bits, frac):
            if cfg.dqn.use_pallas_qnet:
                from repro.kernels.packed_qnet.ops import packed_qnet_stacked
                return packed_qnet_stacked(params, bits, frac)
            return net.apply_stacked_packed(params, bits, frac)

        self._fleet_q_packed = jax.jit(fleet_q_packed_body)
        self._fleet_q_packed_sharded = jax.jit(shard_map(
            fleet_q_packed_body, mesh=mesh,
            in_specs=(spec_w, spec_w, spec_w), out_specs=spec_w,
        ), out_shardings=out_w)

    # ------------------------------------------------------------ #
    # training
    # ------------------------------------------------------------ #
    def train_episode(self) -> dict:
        """One paper episode: rollouts on all workers, local training
        updates, then (episode mode) the parameter sync."""
        cfg = self.cfg
        records = self.rollout_episode()

        losses = []
        min_fill = min(len(b) for b in self.buffers)
        if min_fill >= cfg.train_batch_size:
            losses = self.run_updates(cfg.updates_per_episode)

        if cfg.sync_mode == "episode":
            self.params = self._sync(self.params)
            self.opt_state = self._sync_opt(self.opt_state)

        self.episode += 1
        if self.episode % cfg.dqn.target_update_episodes == 0:
            self.target_params = jax.tree_util.tree_map(jnp.copy, self.params)
        self.epsilon = max(self.epsilon * cfg.dqn.epsilon_decay, cfg.dqn.epsilon_min)

        flat = [r for recs in records for r in recs]
        final = [r for r in flat if r.done]
        n_invalid = sum(1 for r in flat if not r.conformer_valid)
        st = {
            "episode": self.episode,
            "mean_final_reward": float(np.mean([r.reward for r in final])) if final else float("nan"),
            "loss": float(np.mean(losses)) if losses else float("nan"),
            "epsilon": self.epsilon,
            "invalid_conformer_rate": n_invalid / max(len(flat), 1),
        }
        self.loss_log.append(st["loss"])
        self.reward_log.append(st["mean_final_reward"])
        return st

    def rollout_episode(self) -> list[list[StepRecord]]:
        """One full acting episode for every worker, grouped per worker.

        The fleet modes drive the RolloutEngine: all workers advance in
        lockstep with one Q dispatch + one property batch per step
        ("fleet_sharded" dispatches through shard_map, "fleet_pipelined"
        additionally overlaps next-step chemistry with the property batch).
        ``rollout="per_worker"`` replays the paper's sequential per-process
        loop.  All paths draw from the same per-worker RNG streams, so
        they produce identical transitions (tests/test_rollout.py).
        """
        W = self.cfg.n_workers
        if self._dataset_stream is not None:
            # multi-start: the next cursor draw becomes this episode's
            # start assignment, BEFORE the rollout-mode branch — one host
            # cursor, so every mode sees the identical schedule
            self._assign_starts(
                self._dataset_stream.draw(W * self.cfg.mols_per_worker))
        if self.cfg.rollout in _FLEET_MODES:
            flat = self.engine.run_episode(
                self._active_fleet_view, self.service, self.reward_cfg,
                self.buffers, pipelined=self.cfg.rollout == "fleet_pipelined")
            records: list[list[StepRecord]] = [[] for _ in range(W)]
            for r in flat:
                records[r.worker].append(r)
            return records
        records = []
        for w, env in enumerate(self.envs):
            # scenario fleets hand each worker ITS compiled objective (the
            # same instance the fleet engine stamps on that worker's slots)
            rc = self.worker_objectives[w] \
                if self.worker_objectives is not None else self.reward_cfg
            recs = env.run_episode(self._views[w], self.service, rc,
                                   self.buffers[w])
            for r in recs:  # single-worker envs stamp worker=0; fix up
                r.worker = w
            records.append(recs)
        return records

    def _assign_starts(self, molecules: list[Molecule]) -> None:
        """Install one episode's start molecules everywhere acting reads
        them: the worker-major partition goes into the fleet engine's live
        worker initials (``run_episode`` resets into them) and the legacy
        per-worker envs are dropped for lazy rebuild from ``self.molecules``.
        The schedule is appended to ``start_log`` so cross-mode determinism
        is directly testable."""
        cfg = self.cfg
        self.molecules = list(molecules)
        self.engine.set_initial_molecules(
            [self.molecules[w * cfg.mols_per_worker : (w + 1) * cfg.mols_per_worker]
             for w in range(cfg.n_workers)])
        self._envs = None
        self.start_log.append(tuple(m.iso_key() for m in self.molecules))

    @property
    def _active_fleet_view(self) -> _FleetView:
        """The fleet policy the configured rollout mode dispatches through
        (the sharded view for both sharded and pipelined modes)."""
        return self._fleet_policy if self.cfg.rollout == "fleet" \
            else self._fleet_policy_sharded

    @property
    def candidate_capacity(self) -> int:
        """Current dense candidate-axis capacity of the active fleet view
        (0 until the first dispatch or ``reserve_candidates``)."""
        return 0 if self.cfg.rollout == "per_worker" \
            else self._active_fleet_view._cap

    def reserve_candidates(self, max_candidates: int) -> None:
        """Pre-grow the fleet views' dense candidate capacity (ladder-
        rounded) and compile the resulting dispatch shape eagerly, so a
        known per-worker candidate bound never recompiles mid-run.  Counts
        as warmup: bumps ``n_q_dispatches`` once if it grows.  Only touches
        the view the configured rollout mode actually uses (no-op for the
        per_worker path, which buckets per worker instead)."""
        if self.cfg.rollout == "per_worker":
            return
        view = self._active_fleet_view
        before = view._cap
        view.reserve(max_candidates)
        if view._cap != before:
            view.warm_dispatch()

    def _select_action(self, q: np.ndarray, w: int) -> int:
        """Decaying eps-greedy from worker ``w``'s private RNG stream."""
        rng = self._worker_rngs[w]
        if rng.random() < self.epsilon:
            return int(rng.integers(0, q.shape[0]))
        return int(np.argmax(q))

    def _plan_action(self, n_candidates: int, w: int) -> int:
        """The pre-draw half of ``_select_action`` for the async acting
        path: consume worker ``w``'s RNG stream EXACTLY as
        ``_select_action`` would (one uniform, plus the integer draw on
        the explore branch) but without needing Q values — return the
        explored index, or -1 for argmax-once-Q-lands.  The engine
        resolves -1 with the same ``int(np.argmax(q))``, so the chosen
        actions are bit-identical to the sync path's."""
        rng = self._worker_rngs[w]
        if rng.random() < self.epsilon:
            return int(rng.integers(0, n_candidates))
        return -1

    def _sync_opt(self, opt_state):
        """Average the float moments across workers; keep the int step."""
        from repro.optim.adam import OptState
        return OptState(step=opt_state.step, mu=self._sync(opt_state.mu),
                        nu=self._sync(opt_state.nu))

    # ------------------------------------------------------------ #
    # learner: replay sampling + update dispatch (LEARNER_MODES)
    # ------------------------------------------------------------ #
    def _pad_stacked(self, per: list[dict[str, np.ndarray]]
                     ) -> dict[str, np.ndarray]:
        """Stack per-live-worker sample dicts to ``[W_pad, B, ...]``: dead
        mesh-padding workers ship all-zero batches (their masked updates
        are exact no-ops, and their loss rows are sliced off on the host)."""
        if self.n_padded_workers != self.n_live_workers:
            zero = {k: np.zeros_like(v) for k, v in per[0].items()}
            per = per + [zero] * (self.n_padded_workers - self.n_live_workers)
        return {k: np.stack([p[k] for p in per]) for k in per[0]}

    def _beta(self) -> float:
        """PER importance-weight exponent, annealed ``priority_beta0 -> 1``
        over ``priority_beta_episodes`` (default: the full run).  A pure
        host float shipped as array VALUES inside the batch — the schedule
        never enters a traced shape, so sweeping beta costs zero
        recompiles (gated by bench_train --smoke)."""
        cfg = self.cfg
        horizon = cfg.priority_beta_episodes or cfg.episodes
        frac = min(1.0, self.episode / max(1, horizon))
        return cfg.priority_beta0 + (1.0 - cfg.priority_beta0) * frac

    def _sample_kwargs(self) -> dict:
        """Per-draw keyword args: prioritized adds the annealed beta;
        uniform passes NOTHING so the reference call sites stay verbatim."""
        if self.cfg.replay == "prioritized":
            return {"beta": self._beta()}
        return {}

    def _stacked_sample_np(self) -> dict[str, np.ndarray]:
        """Seed path host work: one DENSE float32 sample per worker buffer,
        stacked to ``[W_pad, B, ...]`` (what `_stacked_sample` ships)."""
        kw = self._sample_kwargs()
        return self._pad_stacked(
            [b.sample(self.cfg.train_batch_size, self.cfg.max_candidates, **kw)
             for b in self.buffers])

    def _stacked_sample_packed_np(self) -> dict[str, np.ndarray]:
        """Packed path host work: uint8 bit planes + scalars, stacked to
        ``[W_pad, B, ...]`` — ~32x fewer bytes than ``_stacked_sample_np``
        and no host-side unpack at all.  Draws the SAME per-buffer seeded
        indices as the dense sampler, which is what makes the two learner
        paths loss-trajectory-identical (tests/test_learner.py)."""
        kw = self._sample_kwargs()
        return self._pad_stacked(
            [b.sample_packed(self.cfg.train_batch_size, self.cfg.max_candidates,
                             **kw)
             for b in self.buffers])

    def _ship(self, host_batch: dict[str, np.ndarray]) -> dict[str, jnp.ndarray]:
        self.h2d_update_bytes += packed_nbytes(host_batch)
        return {k: jnp.asarray(v) for k, v in host_batch.items()}

    def _stacked_sample(self) -> dict[str, jnp.ndarray]:
        return self._ship(self._stacked_sample_np())

    def _stacked_sample_packed(self) -> dict[str, jnp.ndarray]:
        return self._ship(self._stacked_sample_packed_np())

    def _update_once(self, batch: dict[str, jnp.ndarray], packed: bool):
        """One optimiser step under the configured sync mode; returns the
        per-worker ``(loss, |td|)`` pair still on device (don't block the
        pipeline — prioritized replay is the only consumer of the td)."""
        if self.cfg.sync_mode == "step":
            fn = self._ddp_update_packed if packed else self._ddp_update
        else:
            fn = self._local_update_packed if packed else self._local_update
        self.params, self.opt_state, loss, td = fn(
            self.params, self.target_params, self.opt_state, batch)
        self.n_updates += 1
        return loss, td

    def _apply_priorities(self, td) -> None:
        """Feed the update's ``[W_pad, B]`` |TD| errors back into the live
        workers' buffers (dead mesh-padding rows carry zero-batch garbage
        and are dropped) — the sample -> update -> reprioritise cycle of
        proportional PER."""
        td_host = np.asarray(td)
        for w, buf in enumerate(self.buffers):
            buf.update_priorities(td_host[w])

    def _loss_scalar(self, loss) -> float:
        """Scalar loss over the LIVE workers of a ``[W_pad]`` loss vector
        (dead mesh-padding rows carry zero-batch garbage).  Computed the
        same way at every mesh size so loss trajectories are comparable
        bit for bit across nd."""
        return float(np.asarray(loss)[: self.n_live_workers].mean())

    def _get_sampler(self) -> ThreadPoolExecutor:
        if self._sampler_pool is None:
            self._sampler_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="replay-sample")
        return self._sampler_pool

    def run_updates(self, n: int) -> list[float]:
        """``n`` optimiser steps from the replay buffers under
        ``cfg.learner``.  ``packed_pipelined`` double-buffers: the sampler
        thread gathers update k+1's packed batch while update k runs on
        device (sound because nothing writes the buffers between updates
        and the single sampler thread drains each buffer's RNG stream in
        order — so every path sees identical batches).

        Prioritized replay forces the SEQUENTIAL order for every learner
        mode, packed_pipelined included: update k's |TD| errors must
        reprioritise the buffers before batch k+1 is drawn, so there is
        nothing sound to overlap — pre-sampling would read stale
        priorities and break the learner-mode equivalence matrix.  (The
        documented cost of PER's sample/update data dependence.)"""
        if n <= 0:
            return []   # before the eager submit below: a zero-update call
            # must not advance the buffers' sample RNG streams
        mode = self.cfg.learner
        prioritized = self.cfg.replay == "prioritized"
        if mode != "packed_pipelined" or prioritized:
            packed = mode != "dense"
            losses = []
            for _ in range(n):
                batch = self._stacked_sample_packed() if packed \
                    else self._stacked_sample()
                loss, td = self._update_once(batch, packed=packed)
                if prioritized:
                    self._apply_priorities(td)
                losses.append(self._loss_scalar(loss))
            return losses
        pool = self._get_sampler()
        fut = pool.submit(self._stacked_sample_packed_np)
        device_losses = []
        for k in range(n):
            host_batch = fut.result()
            if k + 1 < n:
                fut = pool.submit(self._stacked_sample_packed_np)
            # the update dispatch is async: XLA computes while the sampler
            # thread gathers; only the final host conversions block
            device_losses.append(
                self._update_once(self._ship(host_batch), packed=True)[0])
        return [self._loss_scalar(l) for l in device_losses]

    def train(self, episodes: int | None = None, log_every: int = 0) -> list[dict]:
        stats = []
        for _ in range(episodes or self.cfg.episodes):
            st = self.train_episode()
            stats.append(st)
            if log_every and st["episode"] % log_every == 0:
                print(f"[ep {st['episode']}] reward {st['mean_final_reward']:.3f} "
                      f"loss {st['loss']:.4f} eps {st['epsilon']:.3f}")
        return stats

    # ------------------------------------------------------------ #
    # checkpoint / resume (bit-exact)
    # ------------------------------------------------------------ #
    # Everything a continued run's bits depend on, at an EPISODE BOUNDARY:
    # the three stacked device trees, every worker's action RNG, every
    # replay buffer ring (priorities included — their sample RNG rides in
    # the buffer state), the dataset cursor, the episode counter (which
    # alone positions the target-update cadence and the PER beta anneal)
    # and the exact epsilon float.  NOT state: the engine (rebuilt from the
    # start assignment every reset), the chemistry cache and property
    # memo (pure deterministic memos — they change speed, never bits), and
    # the fleet views' sticky batch capacities (the resumed process
    # re-warms its own jit cache).

    def _config_fingerprint(self) -> str:
        """Canonical JSON of the full TrainerConfig — a resume against a
        DIFFERENT config is an operator error, caught loudly at load."""
        import dataclasses
        import json

        def enc(o):
            if isinstance(o, frozenset):
                return sorted(o)
            raise TypeError(f"unserialisable config field: {o!r}")
        return json.dumps(dataclasses.asdict(self.cfg), sort_keys=True,
                          default=enc)

    def state_dict(self) -> dict[str, np.ndarray]:
        """Flat ``{key: array}`` snapshot of the complete training state
        (``repro.checkpoint.save_flat`` layout)."""
        import json
        from repro.checkpoint.checkpoint import rng_state_to_array
        flat: dict[str, np.ndarray] = {}
        flat["meta/config"] = np.frombuffer(
            self._config_fingerprint().encode(), np.uint8).copy()
        flat["meta/episode"] = np.asarray(self.episode, np.int64)
        flat["meta/epsilon"] = np.asarray(self.epsilon, np.float64)
        flat["meta/n_updates"] = np.asarray(self.n_updates, np.int64)
        flat["meta/loss_log"] = np.asarray(self.loss_log, np.float64)
        flat["meta/reward_log"] = np.asarray(self.reward_log, np.float64)
        flat["meta/start_log"] = np.frombuffer(json.dumps(
            [list(t) for t in self.start_log]).encode(), np.uint8).copy()
        for w, rng in enumerate(self._worker_rngs):
            flat[f"rng/worker_{w}"] = rng_state_to_array(rng)
        for name, tree in (("params", self.params),
                           ("target", self.target_params),
                           ("opt", self.opt_state)):
            for i, leaf in enumerate(jax.tree_util.tree_leaves(tree)):
                flat[f"{name}/{i}"] = np.asarray(leaf)
        for w, buf in enumerate(self.buffers):
            for k, v in buf.state_dict().items():
                flat[f"replay/{w}/{k}"] = v
        if self._dataset_stream is not None:
            for k, v in self._dataset_stream.state_dict().items():
                flat[f"dataset/{k}"] = v
        if self.worker_objectives is not None:
            # scenario objectives carry mutable state (novelty visit
            # counts) — snapshot it per worker so a resumed mixed fleet
            # keeps the exact intrinsic-bonus schedule
            for w, obj in enumerate(self.worker_objectives):
                flat[f"scenario/{w}"] = np.frombuffer(json.dumps(
                    obj.state_dict(), sort_keys=True).encode(),
                    np.uint8).copy()
        return flat

    def load_state_dict(self, flat) -> None:
        """Restore a :meth:`state_dict` snapshot; the continued run is
        bit-identical to one that never stopped (tests/multidevice
        crash-resume matrix)."""
        import json
        from repro.checkpoint.checkpoint import (
            CheckpointError, rng_state_from_array)
        got = bytes(np.asarray(flat["meta/config"], np.uint8)).decode()
        want = self._config_fingerprint()
        if got != want:
            raise CheckpointError(
                "checkpoint was written under a different TrainerConfig — "
                "resume requires the identical configuration")
        self.episode = int(flat["meta/episode"])
        self.epsilon = float(flat["meta/epsilon"])
        self.n_updates = int(flat["meta/n_updates"])
        self.loss_log = [float(x) for x in
                         np.asarray(flat["meta/loss_log"], np.float64)]
        self.reward_log = [float(x) for x in
                           np.asarray(flat["meta/reward_log"], np.float64)]
        self.start_log = [tuple(x) for x in json.loads(
            bytes(np.asarray(flat["meta/start_log"], np.uint8)).decode())]
        for w in range(len(self._worker_rngs)):
            self._worker_rngs[w] = rng_state_from_array(flat[f"rng/worker_{w}"])
        shard = lambda x: jax.device_put(
            x, NamedSharding(self.mesh, P("data")))
        for name, attr in (("params", "params"), ("target", "target_params"),
                           ("opt", "opt_state")):
            live = getattr(self, attr)
            treedef = jax.tree_util.tree_structure(live)
            leaves = []
            for i, ref in enumerate(jax.tree_util.tree_leaves(live)):
                key = f"{name}/{i}"
                if key not in flat:
                    raise CheckpointError(f"checkpoint missing leaf {key!r}")
                arr = np.asarray(flat[key])
                if tuple(arr.shape) != tuple(ref.shape):
                    raise CheckpointError(
                        f"leaf {key!r}: checkpoint shape {arr.shape} != "
                        f"live shape {tuple(ref.shape)}")
                leaves.append(shard(jnp.asarray(arr, dtype=ref.dtype)))
            setattr(self, attr, jax.tree_util.tree_unflatten(treedef, leaves))
        for w, buf in enumerate(self.buffers):
            prefix = f"replay/{w}/"
            sub = {k[len(prefix):]: v for k, v in flat.items()
                   if k.startswith(prefix)}
            if not sub:
                raise CheckpointError(f"checkpoint missing replay state "
                                      f"for worker {w}")
            buf.load_state_dict(sub)
        if self._dataset_stream is not None:
            sub = {k[len("dataset/"):]: v for k, v in flat.items()
                   if k.startswith("dataset/")}
            if not sub:
                raise CheckpointError(
                    "trainer streams episode starts but the checkpoint "
                    "carries no dataset cursor")
            self._dataset_stream.load_state_dict(sub)
        if self.worker_objectives is not None:
            # cfg.scenarios rides the config fingerprint, so a matching
            # checkpoint always carries every worker's scenario state
            for w, obj in enumerate(self.worker_objectives):
                key = f"scenario/{w}"
                if key not in flat:
                    raise CheckpointError(
                        f"trainer runs a scenario fleet but the checkpoint "
                        f"carries no objective state for worker {w}")
                obj.load_state_dict(json.loads(
                    bytes(np.asarray(flat[key], np.uint8)).decode()))

    def save_checkpoint(self, manager, step: int | None = None) -> int:
        """Snapshot into a ``repro.checkpoint.CheckpointManager`` (flat
        layout); returns the step label (default: the episode counter)."""
        label = self.episode if step is None else int(step)
        manager.save(label, self.state_dict(), flat=True)
        return label

    def restore_checkpoint(self, manager, step: int | None = None) -> int:
        """Load the latest (or given) snapshot from a manager; returns the
        restored episode counter."""
        _, flat = manager.restore_flat(step)
        self.load_state_dict(flat)
        return self.episode

    # ------------------------------------------------------------ #
    # evaluation / export
    # ------------------------------------------------------------ #
    def mean_params(self) -> dict:
        """The general model: worker-averaged parameters."""
        synced = self._sync(self.params)
        return jax.tree_util.tree_map(lambda x: np.asarray(x[0]), synced)

    def as_agent(self, epsilon: float = 0.0, seed: int = 1234) -> DQNAgent:
        """Materialise the general model as a single-model DQNAgent."""
        agent = DQNAgent(replace(self.cfg.dqn, epsilon_initial=epsilon), seed=seed,
                         network=self.network)
        mp = self.mean_params()
        agent.params = jax.tree_util.tree_map(jnp.asarray, mp)
        agent.target_params = jax.tree_util.tree_map(jnp.copy, agent.params)
        agent.epsilon = epsilon
        return agent


def greedy_optimize(
    agent: DQNAgent,
    molecules: list[Molecule],
    service: PropertyService,
    reward_cfg: RewardConfig,
    env_cfg: EnvConfig = EnvConfig(),
    seed: int = 0,
) -> list[StepRecord]:
    """Greedy (eps as configured in ``agent``) rollout over a molecule
    batch; returns final-step records — the paper's 'optimize the N
    antioxidants with the trained model' evaluation."""
    env = BatchedEnv(molecules, env_cfg, seed=seed)
    last: list[StepRecord] = []
    while not env.done:
        recs = env.step(agent, service, reward_cfg, buffer=None)
        if recs:
            last = recs
    return last


def optimization_failure_rate(records: list[StepRecord], *, bde_max: float = 76.0,
                              ip_min: float = 145.0) -> float:
    """Eq. 2: OFR = 1 - S/A (success = BDE < 76 and IP > 145)."""
    if not records:
        return 1.0
    ok = sum(
        1 for r in records
        if r.bde is not None and r.ip is not None and r.bde < bde_max and r.ip > ip_min
    )
    return 1.0 - ok / len(records)

