"""DA-MolDQN: the paper's primary contribution.

Distributed deep-Q molecular optimisation with:
  * batched modification (many molecules per worker, §3.1),
  * per-worker replay buffers + episode-boundary model sync (§3.2),
  * O-H-protected action space (§3.3, via repro.chem.actions),
  * invalid-3D-conformer penalty of -1000 (§3.3),
  * the normalised BDE/IP/γ reward (§3.4, Eq. 1),
  * filter script + per-molecule fine-tuning (§3.5),
  * the §3.6 performance optimisations (vectorised env, incremental
    fingerprints, LRU property cache) living in repro.chem / repro.predictors.

Layout:
  reward.py       Eq. 1 + min-max normalisation bounds from the dataset,
                  plus term-composed objectives (ObjectiveSpec →
                  CompiledObjective) behind configs/scenarios.py's registry
  agent.py        Q-network (fingerprint MLP), double-DQN loss, eps-greedy
  replay.py       bit-packed SoA replay ring buffer (vectorized sampling,
                  packed uint8 batches for the device-side unpack)
  packed_batch.py jit-side unpack of packed replay batches
  rollout.py      fleet-level rollout engine: one Q dispatch + one property
                  batch per step across ALL workers
  env.py          single + batched molecule environments (thin single-worker
                  adapters over the rollout engine)
  distributed.py  the distributed trainer (DDP-style per-step pmean and the
                  paper's episode-boundary sync), shard_map-based
  finetune.py     §3.5 fine-tuning from the general model
  filter.py       §3.5 filter script
  jit_stats.py    XLA recompile accounting for the acting hot path
  faults.py       deterministic fault injection (FaultPlan) + the
                  quarantine/incident machinery behind the self-healing
                  fleet and the crash-resume matrix
"""

from repro.core.reward import (
    RewardConfig, compute_reward, INVALID_CONFORMER_REWARD,
    ObjectiveSpec, TermSpec, CompiledObjective, evaluate_rewards,
    REWARD_TERMS,
)
from repro.core.agent import QNetwork, DQNAgent, DQNConfig
from repro.core.replay import ReplayBuffer, Transition
from repro.core.rollout import CHEM_MODES, RolloutEngine, StepRecord, AgentFleetPolicy
from repro.core.env import MoleculeEnv, BatchedEnv, EnvConfig
from repro.core.distributed import (
    DistributedTrainer, TrainerConfig, ACTING_MODES, LEARNER_MODES,
    ROLLOUT_MODES,
)
from repro.core.faults import (
    FaultError, FaultPlan, FaultRule, FaultTimeout, Incident, TransientFault,
)
from repro.core.finetune import fine_tune
from repro.core.filter import filter_molecules, FilterCriteria

__all__ = [
    "FaultError", "FaultPlan", "FaultRule", "FaultTimeout", "Incident",
    "TransientFault",
    "RewardConfig", "compute_reward", "INVALID_CONFORMER_REWARD",
    "ObjectiveSpec", "TermSpec", "CompiledObjective", "evaluate_rewards",
    "REWARD_TERMS",
    "QNetwork", "DQNAgent", "DQNConfig",
    "ReplayBuffer", "Transition",
    "RolloutEngine", "StepRecord", "AgentFleetPolicy", "CHEM_MODES",
    "MoleculeEnv", "BatchedEnv", "EnvConfig",
    "DistributedTrainer", "TrainerConfig", "ACTING_MODES", "LEARNER_MODES",
    "ROLLOUT_MODES",
    "fine_tune", "filter_molecules", "FilterCriteria",
]
