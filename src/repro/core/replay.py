"""Replay buffer with bit-packed fingerprints (paper §3.2, size 4000).

Each transition stores the *chosen next state* fingerprint (the Q-network
input), the reward, terminal flag, and the candidate fingerprints of the
successor state (needed for the double-DQN max).  At 2048 bits a raw
float32 layout would cost ~1.2 MB per transition (~150 candidates); packing
to bits brings it to ~40 KB, which is what makes a 4000-entry buffer per
worker viable — the same engineering pressure the paper's §3.6 reacts to.

Two implementations share the semantics:

``ReplayBuffer``      structure-of-arrays ring storage.  ``add`` writes one
                      row of each preallocated array (the candidate axis and
                      the row axis grow geometrically to their caps, so
                      small buffers stay small); ``sample`` is pure
                      vectorized fancy indexing — no per-transition Python
                      loop, and the dense reconstruction needs exactly ONE
                      batched ``np.unpackbits`` per field.
                      ``sample_packed`` skips the unpack entirely and
                      returns the uint8 bit planes + scalar features: the
                      learner ships those to the device (32x less H2D
                      traffic) and unpacks inside the jit'd update step
                      (``repro.core.packed_batch.densify_batch`` is the
                      jit-side twin of the host densify here).
``ListReplayBuffer``  the seed ``list[Transition]`` implementation, kept as
                      the CORRECTNESS REFERENCE: tests/test_replay.py pins
                      seeded ``sample()`` equivalence of the two, and
                      benchmarks/bench_train.py measures the host-sample
                      speedup against it.

``ReplayBuffer`` additionally supports proportional PRIORITIZED sampling
(``sampling="prioritized"``, Schaul et al. 2015): per-row priority arrays
ride the same SoA ring storage, the weighted draw is one vectorized
inverse-CDF ``searchsorted`` over the cumulative priorities, and the batch
gains a ``weights`` key (importance weights ``(N * P(i))^-beta``, max-
normalised) the learner folds into the loss.  THE parity invariant: when
the effective priorities ``p^alpha`` are all equal (``alpha = 0``, or no
``update_priorities`` call has differentiated them yet), the draw takes the
EXACT uniform path — the same ``rng.integers`` call the uniform sampler
makes, unit weights — so a prioritized buffer with flat priorities is
BIT-identical (indices, batches, RNG stream) to a uniform one.
``ListReplayBuffer`` + uniform sampling stays the pinned reference.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

import numpy as np

from repro.chem.fingerprint import FP_BITS, pack_fps

FP_BYTES = FP_BITS // 8


@dataclass
class Transition:
    state_fp: np.ndarray        # packed uint8 [FP_BITS/8]
    steps_left_frac: float      # steps-left feature of the state
    reward: float
    done: bool
    next_fps: np.ndarray        # packed uint8 [n_candidates, FP_BITS/8]
    next_steps_left_frac: float


def pack_fp(fp: np.ndarray) -> np.ndarray:
    """Single-row twin of ``chem.fingerprint.pack_fps`` (the one bit-order
    contract all packed fingerprints share)."""
    return pack_fps(fp)


def unpack_fp(packed: np.ndarray, n_bits: int = FP_BITS) -> np.ndarray:
    return np.unpackbits(packed, axis=-1)[..., :n_bits].astype(np.float32)


def densify_sample(packed: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Packed sample -> the dense train-step layout (host-side twin of
    ``repro.core.packed_batch.densify_batch``; keep the two in lockstep).

    Candidate rows past each transition's count — and ALL rows of terminal
    transitions — are zeroed, exactly like the reference per-row loop."""
    bits, counts = packed["next_bits"], packed["next_counts"]
    B, C = bits.shape[0], bits.shape[1]
    states = np.empty((B, FP_BITS + 1), np.float32)
    states[:, :FP_BITS] = np.unpackbits(packed["state_bits"], axis=-1)
    states[:, FP_BITS] = packed["state_frac"]
    eff = np.where(packed["dones"] > 0, 0, np.minimum(counts, C))
    next_mask = (np.arange(C)[None, :] < eff[:, None]).astype(np.float32)
    next_fps = np.empty((B, C, FP_BITS + 1), np.float32)
    if C:
        next_fps[..., :FP_BITS] = np.unpackbits(bits, axis=-1) * next_mask[..., None]
    next_fps[..., FP_BITS] = packed["next_frac"][:, None] * next_mask
    out = {"states": states, "rewards": packed["rewards"],
           "dones": packed["dones"], "next_fps": next_fps,
           "next_mask": next_mask}
    if "weights" in packed:          # prioritized replay importance weights
        out["weights"] = packed["weights"]
    return out


SAMPLING_MODES = ("uniform", "prioritized")


class ReplayBuffer:
    """SoA ring buffer (paper Table 3: size 4000), uniform or prioritized.

    ``max_candidates`` bounds the stored successor set per transition
    (``None`` = keep every candidate); the trainer passes its replay
    truncation target so storage never holds rows ``sample`` would drop.
    Sampling wider than that storage bound raises: the dropped rows may
    include the taken action's candidate, so a silent zero-padded answer
    would diverge from the ``ListReplayBuffer`` reference (which stores
    full rows and truncates only at sample time).
    Row and candidate capacities grow geometrically up to their caps, so
    the arrays a mostly-empty buffer owns stay proportional to what was
    actually added.

    ``sampling="prioritized"`` keeps a per-row priority (new rows get the
    running max, so every transition is sampled at least once with high
    probability), draws proportional to ``priority**priority_alpha``, and
    adds max-normalised importance weights under the ``weights`` key.
    ``update_priorities(td_abs)`` refreshes the rows of the LAST draw with
    ``|td| + priority_eps`` (duplicate indices: last write wins).
    """

    def __init__(self, capacity: int = 4000, seed: int = 0,
                 max_candidates: int | None = None,
                 sampling: str = "uniform",
                 priority_alpha: float = 0.6,
                 priority_eps: float = 1e-3):
        if sampling not in SAMPLING_MODES:
            raise ValueError(f"sampling={sampling!r} not in {SAMPLING_MODES}")
        self.capacity = capacity
        self.max_candidates = max_candidates
        self.sampling = sampling
        self.priority_alpha = float(priority_alpha)
        self.priority_eps = float(priority_eps)
        self._rng = np.random.default_rng(seed)
        self._size = 0
        self._pos = 0
        self._rows = 0          # allocated rows (<= capacity)
        self._cand_cap = 0      # allocated candidate axis
        self._state_bits = np.zeros((0, FP_BYTES), np.uint8)
        self._state_frac = np.zeros((0,), np.float32)
        self._rewards = np.zeros((0,), np.float32)
        self._dones = np.zeros((0,), bool)
        self._next_bits = np.zeros((0, 0, FP_BYTES), np.uint8)
        self._next_frac = np.zeros((0,), np.float32)
        self._next_counts = np.zeros((0,), np.int32)
        self._priorities = np.zeros((0,), np.float64)
        self._max_priority = 1.0
        self._last_idx: np.ndarray | None = None   # indices of the last draw

    def __len__(self) -> int:
        return self._size

    # ------------------------------------------------------------ #
    # storage growth (amortised: both axes double up to their caps)
    # ------------------------------------------------------------ #
    def _grow_rows(self, need: int) -> None:
        rows = min(self.capacity, max(need, 64, 2 * self._rows))
        def grow(a, shape):
            out = np.zeros(shape, a.dtype)
            out[: a.shape[0]] = a
            return out
        self._state_bits = grow(self._state_bits, (rows, FP_BYTES))
        self._state_frac = grow(self._state_frac, (rows,))
        self._rewards = grow(self._rewards, (rows,))
        self._dones = grow(self._dones, (rows,))
        self._next_bits = grow(self._next_bits, (rows, self._cand_cap, FP_BYTES))
        self._next_frac = grow(self._next_frac, (rows,))
        self._next_counts = grow(self._next_counts, (rows,))
        self._priorities = grow(self._priorities, (rows,))
        self._rows = rows

    def _grow_candidates(self, need: int) -> None:
        cap = max(need, 2 * self._cand_cap, 8)
        if self.max_candidates is not None:
            cap = min(max(cap, need), self.max_candidates)
        out = np.zeros((self._rows, cap, FP_BYTES), np.uint8)
        out[:, : self._cand_cap] = self._next_bits
        self._next_bits = out
        self._cand_cap = cap

    # ------------------------------------------------------------ #
    def add(self, t: Transition) -> None:
        k = t.next_fps.shape[0]
        if self.max_candidates is not None:
            k = min(k, self.max_candidates)
        pos = self._pos
        if pos >= self._rows:
            self._grow_rows(pos + 1)
        if k > self._cand_cap:
            self._grow_candidates(k)
        self._state_bits[pos] = t.state_fp
        self._state_frac[pos] = t.steps_left_frac
        self._rewards[pos] = t.reward
        self._dones[pos] = t.done
        self._next_bits[pos, :k] = t.next_fps[:k]
        self._next_bits[pos, k:] = 0          # clear the evicted row's tail
        self._next_frac[pos] = t.next_steps_left_frac
        self._next_counts[pos] = k
        self._priorities[pos] = self._max_priority
        self._size = min(self._size + 1, self.capacity)
        self._pos = (pos + 1) % self.capacity

    def add_many(self, ts: "Iterable[Transition]") -> None:
        """Insertion-order bulk add (the rollout engine's per-worker flush)."""
        for t in ts:
            self.add(t)

    # ------------------------------------------------------------ #
    # sampling: one seeded index draw + pure fancy-indexing gathers
    # ------------------------------------------------------------ #
    def _check_candidate_bound(self, C: int) -> None:
        """Sampling wider than the storage bound cannot be answered
        honestly: rows past ``self.max_candidates`` (possibly including the
        taken action's candidate) were dropped at ``add`` time, while the
        ``ListReplayBuffer`` reference would return them — so fail loudly
        instead of silently zero-padding a divergent batch."""
        if self.max_candidates is not None and C > self.max_candidates:
            raise ValueError(
                f"sample max_candidates={C} exceeds the storage bound "
                f"max_candidates={self.max_candidates}: candidate rows past "
                f"the bound were dropped at add() time and cannot be "
                f"reconstructed (the list reference would return them)")

    def _draw(self, batch_size: int) -> np.ndarray:
        if self._size == 0:
            raise ValueError("empty replay buffer")
        idx = self._rng.integers(0, self._size, size=batch_size)
        self._last_idx = idx
        return idx

    def _draw_prioritized(self, batch_size: int, beta: float
                          ) -> tuple[np.ndarray, np.ndarray]:
        """One vectorized weighted draw: inverse-CDF ``searchsorted`` over
        the cumulative effective priorities, plus max-normalised importance
        weights ``(N * P(i))**-beta``.

        PARITY INVARIANT: with all-equal effective priorities this MUST
        take the exact uniform path — same ``rng.integers`` call, unit
        weights — so priorities-all-equal stays bit-identical to the
        uniform sampler (numpy's bounded-integer draw uses rejection
        sampling, which no weighted draw can reproduce)."""
        if self._size == 0:
            raise ValueError("empty replay buffer")
        q = self._priorities[: self._size] ** self.priority_alpha
        if q[0] == q[-1] and np.all(q == q[0]):
            idx = self._rng.integers(0, self._size, size=batch_size)
            weights = np.ones(batch_size, np.float32)
        else:
            csum = np.cumsum(q)
            u = self._rng.random(batch_size) * csum[-1]
            idx = np.searchsorted(csum, u, side="right")
            idx = np.minimum(idx, self._size - 1)
            probs = q[idx] / csum[-1]
            w = (self._size * probs) ** -float(beta)
            weights = (w / w.max()).astype(np.float32)
        self._last_idx = idx
        return idx, weights

    def update_priorities(self, td_abs: np.ndarray) -> None:
        """Refresh the priorities of the LAST sampled batch from its |TD|
        errors (proportional variant: ``p = |td| + eps``).  Duplicate draws
        of the same row resolve last-write-wins; the running max feeds the
        max-priority init of subsequently added rows."""
        if self.sampling != "prioritized":
            raise ValueError("update_priorities called on a uniform buffer")
        if self._last_idx is None:
            raise ValueError("update_priorities before any sample")
        td_abs = np.abs(np.asarray(td_abs, np.float64)).reshape(-1)
        if td_abs.shape[0] != self._last_idx.shape[0]:
            raise ValueError(
                f"td batch {td_abs.shape[0]} != last sampled batch "
                f"{self._last_idx.shape[0]}")
        p = td_abs + self.priority_eps
        self._priorities[self._last_idx] = p
        self._max_priority = max(self._max_priority, float(p.max()))

    def _gather_packed(self, idx: np.ndarray, C: int) -> dict[str, np.ndarray]:
        k = min(C, self._cand_cap)
        next_bits = np.zeros((idx.shape[0], C, FP_BYTES), np.uint8)
        if k:
            next_bits[:, :k] = self._next_bits[idx, :k]
        return {
            "state_bits": self._state_bits[idx],
            "state_frac": self._state_frac[idx],
            "rewards": self._rewards[idx],
            "dones": self._dones[idx].astype(np.float32),
            "next_bits": next_bits,
            "next_frac": self._next_frac[idx],
            "next_counts": np.minimum(self._next_counts[idx], C).astype(np.int32),
        }

    def sample_packed(self, batch_size: int, max_candidates: int = 160,
                      *, beta: float = 0.0) -> dict[str, np.ndarray]:
        """Packed uint8 bit planes + scalar features — what the packed
        learner ships to the device (32x smaller than the dense layout):

        state_bits  u8[B, FP_BITS/8]   state_frac  f32[B]
        rewards     f32[B]             dones       f32[B]
        next_bits   u8[B, C, FP_BITS/8] (zero past each count)
        next_frac   f32[B]             next_counts i32[B]
        weights     f32[B]             (prioritized mode ONLY — uniform
                                        batches keep exactly today's keys)

        Draws the SAME seeded indices as ``sample`` would have.  ``beta``
        is the importance-weight exponent (prioritized mode; ignored under
        uniform sampling).
        """
        self._check_candidate_bound(max_candidates)
        if self.sampling == "prioritized":
            idx, weights = self._draw_prioritized(batch_size, beta)
            out = self._gather_packed(idx, max_candidates)
            out["weights"] = weights
            return out
        return self._gather_packed(self._draw(batch_size), max_candidates)

    def sample(self, batch_size: int, max_candidates: int = 160,
               *, beta: float = 0.0) -> dict[str, np.ndarray]:
        """Returns dense arrays for the jit'd train step.

        states   f32[B, FP_BITS+1]
        rewards  f32[B]
        dones    f32[B]
        next_fps f32[B, C, FP_BITS+1]  (zero-padded)
        next_mask f32[B, C]
        weights  f32[B]  (prioritized mode only)
        """
        return densify_sample(
            self.sample_packed(batch_size, max_candidates, beta=beta))

    # ------------------------------------------------------------ #
    # checkpoint state (bit-exact resume)
    # ------------------------------------------------------------ #
    def state_dict(self) -> dict[str, np.ndarray]:
        """Everything needed to resume bit-identically: the SoA rings
        (including the per-slot priority array), the ring cursor, the
        running max priority, the indices of the last draw (pending
        ``update_priorities`` feedback), and the sampler RNG stream.
        Allocated capacities (``_rows``/``_cand_cap``) ride along as the
        array shapes themselves."""
        from repro.checkpoint.checkpoint import rng_state_to_array

        d = {
            "state_bits": self._state_bits,
            "state_frac": self._state_frac,
            "rewards": self._rewards,
            "dones": self._dones,
            "next_bits": self._next_bits,
            "next_frac": self._next_frac,
            "next_counts": self._next_counts,
            "priorities": self._priorities,
            "size": np.int64(self._size),
            "pos": np.int64(self._pos),
            "max_priority": np.float64(self._max_priority),
            "rng": rng_state_to_array(self._rng),
        }
        if self._last_idx is not None:
            d["last_idx"] = np.asarray(self._last_idx, np.int64)
        return d

    def load_state_dict(self, d: dict[str, np.ndarray]) -> None:
        """Restore the state written by :meth:`state_dict` into a buffer
        constructed with the SAME config (capacity / sampling / bounds —
        those live in the trainer config, not the checkpoint)."""
        from repro.checkpoint.checkpoint import rng_state_from_array

        bits = np.asarray(d["state_bits"], np.uint8)
        rows = bits.shape[0]
        nb = np.asarray(d["next_bits"], np.uint8)
        if bits.shape[1:] != (FP_BYTES,) or nb.shape[0] != rows \
                or nb.shape[2:] != (FP_BYTES,) or rows > self.capacity:
            raise ValueError(
                f"replay state shape mismatch: state_bits {bits.shape}, "
                f"next_bits {nb.shape}, capacity {self.capacity}")
        self._state_bits = bits
        self._state_frac = np.asarray(d["state_frac"], np.float32)
        self._rewards = np.asarray(d["rewards"], np.float32)
        self._dones = np.asarray(d["dones"]).astype(bool)
        self._next_bits = nb
        self._next_frac = np.asarray(d["next_frac"], np.float32)
        self._next_counts = np.asarray(d["next_counts"], np.int32)
        self._priorities = np.asarray(d["priorities"], np.float64)
        self._rows = rows
        self._cand_cap = nb.shape[1]
        self._size = int(d["size"])
        self._pos = int(d["pos"])
        self._max_priority = float(d["max_priority"])
        self._last_idx = (np.asarray(d["last_idx"], np.int64)
                          if "last_idx" in d else None)
        self._rng = rng_state_from_array(d["rng"])

    # ------------------------------------------------------------ #
    # compatibility / introspection
    # ------------------------------------------------------------ #
    @property
    def _items(self) -> list[Transition]:
        """Materialise the ring as ``Transition`` objects in slot order —
        exactly the ``ListReplayBuffer._items`` layout (insertion order
        until the first wraparound, then cyclic overwrite order)."""
        return [
            Transition(
                state_fp=self._state_bits[i].copy(),
                steps_left_frac=float(self._state_frac[i]),
                reward=float(self._rewards[i]),
                done=bool(self._dones[i]),
                next_fps=self._next_bits[i, : self._next_counts[i]].copy(),
                next_steps_left_frac=float(self._next_frac[i]),
            )
            for i in range(self._size)
        ]


class ListReplayBuffer:
    """The seed list-based ring buffer — kept as the correctness reference
    for ``ReplayBuffer`` (seeded-sample equivalence pinned in
    tests/test_replay.py) and as the baseline in benchmarks/bench_train.py.
    Its ``sample`` loops over transitions calling ``np.unpackbits`` per row:
    O(B) Python iterations per draw, dense float32 output only."""

    def __init__(self, capacity: int = 4000, seed: int = 0):
        self.capacity = capacity
        self._items: list[Transition] = []
        self._pos = 0
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return len(self._items)

    def add(self, t: Transition) -> None:
        if len(self._items) < self.capacity:
            self._items.append(t)
        else:
            self._items[self._pos] = t
        self._pos = (self._pos + 1) % self.capacity

    def add_many(self, ts: "Iterable[Transition]") -> None:
        for t in ts:
            self.add(t)

    def sample(self, batch_size: int, max_candidates: int = 160) -> dict[str, np.ndarray]:
        n = len(self._items)
        if n == 0:
            raise ValueError("empty replay buffer")
        idx = self._rng.integers(0, n, size=batch_size)
        C = max_candidates
        B = batch_size
        states = np.zeros((B, FP_BITS + 1), dtype=np.float32)
        rewards = np.zeros((B,), dtype=np.float32)
        dones = np.zeros((B,), dtype=np.float32)
        next_fps = np.zeros((B, C, FP_BITS + 1), dtype=np.float32)
        next_mask = np.zeros((B, C), dtype=np.float32)
        for r, i in enumerate(idx):
            t = self._items[int(i)]
            states[r, :FP_BITS] = unpack_fp(t.state_fp)
            states[r, FP_BITS] = t.steps_left_frac
            rewards[r] = t.reward
            dones[r] = float(t.done)
            k = min(t.next_fps.shape[0], C)
            if k and not t.done:
                next_fps[r, :k, :FP_BITS] = unpack_fp(t.next_fps[:k])
                next_fps[r, :k, FP_BITS] = t.next_steps_left_frac
                next_mask[r, :k] = 1.0
        return {"states": states, "rewards": rewards, "dones": dones,
                "next_fps": next_fps, "next_mask": next_mask}
