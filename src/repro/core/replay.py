"""Replay buffer with bit-packed fingerprints (paper §3.2, size 4000).

Each transition stores the *chosen next state* fingerprint (the Q-network
input), the reward, terminal flag, and the candidate fingerprints of the
successor state (needed for the double-DQN max).  At 2048 bits a raw
float32 layout would cost ~1.2 MB per transition (~150 candidates); packing
to bits brings it to ~40 KB, which is what makes a 4000-entry buffer per
worker viable — the same engineering pressure the paper's §3.6 reacts to.

Two implementations share the semantics:

``ReplayBuffer``      structure-of-arrays ring storage.  ``add`` writes one
                      row of each preallocated array (the candidate axis and
                      the row axis grow geometrically to their caps, so
                      small buffers stay small); ``sample`` is pure
                      vectorized fancy indexing — no per-transition Python
                      loop, and the dense reconstruction needs exactly ONE
                      batched ``np.unpackbits`` per field.
                      ``sample_packed`` skips the unpack entirely and
                      returns the uint8 bit planes + scalar features: the
                      learner ships those to the device (32x less H2D
                      traffic) and unpacks inside the jit'd update step
                      (``repro.core.packed_batch.densify_batch`` is the
                      jit-side twin of the host densify here).
``ListReplayBuffer``  the seed ``list[Transition]`` implementation, kept as
                      the CORRECTNESS REFERENCE: tests/test_replay.py pins
                      seeded ``sample()`` equivalence of the two, and
                      benchmarks/bench_train.py measures the host-sample
                      speedup against it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

import numpy as np

from repro.chem.fingerprint import FP_BITS, pack_fps

FP_BYTES = FP_BITS // 8


@dataclass
class Transition:
    state_fp: np.ndarray        # packed uint8 [FP_BITS/8]
    steps_left_frac: float      # steps-left feature of the state
    reward: float
    done: bool
    next_fps: np.ndarray        # packed uint8 [n_candidates, FP_BITS/8]
    next_steps_left_frac: float


def pack_fp(fp: np.ndarray) -> np.ndarray:
    """Single-row twin of ``chem.fingerprint.pack_fps`` (the one bit-order
    contract all packed fingerprints share)."""
    return pack_fps(fp)


def unpack_fp(packed: np.ndarray, n_bits: int = FP_BITS) -> np.ndarray:
    return np.unpackbits(packed, axis=-1)[..., :n_bits].astype(np.float32)


def densify_sample(packed: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Packed sample -> the dense train-step layout (host-side twin of
    ``repro.core.packed_batch.densify_batch``; keep the two in lockstep).

    Candidate rows past each transition's count — and ALL rows of terminal
    transitions — are zeroed, exactly like the reference per-row loop."""
    bits, counts = packed["next_bits"], packed["next_counts"]
    B, C = bits.shape[0], bits.shape[1]
    states = np.empty((B, FP_BITS + 1), np.float32)
    states[:, :FP_BITS] = np.unpackbits(packed["state_bits"], axis=-1)
    states[:, FP_BITS] = packed["state_frac"]
    eff = np.where(packed["dones"] > 0, 0, np.minimum(counts, C))
    next_mask = (np.arange(C)[None, :] < eff[:, None]).astype(np.float32)
    next_fps = np.empty((B, C, FP_BITS + 1), np.float32)
    if C:
        next_fps[..., :FP_BITS] = np.unpackbits(bits, axis=-1) * next_mask[..., None]
    next_fps[..., FP_BITS] = packed["next_frac"][:, None] * next_mask
    return {"states": states, "rewards": packed["rewards"],
            "dones": packed["dones"], "next_fps": next_fps,
            "next_mask": next_mask}


class ReplayBuffer:
    """Uniform-sampling SoA ring buffer (paper Table 3: size 4000).

    ``max_candidates`` bounds the stored successor set per transition
    (``None`` = keep every candidate); the trainer passes its replay
    truncation target so storage never holds rows ``sample`` would drop.
    Row and candidate capacities grow geometrically up to their caps, so
    the arrays a mostly-empty buffer owns stay proportional to what was
    actually added.
    """

    def __init__(self, capacity: int = 4000, seed: int = 0,
                 max_candidates: int | None = None):
        self.capacity = capacity
        self.max_candidates = max_candidates
        self._rng = np.random.default_rng(seed)
        self._size = 0
        self._pos = 0
        self._rows = 0          # allocated rows (<= capacity)
        self._cand_cap = 0      # allocated candidate axis
        self._state_bits = np.zeros((0, FP_BYTES), np.uint8)
        self._state_frac = np.zeros((0,), np.float32)
        self._rewards = np.zeros((0,), np.float32)
        self._dones = np.zeros((0,), bool)
        self._next_bits = np.zeros((0, 0, FP_BYTES), np.uint8)
        self._next_frac = np.zeros((0,), np.float32)
        self._next_counts = np.zeros((0,), np.int32)

    def __len__(self) -> int:
        return self._size

    # ------------------------------------------------------------ #
    # storage growth (amortised: both axes double up to their caps)
    # ------------------------------------------------------------ #
    def _grow_rows(self, need: int) -> None:
        rows = min(self.capacity, max(need, 64, 2 * self._rows))
        def grow(a, shape):
            out = np.zeros(shape, a.dtype)
            out[: a.shape[0]] = a
            return out
        self._state_bits = grow(self._state_bits, (rows, FP_BYTES))
        self._state_frac = grow(self._state_frac, (rows,))
        self._rewards = grow(self._rewards, (rows,))
        self._dones = grow(self._dones, (rows,))
        self._next_bits = grow(self._next_bits, (rows, self._cand_cap, FP_BYTES))
        self._next_frac = grow(self._next_frac, (rows,))
        self._next_counts = grow(self._next_counts, (rows,))
        self._rows = rows

    def _grow_candidates(self, need: int) -> None:
        cap = max(need, 2 * self._cand_cap, 8)
        if self.max_candidates is not None:
            cap = min(max(cap, need), self.max_candidates)
        out = np.zeros((self._rows, cap, FP_BYTES), np.uint8)
        out[:, : self._cand_cap] = self._next_bits
        self._next_bits = out
        self._cand_cap = cap

    # ------------------------------------------------------------ #
    def add(self, t: Transition) -> None:
        k = t.next_fps.shape[0]
        if self.max_candidates is not None:
            k = min(k, self.max_candidates)
        pos = self._pos
        if pos >= self._rows:
            self._grow_rows(pos + 1)
        if k > self._cand_cap:
            self._grow_candidates(k)
        self._state_bits[pos] = t.state_fp
        self._state_frac[pos] = t.steps_left_frac
        self._rewards[pos] = t.reward
        self._dones[pos] = t.done
        self._next_bits[pos, :k] = t.next_fps[:k]
        self._next_bits[pos, k:] = 0          # clear the evicted row's tail
        self._next_frac[pos] = t.next_steps_left_frac
        self._next_counts[pos] = k
        self._size = min(self._size + 1, self.capacity)
        self._pos = (pos + 1) % self.capacity

    def add_many(self, ts: "Iterable[Transition]") -> None:
        """Insertion-order bulk add (the rollout engine's per-worker flush)."""
        for t in ts:
            self.add(t)

    # ------------------------------------------------------------ #
    # sampling: one seeded index draw + pure fancy-indexing gathers
    # ------------------------------------------------------------ #
    def _draw(self, batch_size: int) -> np.ndarray:
        if self._size == 0:
            raise ValueError("empty replay buffer")
        return self._rng.integers(0, self._size, size=batch_size)

    def _gather_packed(self, idx: np.ndarray, C: int) -> dict[str, np.ndarray]:
        k = min(C, self._cand_cap)
        next_bits = np.zeros((idx.shape[0], C, FP_BYTES), np.uint8)
        if k:
            next_bits[:, :k] = self._next_bits[idx, :k]
        return {
            "state_bits": self._state_bits[idx],
            "state_frac": self._state_frac[idx],
            "rewards": self._rewards[idx],
            "dones": self._dones[idx].astype(np.float32),
            "next_bits": next_bits,
            "next_frac": self._next_frac[idx],
            "next_counts": np.minimum(self._next_counts[idx], C).astype(np.int32),
        }

    def sample_packed(self, batch_size: int, max_candidates: int = 160
                      ) -> dict[str, np.ndarray]:
        """Packed uint8 bit planes + scalar features — what the packed
        learner ships to the device (32x smaller than the dense layout):

        state_bits  u8[B, FP_BITS/8]   state_frac  f32[B]
        rewards     f32[B]             dones       f32[B]
        next_bits   u8[B, C, FP_BITS/8] (zero past each count)
        next_frac   f32[B]             next_counts i32[B]

        Draws the SAME seeded indices as ``sample`` would have.
        """
        return self._gather_packed(self._draw(batch_size), max_candidates)

    def sample(self, batch_size: int, max_candidates: int = 160) -> dict[str, np.ndarray]:
        """Returns dense arrays for the jit'd train step.

        states   f32[B, FP_BITS+1]
        rewards  f32[B]
        dones    f32[B]
        next_fps f32[B, C, FP_BITS+1]  (zero-padded)
        next_mask f32[B, C]
        """
        return densify_sample(
            self._gather_packed(self._draw(batch_size), max_candidates))

    # ------------------------------------------------------------ #
    # compatibility / introspection
    # ------------------------------------------------------------ #
    @property
    def _items(self) -> list[Transition]:
        """Materialise the ring as ``Transition`` objects in slot order —
        exactly the ``ListReplayBuffer._items`` layout (insertion order
        until the first wraparound, then cyclic overwrite order)."""
        return [
            Transition(
                state_fp=self._state_bits[i].copy(),
                steps_left_frac=float(self._state_frac[i]),
                reward=float(self._rewards[i]),
                done=bool(self._dones[i]),
                next_fps=self._next_bits[i, : self._next_counts[i]].copy(),
                next_steps_left_frac=float(self._next_frac[i]),
            )
            for i in range(self._size)
        ]


class ListReplayBuffer:
    """The seed list-based ring buffer — kept as the correctness reference
    for ``ReplayBuffer`` (seeded-sample equivalence pinned in
    tests/test_replay.py) and as the baseline in benchmarks/bench_train.py.
    Its ``sample`` loops over transitions calling ``np.unpackbits`` per row:
    O(B) Python iterations per draw, dense float32 output only."""

    def __init__(self, capacity: int = 4000, seed: int = 0):
        self.capacity = capacity
        self._items: list[Transition] = []
        self._pos = 0
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return len(self._items)

    def add(self, t: Transition) -> None:
        if len(self._items) < self.capacity:
            self._items.append(t)
        else:
            self._items[self._pos] = t
        self._pos = (self._pos + 1) % self.capacity

    def add_many(self, ts: "Iterable[Transition]") -> None:
        for t in ts:
            self.add(t)

    def sample(self, batch_size: int, max_candidates: int = 160) -> dict[str, np.ndarray]:
        n = len(self._items)
        if n == 0:
            raise ValueError("empty replay buffer")
        idx = self._rng.integers(0, n, size=batch_size)
        C = max_candidates
        B = batch_size
        states = np.zeros((B, FP_BITS + 1), dtype=np.float32)
        rewards = np.zeros((B,), dtype=np.float32)
        dones = np.zeros((B,), dtype=np.float32)
        next_fps = np.zeros((B, C, FP_BITS + 1), dtype=np.float32)
        next_mask = np.zeros((B, C), dtype=np.float32)
        for r, i in enumerate(idx):
            t = self._items[int(i)]
            states[r, :FP_BITS] = unpack_fp(t.state_fp)
            states[r, FP_BITS] = t.steps_left_frac
            rewards[r] = t.reward
            dones[r] = float(t.done)
            k = min(t.next_fps.shape[0], C)
            if k and not t.done:
                next_fps[r, :k, :FP_BITS] = unpack_fp(t.next_fps[:k])
                next_fps[r, :k, FP_BITS] = t.next_steps_left_frac
                next_mask[r, :k] = 1.0
        return {"states": states, "rewards": rewards, "dones": dones,
                "next_fps": next_fps, "next_mask": next_mask}
