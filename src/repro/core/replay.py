"""Replay buffer with bit-packed fingerprints (paper §3.2, size 4000).

Each transition stores the *chosen next state* fingerprint (the Q-network
input), the reward, terminal flag, and the candidate fingerprints of the
successor state (needed for the double-DQN max).  At 2048 bits a raw
float32 layout would cost ~1.2 MB per transition (~150 candidates); packing
to bits brings it to ~40 KB, which is what makes a 4000-entry buffer per
worker viable — the same engineering pressure the paper's §3.6 reacts to.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

import numpy as np

from repro.chem.fingerprint import FP_BITS


@dataclass
class Transition:
    state_fp: np.ndarray        # packed uint8 [FP_BITS/8]
    steps_left_frac: float      # steps-left feature of the state
    reward: float
    done: bool
    next_fps: np.ndarray        # packed uint8 [n_candidates, FP_BITS/8]
    next_steps_left_frac: float


def pack_fp(fp: np.ndarray) -> np.ndarray:
    return np.packbits(fp.astype(bool))


def unpack_fp(packed: np.ndarray, n_bits: int = FP_BITS) -> np.ndarray:
    return np.unpackbits(packed, axis=-1)[..., :n_bits].astype(np.float32)


class ReplayBuffer:
    """Uniform-sampling ring buffer (paper Table 3: size 4000)."""

    def __init__(self, capacity: int = 4000, seed: int = 0):
        self.capacity = capacity
        self._items: list[Transition] = []
        self._pos = 0
        self._rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return len(self._items)

    def add(self, t: Transition) -> None:
        if len(self._items) < self.capacity:
            self._items.append(t)
        else:
            self._items[self._pos] = t
        self._pos = (self._pos + 1) % self.capacity

    def add_many(self, ts: "Iterable[Transition]") -> None:
        """Insertion-order bulk add (the rollout engine's per-worker flush)."""
        for t in ts:
            self.add(t)

    def sample(self, batch_size: int, max_candidates: int = 160) -> dict[str, np.ndarray]:
        """Returns dense arrays for the jit'd train step.

        states   f32[B, FP_BITS+1]
        rewards  f32[B]
        dones    f32[B]
        next_fps f32[B, C, FP_BITS+1]  (zero-padded)
        next_mask f32[B, C]
        """
        n = len(self._items)
        if n == 0:
            raise ValueError("empty replay buffer")
        idx = self._rng.integers(0, n, size=batch_size)
        C = max_candidates
        B = batch_size
        states = np.zeros((B, FP_BITS + 1), dtype=np.float32)
        rewards = np.zeros((B,), dtype=np.float32)
        dones = np.zeros((B,), dtype=np.float32)
        next_fps = np.zeros((B, C, FP_BITS + 1), dtype=np.float32)
        next_mask = np.zeros((B, C), dtype=np.float32)
        for r, i in enumerate(idx):
            t = self._items[int(i)]
            states[r, :FP_BITS] = unpack_fp(t.state_fp)
            states[r, FP_BITS] = t.steps_left_frac
            rewards[r] = t.reward
            dones[r] = float(t.done)
            k = min(t.next_fps.shape[0], C)
            if k and not t.done:
                next_fps[r, :k, :FP_BITS] = unpack_fp(t.next_fps[:k])
                next_fps[r, :k, FP_BITS] = t.next_steps_left_frac
                next_mask[r, :k] = 1.0
        return {"states": states, "rewards": rewards, "dones": dones,
                "next_fps": next_fps, "next_mask": next_mask}
