"""Fleet-level rollout engine.

The paper's *batched modification* (§3.1) batches the candidates of the
molecules owned by ONE worker.  ``RolloutEngine`` lifts that one level up:
the unit of batching is the whole fleet.  Per environment step, across all
W workers it performs

* one candidate-enumeration + fingerprint pass over every live slot,
* ONE Q-network jit dispatch over the concatenation of every worker's
  candidate states (per-worker parameters selected inside the call via a
  vmap'd apply over the stacked ``[W, ...]`` parameter tree),
* per-worker epsilon-greedy selection (each worker keeps its own RNG
  stream, so fleet-stepping reproduces the per-worker sequential rollout
  transition-for-transition),
* ONE ``PropertyService.predict`` over all chosen successors fleet-wide
  (bigger predictor buckets, fewer recompiles),
* replay-buffer writes threaded through per worker.

Acting cost is therefore O(1) jit dispatches per step instead of O(W).
``BatchedEnv``/``MoleculeEnv`` (core/env.py) are thin single-worker
adapters over this engine, so the MolDQN-style APIs keep working.

Two step implementations share every helper:

``step()``            the CORRECTNESS REFERENCE.  Strictly sequential:
                      enumerate -> Q dispatch -> select -> property batch
                      -> transitions -> enumerate next.  Driven by a DENSE
                      policy this defines correctness; every other acting
                      path (``step_pipelined``, the packed/async policy
                      protocols, the sharded trainer views) is pinned
                      transition-identical to it by tests/test_rollout.py
                      — change it first, then make the fast paths match.
``step_pipelined()``  the same transition stream, but step t+1's candidate
                      enumeration + fingerprinting runs on host threads
                      WHILE step t's property batch runs on device (the two
                      only depend on step t's selected actions, not on each
                      other).  Bit-identical because per-slot enumeration is
                      pure and the chunked fingerprint batch is
                      composition-independent (pinned by
                      test_chunked_fingerprints_bit_identical).

Ragged fleets are supported: workers may own different slot counts, slots
may finish episodes at different steps, and a slot whose molecule has NO
valid candidate actions dies cleanly — its in-flight transition is
completed with an empty successor set (the double-DQN max treats that as a
zero-value terminal) and flushed, and the slot stops acting.  None of this
changes jit shapes: dead slots simply drop out of the dense batch rows.
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.chem.actions import Action, enumerate_actions
from repro.chem.chemcache import ChemCache, molecule_signature
from repro.chem.fingerprint import (
    FP_BITS, batch_morgan_fingerprints, incremental_fingerprints_grouped,
    pack_fps)
from repro.chem.molecule import ALLOWED_RING_SIZES, Molecule
from repro.core.faults import FaultError, Incident, TransientFault
from repro.core.replay import FP_BYTES, ReplayBuffer, Transition, unpack_fp
from repro.core.reward import (
    CompiledObjective, ObjectiveSpec, RewardConfig, evaluate_rewards)

STATE_DIM = FP_BITS + 1  # fingerprint ++ steps-left feature

# candidate-chemistry paths (see RolloutEngine):
#   "full"         enumerate + full fingerprint recompute every step — the
#                  seed behaviour, kept as the pinned reference
#   "incremental"  shared-parent batched incremental fingerprints + the
#                  fleet-wide ChemCache (canonical-key memo of action set +
#                  packed fingerprints); transition streams are pinned
#                  bit-identical to "full" by tests/test_rollout.py
CHEM_MODES = ("full", "incremental")


@dataclass(frozen=True)
class EnvConfig:
    max_steps: int = 10                       # Table 3
    max_atoms: int = 38
    allow_removal: bool = True
    protect_oh: bool = True                   # §3.3
    allowed_ring_sizes: frozenset = ALLOWED_RING_SIZES


@dataclass
class StepRecord:
    """What one molecule produced in one environment step."""
    slot: int
    molecule: Molecule
    reward: float
    done: bool
    conformer_valid: bool
    bde: float | None
    ip: float | None
    worker: int = 0


@dataclass(eq=False)
class Slot:
    """One molecule episode; ``index`` is its position in the worker's
    modification batch (stored once — no identity scans per record)."""
    worker: int
    index: int
    initial: Molecule
    current: Molecule
    steps_left: int
    candidates: Sequence[Action] = field(default_factory=list)
    cand_fps: np.ndarray | None = None        # f32[C, FP_BITS] (no steps col)
    cand_fps_packed: np.ndarray | None = None  # u8[C, FP_BITS/8] (same rows)
    pending: Transition | None = None         # waiting for next-state candidates
    best: tuple[float, Molecule] | None = None
    # per-slot reward override (a serving request's objective); ``None``
    # falls back to the fleet-wide reward_cfg passed to step()
    objective: object | None = None

    def steps_frac(self, max_steps: int) -> float:
        return self.steps_left / max_steps


@runtime_checkable
class FleetPolicy(Protocol):
    """What the engine needs from the acting side.

    ``fleet_q_values`` receives one stacked state matrix per worker
    (``f32[N_w, STATE_DIM]``, possibly empty) and must evaluate ALL of
    them in a single jit dispatch, returning one ``f32[N_w]`` per worker.
    ``select_action`` draws from the given worker's RNG stream.

    A policy may additionally opt into the PACKED acting protocol by
    exposing ``wants_packed_states = True``: the engine then never builds
    the dense f32 state matrices and instead hands over the per-worker
    ``u8[N_w, FP_BITS/8]`` bit planes + ``f32[N_w]`` steps-left columns
    through ``fleet_q_values_packed``.  With ``async_q = True`` on top,
    the engine splits the dispatch (``fleet_q_dispatch_packed`` returns a
    handle without blocking; ``fleet_q_fetch`` blocks) and pre-draws the
    eps-greedy decisions through ``plan_action(n_candidates, worker)``
    while the device computes — ``plan_action`` must consume the worker's
    RNG stream exactly like ``select_action`` would (one uniform; plus
    the integer draw on the explore branch) and return the explored index
    or -1, in which case the engine resolves the greedy branch as
    ``int(np.argmax(q))`` once the Q values land.  Both packed protocols
    are pinned bit-identical to this dense one by tests/test_rollout.py.
    """

    def fleet_q_values(self, per_worker: Sequence[np.ndarray]) -> list[np.ndarray]: ...

    def select_action(self, q: np.ndarray, worker: int) -> int: ...


class AgentFleetPolicy:
    """Adapts a single-model agent (``q_values``/``select_action``) to the
    fleet interface: shared parameters, so the fleet call is one flat batch."""

    def __init__(self, agent):
        self.agent = agent

    def fleet_q_values(self, per_worker: Sequence[np.ndarray]) -> list[np.ndarray]:
        lens = [x.shape[0] for x in per_worker]
        flat = np.concatenate([x for x in per_worker if x.shape[0]], axis=0) \
            if any(lens) else np.zeros((0, STATE_DIM), np.float32)
        q = self.agent.q_values(flat) if flat.shape[0] else np.zeros((0,), np.float32)
        out, off = [], 0
        for ln in lens:
            out.append(q[off:off + ln])
            off += ln
        return out

    def select_action(self, q: np.ndarray, worker: int) -> int:
        return self.agent.select_action(q)


def as_fleet_policy(obj) -> FleetPolicy:
    if isinstance(obj, FleetPolicy):
        return obj
    return AgentFleetPolicy(obj)


# row marker of the fleet reward layer: the slot's objective raised while
# evaluating this row — the slot quarantines (Incident site "reward"), its
# co-batched neighbours keep their rewards
_REWARD_FAULT = object()


@dataclass(frozen=True)
class _EnumFailure:
    """Sentinel a failed per-molecule chemistry computation returns instead
    of a ``(actions, fps, packed)`` tuple — the quarantine signal that
    travels through the enumeration batch without poisoning its siblings."""
    key: str       # molecule canonical key
    error: str     # repr of the terminal exception


class RolloutEngine:
    """Advances W workers' slot batches in lockstep, fleet-batched.

    The engine itself is deterministic: all action stochasticity comes from
    the policy's per-worker RNG streams (``FleetPolicy.select_action``).
    ``pipeline_threads`` sizes the host thread pool used only by
    ``step_pipelined``.
    """

    def __init__(self, worker_molecules: Sequence[Sequence[Molecule]],
                 cfg: EnvConfig | None = None, pipeline_threads: int | None = None,
                 chem: str = "full", chem_cache: ChemCache | None = None,
                 pad_workers_to: int | None = None, packed_states: bool = False,
                 fault_plan=None, chem_retries: int = 2):
        if chem not in CHEM_MODES:
            raise ValueError(f"chem must be one of {CHEM_MODES}, got {chem!r}")
        self.cfg = cfg if cfg is not None else EnvConfig()
        self.chem = chem
        # packed acting: every consumer reads Slot.cand_fps_packed, so chem
        # may skip rebuilding dense f32 rows for cache hits (cand_fps stays
        # None) — the fleet-mode contract that no dense f32 candidate
        # buffer is ever materialised on the host (ROADMAP invariants)
        self.packed_states = packed_states
        # the cache may be shared fleet-wide (the trainer hands the same
        # instance to every engine/env it builds)
        self.chem_cache = chem_cache if chem_cache is not None else \
            (ChemCache() if chem == "incremental" else None)
        self.worker_initials = [list(ms) for ms in worker_molecules]
        self.n_live_workers = len(self.worker_initials)
        # mesh padding: DEAD workers own no molecules, contribute zero-row
        # state matrices to every dense batch, and never touch a buffer —
        # how a fleet that does not divide the device count tiles the mesh
        # without changing any live worker's transitions (PR-2's ragged
        # zero-slot semantics, promoted to whole workers)
        if pad_workers_to is not None:
            if pad_workers_to < self.n_live_workers:
                raise ValueError(
                    f"pad_workers_to={pad_workers_to} < {self.n_live_workers} live workers")
            self.worker_initials += [
                [] for _ in range(pad_workers_to - self.n_live_workers)]
        self.n_workers = len(self.worker_initials)
        # per-worker default objectives (the heterogeneous-scenario fleet):
        # stamped onto every Slot at reset(); None falls through to the
        # reward_cfg argument of step()/run_episode().  A serving bind_slot
        # objective still wins per slot.
        self.worker_objectives: list[object | None] = [None] * self.n_workers
        # lazy (worker, spec-or-name) -> CompiledObjective memo for raw
        # ObjectiveSpec / registry-name objectives handed straight to the
        # engine — per-WORKER instances, never shared (the novelty term's
        # counts are worker-scoped state)
        self._compiled_objectives: dict[tuple[int, object], CompiledObjective] = {}
        self.workers: list[list[Slot]] = []
        self.n_env_steps = 0
        self.chem_enum_s = 0.0   # host seconds in candidate enumeration
        self.chem_fp_s = 0.0     # host seconds in candidate fingerprints
        self._stats_lock = threading.Lock()  # pipelined threads accumulate
        # self-healing: a slot whose chem/property path raises a terminal
        # FaultError drains to dead under quarantine (empty successor set,
        # structured Incident record) and is revived from the worker's
        # start assignment at the next episode boundary (run_episode ->
        # reset()); transient chem faults are retried in place
        self.fault_plan = fault_plan
        self.chem_retries = int(chem_retries)
        self.incidents: list[Incident] = []
        self.episode_counter = 0
        self.n_quarantined = 0
        self.n_chem_retries = 0
        self.n_pipeline_restarts = 0
        self._enumerated = False
        # leave a core for the main thread (property featurize + the XLA
        # dispatch): oversubscribing a small host makes the overlap a loss
        self._pipeline_threads = pipeline_threads or \
            max(1, min(4, (os.cpu_count() or 2) - 1))
        self._pool: ThreadPoolExecutor | None = None  # built on first pipelined step
        self.reset()

    # ------------------------------------------------------------ #
    def set_initial_molecules(
            self, worker_molecules: Sequence[Sequence[Molecule]]) -> None:
        """Swap every LIVE worker's start molecules — the multi-start
        dataset stream's per-episode assignment.  Mesh-padding (dead)
        workers keep their empty slots.  Takes effect at the next
        ``reset()``; ``run_episode`` resets first, so the trainer can
        re-seed starts right before each episode."""
        if len(worker_molecules) != self.n_live_workers:
            raise ValueError(
                f"expected {self.n_live_workers} live workers' molecule "
                f"batches, got {len(worker_molecules)}")
        pad = self.worker_initials[self.n_live_workers:]
        self.worker_initials = [list(ms) for ms in worker_molecules] + pad

    def set_worker_objectives(self, objectives: Sequence[object | None]) -> None:
        """Install per-worker default objectives (the scenario mix): one
        entry per LIVE worker — a ``RewardConfig``, ``ObjectiveSpec``,
        compiled objective, callable, or ``None`` (fall through to the
        fleet-wide ``reward_cfg``).  Takes effect on current slots and at
        every subsequent ``reset()``; mesh-padding workers stay ``None``."""
        objectives = list(objectives)
        if len(objectives) != self.n_live_workers:
            raise ValueError(
                f"expected {self.n_live_workers} live workers' objectives, "
                f"got {len(objectives)}")
        self.worker_objectives = objectives + \
            [None] * (self.n_workers - self.n_live_workers)
        for w, slots in enumerate(self.workers):
            for s in slots:
                s.objective = self.worker_objectives[w]

    def reset(self) -> None:
        self.workers = [
            [Slot(worker=w, index=i, initial=m, current=m,
                  steps_left=self.cfg.max_steps,
                  objective=self.worker_objectives[w])
             for i, m in enumerate(ms)]
            for w, ms in enumerate(self.worker_initials)
        ]
        # the enumerate+fingerprint pass is deferred to the first step():
        # run_episode resets again, and the trainer builds engines it may
        # never step (rollout="per_worker"), so eager work here is wasted
        self._enumerated = False

    @property
    def done(self) -> bool:
        return all(s.steps_left <= 0 for slots in self.workers for s in slots)

    def _live(self, w: int) -> list[Slot]:
        return [s for s in self.workers[w] if s.steps_left > 0]

    def _pad_buffers(self, buffers: Sequence[ReplayBuffer | None] | None
                     ) -> Sequence[ReplayBuffer | None] | None:
        """Accept per-LIVE-worker buffer lists on a mesh-padded engine: the
        padding workers own no slots, so they can never write a transition —
        extend the list with ``None`` instead of making every caller care
        about the padded width."""
        if buffers is None or len(buffers) == self.n_workers:
            return buffers
        if len(buffers) != self.n_live_workers:
            raise ValueError(
                f"expected {self.n_live_workers} (live) or {self.n_workers} "
                f"(padded) buffers, got {len(buffers)}")
        return list(buffers) + [None] * (self.n_workers - self.n_live_workers)

    def _get_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._pipeline_threads,
                thread_name_prefix="rollout-enum")
        return self._pool

    # ------------------------------------------------------------ #
    # candidate enumeration + fingerprinting
    # ------------------------------------------------------------ #
    def _enumerate_one(self, m: Molecule) -> list[Action]:
        return enumerate_actions(
            m,
            allow_removal=self.cfg.allow_removal,
            protect_oh=self.cfg.protect_oh,
            allowed_ring_sizes=self.cfg.allowed_ring_sizes,
            max_atoms=self.cfg.max_atoms,
        )

    def _record_incident(self, *, site: str, worker: int, slot: int,
                         key: str, error: str, action: str) -> None:
        with self._stats_lock:
            self.incidents.append(Incident(
                episode=self.episode_counter, step=self.n_env_steps,
                site=site, worker=worker, slot=slot, key=key,
                error=error, action=action))

    def _enum_or_failure(self, m: Molecule):
        """``_enumerate_one`` under the fault plan: retries transient chem
        faults in place (bit-identical — enumeration is pure), degrades a
        terminal fault to an :class:`_EnumFailure` sentinel instead of
        letting one molecule sink the whole batch.  Thread-safe and
        thread-order independent: injection keys on molecule content."""
        if self.fault_plan is None:
            return self._enumerate_one(m)
        key = m.canonical_key()
        attempt = 0
        while True:
            try:
                self.fault_plan.check_key("chem", key)
                return self._enumerate_one(m)
            except FaultError as e:
                return _EnumFailure(key=key, error=repr(e))
            except TransientFault as e:
                if attempt >= self.chem_retries:
                    return _EnumFailure(key=key, error=repr(e))
                attempt += 1
                with self._stats_lock:
                    self.n_chem_retries += 1

    def _quarantine(self, s: Slot, *, site: str, key: str, error: str) -> None:
        """Drain a faulted slot to dead: empty candidate set, in-flight
        transition completed with an empty successor (the double-DQN max
        values it at zero — identical to the no-legal-action death), and a
        structured incident on the operator trail.  The slot revives from
        the worker's start assignment at the next ``reset()``."""
        s.candidates = []
        s.cand_fps = np.zeros((0, FP_BITS), np.float32)
        s.cand_fps_packed = np.zeros((0, FP_BYTES), np.uint8)
        if s.pending is not None:
            s.pending.next_fps = s.cand_fps_packed
            s.pending.next_steps_left_frac = (s.steps_left - 1) / self.cfg.max_steps
        s.steps_left = 0
        with self._stats_lock:
            self.n_quarantined += 1
        self._record_incident(site=site, worker=s.worker, slot=s.index,
                              key=key, error=error, action="quarantined")

    def _compute_enum(self, mols: Sequence[Molecule]
                      ) -> list[tuple[Sequence[Action], np.ndarray, np.ndarray]]:
        """Pure per-molecule work: candidate actions, their fingerprints
        (dense f32 rows for the Q states) and the SAME rows bit-packed (what
        the replay successor sets store).  Thread-safe (reads molecules,
        builds fresh ones; the chem cache locks internally); per-slot
        results do not depend on how the molecule list is sharded across
        calls — cache hits return values identical to a fresh compute.
        """
        if self.chem == "incremental":
            return self._compute_enum_incremental(mols)
        t0 = time.perf_counter()
        cands = [self._enum_or_failure(m) for m in mols]
        t1 = time.perf_counter()
        # the full path materialises every candidate and recomputes every
        # fingerprint from scratch — the pinned reference behaviour.
        # Failed molecules carry their sentinel through; their siblings'
        # fingerprint batch is unchanged (composition-independent).
        flat = [a.result for acts in cands
                if not isinstance(acts, _EnumFailure) for a in acts]
        fps = batch_morgan_fingerprints(flat) if flat else \
            np.zeros((0, FP_BITS), np.float32)
        packed = pack_fps(fps)
        t2 = time.perf_counter()
        with self._stats_lock:
            self.chem_enum_s += t1 - t0
            self.chem_fp_s += t2 - t1
        out, off = [], 0
        for acts in cands:
            if isinstance(acts, _EnumFailure):
                out.append(acts)
                continue
            out.append((acts, fps[off:off + len(acts)],
                        packed[off:off + len(acts)]))
            off += len(acts)
        return out

    def _compute_enum_incremental(self, mols: Sequence[Molecule]
                                  ) -> list[tuple[Sequence[Action], np.ndarray, np.ndarray]]:
        """The tentpole path: fleet-wide ChemCache lookups short-circuit the
        whole per-parent chemistry; misses enumerate (delta descriptors) and
        derive all candidate fingerprints from ONE shared parent env-hash
        table per slot, batched across the miss slots."""
        cache = self.chem_cache
        t0 = time.perf_counter()
        out: list = [None] * len(mols)
        miss: list[int] = []
        for i, m in enumerate(mols):
            entry = cache.get(m) if cache is not None else None
            if entry is not None:
                out[i] = (entry.actions, None, entry.packed_fps)
            else:
                miss.append(i)
        # in-batch dedup (the PropertyService idiom): workers sharing a
        # concrete parent — e.g. every slot at episode start — enumerate it
        # ONCE per step and share the (immutable) results
        uniq: list[int] = []
        rep_of: dict[bytes, int] = {}
        dup_of: dict[int, int] = {}
        for i in miss:
            sig = molecule_signature(mols[i])
            if sig in rep_of:
                dup_of[i] = rep_of[sig]
            else:
                rep_of[sig] = i
                uniq.append(i)
        acts_by = [self._enum_or_failure(mols[i]) for i in uniq]
        t1 = time.perf_counter()
        # failed molecules keep their sentinel; only intact ones enter the
        # grouped fingerprint batch and the cache (all-or-nothing put)
        good = [(i, acts) for i, acts in zip(uniq, acts_by)
                if not isinstance(acts, _EnumFailure)]
        for i, acts in zip(uniq, acts_by):
            if isinstance(acts, _EnumFailure):
                out[i] = acts
        if good:
            fps_by = incremental_fingerprints_grouped(
                [mols[i] for i, _ in good], [acts for _, acts in good])
            for (i, acts), fps in zip(good, fps_by):
                packed = pack_fps(fps)
                if cache is not None:
                    cache.put(mols[i], acts, packed)
                out[i] = (acts, fps, packed)
        for i, rep in dup_of.items():
            out[i] = out[rep]   # duplicates share results AND failures
        # cache hits rebuild the dense rows from the packed bits (exact:
        # the fingerprints are {0,1}-valued) — unless the engine runs
        # packed acting, where nothing ever reads the dense rows and the
        # unpack would be the hot path's only host f32 materialisation
        if not self.packed_states:
            out = [res if isinstance(res, _EnumFailure) else
                   (res[0], unpack_fp(res[2]) if res[1] is None else res[1],
                    res[2])
                   for res in out]
        t2 = time.perf_counter()
        with self._stats_lock:
            self.chem_enum_s += t1 - t0
            self.chem_fp_s += t2 - t1
        return out

    def _apply_enum(self, slots: Sequence[Slot],
                    results: Sequence[tuple[Sequence[Action], np.ndarray, np.ndarray]]
                    ) -> None:
        """Install fresh candidate sets; complete pending transitions; kill
        slots with no legal action (their pending gets an empty successor
        set, which the double-DQN max values at zero).  A slot whose
        chemistry failed terminally (``_EnumFailure``) is quarantined —
        same empty-successor death, plus an incident record."""
        for s, res in zip(slots, results, strict=True):
            if isinstance(res, _EnumFailure):
                self._quarantine(s, site="chem", key=res.key, error=res.error)
                continue
            acts, fps, packed = res
            s.candidates = acts
            s.cand_fps = fps
            s.cand_fps_packed = packed
            if s.pending is not None:
                # successor candidates are exactly this step's candidates;
                # the packed rows are shared with the slot (replay copies)
                s.pending.next_fps = packed
                s.pending.next_steps_left_frac = (s.steps_left - 1) / self.cfg.max_steps
            if not acts:
                s.steps_left = 0  # nothing to act on: the episode ends here

    def _enumerate_all(self) -> None:
        """One candidate-enumeration + ONE fingerprint batch over every live
        slot of every worker (the reference, single-threaded pass)."""
        todo = [s for slots in self.workers for s in slots if s.steps_left > 0]
        if todo:
            self._apply_enum(todo, self._compute_enum([s.current for s in todo]))

    # ------------------------------------------------------------ #
    # step helpers shared by the reference and pipelined paths
    # ------------------------------------------------------------ #
    def _flush_ready(self, live_by_worker: Sequence[Sequence[Slot]],
                     buffers: Sequence[ReplayBuffer | None] | None) -> None:
        """Move completed pending transitions into the per-worker buffers."""
        if buffers is None:
            return
        for w, live in enumerate(live_by_worker):
            buf = buffers[w]
            if buf is None:
                continue
            ready = [s for s in live
                     if s.pending is not None and s.pending.next_fps is not None]
            buf.add_many(s.pending for s in ready)
            for s in ready:
                s.pending = None

    def _flush_dead(self, buffers: Sequence[ReplayBuffer | None] | None) -> None:
        """Flush completed pendings of slots that died mid-episode (no legal
        candidates) — no later step will ever visit them again."""
        if buffers is None:
            return
        for w, slots in enumerate(self.workers):
            buf = buffers[w]
            for s in slots:
                if (s.steps_left <= 0 and s.pending is not None
                        and s.pending.next_fps is not None):
                    if buf is not None:
                        buf.add(s.pending)
                    s.pending = None

    def _build_states(self, live_by_worker: Sequence[Sequence[Slot]]
                      ) -> list[np.ndarray]:
        """Per-worker candidate state matrices (fingerprint ++ steps-left)."""
        per_worker_states: list[np.ndarray] = []
        for live in live_by_worker:
            if not live:
                per_worker_states.append(np.zeros((0, STATE_DIM), np.float32))
                continue
            stacked = []
            for s in live:
                steps_after = (s.steps_left - 1) / self.cfg.max_steps
                col = np.full((s.cand_fps.shape[0], 1), steps_after, dtype=np.float32)
                stacked.append(np.concatenate([s.cand_fps, col], axis=1))
            per_worker_states.append(np.concatenate(stacked, axis=0))
        return per_worker_states

    def _build_states_packed(self, live_by_worker: Sequence[Sequence[Slot]]
                             ) -> tuple[list[np.ndarray], list[np.ndarray]]:
        """Per-worker PACKED candidate states, straight from the slots'
        ``pack_fps`` planes: u8 ``[N_w, FP_BITS/8]`` bits + f32 ``[N_w]``
        steps-left columns.  The packed twin of ``_build_states`` — no
        dense f32 fingerprint buffer is materialised on the host (~32x
        fewer bytes per candidate row)."""
        bits_pw: list[np.ndarray] = []
        frac_pw: list[np.ndarray] = []
        for live in live_by_worker:
            if not live:
                bits_pw.append(np.zeros((0, FP_BYTES), np.uint8))
                frac_pw.append(np.zeros((0,), np.float32))
                continue
            bits_pw.append(live[0].cand_fps_packed if len(live) == 1 else
                           np.concatenate([s.cand_fps_packed for s in live]))
            frac_pw.append(np.concatenate([
                np.full((len(s.candidates),),
                        (s.steps_left - 1) / self.cfg.max_steps, np.float32)
                for s in live]))
        return bits_pw, frac_pw

    def _plan_selection(self, live_by_worker: Sequence[Sequence[Slot]],
                        policy) -> list[list[int]]:
        """Pre-draw every slot's eps-greedy decision (``plan_action``: the
        explored index, or -1 for argmax-when-Q-lands) in the reference
        worker-major slot order — the host-side half of action selection,
        run while the async Q dispatch is still in flight on device."""
        return [[policy.plan_action(len(s.candidates), w) for s in live]
                for w, live in enumerate(live_by_worker)]

    def _dispatch_q(self, live_by_worker: Sequence[Sequence[Slot]],
                    policy) -> tuple[Sequence[np.ndarray], list[list[int]] | None]:
        """One fleet Q dispatch in the policy's preferred representation
        (dense f32 reference, packed u8, or packed + pre-drawn plans)."""
        if getattr(policy, "wants_packed_states", False):
            bits_pw, frac_pw = self._build_states_packed(live_by_worker)
            if getattr(policy, "async_q", False):
                handle = policy.fleet_q_dispatch_packed(bits_pw, frac_pw)
                plans = self._plan_selection(live_by_worker, policy)
                return policy.fleet_q_fetch(handle), plans
            return policy.fleet_q_values_packed(bits_pw, frac_pw), None
        return policy.fleet_q_values(self._build_states(live_by_worker)), None

    def _select(self, live_by_worker: Sequence[Sequence[Slot]],
                q_by_worker: Sequence[np.ndarray], policy: FleetPolicy,
                plans: Sequence[Sequence[int]] | None = None
                ) -> list[tuple[Slot, Action, np.ndarray]]:
        """Per-worker eps-greedy selection from each worker's RNG stream.

        With ``plans`` (the async path) the RNG draws already happened in
        this exact slot order during ``_plan_selection``; only the greedy
        markers (-1) are resolved here, from the same ``np.argmax`` the
        sync branch uses.  The chosen tuple carries the PACKED fingerprint
        row — it becomes the replay ``state_fp`` without a repack."""
        chosen: list[tuple[Slot, Action, np.ndarray]] = []
        for w, live in enumerate(live_by_worker):
            q_all, off = q_by_worker[w], 0
            for i, s in enumerate(live):
                ln = len(s.candidates)
                if ln == 0:  # _apply_enum kills candidate-less slots
                    raise RuntimeError(
                        f"invariant violation: live slot (worker {w}, index "
                        f"{s.index}) reached selection with zero candidates")
                if plans is None:
                    a_idx = policy.select_action(q_all[off:off + ln], w)
                else:
                    a_idx = plans[w][i]
                    if a_idx < 0:
                        a_idx = int(np.argmax(q_all[off:off + ln]))
                off += ln
                chosen.append((s, s.candidates[a_idx], s.cand_fps_packed[a_idx]))
        return chosen

    def _predict_chosen(self, service, chosen):
        """Fleet property batch with per-molecule fault isolation.  The
        happy path is ONE ``service.predict`` over all chosen successors —
        bit-identical to the reference.  If that batch fails terminally
        (retries exhausted), each molecule is retried in isolation so one
        poisoned successor quarantines one slot, not the fleet; failed rows
        come back as ``None``."""
        mols = [a.result for _, a, _ in chosen]
        try:
            return service.predict(mols)
        except FaultError:
            props = []
            for (s, a, _), m in zip(chosen, mols, strict=True):
                try:
                    props.append(service.predict([m])[0])
                except FaultError as e:
                    props.append(None)
                    self._record_incident(
                        site="predict", worker=s.worker, slot=s.index,
                        key=m.canonical_key(), error=repr(e),
                        action="quarantined")
            return props

    def _resolve_objective(self, obj, worker: int):
        """Normalise a slot/fleet objective to what the reward layer
        evaluates: ``RewardConfig`` and callables (compiled objectives
        included) pass through; a raw ``ObjectiveSpec`` or a scenario
        registry NAME compiles lazily, memoised PER WORKER so the novelty
        term's visit counts persist across steps without leaking between
        workers."""
        if obj is None or isinstance(obj, (RewardConfig, CompiledObjective)):
            return obj
        if isinstance(obj, ObjectiveSpec) or isinstance(obj, str):
            key = (worker, obj)
            hit = self._compiled_objectives.get(key)
            if hit is None:
                spec = obj
                if isinstance(obj, str):
                    from repro.configs.scenarios import get_scenario
                    spec = get_scenario(obj)
                hit = spec.compile()
                self._compiled_objectives[key] = hit
            return hit
        return obj

    def _reward_or_fault(self, obj, pr, initial, current, steps_left: int,
                         s: Slot):
        """One row through an arbitrary objective, isolated: a raising
        objective yields the ``_REWARD_FAULT`` marker plus a structured
        Incident instead of crashing the fleet (the slot quarantines in
        ``_apply_step``)."""
        try:
            return float(obj(pr, initial, current, steps_left))
        except Exception as e:  # noqa: BLE001 - user objectives raise anything
            self._record_incident(
                site="reward", worker=s.worker, slot=s.index,
                key=current.canonical_key(), error=repr(e),
                action="quarantined")
            return _REWARD_FAULT

    def _fleet_rewards(self, chosen, props, reward_cfg) -> list:
        """THE fleet-vectorized reward layer: one NumPy evaluation over
        the step's ``[W]`` property/state rows per distinct objective.

        Rows whose property row is ``None`` (terminal predict fault) are
        masked out — their slots quarantine in ``_apply_step``.  The
        remaining rows group by their RESOLVED objective (the slot's own
        ``Slot.objective`` wins over the fleet-wide ``reward_cfg``): a
        homogeneous fleet is exactly ONE ``evaluate_rewards`` call, a
        mixed fleet one vectorized call per scenario.  Per-group inputs
        keep the reference worker-major row order, so the stateful
        novelty term sees the same visit sequence as the scalar path.

        Returns one entry per chosen row: a float reward, ``None`` for a
        masked predict-fault row, or ``_REWARD_FAULT`` when the objective
        itself raised (satellite of the self-healing contract: a broken
        CUSTOM objective quarantines its slot, never the fleet)."""
        rewards: list = [None] * len(chosen)
        groups: dict[int, tuple[object, list[int]]] = {}
        for i, ((s, _act, _fp), pr) in enumerate(zip(chosen, props, strict=True)):
            if pr is None:
                continue
            obj = self._resolve_objective(
                s.objective if s.objective is not None else reward_cfg,
                s.worker)
            groups.setdefault(id(obj), (obj, []))[1].append(i)
        for obj, idx in groups.values():
            rows = [chosen[i] for i in idx]
            prs = [props[i] for i in idx]
            initials = [s.initial for s, _, _ in rows]
            # the reward sees the POST-step state: the chosen successor and
            # the decremented step budget (Action.result is memoised — this
            # is the very molecule _apply_step installs as s.current)
            currents = [a.result for _, a, _ in rows]
            sls = [s.steps_left - 1 for s, _, _ in rows]
            if isinstance(obj, RewardConfig):
                vals = evaluate_rewards(obj, prs, initials, currents, sls)
                for k, i in enumerate(idx):
                    rewards[i] = float(vals[k])
            elif isinstance(obj, CompiledObjective):
                try:
                    vals = obj.evaluate(prs, initials, currents, sls)
                except Exception:  # noqa: BLE001 - isolate the poisoned row
                    # re-run per row against consistent state (evaluate
                    # mutates nothing on a raise): only the poisoned rows
                    # quarantine, their group neighbours keep rewards
                    for k, i in enumerate(idx):
                        rewards[i] = self._reward_or_fault(
                            obj, prs[k], initials[k], currents[k], sls[k],
                            rows[k][0])
                else:
                    for k, i in enumerate(idx):
                        rewards[i] = float(vals[k])
            else:
                # arbitrary callable objective: per-row, isolated
                for k, i in enumerate(idx):
                    rewards[i] = self._reward_or_fault(
                        obj, prs[k], initials[k], currents[k], sls[k],
                        rows[k][0])
        return rewards

    def _apply_step(self, chosen, props, reward_cfg,
                    buffers) -> list[StepRecord]:
        """Commit the chosen actions: rewards, transitions, slot advance.
        A ``None`` property row (terminal predict fault, isolated by
        ``_predict_chosen``) quarantines its slot: no transition, no step
        record, episode over — revived at the next reset.  A
        ``_REWARD_FAULT`` row (the slot's objective raised inside the
        fleet reward layer) quarantines identically, with its
        ``site="reward"`` Incident already on the trail."""
        records: list[StepRecord] = []
        rewards = self._fleet_rewards(chosen, props, reward_cfg)
        for (s, act, fp), pr, reward in zip(chosen, props, rewards, strict=True):
            if pr is None:
                # the pending (if any) was already flushed at _begin_step,
                # so draining here loses no committed transition
                s.steps_left = 0
                with self._stats_lock:
                    self.n_quarantined += 1
                continue
            if reward is _REWARD_FAULT:
                s.steps_left = 0
                with self._stats_lock:
                    self.n_quarantined += 1
                continue
            s.current = act.result
            s.steps_left -= 1
            done = s.steps_left <= 0
            if s.best is None or reward > s.best[0]:
                s.best = (reward, s.current)
            t = Transition(
                # the chosen candidate's ALREADY-packed row (chem packed it
                # once, pack_fps contract) — no per-transition repack
                state_fp=fp,
                steps_left_frac=s.steps_left / self.cfg.max_steps,
                reward=reward,
                done=done,
                next_fps=np.zeros((0, FP_BYTES), dtype=np.uint8),
                next_steps_left_frac=0.0,
            )
            if done:
                buf = buffers[s.worker] if buffers is not None else None
                if buf is not None:
                    buf.add(t)               # terminal: no successor needed
            else:
                t.next_fps = None            # filled by the next enumerate
                s.pending = t
            records.append(StepRecord(
                slot=s.index, molecule=s.current, reward=reward,
                done=done, conformer_valid=pr.conformer_valid,
                bde=pr.bde, ip=pr.ip, worker=s.worker,
            ))
        return records

    def _begin_step(self, buffers) -> list[list[Slot]] | None:
        """Common step prologue: first-use enumeration, liveness, flush."""
        if not self._enumerated:
            self._enumerate_all()
            self._enumerated = True
        live_by_worker = [self._live(w) for w in range(self.n_workers)]
        if not any(live_by_worker):
            return None
        self.n_env_steps += 1
        self._flush_ready(live_by_worker, buffers)
        return live_by_worker

    # ------------------------------------------------------------ #
    def step(
        self,
        policy,
        service,
        reward_cfg: "RewardConfig | ObjectiveSpec | object",
        buffers: Sequence[ReplayBuffer | None] | None = None,
    ) -> list[StepRecord]:
        """One lockstep step for every live slot of every worker.

        This is the CORRECTNESS REFERENCE implementation — strictly
        sequential, no overlap.  ``step_pipelined`` must stay
        transition-identical to it (tests/test_rollout.py)."""
        policy = as_fleet_policy(policy)
        buffers = self._pad_buffers(buffers)
        live_by_worker = self._begin_step(buffers)
        if live_by_worker is None:
            return []

        # ---- ONE Q dispatch over all candidates of all workers -------- #
        q_by_worker, plans = self._dispatch_q(live_by_worker, policy)

        # ---- per-worker eps-greedy selection --------------------------- #
        chosen = self._select(live_by_worker, q_by_worker, policy, plans)

        # ---- ONE property batch over the chosen successors fleet-wide -- #
        props = self._predict_chosen(service, chosen)

        records = self._apply_step(chosen, props, reward_cfg, buffers)
        self._enumerate_all()
        self._flush_dead(buffers)
        return records

    def _enum_shard(self, mols: Sequence[Molecule]):
        """One pipelined shard, run on a pool thread.  The fault plan's
        ``pipeline`` site models the thread itself dying mid-shard."""
        if self.fault_plan is not None:
            self.fault_plan.check_call("pipeline")
        return self._compute_enum(mols)

    def _submit_enum(self, pairs: Sequence[tuple[Slot, Molecule]]) -> list:
        """Shard ``(slot, successor)`` chemistry across the host pool.
        Returns ``(future, shard_molecules)`` pairs so the supervisor
        (``_collect_enum``) can re-run a crashed shard inline."""
        if not pairs:
            return []
        pool = self._get_pool()
        mols = [m for _, m in pairs]
        shard = -(-len(mols) // self._pipeline_threads)
        return [(pool.submit(self._enum_shard, mols[i:i + shard]),
                 mols[i:i + shard])
                for i in range(0, len(mols), shard)]

    def _collect_enum(self, shards) -> list:
        """Supervised harvest of the pipelined shards: a shard whose thread
        died (injected ``pipeline`` fault) is re-run inline on the calling
        thread — per-shard chemistry is composition-independent and pure,
        so the restarted results are bit-identical to what the dead thread
        would have produced."""
        results: list = []
        for fut, mols in shards:
            try:
                results.extend(fut.result())
            except (TransientFault, FaultError) as e:
                with self._stats_lock:
                    self.n_pipeline_restarts += 1
                self._record_incident(
                    site="pipeline", worker=-1, slot=-1, key="",
                    error=repr(e), action="restarted")
                results.extend(self._compute_enum(mols))
        return results

    def step_pipelined(
        self,
        policy,
        service,
        reward_cfg: "RewardConfig | ObjectiveSpec | object",
        buffers: Sequence[ReplayBuffer | None] | None = None,
    ) -> list[StepRecord]:
        """``step()`` with the host/device overlap: after action selection,
        step t+1's candidate enumeration + fingerprinting is sharded across
        host threads while the fleet property batch runs.  Both depend only
        on the selected actions, not on each other, so the transition
        stream is identical to the reference.

        With an ``async_q`` packed policy the overlap additionally covers
        the Q round-trip itself: the dispatch returns a device handle
        without blocking, the eps-greedy decisions are pre-drawn
        (``_plan_selection``, identical RNG order), and the EXPLORING
        survivors' next-step chemistry — their successors are known before
        any Q value is — starts on the pool while the device still
        computes.  Only then does the fetch block.  Per-slot chemistry
        results are composition-independent (pinned by the chem matrix),
        so splitting the enumeration batch changes nothing downstream."""
        policy = as_fleet_policy(policy)
        buffers = self._pad_buffers(buffers)
        live_by_worker = self._begin_step(buffers)
        if live_by_worker is None:
            return []

        early: list[tuple[Slot, Molecule]] = []
        if getattr(policy, "wants_packed_states", False) and \
                getattr(policy, "async_q", False):
            bits_pw, frac_pw = self._build_states_packed(live_by_worker)
            handle = policy.fleet_q_dispatch_packed(bits_pw, frac_pw)
            plans = self._plan_selection(live_by_worker, policy)
            early = [(s, s.candidates[p].result)
                     for w, live in enumerate(live_by_worker)
                     for s, p in zip(live, plans[w])
                     if p >= 0 and s.steps_left - 1 > 0]
            early_futs = self._submit_enum(early)
            q_by_worker = policy.fleet_q_fetch(handle)
        else:
            q_by_worker, plans = self._dispatch_q(live_by_worker, policy)
            early_futs = []
        chosen = self._select(live_by_worker, q_by_worker, policy, plans)

        # slots still alive after this step, in the reference enumeration
        # order (worker-major, slot order); their successors' candidates are
        # what the end-of-step enumeration would compute.  Exploring slots
        # already submitted above (Action.result is memoised, so the chosen
        # molecule is the very object the early chemistry enumerated).
        early_slots = {id(s) for s, _ in early}
        nxt = [(s, a.result) for s, a, _ in chosen
               if s.steps_left - 1 > 0 and id(s) not in early_slots]
        futures = self._submit_enum(nxt)

        props = self._predict_chosen(service, chosen)
        records = self._apply_step(chosen, props, reward_cfg, buffers)

        if early_futs:
            self._apply_enum([s for s, _ in early],
                             self._collect_enum(early_futs))
        if futures:
            self._apply_enum([s for s, _ in nxt],
                             self._collect_enum(futures))
        self._flush_dead(buffers)
        return records

    # ------------------------------------------------------------ #
    def run_episode(
        self,
        policy,
        service,
        reward_cfg: "RewardConfig | ObjectiveSpec | object",
        buffers: Sequence[ReplayBuffer | None] | None = None,
        pipelined: bool = False,
    ) -> list[StepRecord]:
        """Reset + roll a full fleet episode; returns ALL step records.

        ``reset()`` is also the REVIVAL hook: slots quarantined by faults
        last episode were drained to dead, and here they are rebuilt from
        the worker's start assignment (``set_initial_molecules`` — the
        dataset cursor's per-episode draw) exactly like any other slot —
        a revived fleet is indistinguishable from a fresh one."""
        self.episode_counter += 1
        self.reset()
        step = self.step_pipelined if pipelined else self.step
        all_recs: list[StepRecord] = []
        while not self.done:
            all_recs.extend(step(policy, service, reward_cfg, buffers))
        return all_recs

    # ------------------------------------------------------------ #
    # continuous-batching slot control (the serving router's hooks)
    # ------------------------------------------------------------ #
    def bind_slot(self, worker: int, molecule: Molecule, steps_left: int,
                  objective=None) -> Slot:
        """Install a FRESH episode in one worker's slot batch without
        touching any sibling — the serving tier's continuous-batching
        rebind: a finished/dead/reclaimed slot is immediately handed the
        next queued request while co-batched slots keep stepping.

        The new slot's candidates are enumerated right here (a one-slot
        chemistry batch — per-slot chemistry is composition-independent,
        so this is bit-identical to enumerating it with the fleet), which
        means a poisoned start molecule quarantines at bind time exactly
        like a mid-episode chem fault: Incident + empty candidate set,
        siblings untouched.  ``objective`` (a ``RewardConfig`` or callable)
        overrides the fleet reward for this slot only."""
        if not 0 <= worker < self.n_live_workers:
            raise ValueError(
                f"worker {worker} out of range [0, {self.n_live_workers})")
        s = Slot(worker=worker, index=0, initial=molecule, current=molecule,
                 steps_left=int(steps_left), objective=objective)
        self.workers[worker] = [s]
        self.worker_initials[worker] = [molecule]
        if self._enumerated:
            self._apply_enum([s], self._compute_enum([molecule]))
        else:
            # first bind on a fresh engine: bring every pre-existing live
            # slot in with the same deferred pass the first step() would run
            self._enumerated = True
            self._enumerate_all()
        return s

    def kill_slot(self, worker: int) -> None:
        """Reclaim a worker's slots NOW (deadline passed, request
        cancelled): drop any in-flight transition and stop acting.  The
        dense batch simply loses the rows — jit shapes are unchanged and
        siblings never notice (the ragged-fleet contract)."""
        for s in self.workers[worker]:
            s.pending = None
            s.steps_left = 0

    # ------------------------------------------------------------ #
    def chem_stats(self) -> dict:
        """Host-chemistry accounting: enumeration / fingerprint seconds and
        (incremental mode) the fleet-wide cache hit statistics."""
        st = {
            "mode": self.chem,
            "enum_s": self.chem_enum_s,
            "fp_s": self.chem_fp_s,
            "env_steps": self.n_env_steps,
        }
        if self.chem_cache is not None:
            st.update(self.chem_cache.stats())
        return st

    def fault_stats(self) -> dict:
        """Self-healing accounting: quarantines, in-place retries,
        supervised pipeline restarts, and the structured incident trail."""
        with self._stats_lock:
            return {
                "n_quarantined": self.n_quarantined,
                "n_chem_retries": self.n_chem_retries,
                "n_pipeline_restarts": self.n_pipeline_restarts,
                "n_incidents": len(self.incidents),
                "incidents": [i.as_dict() for i in self.incidents],
            }

    def reset_chem_stats(self) -> None:
        self.chem_enum_s = 0.0
        self.chem_fp_s = 0.0
        if self.chem_cache is not None:
            self.chem_cache.reset_stats()

    # ------------------------------------------------------------ #
    def final_molecules(self, worker: int | None = None) -> list[Molecule]:
        slots = self.workers[worker] if worker is not None else \
            [s for ws in self.workers for s in ws]
        return [s.current for s in slots]

    def best_molecules(self, worker: int | None = None) -> list[tuple[float, Molecule]]:
        slots = self.workers[worker] if worker is not None else \
            [s for ws in self.workers for s in ws]
        return [s.best if s.best is not None else (-np.inf, s.current) for s in slots]
