"""Fleet-level rollout engine.

The paper's *batched modification* (§3.1) batches the candidates of the
molecules owned by ONE worker.  ``RolloutEngine`` lifts that one level up:
the unit of batching is the whole fleet.  Per environment step, across all
W workers it performs

* one candidate-enumeration + fingerprint pass over every live slot,
* ONE Q-network jit dispatch over the concatenation of every worker's
  candidate states (per-worker parameters selected inside the call via a
  vmap'd apply over the stacked ``[W, ...]`` parameter tree),
* per-worker epsilon-greedy selection (each worker keeps its own RNG
  stream, so fleet-stepping reproduces the per-worker sequential rollout
  transition-for-transition),
* ONE ``PropertyService.predict`` over all chosen successors fleet-wide
  (bigger predictor buckets, fewer recompiles),
* replay-buffer writes threaded through per worker.

Acting cost is therefore O(1) jit dispatches per step instead of O(W).
``BatchedEnv``/``MoleculeEnv`` (core/env.py) are thin single-worker
adapters over this engine, so the MolDQN-style APIs keep working.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.chem.actions import Action, enumerate_actions
from repro.chem.fingerprint import FP_BITS, batch_morgan_fingerprints
from repro.chem.molecule import ALLOWED_RING_SIZES, Molecule
from repro.core.replay import ReplayBuffer, Transition, pack_fp
from repro.core.reward import RewardConfig, compute_reward

STATE_DIM = FP_BITS + 1  # fingerprint ++ steps-left feature


@dataclass(frozen=True)
class EnvConfig:
    max_steps: int = 10                       # Table 3
    max_atoms: int = 38
    allow_removal: bool = True
    protect_oh: bool = True                   # §3.3
    allowed_ring_sizes: frozenset = ALLOWED_RING_SIZES


@dataclass
class StepRecord:
    """What one molecule produced in one environment step."""
    slot: int
    molecule: Molecule
    reward: float
    done: bool
    conformer_valid: bool
    bde: float | None
    ip: float | None
    worker: int = 0


@dataclass(eq=False)
class Slot:
    """One molecule episode; ``index`` is its position in the worker's
    modification batch (stored once — no identity scans per record)."""
    worker: int
    index: int
    initial: Molecule
    current: Molecule
    steps_left: int
    candidates: list[Action] = field(default_factory=list)
    cand_fps: np.ndarray | None = None        # f32[C, FP_BITS] (no steps col)
    pending: Transition | None = None         # waiting for next-state candidates
    best: tuple[float, Molecule] | None = None

    def steps_frac(self, max_steps: int) -> float:
        return self.steps_left / max_steps


@runtime_checkable
class FleetPolicy(Protocol):
    """What the engine needs from the acting side.

    ``fleet_q_values`` receives one stacked state matrix per worker
    (``f32[N_w, STATE_DIM]``, possibly empty) and must evaluate ALL of
    them in a single jit dispatch, returning one ``f32[N_w]`` per worker.
    ``select_action`` draws from the given worker's RNG stream.
    """

    def fleet_q_values(self, per_worker: Sequence[np.ndarray]) -> list[np.ndarray]: ...

    def select_action(self, q: np.ndarray, worker: int) -> int: ...


class AgentFleetPolicy:
    """Adapts a single-model agent (``q_values``/``select_action``) to the
    fleet interface: shared parameters, so the fleet call is one flat batch."""

    def __init__(self, agent):
        self.agent = agent

    def fleet_q_values(self, per_worker: Sequence[np.ndarray]) -> list[np.ndarray]:
        lens = [x.shape[0] for x in per_worker]
        flat = np.concatenate([x for x in per_worker if x.shape[0]], axis=0) \
            if any(lens) else np.zeros((0, STATE_DIM), np.float32)
        q = self.agent.q_values(flat) if flat.shape[0] else np.zeros((0,), np.float32)
        out, off = [], 0
        for ln in lens:
            out.append(q[off:off + ln])
            off += ln
        return out

    def select_action(self, q: np.ndarray, worker: int) -> int:
        return self.agent.select_action(q)


def as_fleet_policy(obj) -> FleetPolicy:
    if isinstance(obj, FleetPolicy):
        return obj
    return AgentFleetPolicy(obj)


class RolloutEngine:
    """Advances W workers' slot batches in lockstep, fleet-batched.

    The engine itself is deterministic: all action stochasticity comes from
    the policy's per-worker RNG streams (``FleetPolicy.select_action``).
    """

    def __init__(self, worker_molecules: Sequence[Sequence[Molecule]],
                 cfg: EnvConfig | None = None):
        self.cfg = cfg if cfg is not None else EnvConfig()
        self.worker_initials = [list(ms) for ms in worker_molecules]
        self.n_workers = len(self.worker_initials)
        self.workers: list[list[Slot]] = []
        self.n_env_steps = 0
        self._enumerated = False
        self.reset()

    # ------------------------------------------------------------ #
    def reset(self) -> None:
        self.workers = [
            [Slot(worker=w, index=i, initial=m, current=m,
                  steps_left=self.cfg.max_steps)
             for i, m in enumerate(ms)]
            for w, ms in enumerate(self.worker_initials)
        ]
        # the enumerate+fingerprint pass is deferred to the first step():
        # run_episode resets again, and the trainer builds engines it may
        # never step (rollout="per_worker"), so eager work here is wasted
        self._enumerated = False

    @property
    def done(self) -> bool:
        return all(s.steps_left <= 0 for slots in self.workers for s in slots)

    def _live(self, w: int) -> list[Slot]:
        return [s for s in self.workers[w] if s.steps_left > 0]

    # ------------------------------------------------------------ #
    def _enumerate_all(self) -> None:
        """One candidate-enumeration + ONE fingerprint batch over every live
        slot of every worker; completes pending transitions with the fresh
        candidate sets."""
        todo = [s for slots in self.workers for s in slots if s.steps_left > 0]
        all_cands: list[Molecule] = []
        spans: list[tuple[Slot, int, int]] = []
        for s in todo:
            s.candidates = enumerate_actions(
                s.current,
                allow_removal=self.cfg.allow_removal,
                protect_oh=self.cfg.protect_oh,
                allowed_ring_sizes=self.cfg.allowed_ring_sizes,
                max_atoms=self.cfg.max_atoms,
            )
            spans.append((s, len(all_cands), len(all_cands) + len(s.candidates)))
            all_cands.extend(a.result for a in s.candidates)
        if not all_cands:
            return
        fps = batch_morgan_fingerprints(all_cands)
        for s, lo, hi in spans:
            s.cand_fps = fps[lo:hi]
            if s.pending is not None:
                # successor candidates are exactly this step's candidates
                s.pending.next_fps = np.stack([pack_fp(f) for f in s.cand_fps])
                s.pending.next_steps_left_frac = (s.steps_left - 1) / self.cfg.max_steps

    # ------------------------------------------------------------ #
    def step(
        self,
        policy,
        service,
        reward_cfg: RewardConfig,
        buffers: Sequence[ReplayBuffer | None] | None = None,
    ) -> list[StepRecord]:
        """One lockstep step for every live slot of every worker."""
        policy = as_fleet_policy(policy)
        if not self._enumerated:
            self._enumerate_all()
            self._enumerated = True
        live_by_worker = [self._live(w) for w in range(self.n_workers)]
        if not any(live_by_worker):
            return []
        self.n_env_steps += 1

        # flush completed pending transitions into the per-worker buffers
        if buffers is not None:
            for w, live in enumerate(live_by_worker):
                buf = buffers[w]
                if buf is None:
                    continue
                ready = [s for s in live
                         if s.pending is not None and s.pending.next_fps is not None]
                buf.add_many(s.pending for s in ready)
                for s in ready:
                    s.pending = None

        # ---- ONE Q dispatch over all candidates of all workers -------- #
        per_worker_states: list[np.ndarray] = []
        for live in live_by_worker:
            if not live:
                per_worker_states.append(np.zeros((0, STATE_DIM), np.float32))
                continue
            stacked = []
            for s in live:
                steps_after = (s.steps_left - 1) / self.cfg.max_steps
                col = np.full((s.cand_fps.shape[0], 1), steps_after, dtype=np.float32)
                stacked.append(np.concatenate([s.cand_fps, col], axis=1))
            per_worker_states.append(np.concatenate(stacked, axis=0))
        q_by_worker = policy.fleet_q_values(per_worker_states)

        # ---- per-worker eps-greedy selection --------------------------- #
        chosen: list[tuple[Slot, Action, np.ndarray]] = []
        for w, live in enumerate(live_by_worker):
            q_all, off = q_by_worker[w], 0
            for s in live:
                ln = s.cand_fps.shape[0]
                a_idx = policy.select_action(q_all[off:off + ln], w)
                off += ln
                chosen.append((s, s.candidates[a_idx], s.cand_fps[a_idx]))

        # ---- ONE property batch over the chosen successors fleet-wide -- #
        props = service.predict([a.result for _, a, _ in chosen])

        records: list[StepRecord] = []
        for (s, act, fp), pr in zip(chosen, props, strict=True):
            s.current = act.result
            s.steps_left -= 1
            done = s.steps_left <= 0
            if callable(reward_cfg):
                # pluggable objective (e.g. QED / PlogP, Appendix D)
                reward = reward_cfg(pr, s.initial, s.current, s.steps_left)
            else:
                reward = compute_reward(
                    reward_cfg, bde=pr.bde, ip=pr.ip,
                    initial=s.initial, current=s.current, steps_left=s.steps_left,
                )
            if s.best is None or reward > s.best[0]:
                s.best = (reward, s.current)
            t = Transition(
                state_fp=pack_fp(fp),
                steps_left_frac=s.steps_left / self.cfg.max_steps,
                reward=reward,
                done=done,
                next_fps=np.zeros((0, FP_BITS // 8), dtype=np.uint8),
                next_steps_left_frac=0.0,
            )
            if done:
                buf = buffers[s.worker] if buffers is not None else None
                if buf is not None:
                    buf.add(t)               # terminal: no successor needed
            else:
                t.next_fps = None            # filled by the next enumerate
                s.pending = t
            records.append(StepRecord(
                slot=s.index, molecule=s.current, reward=reward,
                done=done, conformer_valid=pr.conformer_valid,
                bde=pr.bde, ip=pr.ip, worker=s.worker,
            ))

        self._enumerate_all()
        return records

    # ------------------------------------------------------------ #
    def run_episode(
        self,
        policy,
        service,
        reward_cfg: RewardConfig,
        buffers: Sequence[ReplayBuffer | None] | None = None,
    ) -> list[StepRecord]:
        """Reset + roll a full fleet episode; returns ALL step records."""
        self.reset()
        all_recs: list[StepRecord] = []
        while not self.done:
            all_recs.extend(self.step(policy, service, reward_cfg, buffers))
        return all_recs

    # ------------------------------------------------------------ #
    def final_molecules(self, worker: int | None = None) -> list[Molecule]:
        slots = self.workers[worker] if worker is not None else \
            [s for ws in self.workers for s in ws]
        return [s.current for s in slots]

    def best_molecules(self, worker: int | None = None) -> list[tuple[float, Molecule]]:
        slots = self.workers[worker] if worker is not None else \
            [s for ws in self.workers for s in ws]
        return [s.best if s.best is not None else (-np.inf, s.current) for s in slots]
