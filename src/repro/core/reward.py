"""Reward objectives: Eq. 1 (paper §3.4) + composable scenario terms.

The paper's objective is one weighted sum:

    Reward = -w1 * nBDE + w2 * nIP + w3 * γ

* nBDE/nIP are min-max normalised with bounds taken from the *training
  dataset* properties ("The lower bound and upper bound are minimal and
  maximum properties in the proprietary data set").
* weights default to the paper's (0.8, 0.2, 0.5) — Table 3.
* γ rewards shrinking the molecule: "the relatively reduced atoms and bonds
  from the initial molecule".
* per-property factors (Table 3: BDE Factor 0.9, IP Factor 0.8) are applied
  as step-decays ``factor ** steps_left`` — early in the episode the agent
  sees weaker property signal, at the terminal step the full value (this is
  the MolDQN per-step discounting convention applied per property).
* molecules without a valid 3D conformer get INVALID_CONFORMER_REWARD
  (-1000, §3.3) — "much less than the normal rewards".

PR 10 generalises the objective layer around TERM COMPOSITION: an
:class:`ObjectiveSpec` names its reward terms (:data:`REWARD_TERMS`) with
per-term weights/factors and compiles to a :class:`CompiledObjective` — a
vectorized evaluator the rollout engine runs ONCE per env step over the
fleet's ``[W]`` property/state rows.  The scenario registry over these
specs lives in ``repro.configs.scenarios`` (one table serving trainer and
server).

Determinism contract (the repo's style, pinned by tests/test_reward_terms
and the rollout/multidevice matrices):

* :func:`compute_reward` stays THE scalar correctness reference, untouched.
* :func:`evaluate_rewards` (its fleet-vectorized twin) and a compiled
  Eq. 1-family spec are BIT-identical to it: elementwise float64 NumPy ops
  mirror the scalar arithmetic operation-for-operation, and the per-step
  decays are computed with the same Python ``float ** int`` pow — no libm
  vectorisation drift.
* the only stateful term (``novelty`` — a count-based intrinsic bonus over
  canonical keys, Thiede et al. arXiv 2012.11293) keeps its visit counts
  PER compiled instance, and a compiled objective is created per worker /
  per serving request — a worker in a mixed fleet is bit-identical to the
  same worker in a fleet running only its scenario.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.chem.molecule import Molecule

INVALID_CONFORMER_REWARD = -1000.0

# the composable reward term vocabulary (ObjectiveSpec validates against it):
#   bde / ip      Eq. 1 min-max normalised properties (need predictor props;
#                 an invalid conformer forces INVALID_CONFORMER_REWARD)
#   gamma         Eq. 1 shrinkage: relative atom+bond reduction vs the start
#   qed / plogp / sa
#                 structure-only surrogates from repro.chem.properties
#   similarity    Tanimoto to a fixed target SMILES (TermSpec.target) or, with
#                 target=None, to the slot's own start molecule (MEG-style
#                 "stay close to the lead" tether)
#   novelty       count-based intrinsic bonus 1/sqrt(visits) over canonical
#                 keys — stateful, scoped to the compiled instance
REWARD_TERMS = ("bde", "ip", "gamma", "qed", "plogp", "sa",
                "similarity", "novelty")


@dataclass(frozen=True)
class RewardConfig:
    bde_weight: float = 0.8     # w1
    ip_weight: float = 0.2      # w2
    gamma_weight: float = 0.5   # w3
    bde_factor: float = 0.9
    ip_factor: float = 0.8
    # min-max normalisation bounds (from the training set; §3.4)
    bde_min: float = 55.0
    bde_max: float = 95.0
    ip_min: float = 95.0
    ip_max: float = 200.0

    @classmethod
    def from_dataset(cls, bde_values, ip_values, **kw) -> "RewardConfig":
        return cls(
            bde_min=float(np.min(bde_values)), bde_max=float(np.max(bde_values)),
            ip_min=float(np.min(ip_values)), ip_max=float(np.max(ip_values)),
            **kw,
        )

    # ------------------------------------------------------------ #
    def normalize_bde(self, bde: float) -> float:
        return (bde - self.bde_min) / max(self.bde_max - self.bde_min, 1e-9)

    def normalize_ip(self, ip: float) -> float:
        return (ip - self.ip_min) / max(self.ip_max - self.ip_min, 1e-9)


def gamma_term(initial: Molecule, current: Molecule) -> float:
    """Relative reduction of atoms + bonds vs the initial molecule."""
    a0 = max(initial.num_atoms, 1)
    b0 = max(initial.num_bonds, 1)
    da = (a0 - current.num_atoms) / a0
    db = (b0 - current.num_bonds) / b0
    return 0.5 * (da + db)


def compute_reward(
    cfg: RewardConfig,
    *,
    bde: float | None,
    ip: float | None,
    initial: Molecule,
    current: Molecule,
    steps_left: int = 0,
) -> float:
    """Eq. 1.  ``ip is None`` means no valid 3D conformer -> -1000 (§3.3).
    ``bde is None`` (no O-H bond) is unreachable through protected actions
    but treated identically for robustness.

    This is the pinned SCALAR CORRECTNESS REFERENCE: the fleet-vectorized
    paths (:func:`evaluate_rewards`, a compiled Eq. 1 spec) must stay
    bit-identical to it."""
    if ip is None or bde is None:
        return INVALID_CONFORMER_REWARD
    nbde = cfg.normalize_bde(bde) * (cfg.bde_factor ** steps_left)
    nip = cfg.normalize_ip(ip) * (cfg.ip_factor ** steps_left)
    return -cfg.bde_weight * nbde + cfg.ip_weight * nip + cfg.gamma_weight * gamma_term(initial, current)


# ------------------------------------------------------------------ #
# fleet-vectorized Eq. 1 (the RewardConfig fast path of the reward layer)
# ------------------------------------------------------------------ #
def _decay_column(factor: float, steps_left) -> np.ndarray:
    """``factor ** steps_left`` per row, via the SAME Python ``float **
    int`` pow the scalar reference uses — np.power may route through SIMD
    loops whose last-ulp rounding differs from libm, which would break the
    bit-identity contract."""
    return np.array([factor ** int(s) for s in steps_left], np.float64)


def _gamma_values(initials, currents) -> np.ndarray:
    """Vectorized :func:`gamma_term`: int64 arrays divide to float64 with
    the exact IEEE ops of the scalar int/int division."""
    a0 = np.maximum(np.array([m.num_atoms for m in initials], np.int64), 1)
    b0 = np.maximum(np.array([m.num_bonds for m in initials], np.int64), 1)
    da = (a0 - np.array([m.num_atoms for m in currents], np.int64)) / a0
    db = (b0 - np.array([m.num_bonds for m in currents], np.int64)) / b0
    return 0.5 * (da + db)


def _invalid_mask(props) -> np.ndarray:
    return np.array([p.bde is None or p.ip is None for p in props], bool)


def evaluate_rewards(cfg: RewardConfig, props, initials, currents,
                     steps_left) -> np.ndarray:
    """Eq. 1 over ``[N]`` rows in ONE NumPy evaluation — the fleet reward
    layer's path for a plain :class:`RewardConfig` objective.  Every
    elementwise op mirrors :func:`compute_reward`'s scalar arithmetic in
    the same order, so the result is bit-identical per row (pinned by
    tests/test_reward_terms.py and the rollout equivalence matrix)."""
    invalid = _invalid_mask(props)
    bde = np.array([np.nan if v else p.bde for p, v in zip(props, invalid)],
                   np.float64)
    ip = np.array([np.nan if v else p.ip for p, v in zip(props, invalid)],
                  np.float64)
    nbde = (bde - cfg.bde_min) / max(cfg.bde_max - cfg.bde_min, 1e-9) \
        * _decay_column(cfg.bde_factor, steps_left)
    nip = (ip - cfg.ip_min) / max(cfg.ip_max - cfg.ip_min, 1e-9) \
        * _decay_column(cfg.ip_factor, steps_left)
    r = -cfg.bde_weight * nbde + cfg.ip_weight * nip \
        + cfg.gamma_weight * _gamma_values(initials, currents)
    if invalid.any():
        r = np.where(invalid, INVALID_CONFORMER_REWARD, r)
    return r


# ------------------------------------------------------------------ #
# term-composed objectives
# ------------------------------------------------------------------ #
@dataclass(frozen=True)
class TermSpec:
    """One weighted reward term of an :class:`ObjectiveSpec`.

    ``weight`` is the SIGNED contribution (Eq. 1's BDE term carries a
    negative weight); ``factor`` is the per-step decay ``factor **
    steps_left`` (1.0 = none).  ``lo``/``hi`` are the min-max bounds of the
    ``bde``/``ip`` terms — ``None`` defers to the ``base`` RewardConfig at
    compile time, which is how dataset-derived bounds flow into named
    scenarios.  ``target`` is the ``similarity`` term's target SMILES
    (``None`` = the slot's own start molecule)."""

    term: str
    weight: float = 1.0
    factor: float = 1.0
    lo: float | None = None
    hi: float | None = None
    target: str | None = None

    def __post_init__(self):
        if self.term not in REWARD_TERMS:
            raise ValueError(
                f"unknown reward term {self.term!r}; terms: {REWARD_TERMS}")


@dataclass(frozen=True)
class ObjectiveSpec:
    """A named objective as an ordered composition of weighted terms.

    The ONE objective abstraction of the system: the trainer assigns specs
    per worker (``TrainerConfig.scenarios``), the serving tier resolves
    request objectives to specs through the same registry
    (``repro.configs.scenarios``), and both compile here into the
    vectorized evaluator the rollout engine's fleet reward layer runs.

    Terms accumulate IN ORDER (IEEE addition is not associative — order is
    part of the bit-identity contract with the scalar reference)."""

    name: str
    terms: tuple[TermSpec, ...]

    def __post_init__(self):
        if not self.terms:
            raise ValueError(f"objective {self.name!r} has no terms")

    @classmethod
    def from_reward_config(cls, name: str, cfg: RewardConfig) -> "ObjectiveSpec":
        """Express an Eq. 1 :class:`RewardConfig` as term composition —
        compiled, it is bit-identical to :func:`compute_reward` under that
        config."""
        return cls(name, (
            TermSpec("bde", weight=-cfg.bde_weight, factor=cfg.bde_factor,
                     lo=cfg.bde_min, hi=cfg.bde_max),
            TermSpec("ip", weight=cfg.ip_weight, factor=cfg.ip_factor,
                     lo=cfg.ip_min, hi=cfg.ip_max),
            TermSpec("gamma", weight=cfg.gamma_weight),
        ))

    @property
    def uses_props(self) -> bool:
        """True when the spec reads predictor properties (bde/ip terms) —
        which also switches on the invalid-conformer -1000 guard."""
        return any(t.term in ("bde", "ip") for t in self.terms)

    def compile(self, base: RewardConfig | None = None) -> "CompiledObjective":
        """Build a FRESH vectorized evaluator.  ``base`` supplies the
        bde/ip bounds for terms that left ``lo``/``hi`` unset (the
        trainer passes its dataset-derived RewardConfig).  Fresh means
        fresh novelty state: compile once per worker / per request."""
        return CompiledObjective(self, base=base)


@dataclass(frozen=True)
class _BoundTerm:
    """A TermSpec with its bounds/target resolved at compile time."""
    term: str
    weight: float
    factor: float
    lo: float = 0.0
    den: float = 1.0                      # max(hi - lo, 1e-9)
    target_fp: np.ndarray | None = field(default=None, compare=False)


class CompiledObjective:
    """The vectorized reward evaluator an :class:`ObjectiveSpec` compiles
    to.  ``evaluate`` computes all terms over ``[N]`` rows in one NumPy
    pass; ``__call__`` is the one-row scalar convenience carrying the
    established pluggable-objective signature ``(props, initial, current,
    steps_left) -> float`` (so a compiled objective IS a valid
    ``Slot.objective``).

    Exception safety: term values are all computed before any state
    mutates (the novelty counts update last), so an objective that raises
    mid-evaluation leaves the instance unchanged — the rollout engine's
    per-row fallback then re-evaluates against consistent state.

    ``state_dict``/``load_state_dict`` expose the novelty visit counts for
    bit-exact checkpoint/resume."""

    def __init__(self, spec: ObjectiveSpec, base: RewardConfig | None = None):
        base = base if base is not None else RewardConfig()
        self.spec = spec
        self.name = spec.name
        self.uses_props = spec.uses_props
        bound = []
        for t in spec.terms:
            lo, den, target_fp = 0.0, 1.0, None
            if t.term in ("bde", "ip"):
                lo = t.lo if t.lo is not None else \
                    (base.bde_min if t.term == "bde" else base.ip_min)
                hi = t.hi if t.hi is not None else \
                    (base.bde_max if t.term == "bde" else base.ip_max)
                den = max(hi - lo, 1e-9)
            elif t.term == "similarity" and t.target is not None:
                from repro.chem.fingerprint import morgan_fingerprint
                from repro.chem.smiles import from_smiles
                target_fp = morgan_fingerprint(from_smiles(t.target))
            bound.append(_BoundTerm(term=t.term, weight=t.weight,
                                    factor=t.factor, lo=lo, den=den,
                                    target_fp=target_fp))
        self._terms = tuple(bound)
        self._novelty_counts: dict[str, int] | None = \
            {} if any(t.term == "novelty" for t in spec.terms) else None

    # -------------------------------------------------------------- #
    def _term_values(self, t: _BoundTerm, props, initials, currents
                     ) -> np.ndarray:
        from repro.chem.properties import penalized_logp, qed_score, \
            sa_score, tanimoto
        if t.term == "bde":
            bde = np.array([np.nan if p.bde is None or p.ip is None
                            else p.bde for p in props], np.float64)
            return (bde - t.lo) / t.den
        if t.term == "ip":
            ip = np.array([np.nan if p.bde is None or p.ip is None
                           else p.ip for p in props], np.float64)
            return (ip - t.lo) / t.den
        if t.term == "gamma":
            return _gamma_values(initials, currents)
        if t.term == "qed":
            return np.array([qed_score(m) for m in currents], np.float64)
        if t.term == "plogp":
            return np.array([penalized_logp(m) for m in currents], np.float64)
        if t.term == "sa":
            return np.array([sa_score(m) for m in currents], np.float64)
        if t.term == "similarity":
            if t.target_fp is not None:
                return np.array([tanimoto(m, t.target_fp) for m in currents],
                                np.float64)
            return np.array(
                [tanimoto(m, m0) for m, m0 in zip(currents, initials)],
                np.float64)
        raise AssertionError(f"unhandled term {t.term!r}")  # pragma: no cover

    def _novelty_values(self, currents) -> np.ndarray:
        """Count-based intrinsic bonus 1/sqrt(visits), visits counted in
        row order over THIS instance's lifetime — per-worker / per-request
        scoping is what keeps a mixed fleet's worker bit-identical to its
        solo twin."""
        out = np.empty(len(currents), np.float64)
        for i, m in enumerate(currents):
            k = m.canonical_key()
            c = self._novelty_counts.get(k, 0) + 1
            self._novelty_counts[k] = c
            out[i] = 1.0 / math.sqrt(c)
        return out

    def evaluate(self, props, initials, currents, steps_left) -> np.ndarray:
        """All terms over ``[N]`` rows, accumulated in spec order; rows
        with invalid conformers collapse to INVALID_CONFORMER_REWARD when
        the spec reads bde/ip (exactly the scalar reference's guard)."""
        sl = [int(s) for s in steps_left]
        vals: dict[int, np.ndarray] = {}
        novelty_at = None
        for ti, t in enumerate(self._terms):
            if t.term == "novelty":
                novelty_at = ti           # stateful: computed after the
                continue                  # raise-capable terms
            vals[ti] = self._term_values(t, props, initials, currents)
        if novelty_at is not None:
            vals[novelty_at] = self._novelty_values(currents)
        out = None
        for ti, t in enumerate(self._terms):
            v = vals[ti]
            if t.factor != 1.0:
                v = v * _decay_column(t.factor, sl)
            contrib = t.weight * v
            out = contrib if out is None else out + contrib
        if self.uses_props:
            invalid = _invalid_mask(props)
            if invalid.any():
                out = np.where(invalid, INVALID_CONFORMER_REWARD, out)
        return out

    def __call__(self, props, initial, current, steps_left) -> float:
        return float(self.evaluate([props], [initial], [current],
                                   [steps_left])[0])

    # -------------------------------------------------------------- #
    def state_dict(self) -> dict:
        """JSON-serialisable mutable state (novelty visit counts)."""
        return {"novelty_counts": dict(self._novelty_counts)
                if self._novelty_counts is not None else None}

    def load_state_dict(self, state: dict) -> None:
        counts = state.get("novelty_counts")
        if (counts is None) != (self._novelty_counts is None):
            raise ValueError(
                f"objective {self.name!r}: checkpointed novelty state "
                f"mismatches the compiled spec")
        if self._novelty_counts is not None:
            self._novelty_counts = {str(k): int(v) for k, v in counts.items()}

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"CompiledObjective({self.name!r})"
